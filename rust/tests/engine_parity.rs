//! Engine-parity integration tests: the native Rust quantizer/optimizer
//! and the AOT Pallas/HLO kernels must agree on the same inputs.
//!
//! These tests need `artifacts/` (run `make artifacts` first); they skip
//! with a notice when the manifest is missing so plain `cargo test` works
//! on a fresh checkout.

use bitopt8::optim::{build, Bits, OptimConfig, StateTensor};
use bitopt8::quant::dynamic_tree::{dynamic_signed, dynamic_unsigned};
use bitopt8::quant::{BlockQuantizer, CodeBuf, CodeWidth, Quantized};
use bitopt8::runtime::{self, Runtime};
use bitopt8::util::rng::Rng;
use std::sync::Arc;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json not found (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new("artifacts").expect("pjrt client"))
}

#[test]
fn codebooks_match_manifest_bitwise() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    for (name, native) in [
        ("dynamic_signed", dynamic_signed()),
        ("dynamic_unsigned", dynamic_unsigned()),
        ("linear_signed", bitopt8::quant::linear::linear_signed()),
        ("linear_unsigned", bitopt8::quant::linear::linear_unsigned()),
    ] {
        let from_python = &manifest.codebooks[name];
        assert_eq!(from_python.len(), native.len(), "{name} length");
        for (i, (a, b)) in from_python.iter().zip(native.values()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{name}[{i}]: python {a} != rust {b}"
            );
        }
    }
}

#[test]
fn quantize_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    for (key, signed) in [("quant_signed", true), ("quant_unsigned", false)] {
        let (n, quant_file, dequant_file) = manifest.parity[key].clone();
        let mut rng = Rng::new(0xA11CE);
        let mut x: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();
        if !signed {
            x.iter_mut().for_each(|v| *v = v.abs());
        }
        // HLO path
        let outs = rt.run(&quant_file, &[runtime::lit_f32(&x)]).unwrap();
        let codes_hlo = runtime::u8_of(&outs[0]).unwrap();
        let absmax_hlo = runtime::f32_of(&outs[1]).unwrap();
        // native path
        let cb = if signed { dynamic_signed() } else { dynamic_unsigned() };
        let bq = BlockQuantizer::new(Arc::new(cb), manifest.block);
        let q = bq.quantize(&x);
        assert_eq!(q.codes.to_codes(), codes_hlo, "{key}: codes differ");
        assert_eq!(q.absmax, absmax_hlo, "{key}: absmax differ");
        // HLO dequant matches native dequant exactly
        let outs = rt
            .run(
                &dequant_file,
                &[runtime::lit_u8(&codes_hlo).unwrap(), runtime::lit_f32(&absmax_hlo)],
            )
            .unwrap();
        let deq_hlo = runtime::f32_of(&outs[0]).unwrap();
        assert_eq!(bq.dequantize(&q), deq_hlo, "{key}: dequant differs");
    }
}

#[test]
fn adam8_artifact_matches_native_step() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    // pick an artifact size present in the manifest
    let (&n, artifact) = manifest.updates["adam8"].iter().next().expect("adam8 artifacts");
    let artifact = artifact.clone();
    let npad = n.div_ceil(manifest.block) * manifest.block;
    let nb = npad / manifest.block;

    let mut rng = Rng::new(0xADA);
    let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let g: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.1) as f32).collect();
    let m0: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();
    let r0: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.003).powi(2) as f32).collect();

    let (lr, b1, b2, eps, wd) = (0.01f32, 0.9f32, 0.995f32, 1e-7f32, 0.0f32);
    let t = 3u64;

    // ---- native step with preloaded state --------------------------------
    let mut cfg = OptimConfig::adam(lr, Bits::b8_dynamic());
    cfg.beta1 = b1;
    cfg.beta2 = b2;
    cfg.eps = eps;
    cfg.weight_decay = wd;
    let mut opt = build(&cfg, n, None);
    opt.set_t(t - 1); // step() will advance to t
    for (name, st) in opt.states_mut() {
        let src = if name == "m" { &m0 } else { &r0 };
        match st {
            StateTensor::Quant { q, codebook } => {
                let bq = BlockQuantizer::new(codebook.clone(), q.block);
                bq.quantize_into(src, q);
            }
            StateTensor::F32(_) => panic!("expected quantized state"),
        }
    }
    let mut p_native = p0.clone();
    opt.step(&mut p_native, &g);

    // ---- HLO step on the same quantized starting state -------------------
    // quantize the initial state exactly like the native engine, but into
    // the padded layout the artifact expects
    let pad = |v: &[f32]| {
        let mut out = v.to_vec();
        out.resize(npad, 0.0);
        out
    };
    let cb1 = Arc::new(dynamic_signed());
    let cb2 = Arc::new(dynamic_unsigned());
    let bq1 = BlockQuantizer::new(cb1.clone(), manifest.block);
    let bq2 = BlockQuantizer::new(cb2.clone(), manifest.block);
    let q1 = bq1.quantize(&pad(&m0));
    let q2 = bq2.quantize(&pad(&r0));
    assert_eq!(q1.codes.len(), npad);
    assert_eq!(q1.absmax.len(), nb);

    let bias1 = 1.0 - b1.powi(t as i32);
    let bias2 = 1.0 - b2.powi(t as i32);
    let hp = [lr, b1, b2, eps, wd, bias1, bias2, 0.0f32];
    let outs = rt
        .run(
            &artifact,
            &[
                runtime::lit_f32(&hp),
                runtime::lit_f32(&p0),
                runtime::lit_f32(&g),
                runtime::lit_u8(q1.codes.as_bytes()).unwrap(),
                runtime::lit_f32(&q1.absmax),
                runtime::lit_u8(q2.codes.as_bytes()).unwrap(),
                runtime::lit_f32(&q2.absmax),
            ],
        )
        .unwrap();
    let p_hlo = runtime::f32_of(&outs[0]).unwrap();
    let codes1_hlo = runtime::u8_of(&outs[1]).unwrap();
    let absmax1_hlo = runtime::f32_of(&outs[2]).unwrap();

    // params agree to float tolerance (XLA may fuse to FMA)
    assert_eq!(p_hlo.len(), n);
    let mut max_rel = 0f32;
    for (a, b) in p_native.iter().zip(&p_hlo) {
        let rel = (a - b).abs() / a.abs().max(1e-6);
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-5, "param divergence {max_rel}");

    // state codes: compare dequantized values (codes may differ ±1 at
    // exact decision boundaries under FMA contraction)
    let q1_hlo = Quantized {
        codes: CodeBuf::from_codes(CodeWidth::U8, &codes1_hlo),
        absmax: absmax1_hlo,
        len: npad,
        block: manifest.block,
    };
    let m_hlo = bq1.dequantize(&q1_hlo);
    let m_native = match &opt.states()[0].1 {
        StateTensor::Quant { .. } => opt.states()[0].1.to_f32(),
        _ => unreachable!(),
    };
    let mut mismatches = 0;
    for i in 0..n {
        let (a, b) = (m_native[i], m_hlo[i]);
        if (a - b).abs() > 1e-6 + 0.05 * a.abs() {
            mismatches += 1;
        }
    }
    assert!(
        mismatches < n / 1000 + 1,
        "state divergence in {mismatches}/{n} elements"
    );
}

#[test]
fn momentum8_artifact_first_step_initializes_with_gradient() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    let (&n, artifact) = manifest.updates["momentum8"].iter().next().expect("momentum8");
    let npad = n.div_ceil(manifest.block) * manifest.block;
    let nb = npad / manifest.block;
    let mut rng = Rng::new(0x5EED);
    let p0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let g: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.1) as f32).collect();
    let cb = Arc::new(dynamic_signed());
    let zero = cb.encode(0.0);
    let hp = [0.1f32, 0.9, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]; // t = 1
    let outs = rt
        .run(
            artifact,
            &[
                runtime::lit_f32(&hp),
                runtime::lit_f32(&p0),
                runtime::lit_f32(&g),
                runtime::lit_u8(&vec![zero; npad]).unwrap(),
                runtime::lit_f32(&vec![0.0; nb]),
            ],
        )
        .unwrap();
    let p_new = runtime::f32_of(&outs[0]).unwrap();
    // m_0 = g_0 and the update uses the *in-register* (pre-quantization)
    // state — Figure 1's pipeline quantizes only for storage. So the first
    // step is exactly p0 - lr*g.
    for i in 0..n {
        let expect = p0[i] - 0.1 * g[i];
        assert!(
            (p_new[i] - expect).abs() < 1e-6 + 1e-6 * expect.abs(),
            "i={i}: {} vs {expect}",
            p_new[i]
        );
    }
    // and the stored state round-trips to ~g
    let codes = runtime::u8_of(&outs[1]).unwrap();
    let absmax = runtime::f32_of(&outs[2]).unwrap();
    let bq = BlockQuantizer::new(cb, manifest.block);
    let m_stored = bq.dequantize(&Quantized {
        codes: CodeBuf::from_codes(CodeWidth::U8, &codes),
        absmax,
        len: npad,
        block: manifest.block,
    });
    for i in 0..n {
        assert!(
            (m_stored[i] - g[i]).abs() <= 0.35 * g[i].abs() + 1e-4,
            "i={i}: stored {} vs g {}",
            m_stored[i],
            g[i]
        );
    }
}
