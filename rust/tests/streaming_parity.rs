//! Streaming-step parity tests — the PR-4 tentpole contract.
//!
//! The streaming engine (`optim::engine::StreamingStep`, and the trainer's
//! `ParamOptimizer::stream_native` split on top of it) must be
//! **bit-identical** to the fused step and to serial per-tensor stepping:
//!
//! * at every thread count {1, 4, default} — the pool may run phase items
//!   in any order on any worker;
//! * for every admission order — policy order, reversed, interleaved with
//!   main-thread work between admissions (the trainer's PJRT round-trips);
//! * for mixed-precision group layouts — 32-bit stable-embedding groups
//!   next to 8-bit dynamic/linear groups, resolved per tensor.
//!
//! This holds because tensors never share optimizer state and each tensor
//! walks its phases in the canonical `StepPlan::execute` order; these
//! tests pin it so a scheduling "optimization" can never silently change
//! results.

use std::sync::Mutex;

use bitopt8::optim::{
    build, fused_update, streaming_update, Bits, GroupOverride, OptimConfig, OptimKind, OptimSpec,
    Optimizer, ParamOptimizer, StreamingStep, TensorInfo,
};
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

/// Serializes tests that toggle the process-global thread count.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn at_threads<R>(threads: Option<usize>, f: impl FnOnce() -> R) -> R {
    match threads {
        Some(t) => parallel::with_threads(t, f),
        None => f(),
    }
}

type Fleet = (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

/// Mixed fleet: block-local single-phase plans (Adam, Momentum, AdaGrad)
/// and multi-phase reduction plans (LAMB, LARS, factored Adafactor / SM3),
/// sizes from sub-block to many-block ragged.
fn fleet(bits: Bits) -> Fleet {
    let spec: Vec<(OptimKind, usize, Option<(usize, usize)>)> = vec![
        (OptimKind::Adam, 1, None),
        (OptimKind::Adam, 2049, None),
        (OptimKind::Momentum, 4096, None),
        (OptimKind::Adagrad, 173, None),
        (OptimKind::Lamb, 20000, None),
        (OptimKind::Lars, 777, None),
        (OptimKind::Adafactor, 64 * 72, Some((64, 72))),
        (OptimKind::Sm3, 129 * 31, Some((129, 31))),
        (OptimKind::AdamW, 300, None),
    ];
    let mut rng = Rng::new(0x57AE);
    let mut opts = Vec::new();
    let mut params = Vec::new();
    let mut grads = Vec::new();
    for (kind, n, shape) in spec {
        let mut cfg = OptimConfig::adam(0.005, bits);
        cfg.kind = kind;
        opts.push(build(&cfg, n, shape));
        params.push((0..n).map(|_| rng.normal() as f32).collect());
        grads.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect());
    }
    (opts, params, grads)
}

fn assert_fleet_eq(a: &Fleet, b: &Fleet, what: &str) {
    assert_eq!(a.1, b.1, "{what}: params diverged");
    for (oa, ob) in a.0.iter().zip(&b.0) {
        assert_eq!(oa.t(), ob.t(), "{what}: step counters diverged");
        for ((name, sa), (_, sb)) in oa.states().iter().zip(ob.states().iter()) {
            assert_eq!(sa.to_f32(), sb.to_f32(), "{what}: state {name} diverged");
        }
    }
}

#[test]
fn streaming_matches_fused_and_serial_across_thread_counts() {
    let _g = locked();
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        for threads in [Some(1usize), Some(4), None] {
            at_threads(threads, || {
                let mut serial = fleet(bits);
                let mut fused = fleet(bits);
                let mut stream = fleet(bits);
                for _ in 0..4 {
                    for i in 0..serial.0.len() {
                        serial.0[i].step(&mut serial.1[i], &serial.2[i]);
                    }
                    {
                        let (o, p, g) = &mut fused;
                        fused_update(o, p, g);
                    }
                    {
                        let (o, p, g) = &mut stream;
                        streaming_update(o, p, g);
                    }
                }
                let what = format!("{} / {threads:?} threads", bits.describe());
                assert_fleet_eq(&serial, &fused, &format!("fused vs serial ({what})"));
                assert_fleet_eq(&serial, &stream, &format!("streaming vs serial ({what})"));
            });
        }
    }
}

/// Fleet with the stability phases on: every tensor clips via the
/// percentile window, a tight `max_unorm` drives the u-materialization
/// path, and `skip_zeros` sees stride-zeroed gradients.
fn stabilized_fleet(bits: Bits) -> Fleet {
    let spec: Vec<(OptimKind, usize)> = vec![
        (OptimKind::Adam, 2049),
        (OptimKind::AdamW, 300),
        (OptimKind::Momentum, 4096),
        (OptimKind::Adagrad, 5000),
        (OptimKind::Adam, 1),
    ];
    let mut rng = Rng::new(0x57AB1);
    let mut opts = Vec::new();
    let mut params = Vec::new();
    let mut grads = Vec::new();
    for (kind, n) in spec {
        let mut cfg = OptimConfig::adam(0.005, bits);
        cfg.kind = kind;
        cfg.clip_percentile = 95.0;
        cfg.max_unorm = 0.05;
        cfg.skip_zeros = true;
        opts.push(build(&cfg, n, None));
        params.push((0..n).map(|_| rng.normal() as f32).collect());
        let mut g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
        for v in g.iter_mut().step_by(5) {
            *v = 0.0;
        }
        grads.push(g);
    }
    (opts, params, grads)
}

#[test]
fn stabilized_streaming_matches_fused_and_serial() {
    // The clipped paths run norm phases with combines inside the batch;
    // streaming admission must not change a single clip decision. Ten
    // steps push every tensor past GNORM_MIN_HISTORY, with a spike step so
    // the percentile clip actually engages.
    let _g = locked();
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        for threads in [Some(1usize), Some(4), None] {
            at_threads(threads, || {
                let mut serial = stabilized_fleet(bits);
                let mut fused = stabilized_fleet(bits);
                let mut stream = stabilized_fleet(bits);
                for step in 0..10 {
                    let scale = if step == 7 { 80.0f32 } else { 1.0 };
                    for fl in [&mut serial, &mut fused, &mut stream] {
                        for g in fl.2.iter_mut() {
                            for v in g.iter_mut() {
                                *v *= scale;
                            }
                        }
                    }
                    for i in 0..serial.0.len() {
                        serial.0[i].step(&mut serial.1[i], &serial.2[i]);
                    }
                    {
                        let (o, p, g) = &mut fused;
                        fused_update(o, p, g);
                    }
                    {
                        let (o, p, g) = &mut stream;
                        streaming_update(o, p, g);
                    }
                    // undo the spike for the following steps
                    for fl in [&mut serial, &mut fused, &mut stream] {
                        for g in fl.2.iter_mut() {
                            for v in g.iter_mut() {
                                *v /= scale;
                            }
                        }
                    }
                }
                let what = format!("stabilized {} / {threads:?} threads", bits.describe());
                assert_fleet_eq(&serial, &fused, &format!("fused vs serial ({what})"));
                assert_fleet_eq(&serial, &stream, &format!("streaming vs serial ({what})"));
            });
        }
    }
}

type Entry<'a> = (&'a mut dyn Optimizer, &'a mut [f32], &'a [f32]);

/// Stream one step, admitting tensors in the given order, with optional
/// main-thread busy work + poll between admissions (the trainer's
/// interleaved-with-PJRT shape).
fn stream_in_order(fl: &mut Fleet, order: &[usize], interleave: bool) {
    let (opts, params, grads) = fl;
    let mut entries: Vec<Option<Entry<'_>>> = opts
        .iter_mut()
        .zip(params.iter_mut())
        .zip(grads.iter())
        .map(|((o, p), g)| {
            let o: &mut dyn Optimizer = o.as_mut();
            Some((o, p.as_mut_slice(), g.as_slice()))
        })
        .collect();
    let mut stream = StreamingStep::new();
    let mut busy = 1u64;
    for &i in order {
        let (o, p, g) = entries[i].take().expect("each tensor admitted once");
        stream.push(o, p, g);
        if interleave {
            // stand-in for a serial PJRT round-trip between admissions
            for k in 0..10_000u64 {
                busy = busy.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            stream.poll();
        }
    }
    assert!(busy != 0);
    stream.finish();
}

#[test]
fn admission_order_cannot_change_results() {
    let _g = locked();
    parallel::with_threads(4, || {
        let bits = Bits::b8_dynamic();
        let n = fleet(bits).0.len();
        let sorted: Vec<usize> = (0..n).collect();
        let reversed: Vec<usize> = (0..n).rev().collect();
        let straddled: Vec<usize> = (0..n).step_by(2).chain((0..n).skip(1).step_by(2)).collect();

        let mut reference = fleet(bits);
        for _ in 0..3 {
            let (o, p, g) = &mut reference;
            fused_update(o, p, g);
        }
        for (name, order, interleave) in [
            ("sorted", &sorted, false),
            ("reversed", &reversed, false),
            ("interleaved-with-main-thread-work", &straddled, true),
        ] {
            let mut fl = fleet(bits);
            for _ in 0..3 {
                stream_in_order(&mut fl, order, interleave);
            }
            assert_fleet_eq(&reference, &fl, name);
        }
    });
}

// ---------------------------------------------------------------- groups

/// An LM-shaped tensor list with distinctive sizes for the admission-policy
/// test.
fn lm_tensors() -> Vec<TensorInfo> {
    [
        ("embed.tok", 512 * 64, Some((512, 64))),
        ("embed.pos", 64 * 64, Some((64, 64))),
        ("embed.ln.bias", 64, None),
        ("block0.attn.wq", 96 * 96, Some((96, 96))),
        ("block0.mlp.w1", 64 * 256, Some((64, 256))),
        ("lm_head", 64 * 512, Some((64, 512))),
    ]
    .into_iter()
    .map(|(name, size, shape)| TensorInfo {
        name: name.to_string(),
        size,
        shape,
        padded: size.next_multiple_of(2048),
    })
    .collect()
}

fn mixed_precision_spec() -> OptimSpec {
    let mut base = OptimConfig::adam(0.01, Bits::b8_dynamic());
    base.kind = OptimKind::AdamW;
    base.weight_decay = 0.01;
    OptimSpec::with_groups(
        base,
        vec![
            GroupOverride::emb32(),
            GroupOverride::parse("*.bias:format=linear,lr=0.02").unwrap(),
        ],
    )
}

fn mk_data(tensors: &[TensorInfo]) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(0xD00D);
    let params = tensors
        .iter()
        .map(|t| (0..t.size).map(|_| rng.normal() as f32).collect())
        .collect();
    let grads = tensors
        .iter()
        .map(|t| (0..t.size).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect();
    (params, grads)
}

#[test]
fn mixed_precision_group_stream_matches_fused_step() {
    let _g = locked();
    let tensors = lm_tensors();
    for threads in [Some(1usize), Some(4), None] {
        at_threads(threads, || {
            // fused reference
            let mut popt_f = ParamOptimizer::build(mixed_precision_spec(), &tensors, None).unwrap();
            let (mut p_fused, grads) = mk_data(&tensors);
            for _ in 0..3 {
                popt_f.step_native(&mut p_fused, &grads);
            }

            // streaming in policy order
            let mut popt_s = ParamOptimizer::build(mixed_precision_spec(), &tensors, None).unwrap();
            let (mut p_stream, _) = mk_data(&tensors);
            for _ in 0..3 {
                let (stream, dispatches) = popt_s.stream_native(&mut p_stream, &grads);
                assert!(dispatches.is_empty(), "no HLO env, no HLO tensors");
                stream.finish();
            }
            assert_eq!(p_fused, p_stream, "streaming diverged from fused ({threads:?} threads)");
            for i in 0..tensors.len() {
                for ((name, sa), (_, sb)) in
                    popt_f.opt(i).states().iter().zip(popt_s.opt(i).states().iter())
                {
                    assert_eq!(sa.to_f32(), sb.to_f32(), "{}: state {name}", tensors[i].name);
                }
            }

            // streaming again, admitting in raw tensor-index order with
            // main-thread work + polls in between (the trainer shape)
            let mut popt_i = ParamOptimizer::build(mixed_precision_spec(), &tensors, None).unwrap();
            let (mut p_inter, _) = mk_data(&tensors);
            for _ in 0..3 {
                let (mut stream, _) = popt_i.stream_native(&mut p_inter, &grads);
                let mut busy = 1u64;
                for t in 0..tensors.len() {
                    assert!(stream.admit_index(t));
                    for k in 0..5_000u64 {
                        busy = busy.wrapping_mul(6364136223846793005).wrapping_add(k);
                    }
                    stream.poll();
                }
                assert!(busy != 0);
                assert_eq!(stream.n_queued(), 0);
                stream.finish();
            }
            assert_eq!(p_fused, p_inter, "custom admission diverged ({threads:?} threads)");
        });
    }
}

#[test]
fn admission_policy_puts_32bit_groups_first_then_descending_size() {
    let _g = locked();
    let tensors = lm_tensors();
    let mut popt = ParamOptimizer::build(mixed_precision_spec(), &tensors, None).unwrap();
    let (mut params, grads) = mk_data(&tensors);
    let (stream, _) = popt.stream_native(&mut params, &grads);
    let order = stream.admission_order();
    let names: Vec<&str> = order.iter().map(|&i| tensors[i].name.as_str()).collect();
    // 32-bit stable-embedding group first (descending size), then the
    // 8-bit tensors by descending size, index breaking ties.
    assert_eq!(
        names,
        vec![
            "embed.tok",      // 32768, bits=32
            "embed.pos",      // 4096, bits=32
            "lm_head",        // 32768, 8-bit
            "block0.mlp.w1",  // 16384, 8-bit
            "block0.attn.wq", // 9216, 8-bit
            "embed.ln.bias",  // 64, 8-bit linear group
        ],
        "admission order must follow the group policy"
    );
    stream.finish();
}
