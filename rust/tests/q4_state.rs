//! 4-bit quantized-state integration tests: the `bits = 4` group override
//! end to end.
//!
//! * `CodeBuf::U4` packing property tests — pack→unpack identity for odd
//!   and even lengths, and ranges that straddle byte/block boundaries.
//! * The 16-entry analytic dynamic-tree codebook pinned against its
//!   closed-form values and a brute-force nearest-value reference encode.
//! * Q4 optimizer steps bit-identical across thread counts {1, 4, default}
//!   with the precision resolved per parameter group from TOML and the CLI
//!   `--override` flag — the same parity contract the 8-bit substrate is
//!   pinned by in `pool_parity.rs`.

use std::sync::Mutex;

use bitopt8::config::RunConfig;
use bitopt8::optim::{build, Bits, OptimConfig, OptimKind, Optimizer, ParamOptimizer, TensorInfo};
use bitopt8::quant::{dynamic_tree, CodeBuf, CodeWidth};
use bitopt8::util::args::Args;
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

/// Serializes tests that toggle the process-global thread count.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ------------------------------------------------------- CodeBuf packing

#[test]
fn u4_pack_unpack_identity_for_every_parity() {
    let mut rng = Rng::new(0x40);
    for n in [0usize, 1, 2, 3, 15, 16, 17, 255, 256, 257, 2047, 2048, 2049, 4097] {
        let codes: Vec<u8> = (0..n).map(|_| (rng.uniform() * 16.0) as u8).collect();
        let buf = CodeBuf::from_codes(CodeWidth::U4, &codes);
        assert_eq!(buf.len(), n);
        assert_eq!(buf.storage_bytes(), n.div_ceil(2), "n={n}");
        assert_eq!(buf.to_codes(), codes, "n={n}");
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(buf.get(i), c, "n={n} i={i}");
        }
    }
}

#[test]
fn u4_block_boundary_straddles_roundtrip() {
    // read/write windows crossing the 2048-element quantization-block
    // boundary (and its byte image at 1024) must not disturb neighbours
    let n = 3 * 2048 + 33; // ragged odd tail
    let mut rng = Rng::new(0x41);
    let codes: Vec<u8> = (0..n).map(|_| (rng.uniform() * 16.0) as u8).collect();
    let mut buf = CodeBuf::from_codes(CodeWidth::U4, &codes);
    for lo in [2047usize, 2048, 2049, 4095, 4096, 6143, n - 34] {
        let len = 35.min(n - lo);
        let mut out = vec![0u8; len];
        buf.read_range(lo, &mut out);
        assert_eq!(&out[..], &codes[lo..lo + len], "lo={lo}");
        // write the same values back: a no-op for the whole buffer
        buf.write_range(lo, &out);
        assert_eq!(buf.to_codes(), codes, "lo={lo}");
    }
}

// ----------------------------------------- 16-entry dynamic-tree codebook

#[test]
fn pinned_16_entry_dynamic_tree_codebook() {
    // Closed-form expected values (3 decades, f = 2-e fraction bits):
    //   e=0: midpoints of linspace(0.1, 1.0, 5), largest replaced by 1.0
    //   e=1: midpoints of linspace(0.1, 1.0, 3) × 0.1
    //   e=2: the single midpoint 0.55 × 0.01
    // plus 0.0 and the 1e-3 denormal, mirrored for the sign.
    let expected: [f32; 16] = [
        -1.0, -0.6625, -0.4375, -0.2125, -0.0775, -0.0325, -0.0055, 0.0, 1e-3, 0.0055,
        0.0325, 0.0775, 0.2125, 0.4375, 0.6625, 1.0,
    ];
    let cb = dynamic_tree::dynamic_signed4();
    assert_eq!(cb.len(), 16);
    for (got, want) in cb.values().iter().zip(&expected) {
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }
}

#[test]
fn analytic_16_entry_encode_matches_brute_force() {
    // The analytic candidate + fixup must be a true nearest-value encode:
    // compare against brute-force argmin over all 16 values (by distance,
    // so exact midpoint ties accept either neighbour) and bit-exactly
    // against the reference midpoint search.
    let mut rng = Rng::new(0x42);
    for cb in [dynamic_tree::dynamic_signed4(), dynamic_tree::dynamic_unsigned4()] {
        let mut probes: Vec<f32> = vec![0.0, -0.0, 1.0, -1.0, 5.0, -5.0, 1e-9, -1e-9];
        for &v in cb.values() {
            for d in [-2i64, -1, 0, 1, 2] {
                let b = (v.to_bits() as i64 + d).clamp(0, u32::MAX as i64) as u32;
                probes.push(f32::from_bits(b));
            }
        }
        for w in cb.values().windows(2) {
            let m = 0.5 * (w[0] + w[1]);
            for d in [-1i64, 0, 1] {
                probes.push(f32::from_bits((m.to_bits() as i64 + d) as u32));
            }
        }
        for _ in 0..50_000 {
            let exp = rng.uniform_range(-6.0, 1.0);
            let mag = 10f64.powf(exp) as f32;
            probes.push(if rng.uniform() < 0.5 { mag } else { -mag });
        }
        for x in probes {
            if !x.is_finite() {
                continue;
            }
            let got = cb.encode(x);
            assert_eq!(got, cb.encode_reference(x), "{}: x={x}", cb.name());
            let d_got = (cb.values()[got as usize] - x).abs();
            let d_brute = cb
                .values()
                .iter()
                .map(|v| (v - x).abs())
                .fold(f32::INFINITY, f32::min);
            assert!(
                (d_got - d_brute).abs() <= f32::EPSILON * x.abs().max(1.0),
                "{}: x={x} not nearest (got dist {d_got}, best {d_brute})",
                cb.name()
            );
        }
    }
}

// -------------------------------------------------- thread-count parity

/// `steps` Q4 updates of one optimizer on a quadratic; returns final
/// params and dequantized states.
fn q4_trajectory(
    kind: OptimKind,
    threads: Option<usize>,
    steps: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let n = 64 * 72; // three 2048-blocks, last one ragged
    let mut cfg = OptimConfig::adam(0.01, Bits::b4_dynamic());
    cfg.kind = kind;
    let mut opt = build(&cfg, n, Some((64, 72)));
    let mut rng = Rng::new(0x4B17);
    let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
    let run = |opt: &mut Box<dyn Optimizer>, p: &mut Vec<f32>| {
        for _ in 0..steps {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(p, &g);
        }
    };
    match threads {
        Some(t) => parallel::with_threads(t, || run(&mut opt, &mut p)),
        None => run(&mut opt, &mut p),
    }
    let states = opt.states().into_iter().map(|(_, s)| s.to_f32()).collect();
    (p, states)
}

#[test]
fn q4_steps_are_bit_identical_across_thread_counts() {
    let _g = locked();
    for kind in [OptimKind::Adam, OptimKind::AdamW, OptimKind::Momentum, OptimKind::Lamb] {
        let (p_seq, s_seq) = q4_trajectory(kind, Some(1), 5);
        let (p_par, s_par) = q4_trajectory(kind, Some(4), 5);
        let (p_def, s_def) = q4_trajectory(kind, None, 5);
        assert!(p_seq.iter().all(|v| v.is_finite()));
        assert_eq!(p_seq, p_par, "{kind:?} params diverged between 1 and 4 threads");
        assert_eq!(p_seq, p_def, "{kind:?} params diverged between 1 and default threads");
        assert_eq!(s_seq, s_par, "{kind:?} states diverged");
        assert_eq!(s_seq, s_def, "{kind:?} states diverged");
    }
}

// ----------------------------------- group-resolved Q4 end-to-end parity

fn lm_tensors() -> Vec<TensorInfo> {
    [
        ("embed.tok", 512 * 64),
        ("embed.pos", 64 * 64),
        ("block0.attn.wq", 64 * 64),
        ("block0.attn.wv", 64 * 64),
        ("block0.mlp.w1", 64 * 256),
        ("lm_head", 64 * 512),
    ]
    .into_iter()
    .map(|(name, size)| TensorInfo {
        name: name.to_string(),
        size,
        shape: None,
        padded: size.next_multiple_of(2048),
    })
    .collect()
}

/// TOML + CLI resolution: the attention tensors land in the 4-bit group
/// (from the file), lm_head in a CLI-added 4-bit linear group, embeddings
/// at 32-bit — then the fused step over that mixed layout is bit-identical
/// across thread counts and to serial per-tensor stepping.
#[test]
fn toml_and_cli_resolved_q4_groups_step_identically_at_every_thread_count() {
    let _g = locked();
    let mut cfg = RunConfig::from_toml(
        r#"
[optimizer]
kind = "adam"
bits = 8
lr = 0.01

[[optimizer.group]]
pattern = "embed.tok|embed.pos"
bits = 32

[[optimizer.group]]
pattern = "block?.attn.*"
bits = 4
"#,
    )
    .unwrap();
    let args = Args::parse(
        ["train", "--override", "lm_head:bits=4,format=linear"]
            .iter()
            .map(|s| s.to_string()),
    );
    cfg.apply_args(&args).unwrap();

    let spec = cfg.optim_spec();
    assert_eq!(spec.resolve("block0.attn.wq").0.bits, Bits::b4_dynamic());
    assert_eq!(
        spec.resolve("lm_head").0.bits,
        Bits::B4 { format: bitopt8::quant::Format::Linear, blockwise: true }
    );
    assert_eq!(spec.resolve("embed.tok").0.bits, Bits::B32);
    assert_eq!(spec.resolve("block0.mlp.w1").0.bits, Bits::b8_dynamic());

    let tensors = lm_tensors();
    let mk_data = || {
        let mut rng = Rng::new(0x9E);
        let params: Vec<Vec<f32>> = tensors
            .iter()
            .map(|t| (0..t.size).map(|_| rng.normal() as f32).collect())
            .collect();
        let grads: Vec<Vec<f32>> = tensors
            .iter()
            .map(|t| (0..t.size).map(|_| rng.normal() as f32 * 0.1).collect())
            .collect();
        (params, grads)
    };

    let run_fused = |threads: Option<usize>| -> Vec<Vec<f32>> {
        let step = || {
            let mut popt =
                ParamOptimizer::build(cfg.optim_spec(), &tensors, None).unwrap();
            let (mut params, grads) = mk_data();
            for _ in 0..3 {
                popt.step_native(&mut params, &grads);
            }
            params
        };
        match threads {
            Some(t) => parallel::with_threads(t, step),
            None => step(),
        }
    };
    let p1 = run_fused(Some(1));
    assert_eq!(p1, run_fused(Some(4)), "Q4 groups diverged at 4 threads");
    assert_eq!(p1, run_fused(None), "Q4 groups diverged at default threads");

    // serial per-tensor reference over the same resolved spec
    let spec = cfg.optim_spec();
    let (mut p_serial, grads) = mk_data();
    let mut opts: Vec<Box<dyn Optimizer>> = tensors
        .iter()
        .map(|t| build(&spec.resolve(&t.name).0, t.size, t.shape))
        .collect();
    for _ in 0..3 {
        for (i, opt) in opts.iter_mut().enumerate() {
            opt.step(&mut p_serial[i], &grads[i]);
        }
    }
    assert_eq!(p1, p_serial, "fused Q4 diverged from serial stepping");

    // and the 4-bit groups actually pay ~1 byte/param (Adam, two states)
    let popt = ParamOptimizer::build(cfg.optim_spec(), &tensors, None).unwrap();
    let reports = popt.group_reports();
    let q4_report = reports
        .iter()
        .find(|r| r.label.contains("attn"))
        .expect("attn group report");
    assert_eq!(q4_report.bits, 4);
    assert!(
        q4_report.bytes_per_param() > 0.9 && q4_report.bytes_per_param() < 1.1,
        "{}",
        q4_report.bytes_per_param()
    );
}
