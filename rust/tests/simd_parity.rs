//! Exhaustive scalar-vs-lane bitwise parity for the vectorized block
//! kernels.
//!
//! The lane-chunking contract (`util::lanes` module docs): every
//! lane-chunked kernel — absmax scan, packed encode, packed decode, and
//! the optimizers' elementwise rules — performs the identical per-element
//! IEEE arithmetic as its scalar tail loop, so forcing the scalar path
//! (`lanes::with_forced_scalar`) must reproduce the exact same bits. These
//! tests sweep every tail size (block lengths 1..=2·LANES² exhaustively,
//! then strided up to BLOCK with lengths covering every residue mod LANES,
//! including U4 odd-tail blocks), all four quantization formats, both code
//! widths, and the optimizer kernels at 32/8/4-bit state.

use std::sync::{Arc, Mutex};

use bitopt8::optim::{build, Bits, OptimConfig, OptimKind, Optimizer};
use bitopt8::quant::{
    dequantize_block_codes, quantize_block_codes, Codebook, CodeWidth, Format, BLOCK,
};
use bitopt8::util::lanes::{self, LANES};
use bitopt8::util::rng::Rng;

/// Serializes tests that toggle the process-global forced-scalar flag (a
/// racing test would silently compare scalar against scalar).
static SCALAR_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    SCALAR_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const FORMATS: [Format; 4] =
    [Format::Dynamic, Format::Linear, Format::Quantile, Format::InverseDynamic];

/// Hostile block data: exact zeros (and negative zero), tiny and huge
/// magnitudes mixed in one block, plus plain normals — stresses the
/// normalization, the analytic encode candidates, and midpoint ties.
fn data(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| match i % 7 {
            0 => 0.0,
            1 => -0.0,
            2 => (rng.normal() * 1e-6) as f32,
            3 => (rng.normal() * 100.0) as f32,
            _ => rng.normal() as f32,
        })
        .collect()
}

fn codebooks(format: Format, width: CodeWidth) -> [Arc<Codebook>; 2] {
    match width {
        CodeWidth::U8 => [format.signed_codebook(), format.unsigned_codebook()],
        CodeWidth::U4 => [format.signed_codebook4(), format.unsigned_codebook4()],
    }
}

/// Every block length 1..=2·LANES² (each tail size many times over, all U4
/// odd tails), then strided to BLOCK with a stride coprime to LANES so
/// every residue keeps appearing, plus the exact block boundary.
fn block_lengths() -> Vec<usize> {
    let mut lens: Vec<usize> = (1..=2 * LANES * LANES).collect();
    lens.extend((2 * LANES * LANES + 63..BLOCK).step_by(191));
    lens.extend([BLOCK - 1, BLOCK]);
    lens
}

#[test]
fn packed_block_kernels_bitwise_invariant_to_forced_scalar() {
    let _g = locked();
    for width in [CodeWidth::U8, CodeWidth::U4] {
        for format in FORMATS {
            for cb in codebooks(format, width) {
                for &n in &block_lengths() {
                    let xs = data(n, 0x51D0 + n as u64);
                    let mut bytes = vec![0u8; width.bytes_for(n)];
                    let am = quantize_block_codes(&cb, width, &xs, &mut bytes);
                    let mut bytes_s = vec![0u8; width.bytes_for(n)];
                    let am_s = lanes::with_forced_scalar(|| {
                        quantize_block_codes(&cb, width, &xs, &mut bytes_s)
                    });
                    assert_eq!(
                        am.to_bits(),
                        am_s.to_bits(),
                        "{} {width:?} n={n}: absmax diverged",
                        cb.name()
                    );
                    assert_eq!(bytes, bytes_s, "{} {width:?} n={n}: codes diverged", cb.name());
                    let mut out = vec![0.0f32; n];
                    dequantize_block_codes(&cb, width, &bytes, am, &mut out);
                    let mut out_s = vec![0.0f32; n];
                    lanes::with_forced_scalar(|| {
                        dequantize_block_codes(&cb, width, &bytes_s, am_s, &mut out_s)
                    });
                    for i in 0..n {
                        assert_eq!(
                            out[i].to_bits(),
                            out_s[i].to_bits(),
                            "{} {width:?} n={n}: decode diverged at {i}",
                            cb.name()
                        );
                    }
                }
            }
        }
    }
}

/// `steps` optimizer updates on a quadratic; returns final params and
/// dequantized states.
fn trajectory(kind: OptimKind, bits: Bits, n: usize, steps: usize) -> (Vec<f32>, Vec<Vec<f32>>) {
    let mut cfg = OptimConfig::adam(0.01, bits);
    cfg.kind = kind;
    cfg.weight_decay = 0.01;
    let mut opt = build(&cfg, n, None);
    let mut rng = Rng::new(0xAB5 + n as u64);
    let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
    for _ in 0..steps {
        let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
        opt.step(&mut p, &g);
    }
    let states = opt.states().into_iter().map(|(_, s)| s.to_f32()).collect();
    (p, states)
}

#[test]
fn optimizer_lane_kernels_match_scalar_oracle() {
    // The lane-chunked elementwise rules (Adam/AdamW/Momentum/Adagrad via
    // `block_steps_vec`, LARS phase B, LAMB's hand-chunked phase A) against
    // the whole-pipeline scalar oracle, at every tail size and across
    // block boundaries, for 32/8/4-bit state in both formats that support
    // every width.
    let _g = locked();
    let lens: Vec<usize> =
        (1..=2 * LANES).chain([101, 1000, BLOCK - 1, BLOCK, BLOCK + 1, 2 * BLOCK + 49]).collect();
    let bit_sweep = [
        Bits::B32,
        Bits::B8 { format: Format::Dynamic, blockwise: true },
        Bits::B8 { format: Format::Linear, blockwise: true },
        Bits::B4 { format: Format::Dynamic, blockwise: true },
        Bits::B4 { format: Format::Linear, blockwise: true },
    ];
    let kinds = [
        OptimKind::Adam,
        OptimKind::AdamW,
        OptimKind::Momentum,
        OptimKind::Adagrad,
        OptimKind::Lars,
        OptimKind::Lamb,
    ];
    for kind in kinds {
        for bits in bit_sweep {
            for &n in &lens {
                let (p_lane, s_lane) = trajectory(kind, bits, n, 3);
                let (p_scalar, s_scalar) =
                    lanes::with_forced_scalar(|| trajectory(kind, bits, n, 3));
                assert!(p_lane.iter().all(|v| v.is_finite()), "{kind:?} n={n}");
                let same = p_lane.iter().zip(&p_scalar).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "{kind:?} {} n={n}: params diverged", bits.describe());
                assert_eq!(
                    s_lane,
                    s_scalar,
                    "{kind:?} {} n={n}: states diverged",
                    bits.describe()
                );
            }
        }
    }
}
