//! Parity tests for ZeRO-style sharded placement (engine layer 5).
//!
//! The placement contract: `shards = N` moves optimizer state around — it
//! never changes the math. Each shard owns the full dequantize → update →
//! requantize of its tensors as an independent streaming batch, tensors
//! never share state, and every tensor's phases run in the same canonical
//! order regardless of which shard admits them — so any tensor → shard
//! partition is **bit-identical** to the unsharded step. These tests pin
//! that down:
//!
//! * shard counts {1, 2, 4, 8} × threads {1, 4, default} × lane-chunked vs
//!   forced-scalar kernels × state widths {32, 8, 4} × {Adam, Momentum,
//!   LAMB} produce bit-identical params and states,
//! * the same holds end to end through `ParamOptimizer` specs that differ
//!   only in their `shards =` placement,
//! * a checkpoint saved from a 4-shard run (v5 manifest + shard files)
//!   restores into a 2-shard layout with a bit-identical continued
//!   trajectory (state is keyed by tensor, not shard, so resharding is
//!   free),
//! * a v4 monolithic checkpoint restores into a sharded run (forward
//!   compat), and
//! * `configs/zero_shard.toml` parses, validates, and builds the 4-shard
//!   placement it documents.

use std::sync::Mutex;

use bitopt8::config::RunConfig;
use bitopt8::coordinator::Checkpoint;
use bitopt8::optim::{
    assign_greedy, build, sharded_update, Bits, OptimConfig, OptimKind, OptimSpec, Optimizer,
    ParamOptimizer, TensorInfo,
};
use bitopt8::util::lanes;
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

/// Serializes tests that toggle process-global knobs (thread count, the
/// forced-scalar lane switch); see `pool_parity.rs` for the rationale.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Mixed tensor sizes: multi-block, exactly one block, sub-block (ragged),
/// and tiny — the shapes a real model hands the placement layer.
const FLEET_SIZES: [usize; 6] = [4096, 2048, 511, 8192, 64, 3000];

fn fleet(kind: OptimKind, bits: Bits) -> (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(0x5AAD);
    let mut opts: Vec<Box<dyn Optimizer>> = Vec::new();
    let mut params: Vec<Vec<f32>> = Vec::new();
    let mut targets: Vec<Vec<f32>> = Vec::new();
    for &n in &FLEET_SIZES {
        let mut cfg = OptimConfig::adam(0.01, bits);
        cfg.kind = kind;
        opts.push(build(&cfg, n, None));
        params.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect());
        targets.push((0..n).map(|_| rng.normal() as f32).collect());
    }
    (opts, params, targets)
}

/// `steps` sharded updates of the fleet on per-tensor quadratics; returns
/// final params and dequantized states.
fn fleet_trajectory(
    kind: OptimKind,
    bits: Bits,
    n_shards: usize,
    threads: Option<usize>,
    steps: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<Vec<f32>>>) {
    let (mut opts, mut params, targets) = fleet(kind, bits);
    let state_bytes: Vec<usize> = opts.iter().map(|o| o.state_bytes()).collect();
    let assignment = assign_greedy(&state_bytes, n_shards);
    let run = |opts: &mut Vec<Box<dyn Optimizer>>, params: &mut Vec<Vec<f32>>| {
        for _ in 0..steps {
            let grads: Vec<Vec<f32>> = params
                .iter()
                .zip(&targets)
                .map(|(p, t)| p.iter().zip(t).map(|(a, b)| a - b).collect())
                .collect();
            sharded_update(opts, params, &grads, &assignment, n_shards);
        }
    };
    match threads {
        Some(t) => parallel::with_threads(t, || run(&mut opts, &mut params)),
        None => run(&mut opts, &mut params),
    }
    let states = opts
        .iter()
        .map(|o| o.states().into_iter().map(|(_, s)| s.to_f32()).collect())
        .collect();
    (params, states)
}

#[test]
fn sharded_fleet_is_bit_identical_across_shards_threads_and_lanes() {
    let _g = locked();
    let kinds = [OptimKind::Adam, OptimKind::Momentum, OptimKind::Lamb];
    let widths = [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()];
    for kind in kinds {
        for bits in widths {
            // single shard, single thread, lane kernels = the reference
            let (p_ref, s_ref) = fleet_trajectory(kind, bits, 1, Some(1), 3);
            for n_shards in [2usize, 4, 8] {
                for threads in [Some(1), Some(4), None] {
                    let (p, st) = fleet_trajectory(kind, bits, n_shards, threads, 3);
                    assert_eq!(
                        p, p_ref,
                        "{kind:?}/{bits:?}: params diverged at {n_shards} shards, {threads:?} threads"
                    );
                    assert_eq!(
                        st, s_ref,
                        "{kind:?}/{bits:?}: states diverged at {n_shards} shards, {threads:?} threads"
                    );
                }
                // forced-scalar kernels through the sharded path
                let (p, st) =
                    lanes::with_forced_scalar(|| fleet_trajectory(kind, bits, n_shards, Some(4), 3));
                assert_eq!(p, p_ref, "{kind:?}/{bits:?}: scalar sharded params diverged");
                assert_eq!(st, s_ref, "{kind:?}/{bits:?}: scalar sharded states diverged");
            }
        }
    }
}

/// A small stable-embedding tensor listing for the ParamOptimizer-level
/// tests (subset of the dry-run set; sizes span multiple blocks).
fn model_tensors() -> Vec<TensorInfo> {
    let specs: [(&str, usize, Option<(usize, usize)>); 7] = [
        ("embed.tok", 512 * 64, Some((512, 64))),
        ("embed.pos", 64 * 64, Some((64, 64))),
        ("block0.attn.wq", 64 * 64, Some((64, 64))),
        ("block0.mlp.w1", 64 * 256, Some((64, 256))),
        ("block0.mlp.b1", 256, None),
        ("final_ln.scale", 64, None),
        ("lm_head", 64 * 512, Some((64, 512))),
    ];
    specs
        .into_iter()
        .map(|(name, size, shape)| TensorInfo {
            name: name.to_string(),
            size,
            shape,
            padded: size.next_multiple_of(2048),
        })
        .collect()
}

fn spec_with_shards(shards: u32) -> OptimSpec {
    let base = OptimConfig::adam(0.01, Bits::b8_dynamic());
    let mut spec = OptimSpec::with_groups(
        base,
        vec![bitopt8::optim::GroupOverride::parse("embed.tok|embed.pos:bits=32").unwrap()],
    );
    spec.default_shards = shards;
    spec
}

fn synth_run(
    popt: &mut ParamOptimizer,
    params: &mut [Vec<f32>],
    steps: usize,
    seed: u64,
) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Rng::new(seed);
    let grad_rounds: Vec<Vec<Vec<f32>>> = (0..steps)
        .map(|_| {
            params
                .iter()
                .map(|p| p.iter().map(|_| rng.normal() as f32 * 0.02).collect())
                .collect()
        })
        .collect();
    for grads in &grad_rounds {
        popt.step_native(params, grads);
    }
    grad_rounds
}

#[test]
fn param_optimizer_placement_is_bit_identical_end_to_end() {
    let _g = locked();
    let tensors = model_tensors();
    let mut rng = Rng::new(0xD1CE);
    let init: Vec<Vec<f32>> = tensors
        .iter()
        .map(|t| (0..t.size).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect();

    let mut popt_ref = ParamOptimizer::build(spec_with_shards(1), &tensors, None).unwrap();
    let mut p_ref = init.clone();
    synth_run(&mut popt_ref, &mut p_ref, 4, 0xFEED);

    for shards in [2u32, 4] {
        let mut popt = ParamOptimizer::build(spec_with_shards(shards), &tensors, None).unwrap();
        assert_eq!(popt.shard_layout().n_shards, shards as usize);
        assert!(popt.max_shard_state_bytes() < popt.state_bytes());
        assert!(popt.describe_placement().is_some());
        let mut p = init.clone();
        synth_run(&mut popt, &mut p, 4, 0xFEED);
        assert_eq!(p, p_ref, "params diverged at shards={shards}");
        assert_eq!(
            popt.state_snapshot(),
            popt_ref.state_snapshot(),
            "states diverged at shards={shards}"
        );
        // the per-group shard accounting must cover the whole footprint
        for r in popt.group_reports() {
            assert_eq!(r.shards, shards);
            assert_eq!(r.shard_state_bytes.iter().sum::<usize>(), r.state_bytes);
            assert!(r.max_shard_bytes() <= r.state_bytes);
        }
    }
}

#[test]
fn sharded_checkpoint_reshards_with_identical_trajectory() {
    let _g = locked();
    let tensors = model_tensors();
    let mut rng = Rng::new(0xC4A9);
    let init: Vec<Vec<f32>> = tensors
        .iter()
        .map(|t| (0..t.size).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect();

    // train 3 steps at 4 shards, save the v5 sharded checkpoint
    let mut popt_a = ParamOptimizer::build(spec_with_shards(4), &tensors, None).unwrap();
    let mut p_a = init.clone();
    synth_run(&mut popt_a, &mut p_a, 3, 0xAB);
    let dir = std::env::temp_dir().join(format!("bitopt8_reshard_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.bin");
    let ck = Checkpoint::capture(3, &Rng::new(7), &p_a, &popt_a, None);
    let layout = popt_a.shard_layout();
    ck.save_sharded(&path, &layout.assignment, layout.n_shards).unwrap();
    for s in 0..4 {
        assert!(
            dir.join(format!("ck.bin.shard{s:02}")).exists(),
            "missing shard file {s}"
        );
    }

    // continue the source run
    synth_run(&mut popt_a, &mut p_a, 3, 0xCD);

    // restore into a 2-shard layout and continue with the same gradients
    let mut popt_b = ParamOptimizer::build(spec_with_shards(2), &tensors, None).unwrap();
    let mut p_b: Vec<Vec<f32>> = tensors.iter().map(|t| vec![0.0; t.size]).collect();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.step, 3);
    loaded.restore(&mut p_b, &mut popt_b, None).unwrap();
    synth_run(&mut popt_b, &mut p_b, 3, 0xCD);

    assert_eq!(p_b, p_a, "4-shard checkpoint resharded to 2 diverged");
    assert_eq!(popt_b.state_snapshot(), popt_a.state_snapshot());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v4_monolithic_checkpoint_restores_into_sharded_run() {
    let _g = locked();
    let tensors = model_tensors();
    let mut rng = Rng::new(0xB0B);
    let init: Vec<Vec<f32>> = tensors
        .iter()
        .map(|t| (0..t.size).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect();

    // unsharded run, plain v4 save
    let mut popt_a = ParamOptimizer::build(spec_with_shards(1), &tensors, None).unwrap();
    let mut p_a = init.clone();
    synth_run(&mut popt_a, &mut p_a, 3, 0x11);
    let dir = std::env::temp_dir().join(format!("bitopt8_v4fwd_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.bin");
    Checkpoint::capture(3, &Rng::new(7), &p_a, &popt_a, None).save(&path).unwrap();
    synth_run(&mut popt_a, &mut p_a, 2, 0x22);

    // forward compat: the v4 file drops straight into a 4-shard run
    let mut popt_b = ParamOptimizer::build(spec_with_shards(4), &tensors, None).unwrap();
    let mut p_b: Vec<Vec<f32>> = tensors.iter().map(|t| vec![0.0; t.size]).collect();
    Checkpoint::load(&path).unwrap().restore(&mut p_b, &mut popt_b, None).unwrap();
    synth_run(&mut popt_b, &mut p_b, 2, 0x22);

    assert_eq!(p_b, p_a, "v4 checkpoint restored into sharded run diverged");
    assert_eq!(popt_b.state_snapshot(), popt_a.state_snapshot());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zero_shard_config_builds_the_documented_placement() {
    // integration tests run from the package root, so configs/ resolves
    let cfg = RunConfig::from_file("configs/zero_shard.toml").unwrap();
    assert_eq!(cfg.shards, 4);
    let spec = cfg.optim_spec();
    assert_eq!(spec.default_shards, 4);
    assert_eq!(spec.shards_of(1), 1, "embedding group opts out");
    let popt = ParamOptimizer::build(spec, &model_tensors(), None).unwrap();
    assert_eq!(popt.shard_layout().n_shards, 4);
    let placement = popt.describe_placement().expect("placement table");
    assert!(placement.contains("4 shards"), "{placement}");
    // the embeddings stay together on shard 0 of their group
    let emb = popt.find("embed.tok").unwrap();
    let pos = popt.find("embed.pos").unwrap();
    assert_eq!(popt.shard_layout().assignment[emb], 0);
    assert_eq!(popt.shard_layout().assignment[pos], 0);
}
