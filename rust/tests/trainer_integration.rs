//! End-to-end coordinator tests over the real AOT artifacts: training
//! reduces loss with both engines, determinism holds, the stability
//! detector fires on divergent configs, and the GLUE-like cls path learns.
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use bitopt8::config::{parse_optim, Engine, RunConfig, Schedule};
use bitopt8::coordinator::Trainer;
use bitopt8::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json not found (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new("artifacts").expect("pjrt client"))
}

fn nano_cfg(steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "nano".into();
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.eval_batches = 4;
    cfg.seed = 7;
    cfg.optim = parse_optim("adam", 8, "dynamic", true).unwrap();
    cfg.optim.lr = 3e-3;
    cfg.schedule = Schedule::Constant;
    cfg
}

#[test]
fn native_8bit_adam_reduces_lm_loss() {
    let Some(rt) = runtime() else { return };
    let mut tr = Trainer::new(&rt, nano_cfg(40)).unwrap();
    let res = tr.train().unwrap();
    assert!(!res.unstable, "unexpected instability: {:?}", res.reason);
    let first = res.losses.first().copied().unwrap();
    let last = res.losses.last().copied().unwrap();
    assert!(last < first - 1.0, "loss {first} -> {last}");
    assert!(res.final_eval < first, "eval {}", res.final_eval);
}

#[test]
fn hlo_engine_runs_and_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let mut cfg = nano_cfg(30);
    cfg.engine = Engine::Hlo;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let res = tr.train().unwrap();
    assert!(res.hlo_updated_tensors > 0, "HLO path not exercised");
    let first = res.losses.first().copied().unwrap();
    let last = res.losses.last().copied().unwrap();
    assert!(last < first - 0.8, "loss {first} -> {last}");
}

#[test]
fn engines_agree_on_early_trajectory() {
    // The two engines implement the same update; trajectories must match
    // closely for the first steps (they slowly drift apart after — f32
    // non-associativity under XLA fusion).
    let Some(rt) = runtime() else { return };
    let run = |engine: Engine| {
        let mut cfg = nano_cfg(5);
        cfg.engine = engine;
        let mut tr = Trainer::new(&rt, cfg).unwrap();
        tr.train().unwrap().losses
    };
    let native = run(Engine::Native);
    let hlo = run(Engine::Hlo);
    for (i, (a, b)) in native.iter().zip(&hlo).enumerate() {
        assert!(
            (a - b).abs() < 5e-2 * (1.0 + a.abs()),
            "step {i}: native {a} vs hlo {b}"
        );
    }
}

#[test]
fn same_seed_same_run() {
    let Some(rt) = runtime() else { return };
    let run = || {
        let mut tr = Trainer::new(&rt, nano_cfg(10)).unwrap();
        tr.train().unwrap().losses
    };
    assert_eq!(run(), run(), "training must be deterministic per seed");
}

#[test]
fn different_seed_different_run() {
    let Some(rt) = runtime() else { return };
    let mut cfg = nano_cfg(10);
    cfg.seed = 1234;
    let a = Trainer::new(&rt, nano_cfg(10)).unwrap().train().unwrap().losses;
    let b = Trainer::new(&rt, cfg).unwrap().train().unwrap().losses;
    assert_ne!(a, b);
}

#[test]
fn absurd_lr_triggers_instability_detector() {
    let Some(rt) = runtime() else { return };
    let mut cfg = nano_cfg(80);
    cfg.optim.lr = 2.0; // guaranteed divergence
    cfg.grad_clip = 0.0;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let res = tr.train().unwrap();
    assert!(res.unstable, "2.0 lr should diverge");
    assert!(res.steps_done < 80, "run should stop early");
}

#[test]
fn stable_embedding_model_trains() {
    let Some(rt) = runtime() else { return };
    let mut cfg = nano_cfg(30);
    cfg.model = "nano_stable".into();
    cfg.push_emb32();
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let res = tr.train().unwrap();
    assert!(!res.unstable);
    let first = res.losses.first().copied().unwrap();
    let last = res.losses.last().copied().unwrap();
    assert!(last < first - 0.8, "loss {first} -> {last}");
}

#[test]
fn emb32_policy_increases_state_bytes() {
    let Some(rt) = runtime() else { return };
    let mut cfg = nano_cfg(1);
    cfg.model = "nano_stable".into();
    let t_plain = Trainer::new(&rt, cfg.clone()).unwrap();
    cfg.push_emb32();
    let t_emb32 = Trainer::new(&rt, cfg).unwrap();
    assert!(t_emb32.state_bytes() > t_plain.state_bytes());
    // the per-group breakdown singles out the 32-bit embedding group
    let reports = t_emb32.group_reports();
    assert_eq!(reports.len(), 2);
    assert!(reports[1].label.contains("embed.tok"));
    assert!(reports[1].config.contains("32-bit"));
    assert_eq!(
        reports.iter().map(|r| r.state_bytes).sum::<usize>(),
        t_emb32.state_bytes()
    );
}

#[test]
fn toml_mixed_precision_groups_train_end_to_end() {
    // The §2.3 stable-embedding policy expressed TOML-only: embeddings in
    // a 32-bit group, everything else 8-bit dynamic block-wise, per-group
    // state bytes reported.
    let Some(rt) = runtime() else { return };
    let mut cfg = RunConfig::from_toml(
        r#"
[model]
name = "nano_stable"

[optimizer]
kind = "adam"
bits = 8
lr = 3e-3

[[optimizer.group]]
pattern = "embed.tok|embed.pos"
bits = 32

[train]
steps = 30
eval_every = 0
eval_batches = 4
seed = 7
"#,
    )
    .unwrap();
    cfg.schedule = Schedule::Constant;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let popt = tr.param_optimizer();
    let i = popt.find("embed.tok").unwrap();
    assert_eq!(popt.group_of(i), 1);
    let res = tr.train().unwrap();
    assert!(!res.unstable);
    assert_eq!(res.group_state_bytes.len(), 2);
    assert!(res.group_state_bytes.iter().all(|(_, b)| *b > 0));
    let first = res.losses.first().copied().unwrap();
    let last = res.losses.last().copied().unwrap();
    assert!(last < first - 0.8, "loss {first} -> {last}");
}

#[test]
fn trainer_checkpoint_roundtrip_resumes_identically() {
    let Some(rt) = runtime() else { return };
    let mut cfg = nano_cfg(10);
    cfg.push_emb32();
    let dir = std::env::temp_dir().join(format!("bitopt8_tr_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("c.bin");

    let mut tr_a = Trainer::new(&rt, cfg.clone()).unwrap();
    for _ in 0..5 {
        tr_a.train_step().unwrap();
    }
    tr_a.checkpoint().unwrap().save(&path).unwrap();
    let mut tail_a = Vec::new();
    for _ in 0..5 {
        tail_a.push(tr_a.train_step().unwrap());
    }

    let mut tr_b = Trainer::new(&rt, cfg).unwrap();
    tr_b.restore(&bitopt8::coordinator::Checkpoint::load(&path).unwrap()).unwrap();
    assert_eq!(tr_b.step, 5);
    let mut tail_b = Vec::new();
    for _ in 0..5 {
        tail_b.push(tr_b.train_step().unwrap());
    }
    assert_eq!(tail_a, tail_b, "post-restore trajectory diverged");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn state_snapshot_covers_all_tensors() {
    let Some(rt) = runtime() else { return };
    let mut tr = Trainer::new(&rt, nano_cfg(3)).unwrap();
    tr.train().unwrap();
    let snap = tr.state_snapshot();
    // adam: two states per tensor
    assert_eq!(snap.len(), tr.model.params.len() * 2);
    assert!(snap.iter().all(|(_, v)| v.iter().all(|x| x.is_finite())));
    // first-moment state must be non-zero after training
    let nonzero = snap
        .iter()
        .filter(|(name, v)| name.ends_with("::m") && v.iter().any(|&x| x != 0.0))
        .count();
    assert!(nonzero > 0);
}

#[test]
fn jsonl_metrics_written() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join(format!("bitopt8_it_{}", std::process::id()));
    let path = dir.join("m.jsonl");
    let mut cfg = nano_cfg(5);
    cfg.log_jsonl = Some(path.to_string_lossy().to_string());
    Trainer::new(&rt, cfg).unwrap().train().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    // one run-start "groups" record + 5 step records
    assert_eq!(text.lines().count(), 6);
    assert!(text.lines().next().unwrap().contains("\"groups\""));
    assert_eq!(text.lines().filter(|l| l.contains("\"loss\"")).count(), 5);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn final_eval_not_duplicated_when_steps_align_with_eval_every() {
    // regression: when steps is a multiple of eval_every, the post-loop
    // eval used to re-push the in-loop eval of the same step (and pay a
    // second full eval pass)
    let Some(rt) = runtime() else { return };
    let mut cfg = nano_cfg(10);
    cfg.eval_every = 5;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let res = tr.train().unwrap();
    let steps: Vec<usize> = res.evals.iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![5, 10], "evals recorded once per evaluated step");
    assert_eq!(res.final_eval, res.evals.last().unwrap().1);

    // steps NOT aligned with eval_every: the post-loop eval still runs
    let mut cfg = nano_cfg(7);
    cfg.eval_every = 5;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let res = tr.train().unwrap();
    let steps: Vec<usize> = res.evals.iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![5, 7]);
}

#[test]
fn crashed_steps_leave_a_metrics_trace() {
    // regression: the non-finite-gradient early return used to skip the
    // JSONL step record entirely, so crashed steps vanished from loss
    // curves. A huge (finite — validation rejects Inf) LR blows the params
    // past f32 range after step 1, so step 2's forward overflows and its
    // gradients are non-finite deterministically.
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join(format!("bitopt8_crash_{}", std::process::id()));
    let path = dir.join("m.jsonl");
    let mut cfg = nano_cfg(6);
    cfg.optim.lr = 1e30;
    cfg.grad_clip = 0.0;
    cfg.log_jsonl = Some(path.to_string_lossy().to_string());
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let res = tr.train().unwrap();
    assert!(res.unstable, "infinite LR must crash");
    assert_eq!(res.reason, Some("non-finite gradients"));
    let text = std::fs::read_to_string(&path).unwrap();
    // every executed step leaves a record: 1 groups header + steps_done
    assert_eq!(
        text.lines().count(),
        1 + res.steps_done,
        "crashed steps must not vanish from the JSONL stream:\n{text}"
    );
    assert!(
        text.contains("\"grad_crash\":true"),
        "the crashed step must carry the grad_crash marker:\n{text}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hlo_engine_with_streaming_overlap_matches_prior_trajectory() {
    // The HLO path now streams native tensors onto the pool while PJRT
    // dispatches run serially; determinism per seed must survive, and the
    // mixed-engine run (8-bit HLO tensors + 32-bit native embeddings) must
    // still train.
    let Some(rt) = runtime() else { return };
    let run = || {
        let mut cfg = nano_cfg(8);
        cfg.model = "nano_stable".into();
        cfg.engine = Engine::Hlo;
        cfg.push_emb32(); // forces a native (32-bit) group next to HLO tensors
        let mut tr = Trainer::new(&rt, cfg).unwrap();
        let res = tr.train().unwrap();
        assert!(res.hlo_updated_tensors > 0, "HLO path not exercised");
        res.losses
    };
    assert_eq!(run(), run(), "overlapped HLO+native stepping must stay deterministic");
}

#[test]
fn glue_cls_model_learns_above_chance() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    if manifest.model("cls_tiny").is_err() {
        eprintln!("SKIP: cls_tiny not in artifacts");
        return;
    }
    let mut cfg = nano_cfg(60);
    cfg.model = "cls_tiny".into();
    cfg.optim.lr = 1e-3;
    let task = &bitopt8::data::glue::GLUE_TASKS[4]; // SST-2
    let mut tr = Trainer::new(&rt, cfg).unwrap().with_glue_task(task).unwrap();
    let res = tr.train().unwrap();
    let acc = res.eval_accs.last().map(|&(_, a)| a).unwrap_or(0.0);
    assert!(acc > 0.6, "SST-2-like accuracy {acc} not above chance");
}
