//! Parity tests for the adaptive precision controller (engine layer 6).
//!
//! The controller contract: runtime bit-width transitions are a *policy*
//! over deterministic signals — they never depend on how the step was
//! executed. Per-tensor gradient norms are accumulated in fixed element
//! order, clip/crash events are exact counters, and the probes stream
//! states sequentially, so the transition sequence (and therefore the
//! whole trajectory) is pinned across threads × lane/scalar kernels ×
//! shard layouts. These tests pin that down:
//!
//! * a frozen policy (no trigger can fire) is **bit-identical** to the
//!   same spec run with no controller at all, across shard counts,
//!   thread counts, and forced-scalar kernels,
//! * a firing policy produces the identical transition sequence, final
//!   widths, params, and states under every execution shape,
//! * a v6 checkpoint saved mid-run with promoted tensors restores with
//!   the captured widths and review window, and the resumed run replays
//!   the uninterrupted trajectory bit for bit — monolithic and sharded
//!   (including restoring into a different shard count),
//! * a static (v4) checkpoint restored under a live controller keeps its
//!   v2–v5 semantics: built widths, empty review window, and
//! * `configs/adaptive_precision.toml` resolves the bounds and policy it
//!   documents.

use std::sync::Mutex;

use bitopt8::config::RunConfig;
use bitopt8::coordinator::Checkpoint;
use bitopt8::optim::{
    describe_policy, Bits, GroupOverride, OptimConfig, OptimSpec, ParamOptimizer,
    PrecisionController, PrecisionPolicy, TensorInfo, Transition,
};
use bitopt8::util::lanes;
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

/// Serializes tests that toggle process-global knobs (thread count, the
/// forced-scalar lane switch); see `pool_parity.rs` for the rationale.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The stable-embedding tensor listing the other parity suites use:
/// multi-block, single-block, and sub-block sizes.
fn model_tensors() -> Vec<TensorInfo> {
    let specs: [(&str, usize, Option<(usize, usize)>); 7] = [
        ("embed.tok", 512 * 64, Some((512, 64))),
        ("embed.pos", 64 * 64, Some((64, 64))),
        ("block0.attn.wq", 64 * 64, Some((64, 64))),
        ("block0.mlp.w1", 64 * 256, Some((64, 256))),
        ("block0.mlp.b1", 256, None),
        ("final_ln.scale", 64, None),
        ("lm_head", 64 * 512, Some((64, 512))),
    ];
    specs
        .into_iter()
        .map(|(name, size, shape)| TensorInfo {
            name: name.to_string(),
            size,
            shape,
            padded: size.next_multiple_of(2048),
        })
        .collect()
}

/// 4-bit base with pinned 32-bit embeddings and an 8-bit ceiling on the
/// head — exercises pinned tensors, bounded tensors, and free tensors in
/// one fleet.
fn adaptive_spec(shards: u32) -> OptimSpec {
    let base = OptimConfig::adam(0.01, Bits::b4_dynamic());
    let mut spec = OptimSpec::with_groups(
        base,
        vec![
            GroupOverride::parse("embed.tok|embed.pos:bits=32").unwrap(),
            GroupOverride::parse("lm_head:bits_max=8").unwrap(),
        ],
    );
    spec.default_shards = shards;
    spec
}

/// A policy that can never fire: the probe score is capped at 1.0, no
/// gradient norm reaches 1e9× its median, and demotion is disabled.
fn frozen_policy() -> PrecisionPolicy {
    PrecisionPolicy::parse("promote_error=2, spike_factor=1e9, demote_error=0").unwrap()
}

/// Fires only on the signals the driver scripts (spikes and crashes):
/// `promote_error=2` keeps the probe trigger out of the timeline so the
/// expected transition steps are exact.
fn firing_policy() -> PrecisionPolicy {
    PrecisionPolicy::parse("cadence=5, spike_factor=2, promote_error=2, demote_error=0.9")
        .unwrap()
}

/// The promotable tensor the driver spikes (`block0.attn.wq`).
const SPIKED: usize = 2;

fn targets() -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0x7A36);
    model_tensors()
        .iter()
        .map(|t| (0..t.size).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn init_params() -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0xD1CE);
    model_tensors()
        .iter()
        .map(|t| (0..t.size).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect()
}

fn sq_norms(grads: &[Vec<f32>]) -> Vec<f64> {
    grads
        .iter()
        .map(|g| g.iter().map(|&v| v as f64 * v as f64).sum())
        .collect()
}

/// Drive `steps` (1-based, inclusive) of the quadratic fleet: gradients
/// are `params - target` per tensor, tensor `SPIKED`'s gradients are
/// scaled 64× on every `spike_every`-th step, and `crash_step` (0 = none)
/// skips the update and flags a gradient crash — exactly the trainer's
/// crashed-step behavior. The controller (when present) observes every
/// step and reviews on its cadence; returns the transitions applied.
fn drive(
    popt: &mut ParamOptimizer,
    mut ctl: Option<&mut PrecisionController>,
    params: &mut [Vec<f32>],
    steps: std::ops::RangeInclusive<usize>,
    spike_every: usize,
    crash_step: usize,
) -> Vec<Transition> {
    let targets = targets();
    let mut out = Vec::new();
    for step in steps {
        let mut grads: Vec<Vec<f32>> = params
            .iter()
            .zip(&targets)
            .map(|(p, t)| p.iter().zip(t).map(|(a, b)| a - b).collect())
            .collect();
        if spike_every != 0 && step % spike_every == 0 {
            for v in grads[SPIKED].iter_mut() {
                *v *= 64.0;
            }
        }
        let crash = step == crash_step;
        if !crash {
            popt.step_native(params, &grads);
        }
        if let Some(c) = ctl.as_deref_mut() {
            c.observe_step(&sq_norms(&grads), 0, 0, crash);
            if c.due(step) {
                out.extend(c.review(step, popt));
            }
        }
    }
    out
}

fn widths(popt: &ParamOptimizer) -> Vec<u32> {
    (0..popt.n_tensors()).map(|i| popt.tensor_cfg(i).bits.bit_count()).collect()
}

#[test]
fn frozen_policy_is_bit_identical_to_static_run() {
    let _g = locked();
    // reference: no controller at all, single shard, single thread
    let mut popt_ref = ParamOptimizer::build(adaptive_spec(1), &model_tensors(), None).unwrap();
    let mut p_ref = init_params();
    parallel::with_threads(1, || {
        drive(&mut popt_ref, None, &mut p_ref, 1..=20, 8, 0);
    });

    for shards in [1u32, 4] {
        for threads in [Some(1), Some(4), None] {
            let mut popt =
                ParamOptimizer::build(adaptive_spec(shards), &model_tensors(), None).unwrap();
            let mut ctl = PrecisionController::new(frozen_policy(), &popt);
            let mut p = init_params();
            let run = |popt: &mut ParamOptimizer,
                       ctl: &mut PrecisionController,
                       p: &mut [Vec<f32>]| {
                drive(popt, Some(ctl), p, 1..=20, 8, 0)
            };
            let tr = match threads {
                Some(t) => parallel::with_threads(t, || run(&mut popt, &mut ctl, &mut p)),
                None => run(&mut popt, &mut ctl, &mut p),
            };
            assert!(tr.is_empty(), "frozen policy transitioned at shards={shards}");
            assert!(ctl.transitions().is_empty());
            assert_eq!(widths(&popt), widths(&popt_ref));
            assert_eq!(p, p_ref, "params diverged at shards={shards}, {threads:?} threads");
            assert_eq!(popt.state_snapshot(), popt_ref.state_snapshot());
        }
        // forced-scalar kernels under the controller
        let mut popt =
            ParamOptimizer::build(adaptive_spec(shards), &model_tensors(), None).unwrap();
        let mut ctl = PrecisionController::new(frozen_policy(), &popt);
        let mut p = init_params();
        lanes::with_forced_scalar(|| {
            parallel::with_threads(4, || {
                drive(&mut popt, Some(&mut ctl), &mut p, 1..=20, 8, 0);
            })
        });
        assert!(ctl.transitions().is_empty());
        assert_eq!(p, p_ref, "scalar run diverged at shards={shards}");
        assert_eq!(popt.state_snapshot(), popt_ref.state_snapshot());
    }
}

#[test]
fn firing_policy_transitions_are_deterministic_across_execution_shapes() {
    let _g = locked();
    // 25 steps: the 64× spike on step 8 fires `gnorm_spike` at review 10,
    // the crash on step 13 fires `detector` for every unpinned tensor at
    // review 15, and reviews 20/25 are quiet (demotions allowed).
    let run = |shards: u32, threads: Option<usize>, scalar: bool| {
        let mut popt =
            ParamOptimizer::build(adaptive_spec(shards), &model_tensors(), None).unwrap();
        let mut ctl = PrecisionController::new(firing_policy(), &popt);
        let mut p = init_params();
        let mut go = || drive(&mut popt, Some(&mut ctl), &mut p, 1..=25, 8, 13);
        let tr = match (threads, scalar) {
            (Some(t), false) => parallel::with_threads(t, go),
            (Some(t), true) => lanes::with_forced_scalar(|| parallel::with_threads(t, go)),
            (None, false) => go(),
            (None, true) => lanes::with_forced_scalar(go),
        };
        let peak = ctl.peak_state_bytes();
        (tr, widths(&popt), p, popt.state_snapshot(), peak)
    };

    let (tr_ref, w_ref, p_ref, s_ref, peak_ref) = run(1, Some(1), false);
    assert!(!tr_ref.is_empty(), "the firing policy must transition");
    assert!(
        tr_ref.iter().any(|t| t.trigger == "gnorm_spike" && t.tensor == "block0.attn.wq"),
        "{tr_ref:?}"
    );
    assert!(tr_ref.iter().any(|t| t.trigger == "detector"), "{tr_ref:?}");
    // pinned embeddings never move; lm_head never exceeds its ceiling
    assert!(tr_ref.iter().all(|t| !t.tensor.starts_with("embed.")), "{tr_ref:?}");
    assert!(
        tr_ref.iter().filter(|t| t.tensor == "lm_head").all(|t| t.to_bits <= 8),
        "{tr_ref:?}"
    );
    assert_eq!(w_ref[0], 32, "embed.tok stays pinned");
    assert!(peak_ref > 0);

    for (shards, threads, scalar) in [
        (1u32, Some(4), false),
        (1, None, false),
        (4, Some(1), false),
        (4, Some(4), false),
        (4, Some(4), true),
        (1, None, true),
    ] {
        let (tr, w, p, s, peak) = run(shards, threads, scalar);
        let shape = format!("shards={shards}, threads={threads:?}, scalar={scalar}");
        assert_eq!(tr, tr_ref, "transition sequence diverged at {shape}");
        assert_eq!(w, w_ref, "final widths diverged at {shape}");
        assert_eq!(p, p_ref, "params diverged at {shape}");
        assert_eq!(s, s_ref, "states diverged at {shape}");
        assert_eq!(peak, peak_ref, "peak footprint diverged at {shape}");
    }
}

#[test]
fn v6_monolithic_checkpoint_resumes_bit_identically() {
    let _g = locked();
    let dir = std::env::temp_dir().join(format!("bitopt8_v6mono_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.bin");

    // run A: the spike on step 8 promotes block0.attn.wq at review 10,
    // save on step 12 with the promotion live
    let mut popt_a = ParamOptimizer::build(adaptive_spec(1), &model_tensors(), None).unwrap();
    let mut ctl_a = PrecisionController::new(firing_policy(), &popt_a);
    let mut p_a = init_params();
    let head = drive(&mut popt_a, Some(&mut ctl_a), &mut p_a, 1..=12, 8, 0);
    assert!(
        head.iter().any(|t| t.tensor == "block0.attn.wq" && t.to_bits == 8),
        "{head:?}"
    );
    Checkpoint::capture(12, &Rng::new(7), &p_a, &popt_a, Some(&ctl_a)).save(&path).unwrap();
    let snap_at_save = ctl_a.snapshot();

    // the uninterrupted continuation (spike on 16 promotes 8 -> 32)
    let tail_a = drive(&mut popt_a, Some(&mut ctl_a), &mut p_a, 13..=24, 8, 0);
    assert!(
        tail_a.iter().any(|t| t.tensor == "block0.attn.wq" && t.to_bits == 32),
        "{tail_a:?}"
    );

    // the loaded file carries the controller payload and the live widths
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.step, 12);
    let saved_ctl = loaded.ctl.as_ref().expect("v6 controller payload");
    assert_eq!(saved_ctl.tensors.len(), 7);
    let wq = loaded.tensors.iter().find(|t| t.name == "block0.attn.wq").unwrap();
    assert_eq!(wq.state_bits, 8, "captured width must be the promoted one");

    // run B: fresh build (4-bit), restore, continue with the same driver
    let mut popt_b = ParamOptimizer::build(adaptive_spec(1), &model_tensors(), None).unwrap();
    let mut ctl_b = PrecisionController::new(firing_policy(), &popt_b);
    let mut p_b: Vec<Vec<f32>> = model_tensors().iter().map(|t| vec![0.0; t.size]).collect();
    loaded.restore(&mut p_b, &mut popt_b, Some(&mut ctl_b)).unwrap();
    assert_eq!(
        popt_b.tensor_cfg(SPIKED).bits.bit_count(),
        8,
        "restore must re-apply the promoted width"
    );
    assert_eq!(ctl_b.snapshot(), snap_at_save, "review window must restore exactly");
    let tail_b = drive(&mut popt_b, Some(&mut ctl_b), &mut p_b, 13..=24, 8, 0);

    assert_eq!(tail_b, tail_a, "post-restore transitions diverged");
    assert_eq!(p_b, p_a, "post-restore params diverged");
    assert_eq!(popt_b.state_snapshot(), popt_a.state_snapshot());
    assert_eq!(widths(&popt_b), widths(&popt_a));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v6_sharded_checkpoint_restores_into_a_different_shard_count() {
    let _g = locked();
    let dir = std::env::temp_dir().join(format!("bitopt8_v6shard_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.bin");

    // 4-shard run with a live promotion, v6 sharded save
    let mut popt_a = ParamOptimizer::build(adaptive_spec(4), &model_tensors(), None).unwrap();
    let mut ctl_a = PrecisionController::new(firing_policy(), &popt_a);
    let mut p_a = init_params();
    drive(&mut popt_a, Some(&mut ctl_a), &mut p_a, 1..=12, 8, 0);
    assert!(!ctl_a.transitions().is_empty());
    let layout = popt_a.shard_layout();
    let (assignment, n_shards) = (layout.assignment.clone(), layout.n_shards);
    Checkpoint::capture(12, &Rng::new(7), &p_a, &popt_a, Some(&ctl_a))
        .save_sharded(&path, &assignment, n_shards)
        .unwrap();
    let snap_at_save = ctl_a.snapshot();
    for s in 0..4 {
        assert!(dir.join(format!("ck.bin.shard{s:02}")).exists(), "missing shard file {s}");
    }
    let tail_a = drive(&mut popt_a, Some(&mut ctl_a), &mut p_a, 13..=24, 8, 0);

    // controller state is keyed by tensor name, so resharding is free
    let loaded = Checkpoint::load(&path).unwrap();
    assert!(loaded.ctl.is_some(), "sharded v6 manifest must carry the controller");
    let mut popt_b = ParamOptimizer::build(adaptive_spec(2), &model_tensors(), None).unwrap();
    let mut ctl_b = PrecisionController::new(firing_policy(), &popt_b);
    let mut p_b: Vec<Vec<f32>> = model_tensors().iter().map(|t| vec![0.0; t.size]).collect();
    loaded.restore(&mut p_b, &mut popt_b, Some(&mut ctl_b)).unwrap();
    assert_eq!(popt_b.tensor_cfg(SPIKED).bits.bit_count(), 8);
    assert_eq!(ctl_b.snapshot(), snap_at_save);
    let tail_b = drive(&mut popt_b, Some(&mut ctl_b), &mut p_b, 13..=24, 8, 0);

    assert_eq!(tail_b, tail_a, "resharded adaptive restore diverged");
    assert_eq!(p_b, p_a);
    assert_eq!(popt_b.state_snapshot(), popt_a.state_snapshot());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn static_checkpoint_keeps_v5_semantics_under_a_live_controller() {
    let _g = locked();
    let dir = std::env::temp_dir().join(format!("bitopt8_v4compat_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.bin");

    // static run, no controller: capture(..., None) must stay plain v4
    let mut popt_a = ParamOptimizer::build(adaptive_spec(1), &model_tensors(), None).unwrap();
    let mut p_a = init_params();
    drive(&mut popt_a, None, &mut p_a, 1..=8, 0, 0);
    Checkpoint::capture(8, &Rng::new(7), &p_a, &popt_a, None).save(&path).unwrap();
    drive(&mut popt_a, None, &mut p_a, 9..=16, 0, 0);

    // restoring under a live (frozen) controller must not change widths
    // or invent a review window — v2–v5 semantics exactly
    let loaded = Checkpoint::load(&path).unwrap();
    assert!(loaded.ctl.is_none(), "a static save must not carry a controller payload");
    let mut popt_b = ParamOptimizer::build(adaptive_spec(1), &model_tensors(), None).unwrap();
    let mut ctl_b = PrecisionController::new(frozen_policy(), &popt_b);
    let fresh_snap = ctl_b.snapshot();
    let mut p_b: Vec<Vec<f32>> = model_tensors().iter().map(|t| vec![0.0; t.size]).collect();
    loaded.restore(&mut p_b, &mut popt_b, Some(&mut ctl_b)).unwrap();
    assert_eq!(popt_b.tensor_cfg(SPIKED).bits.bit_count(), 4, "built width must survive");
    assert_eq!(ctl_b.snapshot(), fresh_snap, "no saved window to restore");
    let tr = drive(&mut popt_b, Some(&mut ctl_b), &mut p_b, 9..=16, 0, 0);

    assert!(tr.is_empty());
    assert_eq!(p_b, p_a, "static restore under a controller diverged");
    assert_eq!(popt_b.state_snapshot(), popt_a.state_snapshot());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn adaptive_precision_config_resolves_the_documented_policy() {
    // integration tests run from the package root, so configs/ resolves
    let cfg = RunConfig::from_file("configs/adaptive_precision.toml").unwrap();
    let policy = cfg.precision.expect("[precision] table enables the controller");
    assert_eq!(policy.cadence, 10);
    assert_eq!(policy.demote_error, 0.05);
    assert_eq!(cfg.fault.spike_every, 16);

    let spec = cfg.optim_spec();
    let popt = ParamOptimizer::build(spec, &model_tensors(), None).unwrap();
    let head = popt.find("lm_head").unwrap();
    assert_eq!(popt.bits_bounds(head), (4, 8), "bits_max caps the head's ceiling");
    let wq = popt.find("block0.attn.wq").unwrap();
    assert_eq!(popt.bits_bounds(wq), (4, 32));

    let text = describe_policy(&policy, &popt);
    assert!(text.contains("ceiling  8-bit"), "{text}");
    assert!(text.contains("projected state bytes"), "{text}");
    let (lo, hi) = popt.projected_state_bytes();
    assert!(lo < hi, "the adaptive range must span a real footprint spread");
}
