//! Parameter-group API tests that need no AOT artifacts: the `emb32`
//! sugar is bit-identical to the historical hard-coded flag, group
//! resolution is first-match-wins end to end from TOML, and the shipped
//! mixed-precision example config builds the §2.3 stable-embedding layout.

use bitopt8::config::RunConfig;
use bitopt8::optim::{
    build, Bits, GroupOverride, OptimConfig, OptimSpec, ParamOptimizer, TensorInfo,
};
use bitopt8::util::rng::Rng;

/// A stable-embedding model's tensor listing (subset of
/// `python/compile/model.py::param_specs` for a stable preset), with the
/// historical `is_embedding` flag alongside.
fn stable_model_tensors() -> Vec<(TensorInfo, bool)> {
    // `is_embedding` is true for embed.tok/embed.pos only — the stable
    // graph's embed.ln.* LayerNorm tensors are NOT embeddings, which is
    // exactly why the emb32 sugar uses exact names instead of `embed.*`.
    let specs: [(&str, usize, Option<(usize, usize)>, bool); 9] = [
        ("embed.tok", 512 * 64, Some((512, 64)), true),
        ("embed.pos", 64 * 64, Some((64, 64)), true),
        ("embed.ln.bias", 64, None, false),
        ("embed.ln.scale", 64, None, false),
        ("block0.attn.wq", 64 * 64, Some((64, 64)), false),
        ("block0.mlp.w1", 64 * 256, Some((64, 256)), false),
        ("final_ln.bias", 64, None, false),
        ("final_ln.scale", 64, None, false),
        ("lm_head", 64 * 512, Some((64, 512)), false),
    ];
    specs
        .into_iter()
        .map(|(name, size, shape, is_emb)| {
            (
                TensorInfo {
                    name: name.to_string(),
                    size,
                    shape,
                    padded: size.next_multiple_of(2048),
                },
                is_emb,
            )
        })
        .collect()
}

fn synth_data(tensors: &[(TensorInfo, bool)], steps: usize) -> (Vec<Vec<f32>>, Vec<Vec<Vec<f32>>>) {
    let mut rng = Rng::new(0xE3B);
    let params: Vec<Vec<f32>> = tensors
        .iter()
        .map(|(t, _)| (0..t.size).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect();
    let grads: Vec<Vec<Vec<f32>>> = (0..steps)
        .map(|_| {
            tensors
                .iter()
                .map(|(t, _)| (0..t.size).map(|_| rng.normal() as f32 * 0.02).collect())
                .collect()
        })
        .collect();
    (params, grads)
}

/// The acceptance pin: running the emb32 *sugar* through `ParamOptimizer`
/// is bit-identical to the historical trainer policy (`if emb32 &&
/// p.is_embedding { bits = B32 }` + serial/fused stepping).
#[test]
fn emb32_sugar_bit_identical_to_legacy_flag() {
    let base = OptimConfig::adam(2e-3, Bits::b8_dynamic());
    let tensors = stable_model_tensors();
    let steps = 4;

    // New surface: sugar override, fused step through ParamOptimizer.
    let spec = OptimSpec::with_groups(base, vec![GroupOverride::emb32()]);
    let infos: Vec<TensorInfo> = tensors.iter().map(|(t, _)| t.clone()).collect();
    let mut popt = ParamOptimizer::build(spec, &infos, None).unwrap();
    let (mut p_new, grads) = synth_data(&tensors, steps);
    for g in &grads {
        popt.step_native(&mut p_new, g);
    }

    // Historical policy: hard-coded is_embedding check, per-tensor build,
    // serial stepping (bit-identical to the fused engine by contract).
    let (mut p_old, _) = synth_data(&tensors, steps);
    let mut opts: Vec<_> = tensors
        .iter()
        .map(|(t, is_emb)| {
            let mut ocfg = base;
            if *is_emb {
                ocfg.bits = Bits::B32;
            }
            build(&ocfg, t.size, t.shape)
        })
        .collect();
    for g in &grads {
        for (i, opt) in opts.iter_mut().enumerate() {
            opt.step(&mut p_old[i], &g[i]);
        }
    }

    assert_eq!(p_new, p_old, "emb32 sugar diverged from the legacy flag");
    for (i, opt) in opts.iter().enumerate() {
        for ((na, sa), (nb, sb)) in opt.states().iter().zip(popt.opt(i).states()) {
            assert_eq!(*na, nb);
            assert_eq!(sa.to_f32(), sb.to_f32(), "state {nb} of tensor {i} diverged");
        }
    }
}

#[test]
fn toml_groups_resolve_first_match_wins_end_to_end() {
    let cfg = RunConfig::from_toml(
        r#"
[optimizer]
kind = "adamw"
bits = 8
lr = 1e-3
weight_decay = 0.01

[[optimizer.group]]
pattern = "embed.*"
bits = 32

[[optimizer.group]]
pattern = "*.bias|*.scale"
bits = 32
weight_decay = 0.0

[[optimizer.group]]
pattern = "lm_head"
lr = 5e-4
"#,
    )
    .unwrap();
    let tensors: Vec<TensorInfo> =
        stable_model_tensors().into_iter().map(|(t, _)| t).collect();
    let popt = ParamOptimizer::build(cfg.optim_spec(), &tensors, None).unwrap();

    // embed.ln.bias matches group 1 (embed.*) before the bias/scale group
    let i = popt.find("embed.ln.bias").unwrap();
    assert_eq!(popt.group_of(i), 1);
    assert_eq!(popt.tensor_cfg(i).bits, Bits::B32);
    assert!((popt.tensor_cfg(i).weight_decay - 0.01).abs() < 1e-9);
    // final_ln.scale falls to the bias/scale group with its wd override
    let i = popt.find("final_ln.scale").unwrap();
    assert_eq!(popt.group_of(i), 2);
    assert_eq!(popt.tensor_cfg(i).weight_decay, 0.0);
    // lm_head keeps 8-bit but gets its own lr
    let i = popt.find("lm_head").unwrap();
    assert_eq!(popt.group_of(i), 3);
    assert_eq!(popt.tensor_cfg(i).bits, Bits::b8_dynamic());
    assert!((popt.tensor_cfg(i).lr - 5e-4).abs() < 1e-9);
    // plain weights stay on the base config
    let i = popt.find("block0.attn.wq").unwrap();
    assert_eq!(popt.group_of(i), 0);

    // per-group reporting covers all four groups and sums to the total
    let reports = popt.group_reports();
    assert_eq!(reports.len(), 4);
    assert_eq!(reports.iter().map(|r| r.state_bytes).sum::<usize>(), popt.state_bytes());
}

/// The shipped mixed-precision example config is the §2.3 policy: parse it
/// from disk and check the resolved layout (CI additionally `--dry-run`s
/// every config in `configs/`).
#[test]
fn shipped_mixed_precision_config_builds_stable_embedding_layout() {
    let cfg = RunConfig::from_file("configs/mixed_precision_groups.toml").unwrap();
    assert_eq!(cfg.model, "tiny_stable");
    assert_eq!(cfg.groups.len(), 2);
    let tensors: Vec<TensorInfo> =
        stable_model_tensors().into_iter().map(|(t, _)| t).collect();
    let popt = ParamOptimizer::build(cfg.optim_spec(), &tensors, None).unwrap();
    for name in ["embed.tok", "embed.pos"] {
        let i = popt.find(name).unwrap();
        assert_eq!(popt.tensor_cfg(i).bits, Bits::B32, "{name}");
    }
    for name in ["embed.ln.bias", "block0.attn.wq", "lm_head"] {
        let i = popt.find(name).unwrap();
        assert_eq!(popt.tensor_cfg(i).bits, Bits::b8_dynamic(), "{name}");
    }
    let reports = popt.group_reports();
    assert_eq!(reports.len(), 3);
    assert!(reports[1].state_bytes > 0, "32-bit embedding group populated");
}
