//! Parity tests for the unified block-kernel execution engine.
//!
//! The engine contract (and the paper's §2.1 argument): the block
//! partition, the in-block update order, and the combine fold order never
//! change, so the pooled / fused phased implementation is **bit-identical**
//! to the sequential path — at every thread count, for every optimizer, at
//! every precision. These tests pin that down:
//!
//! * every optimizer × {B32, B8 dynamic, B8 linear, B4 dynamic, B4 linear}
//!   × threads {1, 4, default} produces bit-identical params and states,
//! * the same matrix is bit-identical between the lane-chunked kernels and
//!   the forced-scalar oracle (`util::lanes::with_forced_scalar`),
//! * the fused multi-tensor step equals per-tensor stepping exactly,
//!   including the reduction-bearing optimizers whose phased plans put
//!   tensor-wide norms/statistics inside the batch (LAMB, Adafactor,
//!   factored SM3),
//! * 8-bit Adam matches an independent reference built from the public
//!   quantizer API (pinning the dequantize → update → requantize semantics
//!   of the seed implementation).

use std::sync::Mutex;

use bitopt8::optim::{build, engine::fused_update, Bits, OptimConfig, OptimKind, Optimizer};
use bitopt8::quant::{BlockQuantizer, Format, BLOCK};
use bitopt8::util::lanes;
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

/// Serializes tests that toggle process-global knobs (thread count, the
/// forced-scalar lane switch). For the thread count, results are
/// invariant, so racing would still pass — this just makes each test
/// measure what it claims to. For the forced-scalar flag, serialization is
/// required: a racing lane-path run would silently execute scalar code.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const ALL_KINDS: [OptimKind; 8] = [
    OptimKind::Adam,
    OptimKind::AdamW,
    OptimKind::Momentum,
    OptimKind::Lamb,
    OptimKind::Lars,
    OptimKind::Adafactor,
    OptimKind::Adagrad,
    OptimKind::Sm3,
];

fn bit_configs() -> [Bits; 5] {
    [
        Bits::B32,
        Bits::B8 { format: Format::Dynamic, blockwise: true },
        Bits::B8 { format: Format::Linear, blockwise: true },
        Bits::B4 { format: Format::Dynamic, blockwise: true },
        Bits::B4 { format: Format::Linear, blockwise: true },
    ]
}

/// `steps` updates of one optimizer on a quadratic; returns the final
/// params and dequantized states.
fn trajectory(
    kind: OptimKind,
    bits: Bits,
    threads: Option<usize>,
    steps: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    // 64*72 = 4608 spans three 2048-blocks (last one ragged) and factors
    // as a true 2-D shape for Adafactor/SM3.
    let (rows, cols) = (64usize, 72usize);
    let n = rows * cols;
    let mut cfg = OptimConfig::adam(0.01, bits);
    cfg.kind = kind;
    let mut opt = build(&cfg, n, Some((rows, cols)));
    let mut rng = Rng::new(0xC0FFEE);
    let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
    let run = |opt: &mut Box<dyn Optimizer>, p: &mut Vec<f32>| {
        for _ in 0..steps {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(p, &g);
        }
    };
    match threads {
        Some(t) => parallel::with_threads(t, || run(&mut opt, &mut p)),
        None => run(&mut opt, &mut p),
    }
    let states = opt.states().into_iter().map(|(_, s)| s.to_f32()).collect();
    (p, states)
}

#[test]
fn every_optimizer_is_bit_identical_across_thread_counts() {
    let _g = locked();
    for kind in ALL_KINDS {
        for bits in bit_configs() {
            // threads = 1 IS the seed's sequential path: the pool inlines
            // the whole batch on the calling thread in index order.
            let (p_seq, s_seq) = trajectory(kind, bits, Some(1), 5);
            let (p_par, s_par) = trajectory(kind, bits, Some(4), 5);
            let (p_def, s_def) = trajectory(kind, bits, None, 5);
            assert!(p_seq.iter().all(|v| v.is_finite()));
            assert_eq!(
                p_seq, p_par,
                "{} {} params diverged between 1 and 4 threads",
                kind.name(),
                bits.describe()
            );
            assert_eq!(
                p_seq, p_def,
                "{} {} params diverged between 1 and default threads",
                kind.name(),
                bits.describe()
            );
            assert_eq!(s_seq, s_par, "{} {} states diverged", kind.name(), bits.describe());
            assert_eq!(s_seq, s_def, "{} {} states diverged", kind.name(), bits.describe());
        }
    }
}

#[test]
fn every_optimizer_is_bit_identical_between_lane_and_scalar_kernels() {
    // The SIMD-tentpole contract: the lane-chunked block kernels (absmax,
    // packed encode/decode, elementwise rules) are pure instruction-shape
    // changes — same trajectory bits as the scalar oracle, for every
    // optimizer × precision × thread count.
    let _g = locked();
    for kind in ALL_KINDS {
        for bits in bit_configs() {
            for threads in [Some(1usize), Some(4), None] {
                let (p_lane, s_lane) = trajectory(kind, bits, threads, 4);
                let (p_scalar, s_scalar) =
                    lanes::with_forced_scalar(|| trajectory(kind, bits, threads, 4));
                assert!(p_lane.iter().all(|v| v.is_finite()));
                assert_eq!(
                    p_lane,
                    p_scalar,
                    "{} {} params diverged between lane and scalar kernels \
                     ({threads:?} threads)",
                    kind.name(),
                    bits.describe()
                );
                assert_eq!(
                    s_lane,
                    s_scalar,
                    "{} {} states diverged between lane and scalar kernels",
                    kind.name(),
                    bits.describe()
                );
            }
        }
    }
}

/// Trajectory with the stability phases engaged: percentile clipping (a
/// gradient spike lands after the gnorm-history warm-up), a tight
/// `max_unorm` (so the u-materialization + norm-combine + apply path runs
/// and actually clips), and `skip_zeros` against stride-zeroed gradients.
fn stabilized_trajectory(
    kind: OptimKind,
    bits: Bits,
    threads: Option<usize>,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let n = 2048 * 2 + 300; // ragged third block
    let mut cfg = OptimConfig::adam(0.01, bits);
    cfg.kind = kind;
    cfg.clip_percentile = 95.0;
    cfg.max_unorm = 0.05;
    cfg.skip_zeros = true;
    let mut opt = build(&cfg, n, None);
    let mut rng = Rng::new(0x57AB);
    let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
    let run = |opt: &mut Box<dyn Optimizer>, p: &mut Vec<f32>| {
        for step in 0..10 {
            // spike once the rolling window is past GNORM_MIN_HISTORY, so
            // the percentile phase has a live threshold to clip against
            let scale = if step == 7 { 80.0 } else { 1.0 };
            let mut g: Vec<f32> =
                p.iter().zip(&target).map(|(a, b)| scale * (a - b)).collect();
            for v in g.iter_mut().step_by(5) {
                *v = 0.0;
            }
            opt.step(p, &g);
        }
    };
    match threads {
        Some(t) => parallel::with_threads(t, || run(&mut opt, &mut p)),
        None => run(&mut opt, &mut p),
    }
    let states = opt.states().into_iter().map(|(_, s)| s.to_f32()).collect();
    (p, states)
}

#[test]
fn stabilized_paths_are_bit_identical_across_threads_and_lanes() {
    // The stability tentpole's engine contract: the gnorm phase, the
    // u-materialization + unorm combine, and the apply phase all reduce in
    // fixed chunk order, so clipped trajectories stay bit-identical at
    // every thread count and between lane/scalar kernels.
    let _g = locked();
    for kind in
        [OptimKind::Adam, OptimKind::AdamW, OptimKind::Momentum, OptimKind::Adagrad]
    {
        for bits in [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()] {
            let (p1, s1) = stabilized_trajectory(kind, bits, Some(1));
            let (p4, s4) = stabilized_trajectory(kind, bits, Some(4));
            let (pd, sd) = stabilized_trajectory(kind, bits, None);
            let (psc, ssc) =
                lanes::with_forced_scalar(|| stabilized_trajectory(kind, bits, Some(4)));
            assert!(p1.iter().all(|v| v.is_finite()));
            assert_eq!(
                p1,
                p4,
                "{} {} stabilized params diverged between 1 and 4 threads",
                kind.name(),
                bits.describe()
            );
            assert_eq!(
                p1,
                pd,
                "{} {} stabilized params diverged between 1 and default threads",
                kind.name(),
                bits.describe()
            );
            assert_eq!(
                p1,
                psc,
                "{} {} stabilized params diverged between lane and scalar kernels",
                kind.name(),
                bits.describe()
            );
            assert_eq!(s1, s4, "{} {} states diverged", kind.name(), bits.describe());
            assert_eq!(s1, sd, "{} {} states diverged", kind.name(), bits.describe());
            assert_eq!(s1, ssc, "{} {} states diverged", kind.name(), bits.describe());
        }
    }
}

type Fleet = (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

/// Build a many-tensor fleet: mixed sizes (sub-block, exactly one block,
/// ragged multi-block) and mixed optimizers (block-local and whole-tensor).
fn fleet(bits: Bits) -> Fleet {
    let spec: Vec<(OptimKind, usize)> = vec![
        (OptimKind::Adam, 1),
        (OptimKind::Adam, 173),
        (OptimKind::Adam, 2048),
        (OptimKind::Adam, 2049),
        (OptimKind::Momentum, 4096),
        (OptimKind::Momentum, 31),
        (OptimKind::Adagrad, 5000),
        (OptimKind::Lars, 777),
        (OptimKind::AdamW, 300),
        (OptimKind::Lamb, 1500),
        (OptimKind::Lamb, 20000), // many-block phased reductions
        (OptimKind::Adafactor, 1024),
        (OptimKind::Sm3, 900),
    ];
    let mut rng = Rng::new(0xF1EE7);
    let mut opts = Vec::new();
    let mut params = Vec::new();
    let mut grads = Vec::new();
    for (kind, n) in spec {
        let mut cfg = OptimConfig::adam(0.005, bits);
        cfg.kind = kind;
        opts.push(build(&cfg, n, None));
        params.push((0..n).map(|_| rng.normal() as f32).collect());
        grads.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect());
    }
    (opts, params, grads)
}

/// Run `f` at a given thread count, or at the ambient default.
fn at_threads<R>(threads: Option<usize>, f: impl FnOnce() -> R) -> R {
    match threads {
        Some(t) => parallel::with_threads(t, f),
        None => f(),
    }
}

#[test]
fn fused_step_matches_per_tensor_stepping_bitwise() {
    let _g = locked();
    for bits in [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()] {
        for threads in [Some(1usize), Some(4), None] {
            at_threads(threads, || {
                let (mut o_serial, mut p_serial, grads) = fleet(bits);
                let (mut o_fused, mut p_fused, _) = fleet(bits);
                for _ in 0..4 {
                    for i in 0..o_serial.len() {
                        o_serial[i].step(&mut p_serial[i], &grads[i]);
                    }
                    fused_update(&mut o_fused, &mut p_fused, &grads);
                }
                assert_eq!(
                    p_serial,
                    p_fused,
                    "fused vs serial params diverged ({}, {threads:?} threads)",
                    bits.describe()
                );
                for (a, b) in o_serial.iter().zip(&o_fused) {
                    assert_eq!(a.t(), b.t());
                    for ((name, sa), (_, sb)) in a.states().iter().zip(b.states().iter()) {
                        assert_eq!(sa.to_f32(), sb.to_f32(), "state {name} diverged");
                    }
                }
            });
        }
    }
}

/// Fleet of only the reduction-bearing optimizers, with true 2-D shapes so
/// Adafactor and SM3 take their factored (multi-phase) paths. Ragged sizes
/// stress chunk/item boundaries.
fn reduction_fleet(bits: Bits) -> Fleet {
    let spec: Vec<(OptimKind, usize, Option<(usize, usize)>)> = vec![
        (OptimKind::Lamb, 64 * 72, Some((64, 72))),
        (OptimKind::Lamb, 5000, None),
        (OptimKind::Lamb, 2048, None),
        (OptimKind::Adafactor, 64 * 72, Some((64, 72))),
        (OptimKind::Adafactor, 33 * 127, Some((33, 127))),
        (OptimKind::Adafactor, 700, None),
        (OptimKind::Sm3, 64 * 72, Some((64, 72))),
        (OptimKind::Sm3, 129 * 31, Some((129, 31))),
        (OptimKind::Sm3, 513, None),
        (OptimKind::Lars, 4100, None),
    ];
    let mut rng = Rng::new(0xB10C);
    let mut opts = Vec::new();
    let mut params = Vec::new();
    let mut grads = Vec::new();
    for (kind, n, shape) in spec {
        let mut cfg = OptimConfig::adam(0.005, bits);
        cfg.kind = kind;
        opts.push(build(&cfg, n, shape));
        params.push((0..n).map(|_| rng.normal() as f32).collect());
        grads.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect());
    }
    (opts, params, grads)
}

#[test]
fn phased_plans_match_serial_bitwise_for_reduction_optimizers() {
    // The tentpole contract: LAMB / Adafactor / factored SM3 / LARS run
    // their tensor-wide reductions as phased block plans *inside* the
    // fused batch, and stay bit-identical to per-tensor stepping at every
    // thread count.
    let _g = locked();
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        for threads in [Some(1usize), Some(4), None] {
            at_threads(threads, || {
                let (mut o_serial, mut p_serial, grads) = reduction_fleet(bits);
                let (mut o_fused, mut p_fused, _) = reduction_fleet(bits);
                for _ in 0..5 {
                    for i in 0..o_serial.len() {
                        o_serial[i].step(&mut p_serial[i], &grads[i]);
                    }
                    fused_update(&mut o_fused, &mut p_fused, &grads);
                }
                assert_eq!(
                    p_serial,
                    p_fused,
                    "phased fused vs serial params diverged ({}, {threads:?} threads)",
                    bits.describe()
                );
                for (a, b) in o_serial.iter().zip(&o_fused) {
                    assert_eq!(a.t(), b.t());
                    for ((name, sa), (_, sb)) in a.states().iter().zip(b.states().iter()) {
                        assert_eq!(
                            sa.to_f32(),
                            sb.to_f32(),
                            "{}: state {name} diverged",
                            a.name()
                        );
                    }
                }
            });
        }
    }
}

#[test]
fn phased_plans_are_thread_count_invariant() {
    // Same fleet, full trajectories at 1 / 4 / default threads must agree
    // bit-for-bit (the combine folds partials in fixed order).
    let _g = locked();
    let run = |threads: Option<usize>| -> Vec<Vec<f32>> {
        at_threads(threads, || {
            let (mut opts, mut params, grads) = reduction_fleet(Bits::b8_dynamic());
            for _ in 0..5 {
                fused_update(&mut opts, &mut params, &grads);
            }
            params
        })
    };
    let p1 = run(Some(1));
    assert_eq!(p1, run(Some(4)));
    assert_eq!(p1, run(None));
}

#[test]
fn adam8_engine_matches_quantizer_level_reference() {
    let _g = locked();
    let n = 2048 * 2 + 300; // ragged third block
    let (lr, b1, b2, eps) = (0.02f32, 0.9f32, 0.995f32, 1e-7f32);
    let steps = 4;

    let mut rng = Rng::new(0x5EF);
    let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

    // --- engine path ------------------------------------------------------
    let mut cfg = OptimConfig::adam(lr, Bits::b8_dynamic());
    cfg.beta1 = b1;
    cfg.beta2 = b2;
    cfg.eps = eps;
    let mut opt = build(&cfg, n, None);
    let mut p_engine: Vec<f32> = vec![0.5; n];
    parallel::with_threads(4, || {
        for _ in 0..steps {
            let g: Vec<f32> = p_engine.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p_engine, &g);
        }
    });

    // --- independent reference over the public quantizer API --------------
    // Figure 1 semantics: dequantize state, run the exact 32-bit rule on
    // the in-register values, requantize for storage.
    let bq_m = BlockQuantizer::new(Format::Dynamic.signed_codebook(), BLOCK);
    let bq_r = BlockQuantizer::new(Format::Dynamic.unsigned_codebook(), BLOCK);
    let zeros = vec![0.0f32; n];
    let mut qm = bq_m.quantize(&zeros);
    let mut qr = bq_r.quantize(&zeros);
    let mut p_ref: Vec<f32> = vec![0.5; n];
    for t in 1..=steps as i32 {
        let g: Vec<f32> = p_ref.iter().zip(&target).map(|(a, b)| a - b).collect();
        let mut m = bq_m.dequantize(&qm);
        let mut r = bq_r.dequantize(&qr);
        let bias1 = 1.0 - b1.powi(t);
        let bias2 = 1.0 - b2.powi(t);
        for i in 0..n {
            bitopt8::optim::adam::Adam::update_rule(
                &mut p_ref[i],
                g[i],
                &mut m[i],
                &mut r[i],
                lr,
                b1,
                b2,
                eps,
                0.0,
                false,
                bias1,
                bias2,
            );
        }
        bq_m.quantize_into(&m, &mut qm);
        bq_r.quantize_into(&r, &mut qr);
    }

    assert_eq!(p_engine, p_ref, "engine diverged from the quantizer-level reference");
    let states = opt.states();
    assert_eq!(states[0].1.to_f32(), bq_m.dequantize(&qm), "first moment diverged");
    assert_eq!(states[1].1.to_f32(), bq_r.dequantize(&qr), "second moment diverged");
}

#[test]
fn fused_step_handles_degenerate_tensors() {
    let _g = locked();
    let mut opts: Vec<Box<dyn Optimizer>> = vec![
        build(&OptimConfig::adam(0.01, Bits::b8_dynamic()), 1, None),
        build(&OptimConfig::adam(0.01, Bits::B32), 2, None),
    ];
    let mut params = vec![vec![1.0f32], vec![1.0f32, 2.0]];
    let grads = vec![vec![0.5f32], vec![0.5f32, 0.25]];
    parallel::with_threads(4, || fused_update(&mut opts, &mut params, &grads));
    assert!(params.iter().flatten().all(|v| v.is_finite()));
    assert_eq!(opts[0].t(), 1);
    assert_eq!(opts[1].t(), 1);
}
