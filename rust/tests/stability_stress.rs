//! Stability-hardening stress tests — the PR-7 tentpole contract.
//!
//! The headline scenario mirrors the `configs/stability_stress.toml`
//! setup at the optimizer level: a momentum run hit by periodic gradient
//! spikes. Without percentile clipping the spikes fold straight into the
//! velocity and the loss blows through the detector's hard ceiling; with
//! `clip_percentile = 95` the rolling gnorm window flags each spike as an
//! outlier, scales it down to the distribution's own 95th percentile, and
//! the run survives.
//!
//! These tests also pin the per-group override path end to end (spec →
//! `ParamOptimizer` → fused batch → global clip counters) and that the
//! shipped stress config parses.
//!
//! The clip/unorm counters are process-global (`optim::take_clip_events`,
//! `take_unorm_clips`), so every test that drains them holds COUNTER_LOCK
//! — unit tests elsewhere deliberately never assert exact counts.

use std::sync::Mutex;

use bitopt8::config::RunConfig;
use bitopt8::coordinator::StabilityDetector;
use bitopt8::optim::{
    build, take_clip_events, take_unorm_clips, Bits, GroupOverride, OptimConfig, OptimKind,
    OptimSpec, ParamOptimizer, TensorInfo,
};
use bitopt8::util::rng::Rng;

static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Momentum on a quadratic, with an additive gradient spike every 16th
/// step (the stress config's `[fault]` shape). Returns the detector
/// verdict, the drained clip-event count, and the final loss.
fn spiked_momentum_run(clip_percentile: f32) -> (Option<&'static str>, u64, f64) {
    let n = 512;
    let mut cfg = OptimConfig::adam(0.05, Bits::b8_dynamic());
    cfg.kind = OptimKind::Momentum;
    cfg.beta1 = 0.9;
    cfg.beta2 = 0.0;
    cfg.clip_percentile = clip_percentile;
    let mut opt = build(&cfg, n, None);
    let mut rng = Rng::new(0x57E55);
    let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut p = vec![0.0f32; n];
    let mut detector = StabilityDetector::new();
    take_clip_events(); // scope the counter to this run
    let mut clips = 0u64;
    let mut loss = f64::NAN;
    for step in 1..=60usize {
        let mut g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
        if step % 16 == 0 {
            // additive spike: a constant blast, not proportional to the
            // (shrinking) error — the unclipped velocity integrates it
            for v in g.iter_mut() {
                *v += 50.0;
            }
        }
        opt.step(&mut p, &g);
        clips += take_clip_events();
        loss = 0.5
            * p.iter()
                .zip(&target)
                .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
                .sum::<f64>()
            / n as f64;
        if !detector.observe(loss) {
            break;
        }
    }
    (detector.reason(), clips, loss)
}

#[test]
fn percentile_clip_survives_spikes_that_kill_the_unclipped_run() {
    let _g = locked();
    // Unclipped baseline: the first spike displaces every element by
    // ~lr * 50 / (1 - beta) = 25, so the loss (~312) blasts through the
    // hard ceiling and the detector trips.
    let (reason, clips, _) = spiked_momentum_run(0.0);
    assert!(reason.is_some(), "unclipped baseline must trip the detector");
    assert_eq!(clips, 0, "clip_percentile = 0 must never clip");

    // Clipped run: by the first spike the window holds 15 steady gnorms,
    // so the 95th percentile is an ordinary norm and the spike is scaled
    // to it — the run converges through all three spikes.
    let (reason, clips, loss) = spiked_momentum_run(95.0);
    assert_eq!(reason, None, "clipped run must survive the spikes");
    assert!(clips >= 3, "each of the 3 spikes must register a clip event, got {clips}");
    assert!(loss < 1.0, "clipped run should still be converging, loss {loss}");
}

#[test]
fn per_group_stability_overrides_resolve_and_fire() {
    let _g = locked();
    let tensors: Vec<TensorInfo> = [("embed.tok", 4096usize), ("lm_head", 3000)]
        .into_iter()
        .map(|(name, size)| TensorInfo {
            name: name.to_string(),
            size,
            shape: None,
            padded: size.next_multiple_of(2048),
        })
        .collect();
    // Base config: plain coupled-wd Adam. One group turns all three
    // stability mechanisms on for the embeddings only.
    let mut base = OptimConfig::adam(0.01, Bits::b8_dynamic());
    base.weight_decay = 0.01;
    let spec = OptimSpec::with_groups(
        base,
        vec![GroupOverride::parse("embed.*:clip_percentile=95,max_unorm=0.05,skip_zeros=true")
            .unwrap()],
    );
    let mut popt = ParamOptimizer::build(spec, &tensors, None).unwrap();

    // The group surface reports the resolved knobs per group.
    let reports = popt.group_reports();
    assert_eq!(reports[0].clip_percentile, 0.0);
    assert!(!reports[0].skip_zeros);
    assert!((reports[1].clip_percentile - 95.0).abs() < 1e-6);
    assert!((reports[1].max_unorm - 0.05).abs() < 1e-9);
    assert!(reports[1].skip_zeros);

    let mut rng = Rng::new(0x6A0B);
    let mut params: Vec<Vec<f32>> = tensors
        .iter()
        .map(|t| (0..t.size).map(|_| rng.normal() as f32).collect())
        .collect();
    let p0 = params.clone();
    // Even-indexed gradient elements are exactly zero in both tensors.
    let grads: Vec<Vec<f32>> = tensors
        .iter()
        .map(|t| {
            (0..t.size)
                .map(|i| if i % 2 == 0 { 0.0 } else { rng.normal() as f32 * 0.1 })
                .collect()
        })
        .collect();

    take_clip_events();
    take_unorm_clips();
    for step in 1..=10usize {
        let scale = if step == 8 { 100.0f32 } else { 1.0 };
        let g: Vec<Vec<f32>> = grads
            .iter()
            .map(|g| g.iter().map(|v| v * scale).collect())
            .collect();
        popt.step_native(&mut params, &g);
    }
    let clips = take_clip_events();
    let unorms = take_unorm_clips();
    assert!(clips >= 1, "the step-8 spike must clip the embed group, got {clips}");
    assert!(unorms >= 1, "max_unorm = 0.05 is tight enough to fire, got {unorms}");

    // skip_zeros (embed group): zero-grad elements are bitwise untouched,
    // even with coupled weight decay on the base config.
    for i in (0..tensors[0].size).step_by(2) {
        assert_eq!(params[0][i], p0[0][i], "embed.tok[{i}] must be untouched");
    }
    // lm_head has no skip_zeros: coupled wd moves zero-grad elements too.
    let moved = (0..tensors[1].size)
        .step_by(2)
        .filter(|&i| params[1][i] != p0[1][i])
        .count();
    assert!(moved > tensors[1].size / 4, "lm_head zero-grad elements must decay, {moved} moved");
}

#[test]
fn shipped_stress_config_parses_and_resolves() {
    // cargo runs integration tests from the package root, where configs/
    // lives; the CI config-matrix lane additionally runs this file with
    // --dry-run.
    let cfg = RunConfig::from_file("configs/stability_stress.toml").unwrap();
    assert!(cfg.optim.stability_on());
    assert_eq!(cfg.optim.kind, OptimKind::Momentum);
    assert!((cfg.optim.clip_percentile - 95.0).abs() < 1e-6);
    assert!(cfg.optim.skip_zeros);
    assert_eq!(cfg.grad_clip, 0.0, "percentile clipping must be the only defense");
    assert_eq!(cfg.fault.spike_every, 16);
    assert!((cfg.fault.spike_scale - 50.0).abs() < 1e-6);
    assert_eq!(cfg.fault.zero_stride, 7);
    let spec = cfg.optim_spec();
    spec.validate().unwrap();
    // the per-group opt-out resolves: lm_head keeps clipping but not unorm
    let (head, g) = spec.resolve("lm_head");
    assert_eq!(g, 1);
    assert_eq!(head.max_unorm, 0.0);
    assert!((head.clip_percentile - 95.0).abs() < 1e-6);
}
