//! # bitopt8
//!
//! Production-style reproduction of **"8-bit Optimizers via Block-wise
//! Quantization"** (Dettmers et al., ICLR 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): block-wise
//!   quantize/dequantize and fused 8-bit optimizer updates.
//! * **L2** — JAX transformer LM + optimizer graphs
//!   (`python/compile/model.py`, `optim8.py`), AOT-lowered to HLO text.
//! * **L3** — this crate: the training coordinator, the numeric-format and
//!   optimizer substrates, the PJRT runtime, and the benchmark/analysis
//!   harnesses that regenerate every table and figure of the paper.
//!
//! Python never runs on the training path; after `make artifacts` the
//! binary is self-contained.

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod optim;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod util;
