//! bitopt8 CLI — launcher for training runs, paper-table reproduction,
//! and quantization analysis.
//!
//! ```text
//! bitopt8 train   [--config cfg.toml] [--model tiny_stable] [--optimizer adam8]
//!                 [--override "pattern:key=val,..."] [--emb32] [--shards N]
//!                 [--dry-run] ...
//! bitopt8 repro   table1|table2|...|table8|fig3 [--steps N] [--seeds K]
//! bitopt8 analyze fig2|fig4|fig5|fig6 [--n N]
//! bitopt8 info    [--artifacts DIR]
//! bitopt8 --lint  [--configs DIR]
//! ```
//!
//! `--lint` runs the plan-IR determinism linter (`analysis::plan_lint`)
//! over every `configs/*.toml` (each distinct plan its spec builds over
//! the dry-run tensor set) plus the full optimizer kind × bits ×
//! stability capability matrix, printing a greppable `PLAN_LINT ok`
//! summary and exiting nonzero on any violation.
//!
//! `train --dry-run` parses + validates the config (base optimizer,
//! parameter groups, unsupported combos) and prints the resolved group
//! layout over a representative LM tensor set — plus, when placement is on
//! (`[placement] shards` or `--shards N`), the tensor→shard assignment
//! table. No artifacts needed, so CI smoke-checks every example TOML with
//! it.

use anyhow::Result;

use bitopt8::analysis;
use bitopt8::config::RunConfig;
use bitopt8::coordinator::Trainer;
use bitopt8::optim::{describe_policy, ParamOptimizer, TensorInfo};
use bitopt8::quant::{dynamic_tree, linear, quantile, Format};
use bitopt8::repro;
use bitopt8::runtime::Runtime;
use bitopt8::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    if args.flag("lint") {
        return cmd_lint(&args);
    }
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("repro") => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all-static");
            repro::run(id, &args)
        }
        Some("analyze") => cmd_analyze(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: bitopt8 <train|repro|analyze|info> [options] | bitopt8 --lint\n\
                 (see module docs in rust/src/main.rs; tables/figures: DESIGN.md §4)"
            );
            Ok(())
        }
    }
}

/// `--lint`: static plan-IR verification. Lints every shipped config's
/// spec over the dry-run tensor set, then the full kind × bits ×
/// stability capability matrix. Nonzero exit on any violation.
fn cmd_lint(args: &Args) -> Result<()> {
    use bitopt8::analysis::plan_lint;

    let dir = args.get_or("configs", "configs");
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading config dir {dir}: {e}"))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|ext| ext == "toml"))
        .collect();
    paths.sort();

    let tensors = dry_run_tensors();
    let mut configs = 0usize;
    let mut plans = 0usize;
    let mut transition_plans = 0usize;
    let mut violations = 0usize;
    for path in &paths {
        let cfg = RunConfig::from_file(&path.to_string_lossy())?;
        let spec = cfg.optim_spec();
        let report = plan_lint::lint_spec(&spec, &tensors);
        // plans rebuilt after a runtime width transition (the precision
        // controller's promote/demote path) are distinct plan shapes and
        // get the same static checks
        let moved = plan_lint::lint_transitions(&spec, &tensors);
        configs += 1;
        plans += report.plans;
        transition_plans += moved.plans;
        violations += report.errors.len() + moved.errors.len();
        println!(
            "lint {:<40} plans={:<3} transition_plans={:<3} violations={}",
            path.file_name().unwrap_or_default().to_string_lossy(),
            report.plans,
            moved.plans,
            report.errors.len() + moved.errors.len()
        );
        for err in report.errors.iter().chain(&moved.errors) {
            eprintln!("  {err}");
        }
    }

    let matrix_errors = plan_lint::lint_matrix();
    println!(
        "lint {:<40} kinds={:<3} violations={}",
        "capability matrix",
        plan_lint::ALL_KINDS.len(),
        matrix_errors.len()
    );
    for err in &matrix_errors {
        eprintln!("  {err}");
    }
    violations += matrix_errors.len();

    if violations > 0 {
        anyhow::bail!("PLAN_LINT failed: {violations} violation(s)");
    }
    println!(
        "PLAN_LINT ok: configs={configs} plans={plans} transition_plans={transition_plans} \
         matrix_kinds={} violations=0",
        plan_lint::ALL_KINDS.len()
    );
    Ok(())
}

/// A representative transformer-LM tensor listing for `--dry-run` group
/// resolution (mirrors `python/compile/model.py::param_specs` naming).
fn dry_run_tensors() -> Vec<TensorInfo> {
    let (v, d, s, ff) = (512usize, 64usize, 64usize, 256usize);
    let mut t: Vec<(String, usize, Option<(usize, usize)>)> = vec![
        ("embed.tok".into(), v * d, Some((v, d))),
        ("embed.pos".into(), s * d, Some((s, d))),
        ("embed.ln.bias".into(), d, None),
        ("embed.ln.scale".into(), d, None),
        ("final_ln.bias".into(), d, None),
        ("final_ln.scale".into(), d, None),
        ("lm_head".into(), d * v, Some((d, v))),
    ];
    for b in 0..2 {
        let p = format!("block{b}");
        t.push((format!("{p}.ln1.bias"), d, None));
        t.push((format!("{p}.ln1.scale"), d, None));
        t.push((format!("{p}.ln2.bias"), d, None));
        t.push((format!("{p}.ln2.scale"), d, None));
        for w in ["wq", "wk", "wv", "wo"] {
            t.push((format!("{p}.attn.{w}"), d * d, Some((d, d))));
        }
        t.push((format!("{p}.mlp.w1"), d * ff, Some((d, ff))));
        t.push((format!("{p}.mlp.b1"), ff, None));
        t.push((format!("{p}.mlp.w2"), ff * d, Some((ff, d))));
        t.push((format!("{p}.mlp.b2"), d, None));
    }
    t.into_iter()
        .map(|(name, size, shape)| TensorInfo {
            name,
            size,
            shape,
            padded: size.next_multiple_of(2048),
        })
        .collect()
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    println!("run: {}", cfg.describe());
    if args.flag("dry-run") {
        // Parse/build validation only: resolve the spec over a
        // representative tensor set and print the group layout.
        let popt = ParamOptimizer::build(cfg.optim_spec(), &dry_run_tensors(), None)?;
        println!("{}", popt.describe());
        if let Some(placement) = popt.describe_placement() {
            println!("{placement}");
        }
        if let Some(policy) = &cfg.precision {
            println!("{}", describe_policy(policy, &popt));
        }
        println!("dry run OK (config parses, spec validates, optimizers build)");
        return Ok(());
    }
    let rt = Runtime::new(&cfg.artifacts_dir)?;
    let mut tr = Trainer::new(&rt, cfg)?;
    println!(
        "model {}: {:.2}M params, optimizer state {:.2} MB",
        tr.model.name,
        tr.n_params() as f64 / 1e6,
        tr.state_bytes() as f64 / 1e6,
    );
    println!("{}", tr.param_optimizer().describe());
    if let Some(placement) = tr.param_optimizer().describe_placement() {
        println!("{placement}");
    }
    let res = tr.train()?;
    println!("{} tensors updated via the HLO (Pallas) engine", res.hlo_updated_tensors);
    let first = res.losses.first().copied().unwrap_or(f64::NAN);
    let last = res.losses.last().copied().unwrap_or(f64::NAN);
    println!(
        "steps {} | loss {:.4} -> {:.4} | eval {:.4} (ppl {:.2}) | unstable: {}{} | {:.1}s",
        res.steps_done,
        first,
        last,
        res.final_eval,
        res.ppl(),
        res.unstable,
        res.reason.map(|r| format!(" ({r})")).unwrap_or_default(),
        res.wall_secs
    );
    if res.precision_transitions > 0 {
        println!(
            "precision transitions: {} | peak state {:.2} MB",
            res.precision_transitions,
            res.peak_state_bytes as f64 / 1e6
        );
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("fig2");
    match which {
        // Figure 2: the dynamic-tree data type — dump codebooks + bit semantics.
        "fig2" => {
            let mut csv = String::from("codebook,index,value\n");
            for cb in [dynamic_tree::dynamic_signed(), dynamic_tree::dynamic_unsigned()] {
                for (i, v) in analysis::codebook_dump(&cb) {
                    csv.push_str(&format!("{},{},{:e}\n", cb.name(), i, v));
                }
            }
            let path = repro::write_csv("fig2_codebooks.csv", &csv)?;
            println!("dynamic tree quantization (Figure 2)");
            for byte in [0b0_0000001u8, 0b0_0010110, 0b0_1011010, 0b1_0010110] {
                let (sign, zeros, frac) = dynamic_tree::describe_bit_pattern(byte);
                println!(
                    "  byte {byte:#010b}: sign {sign:+}, exponent 10^-{zeros}, fraction bits {frac:#b}"
                );
            }
            println!("-> {}", path.display());
        }
        // Figure 4: 256x256 usage + error maps for linear / dynamic /
        // blockwise-dynamic.
        "fig4" => {
            let n = args.get_usize("n", 1 << 20);
            let (m, r) = analysis::synth_adam_states(n, 0xF16_4);
            for (tag, format, blockwise) in [
                ("linear", Format::Linear, false),
                ("dynamic", Format::Dynamic, false),
                ("blockwise_dynamic", Format::Dynamic, true),
            ] {
                let (bm, br) = analysis::quantizer_pair(format, blockwise);
                let maps = analysis::adam_error_maps(&bm, &br, &m, &r, 1e-8);
                let path = repro::write_csv(&format!("fig4_{tag}.csv"), &maps.to_csv())?;
                println!(
                    "{tag:<18} mean abs err {:.4e}  mean rel err {:.3}  high-use/high-err overlap {:.3} -> {}",
                    maps.overall_abs(),
                    maps.overall_rel(),
                    maps.high_use_high_error_overlap(),
                    path.display()
                );
            }
        }
        // Figure 5: per-code Adam error distribution, dynamic vs quantile.
        "fig5" => {
            let n = args.get_usize("n", 1 << 20);
            let (m, r) = analysis::synth_adam_states(n, 0xF16_5);
            for (tag, format) in [("dynamic", Format::Dynamic), ("quantile", Format::Quantile)] {
                let (bm, br) = analysis::quantizer_pair(format, true);
                let rows = analysis::per_code_error(&bm, &br, &m, &r, 1e-8);
                let mut csv = String::from("norm_value,mean_abs_adam_err,usage\n");
                for (p, e, u) in rows {
                    csv.push_str(&format!("{p},{e:.6e},{u}\n"));
                }
                let path = repro::write_csv(&format!("fig5_{tag}.csv"), &csv)?;
                println!("{tag:<10} -> {}", path.display());
            }
        }
        // Figure 6: quantization maps for linear / dynamic / quantile.
        "fig6" => {
            let mut csv = String::from("codebook,index,value\n");
            for cb in [
                linear::linear_signed(),
                dynamic_tree::dynamic_signed(),
                quantile::quantile_normal(),
            ] {
                for (i, v) in analysis::codebook_dump(&cb) {
                    csv.push_str(&format!("{},{},{:e}\n", cb.name(), i, v));
                }
            }
            let path = repro::write_csv("fig6_quantization_maps.csv", &csv)?;
            println!("-> {}", path.display());
        }
        other => anyhow::bail!("unknown analysis {other:?}; known: fig2, fig4, fig5, fig6"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::new(args.get_or("artifacts", "artifacts"))?;
    let m = rt.manifest()?;
    println!("artifacts: {} | block size {}", rt.artifacts_dir().display(), m.block);
    println!("codebooks: {:?}", m.codebooks.keys().collect::<Vec<_>>());
    println!("models:");
    for model in &m.models {
        println!(
            "  {:<16} task={:<3} {:>8.2}M params, {} tensors, batch {} x seq {}",
            model.name,
            model.task,
            model.n_params as f64 / 1e6,
            model.params.len(),
            model.batch,
            model.seq_len
        );
    }
    for (kind, sizes) in &m.updates {
        println!("updates[{kind}]: {} sizes", sizes.len());
    }
    Ok(())
}
