//! Analytic training-memory model (Tables 1 & 2).
//!
//! Follows the paper's accounting: training memory = weights + gradients +
//! optimizer state + activations. The experiments keep weights/gradients
//! at 16-bit mixed precision and vary only the optimizer state:
//!   32-bit Adam  : 8 bytes/param
//!   32-bit Momentum: 4 bytes/param
//!   Adafactor(β1>0): 4 bytes/param (+ tiny factored second moment)
//!   8-bit Adam   : 2 bytes/param + 8/B bytes absmax overhead
//!   8-bit Momentum: 1 byte/param + 4/B
//!   4-bit Adam   : 1 byte/param + 8/B (two packed states at 0.5 + 4/B
//!                  bytes/element each, per Li et al. 2023)
//! Activation memory is estimated for batch size one at the model's native
//! sequence length (Table 2 uses batch 1).

use crate::quant::BLOCK;

/// Optimizer-state families the tables compare.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptStateKind {
    Adam32,
    Momentum32,
    Adafactor,
    Adam8,
    Momentum8,
    Adam4,
}

impl OptStateKind {
    pub fn bytes_per_param(&self) -> f64 {
        match self {
            OptStateKind::Adam32 => 8.0,
            OptStateKind::Momentum32 => 4.0,
            OptStateKind::Adafactor => 4.0,
            OptStateKind::Adam8 => 2.0 + 8.0 / BLOCK as f64,
            OptStateKind::Momentum8 => 1.0 + 4.0 / BLOCK as f64,
            // two packed 4-bit states: 2 × (0.5 + 4/B) bytes/element
            OptStateKind::Adam4 => 1.0 + 8.0 / BLOCK as f64,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptStateKind::Adam32 => "32-bit Adam",
            OptStateKind::Momentum32 => "32-bit Momentum",
            OptStateKind::Adafactor => "32-bit Adafactor",
            OptStateKind::Adam8 => "8-bit Adam",
            OptStateKind::Momentum8 => "8-bit Momentum",
            OptStateKind::Adam4 => "4-bit Adam",
        }
    }
}

/// A named pretrained model for the Table 2 sweep.
#[derive(Clone, Copy, Debug)]
pub struct NamedModel {
    pub name: &'static str,
    pub params: f64,
    pub d_model: f64,
    pub n_layers: f64,
    pub seq_len: f64,
}

/// The model family of Table 2.
pub const KNOWN_MODELS: [NamedModel; 7] = [
    NamedModel { name: "RoBERTa-base (110M)", params: 110e6, d_model: 768.0, n_layers: 12.0, seq_len: 512.0 },
    NamedModel { name: "MT5-small (300M)", params: 300e6, d_model: 512.0, n_layers: 8.0, seq_len: 512.0 },
    NamedModel { name: "RoBERTa-large (355M)", params: 355e6, d_model: 1024.0, n_layers: 24.0, seq_len: 512.0 },
    NamedModel { name: "MT5-base (580M)", params: 580e6, d_model: 768.0, n_layers: 12.0, seq_len: 512.0 },
    NamedModel { name: "GPT-2-medium (762M)", params: 762e6, d_model: 1024.0, n_layers: 24.0, seq_len: 1024.0 },
    NamedModel { name: "MT5-large (1.2B)", params: 1.2e9, d_model: 1024.0, n_layers: 24.0, seq_len: 512.0 },
    NamedModel { name: "GPT-2-large (1.5B)", params: 1.5e9, d_model: 1280.0, n_layers: 36.0, seq_len: 1024.0 },
];

#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    /// bytes per weight (2 = fp16 mixed precision, the paper's setting)
    pub weight_bytes: f64,
    pub grad_bytes: f64,
    /// master fp32 weights kept by mixed-precision training
    pub master_weights: bool,
    pub batch: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel { weight_bytes: 2.0, grad_bytes: 2.0, master_weights: true, batch: 1.0 }
    }
}

impl MemoryModel {
    /// Optimizer-state bytes for `params` parameters.
    pub fn state_bytes(&self, params: f64, kind: OptStateKind) -> f64 {
        // Stable-embedding policy keeps ~2% of params in 32-bit state;
        // negligible at this granularity, ignored (paper does the same in
        // its GB-level accounting).
        params * kind.bytes_per_param()
    }

    /// Total training footprint in bytes (batch-1 activations).
    pub fn total_bytes(&self, m: &NamedModel, kind: OptStateKind) -> f64 {
        let w = m.params * self.weight_bytes;
        let g = m.params * self.grad_bytes;
        let master = if self.master_weights { m.params * 4.0 } else { 0.0 };
        let state = self.state_bytes(m.params, kind);
        // Activation estimate: ~12 · L · B · S · d bytes at fp16 with
        // checkpoint-free attention (a standard rough rule).
        let act = 12.0 * m.n_layers * self.batch * m.seq_len * m.d_model * 2.0;
        // CUDA context + workspace overhead.
        let overhead = 1.0e9;
        w + g + master + state + act + overhead
    }

    /// Memory saved vs 32-bit Adam, in GB (Table 1 "Mem saved").
    pub fn saved_vs_adam32_gb(&self, params: f64, kind: OptStateKind) -> f64 {
        (self.state_bytes(params, OptStateKind::Adam32) - self.state_bytes(params, kind)) / 1e9
    }

    /// Largest model from `KNOWN_MODELS` trainable within `budget_gb`.
    pub fn largest_finetunable(&self, budget_gb: f64, kind: OptStateKind) -> Option<NamedModel> {
        KNOWN_MODELS
            .iter()
            .filter(|m| self.total_bytes(m, kind) <= budget_gb * 1e9)
            .max_by(|a, b| a.params.partial_cmp(&b.params).unwrap())
            .copied()
    }

    /// Total footprint per device under ZeRO-1-style state placement:
    /// only the optimizer-state term divides by `shards` — weights,
    /// gradients, master copies, and activations stay replicated on every
    /// shard (that is what distinguishes stage 1 from ZeRO-2/3).
    pub fn total_bytes_sharded(&self, m: &NamedModel, kind: OptStateKind, shards: u32) -> f64 {
        let full = self.total_bytes(m, kind);
        let state = self.state_bytes(m.params, kind);
        full - state + state / shards.max(1) as f64
    }

    /// Largest model trainable within `budget_gb` when the optimizer state
    /// is spread across `shards` devices.
    pub fn largest_finetunable_sharded(
        &self,
        budget_gb: f64,
        kind: OptStateKind,
        shards: u32,
    ) -> Option<NamedModel> {
        KNOWN_MODELS
            .iter()
            .filter(|m| self.total_bytes_sharded(m, kind, shards) <= budget_gb * 1e9)
            .max_by(|a, b| a.params.partial_cmp(&b.params).unwrap())
            .copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_bytes_ratios() {
        let mm = MemoryModel::default();
        let p = 1e9;
        assert_eq!(mm.state_bytes(p, OptStateKind::Adam32), 8e9);
        let b8 = mm.state_bytes(p, OptStateKind::Adam8);
        assert!(b8 > 2e9 && b8 < 2.01e9, "{b8}");
        let b4 = mm.state_bytes(p, OptStateKind::Adam4);
        assert!(b4 > 1e9 && b4 < 1.01e9, "{b4}");
        // 4-bit saves ~7 GB/B params vs 32-bit Adam, ~1 GB more than 8-bit
        let saved4 = mm.saved_vs_adam32_gb(p, OptStateKind::Adam4);
        let saved8 = mm.saved_vs_adam32_gb(p, OptStateKind::Adam8);
        assert!(saved4 > 6.9 && saved4 < 7.1, "{saved4}");
        assert!(saved4 > saved8);
    }

    #[test]
    fn four_bit_admits_at_least_the_eight_bit_models() {
        let mm = MemoryModel::default();
        for budget in [6.0, 11.0, 24.0] {
            let p8 = mm
                .largest_finetunable(budget, OptStateKind::Adam8)
                .map(|m| m.params)
                .unwrap_or(0.0);
            let p4 = mm
                .largest_finetunable(budget, OptStateKind::Adam4)
                .map(|m| m.params)
                .unwrap_or(0.0);
            assert!(p4 >= p8, "budget {budget}: 4-bit {p4} vs 8-bit {p8}");
        }
    }

    #[test]
    fn paper_headline_gpt2_adam_state_is_about_11gb() {
        // §Intro: "Adam optimizer states for the largest GPT-2 ... are 11 GB"
        let mm = MemoryModel::default();
        let gb = mm.state_bytes(1.5e9, OptStateKind::Adam32) / 1e9;
        assert!((gb - 12.0).abs() < 2.0, "{gb}");
    }

    #[test]
    fn eight_bit_admits_larger_models_at_every_budget() {
        let mm = MemoryModel::default();
        for budget in [6.0, 11.0, 24.0] {
            let m32 = mm.largest_finetunable(budget, OptStateKind::Adam32);
            let m8 = mm.largest_finetunable(budget, OptStateKind::Adam8);
            let p32 = m32.map(|m| m.params).unwrap_or(0.0);
            let p8 = m8.map(|m| m.params).unwrap_or(0.0);
            assert!(p8 > p32, "budget {budget}: 8-bit {p8} vs 32-bit {p32}");
        }
    }

    #[test]
    fn totals_monotone_in_state_cost() {
        let mm = MemoryModel::default();
        let m = KNOWN_MODELS[2];
        let t32 = mm.total_bytes(&m, OptStateKind::Adam32);
        let taf = mm.total_bytes(&m, OptStateKind::Adafactor);
        let t8 = mm.total_bytes(&m, OptStateKind::Adam8);
        assert!(t32 > taf && taf > t8);
    }

    #[test]
    fn sharding_divides_only_the_state_term() {
        let mm = MemoryModel::default();
        let m = KNOWN_MODELS[6]; // GPT-2-large
        let full = mm.total_bytes(&m, OptStateKind::Adam32);
        let state = mm.state_bytes(m.params, OptStateKind::Adam32);
        let s4 = mm.total_bytes_sharded(&m, OptStateKind::Adam32, 4);
        // saved exactly 3/4 of the state, nothing else
        assert!((full - s4 - state * 0.75).abs() < 1.0, "{}", full - s4);
        // shards = 1 is a no-op
        assert_eq!(mm.total_bytes_sharded(&m, OptStateKind::Adam32, 1), full);
        // monotone in shard count
        assert!(mm.total_bytes_sharded(&m, OptStateKind::Adam32, 8) < s4);
        // a sharded run admits at least the unsharded models at any budget
        for budget in [6.0, 11.0, 24.0] {
            let p1 = mm
                .largest_finetunable(budget, OptStateKind::Adam8)
                .map(|m| m.params)
                .unwrap_or(0.0);
            let p4 = mm
                .largest_finetunable_sharded(budget, OptStateKind::Adam8, 4)
                .map(|m| m.params)
                .unwrap_or(0.0);
            assert!(p4 >= p1, "budget {budget}: sharded {p4} vs {p1}");
        }
    }
}
