//! Model-side substrates: the analytic memory-footprint model used for
//! Table 1's "Mem saved" column and Table 2's largest-finetunable-model
//! analysis.

pub mod memory;

pub use memory::{MemoryModel, NamedModel, OptStateKind, KNOWN_MODELS};
