//! Compile-time stand-in for the `xla` crate when the `pjrt` feature is
//! disabled.
//!
//! Mirrors exactly the slice of the xla-rs API this crate uses, so every
//! target still builds offline (no XLA/PJRT toolchain); each entry point
//! fails at runtime with a clear message instead. The native engine
//! (`Engine::Native` over `optim::*`) never touches these types — only the
//! AOT forward/backward artifacts and the `Engine::Hlo` optimizer path do.

use std::path::Path;

/// The error every stubbed entry point returns.
pub const PJRT_DISABLED: &str = "bitopt8 was built without the `pjrt` feature: PJRT/XLA execution \
     (the AOT forward/backward artifacts and Engine::Hlo) is unavailable. \
     Rebuild with `cargo build --features pjrt`.";

pub struct Error(pub &'static str);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn err<T>() -> Result<T, Error> {
    Err(Error(PJRT_DISABLED))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        err()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        err()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        err()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    // the type parameter mirrors xla-rs (`execute::<Literal>`); unused here
    #[allow(clippy::extra_unused_type_parameters)]
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        err()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        err()
    }
}

/// Only the variant this crate constructs.
pub enum ElementType {
    U8,
}

#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        err()
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        err()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        err()
    }

    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        err()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        err()
    }
}
