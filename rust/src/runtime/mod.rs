//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate exactly as /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//! One compiled executable per artifact, cached by name.
//!
//! The `xla` crate (and with it the whole PJRT toolchain) sits behind the
//! optional `pjrt` cargo feature. Without it, [`pjrt_stub`] supplies the
//! same API surface with every entry point returning a clear runtime error,
//! so offline builds compile every target and the native engine keeps
//! working.

pub mod manifest;

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;
#[cfg(not(feature = "pjrt"))]
use pjrt_stub as xla;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Result};

pub use manifest::{Manifest, ModelEntry, ParamEntry};
/// The literal type the coordinator traffics in (real or stubbed).
#[cfg(feature = "pjrt")]
pub use xla::Literal;
/// The literal type the coordinator traffics in (real or stubbed).
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::Literal;

/// A loaded PJRT client plus an executable cache over an artifact dir.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// CPU PJRT client over an artifacts directory.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load the manifest written by `python/compile/aot.py`.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(self.dir.join("manifest.json"))
    }

    /// Compile (or fetch from cache) an artifact by file name.
    pub fn load(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs; outputs are the elements of
    /// the return tuple (aot.py lowers everything with return_tuple=True).
    pub fn run(&self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(file)?;
        run_exe(&exe, inputs)
    }
}

/// Execute a compiled executable; unpack the result tuple.
pub fn run_exe(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let outs = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = outs
        .first()
        .and_then(|d| d.first())
        .ok_or_else(|| anyhow!("no output buffer"))?
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
}

// ------------------------------------------------------------- conversions

/// f32 slice -> rank-1 literal.
pub fn lit_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// i32 matrix -> rank-2 literal.
pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(v.len(), rows * cols);
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// i32 slice -> rank-1 literal.
pub fn lit_i32(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// u8 slice -> rank-1 literal (optimizer state codes).
pub fn lit_u8(v: &[u8]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, &[v.len()], v)
        .map_err(|e| anyhow!("u8 literal: {e:?}"))
}

/// f32 slice -> rank-N literal with explicit dims.
pub fn lit_f32_shaped(v: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(v.len(), n);
    let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(v).reshape(&dims64).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// literal -> Vec<f32> (any shape, flattened).
pub fn f32_of(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

/// literal -> Vec<u8>.
pub fn u8_of(lit: &xla::Literal) -> Result<Vec<u8>> {
    lit.to_vec::<u8>().map_err(|e| anyhow!("to_vec u8: {e:?}"))
}

/// literal -> f32 scalar.
pub fn scalar_of(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("scalar: {e:?}"))
}

/// Initialize a parameter tensor from its manifest init spec. This is the
/// Rust half of the init contract with `model.param_specs` (python).
pub fn init_param(spec: &ParamEntry, rng: &mut crate::util::rng::Rng) -> Vec<f32> {
    let mut out = vec![0.0f32; spec.size];
    match spec.init.as_str() {
        "zeros" => {}
        "ones" => out.iter_mut().for_each(|v| *v = 1.0),
        "xavier_uniform" => {
            let fan_in = spec.shape.first().copied().unwrap_or(1) as f64;
            let fan_out = spec.shape.last().copied().unwrap_or(1) as f64;
            let a = (6.0 / (fan_in + fan_out)).sqrt();
            rng.fill_uniform_sym(&mut out, a);
        }
        s if s.starts_with("normal:") => {
            let std: f64 = s["normal:".len()..].parse().expect("init std");
            rng.fill_normal(&mut out, std);
        }
        other => panic!("unknown init spec {other:?}"),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn init_param_specs() {
        let mut rng = Rng::new(1);
        let mk = |init: &str, shape: Vec<usize>| ParamEntry {
            name: "t".into(),
            shape: shape.clone(),
            init: init.into(),
            is_embedding: false,
            size: shape.iter().product(),
            padded: 2048,
        };
        assert!(init_param(&mk("zeros", vec![8]), &mut rng).iter().all(|&v| v == 0.0));
        assert!(init_param(&mk("ones", vec![8]), &mut rng).iter().all(|&v| v == 1.0));
        let xu = init_param(&mk("xavier_uniform", vec![100, 50]), &mut rng);
        let bound = (6.0f32 / 150.0).sqrt();
        assert!(xu.iter().all(|&v| v.abs() <= bound));
        let nm = init_param(&mk("normal:2.0e0", vec![10000]), &mut rng);
        let std = (nm.iter().map(|&v| v as f64 * v as f64).sum::<f64>() / 1e4).sqrt();
        assert!((std - 2.0).abs() < 0.1, "{std}");
    }
}
