//! The artifact manifest — the contract between `python/compile/aot.py`
//! and the Rust coordinator. Parsed with the in-tree JSON parser.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub is_embedding: bool,
    pub size: usize,
    /// size rounded up to a quantization-block multiple (HLO state layout).
    pub padded: usize,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub preset: String,
    pub stable_embedding: bool,
    pub task: String,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub n_classes: usize,
    pub n_params: usize,
    pub train: String,
    pub eval: String,
    pub params: Vec<ParamEntry>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub block: usize,
    pub codebooks: BTreeMap<String, Vec<f32>>,
    pub models: Vec<ModelEntry>,
    /// optimizer kind -> tensor size -> artifact file
    pub updates: BTreeMap<String, BTreeMap<usize, String>>,
    /// parity-test artifacts: name -> (n, quant file, dequant file)
    pub parity: BTreeMap<String, (usize, String, String)>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let block = v.get("block").as_usize().ok_or_else(|| anyhow!("missing block"))?;

        let mut codebooks = BTreeMap::new();
        if let Some(obj) = v.get("codebooks").as_obj() {
            for (k, arr) in obj {
                let vals = arr
                    .as_arr()
                    .ok_or_else(|| anyhow!("codebook {k} not array"))?
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(f64::NAN) as f32)
                    .collect();
                codebooks.insert(k.clone(), vals);
            }
        }

        let mut models = Vec::new();
        for m in v.get("models").as_arr().unwrap_or(&[]) {
            let params = m
                .get("params")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|p| ParamEntry {
                    name: p.get("name").as_str().unwrap_or_default().to_string(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect(),
                    init: p.get("init").as_str().unwrap_or("zeros").to_string(),
                    is_embedding: p.get("is_embedding").as_bool().unwrap_or(false),
                    size: p.get("size").as_usize().unwrap_or(0),
                    padded: p.get("padded").as_usize().unwrap_or(0),
                })
                .collect();
            models.push(ModelEntry {
                name: m.get("name").as_str().unwrap_or_default().to_string(),
                preset: m.get("preset").as_str().unwrap_or_default().to_string(),
                stable_embedding: m.get("stable_embedding").as_bool().unwrap_or(false),
                task: m.get("task").as_str().unwrap_or("lm").to_string(),
                batch: m.get("batch").as_usize().unwrap_or(1),
                seq_len: m.get("seq_len").as_usize().unwrap_or(0),
                vocab: m.get("vocab").as_usize().unwrap_or(0),
                n_classes: m.get("n_classes").as_usize().unwrap_or(2),
                n_params: m.get("n_params").as_usize().unwrap_or(0),
                train: m.get("train").as_str().unwrap_or_default().to_string(),
                eval: m.get("eval").as_str().unwrap_or_default().to_string(),
                params,
            });
        }

        let mut updates = BTreeMap::new();
        if let Some(obj) = v.get("updates").as_obj() {
            for (kind, sizes) in obj {
                let mut inner = BTreeMap::new();
                if let Some(szobj) = sizes.as_obj() {
                    for (sz, file) in szobj {
                        if let (Ok(n), Some(f)) = (sz.parse::<usize>(), file.as_str()) {
                            inner.insert(n, f.to_string());
                        }
                    }
                }
                updates.insert(kind.clone(), inner);
            }
        }

        let mut parity = BTreeMap::new();
        if let Some(obj) = v.get("parity").as_obj() {
            for (k, p) in obj {
                parity.insert(
                    k.clone(),
                    (
                        p.get("n").as_usize().unwrap_or(0),
                        p.get("quant").as_str().unwrap_or_default().to_string(),
                        p.get("dequant").as_str().unwrap_or_default().to_string(),
                    ),
                );
            }
        }

        Ok(Manifest { block, codebooks, models, updates, parity })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest (have: {:?})",
                self.models.iter().map(|m| &m.name).collect::<Vec<_>>()))
    }

    /// HLO update artifact for an optimizer kind + tensor size, if built.
    pub fn update_artifact(&self, kind: &str, size: usize) -> Option<&str> {
        self.updates.get(kind).and_then(|m| m.get(&size)).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "block": 2048,
      "codebooks": {"dynamic_signed": [-1.0, 0.0, 1.0]},
      "models": [{
        "name": "nano", "preset": "nano", "stable_embedding": false,
        "task": "lm", "batch": 16, "seq_len": 64, "vocab": 512,
        "n_classes": 2, "n_params": 100,
        "train": "nano.train.hlo.txt", "eval": "nano.eval.hlo.txt",
        "params": [{"name": "embed.tok", "shape": [512, 64],
                    "init": "normal:1.25e-01", "is_embedding": true,
                    "size": 32768, "padded": 32768}]
      }],
      "updates": {"adam8": {"32768": "adam8_n32768.hlo.txt"}},
      "parity": {"quant_signed": {"n": 8192, "quant": "q.hlo.txt", "dequant": "d.hlo.txt"}}
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.block, 2048);
        assert_eq!(m.codebooks["dynamic_signed"].len(), 3);
        let model = m.model("nano").unwrap();
        assert_eq!(model.params[0].shape, vec![512, 64]);
        assert!(model.params[0].is_embedding);
        assert_eq!(m.update_artifact("adam8", 32768), Some("adam8_n32768.hlo.txt"));
        assert_eq!(m.update_artifact("adam8", 999), None);
        assert_eq!(m.parity["quant_signed"].0, 8192);
    }

    #[test]
    fn missing_model_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.model("nope").is_err());
    }
}
