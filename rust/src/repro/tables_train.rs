//! Training-based table generators: Tables 1, 3, 4, 7, 8 and Figure 3.
//!
//! Each reproduces the *comparison structure* of the paper's table on the
//! synthetic workloads (DESIGN.md §Substitutions): same optimizer grid,
//! same ablation axes, same reporting convention (median over seeds /
//! hyperparameter runs, instability percentage). Step counts and seed
//! counts are scaled to this testbed and CLI-overridable.

use anyhow::Result;

use crate::config::{parse_optim, RunConfig, Schedule};
use crate::coordinator::{median_over_seeds, run_config, RunResult};
use crate::data::glue::GLUE_TASKS;
use crate::optim::{Bits, OptimKind};
use crate::quant::Format;
use crate::runtime::Runtime;
use crate::util::args::Args;
use crate::util::stats::median;

fn runtime(args: &Args) -> Result<Runtime> {
    Runtime::new(args.get_or("artifacts", "artifacts"))
}

fn base(model: &str, steps: usize, seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.steps = steps;
    cfg.seed = seed;
    cfg.eval_every = 0; // evaluate once at the end
    cfg.eval_batches = 8;
    cfg.schedule = Schedule::WarmupLinear { warmup: steps / 10, total: steps };
    cfg
}

fn seeds(args: &Args, default: u64) -> Vec<u64> {
    let n = args.get_u64("seeds", default);
    (0..n).map(|i| 1000 + i * 17).collect()
}

/// One (setting × seeds) evaluation returning (median eval metric,
/// unstable %, median wall seconds, state bytes).
fn run_seeds(rt: &Runtime, mk: impl Fn(u64) -> RunConfig, seeds: &[u64]) -> Result<(f64, f64, f64, usize)> {
    let mut results: Vec<RunResult> = Vec::new();
    for &s in seeds {
        results.push(run_config(rt, mk(s))?);
    }
    let (med, unstable) = median_over_seeds(&results);
    let wall = median(&results.iter().map(|r| r.wall_secs).collect::<Vec<_>>());
    let bytes = results.first().map(|r| r.state_bytes).unwrap_or(0);
    Ok((med, unstable, wall, bytes))
}

// ---------------------------------------------------------------- Table 1
pub fn table1(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let steps = args.get_usize("steps", 250);
    let model = args.get_or("model", "tiny_stable");
    let cls_model = "cls_tiny";
    let seeds = seeds(args, 3);

    println!("Table 1 — 8-bit vs 32-bit optimizers (LM: {model}, {steps} steps; CLS: {cls_model})");
    println!(
        "{:<22} {:<5} {:>10} {:>9} {:>12}",
        "Optimizer", "Task", "Metric", "Time s", "Mem saved"
    );
    let mut csv = String::from("optimizer,task,metric,time_s,state_bytes,mem_saved_frac\n");

    // LM rows: Adam32 (reference), Adam8, Adafactor.
    let mut adam32_bytes = 0usize;
    let lm_rows: Vec<(&str, OptimKind, Bits, bool)> = vec![
        ("32-bit Adam", OptimKind::Adam, Bits::B32, true),
        ("32-bit Adafactor", OptimKind::Adafactor, Bits::B32, true),
        ("8-bit Adam", OptimKind::Adam, Bits::b8_dynamic(), true),
    ];
    for (label, kind, bits, emb32) in lm_rows {
        let (ppl, unstable, wall, bytes) = run_seeds(
            &rt,
            |s| {
                let mut cfg = base(model, steps, s);
                cfg.optim = parse_optim(kind.name(), bits_of(bits), "dynamic", true).unwrap();
                cfg.optim.lr = args.get_f64("lr", 1e-3) as f32;
                if emb32 && bits != Bits::B32 {
                    cfg.push_emb32();
                }
                cfg
            },
            &seeds,
        )?;
        if bits == Bits::B32 && kind == OptimKind::Adam {
            adam32_bytes = bytes;
        }
        let saved = (adam32_bytes.saturating_sub(bytes)) as f64 / 1e6;
        println!(
            "{:<22} {:<5} {:>7.2}ppl {:>9.1} {:>9.1} MB  (unstable {unstable:.0}%)",
            label,
            "LM",
            ppl.exp(),
            wall,
            saved
        );
        csv.push_str(&format!(
            "{label},LM,{:.4},{wall:.2},{bytes},{:.4}\n",
            ppl.exp(),
            saved
        ));
    }

    // CLS rows: Momentum32 vs Momentum8 (the ImageNet/MoCo analogue).
    let mut mom32_bytes = 0usize;
    for (label, bits) in [("32-bit Momentum", Bits::B32), ("8-bit Momentum", Bits::b8_dynamic())] {
        let (loss, unstable, wall, bytes) = run_seeds(
            &rt,
            |s| {
                let mut cfg = base(cls_model, steps, s);
                cfg.optim = parse_optim("momentum", bits_of(bits), "dynamic", true).unwrap();
                cfg.optim.lr = args.get_f64("cls-lr", 0.05) as f32;
                cfg
            },
            &seeds,
        )?;
        if bits == Bits::B32 {
            mom32_bytes = bytes;
        }
        // report accuracy: rerun? run_seeds returns eval loss; for CLS we
        // want accuracy — rerun one seed to read accuracy.
        let mut cfg = base(cls_model, steps, seeds[0]);
        cfg.optim = parse_optim("momentum", bits_of(bits), "dynamic", true).unwrap();
        cfg.optim.lr = args.get_f64("cls-lr", 0.05) as f32;
        let r = run_config(&rt, cfg)?;
        let acc = r.eval_accs.last().map(|&(_, a)| a).unwrap_or(f64::NAN);
        let saved = (mom32_bytes.saturating_sub(bytes)) as f64 / 1e6;
        println!(
            "{:<22} {:<5} {:>7.3}acc {:>9.1} {:>9.1} MB  (loss {loss:.3}, unstable {unstable:.0}%)",
            label, "CLS", acc, wall, saved
        );
        csv.push_str(&format!("{label},CLS,{acc:.4},{wall:.2},{bytes},{saved:.4}\n"));
    }

    let path = super::write_csv("table1.csv", &csv)?;
    println!("-> {}", path.display());
    Ok(())
}

fn bits_of(b: Bits) -> usize {
    b.bit_count() as usize
}

// ---------------------------------------------------------------- Table 3
pub fn table3(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let steps = args.get_usize("steps", 150);
    let preset = args.get_or("model", "nano");
    let stable_name = format!("{preset}_stable");
    // The paper's grid: ε, β1, β2 variations (plus small lr changes). The
    // default grid is a 9-combo subsample; --grid full gives all 27.
    let eps_grid = [1e-8f32, 1e-7, 1e-6];
    let b1_grid = [0.90f32, 0.87, 0.93];
    let b2_grid = [0.999f32, 0.99, 0.98];
    let full = args.get_or("grid", "sub") == "full";
    let mut combos: Vec<(f32, f32, f32)> = Vec::new();
    for (i, &eps) in eps_grid.iter().enumerate() {
        for (j, &b1) in b1_grid.iter().enumerate() {
            for (k, &b2) in b2_grid.iter().enumerate() {
                if full || (i + j + k) % 3 == 0 {
                    combos.push((eps, b1, b2));
                }
            }
        }
    }
    let lr = args.get_f64("lr", 4e-3) as f32;

    // (dynamic, blockwise, stable, 8bit)
    let settings: Vec<(&str, bool, bool, bool, bool)> = vec![
        ("32-bit Adam", false, false, false, false),
        ("32-bit Adam + StableEmb", false, false, true, false),
        ("8-bit Adam (linear, tensorwise)", false, false, false, true),
        ("8-bit Adam (linear) + StableEmb", false, false, true, true),
        ("8-bit Adam + Dynamic", true, false, false, true),
        ("8-bit Adam + Dynamic + StableEmb", true, false, true, true),
        ("8-bit Adam + Dynamic + Blockwise", true, true, false, true),
        ("8-bit Adam + Dyn + Block + Stable", true, true, true, true),
    ];

    println!(
        "Table 3 — ablation on {preset} LM ({} hyper combos × {} settings, {steps} steps, lr {lr})",
        combos.len(),
        settings.len()
    );
    println!("{:<36} {:>12} {:>12}", "Setting", "Unstable %", "Median ppl");
    let mut csv = String::from("setting,dynamic,blockwise,stable_emb,unstable_pct,median_ppl\n");

    for (label, dynamic, blockwise, stable, is8) in settings {
        let mut results = Vec::new();
        for (ci, &(eps, b1, b2)) in combos.iter().enumerate() {
            let mut cfg = base(if stable { &stable_name } else { preset }, steps, 500 + ci as u64);
            let format = if dynamic { Format::Dynamic } else { Format::Linear };
            cfg.optim = parse_optim("adam", if is8 { 8 } else { 32 }, format.name(), blockwise)?;
            cfg.optim.lr = lr;
            cfg.optim.eps = eps;
            cfg.optim.beta1 = b1;
            cfg.optim.beta2 = b2;
            if stable && is8 {
                cfg.push_emb32();
            }
            // grad clipping off: the paper's instability manifests as
            // exploding gradients; clipping would mask the ablation signal.
            cfg.grad_clip = 0.0;
            results.push(run_config(&rt, cfg)?);
        }
        let (med, unstable) = median_over_seeds(&results);
        let ppl = med.exp();
        println!("{label:<36} {unstable:>11.0}% {ppl:>12.2}");
        csv.push_str(&format!(
            "{label},{dynamic},{blockwise},{stable},{unstable:.1},{ppl:.3}\n"
        ));
    }
    let path = super::write_csv("table3.csv", &csv)?;
    println!("-> {} (paper: dynamic fixes general stability, blockwise fixes large-scale)", path.display());
    Ok(())
}

// ---------------------------------------------------------------- Table 4
pub fn table4(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let steps = args.get_usize("steps", 150);
    let seeds = seeds(args, 3);
    println!("Table 4 — GLUE-like breakdown (median acc over {} seeds, {steps} steps)", seeds.len());
    print!("{:<18}", "Model");
    for t in &GLUE_TASKS {
        print!("{:>7}", t.name);
    }
    println!("{:>7}", "Mean");
    let mut csv = String::from("optimizer,task,median_acc\n");

    for (label, kind, bits) in [
        ("32-bit Adam", "adam", 32),
        ("32-bit Adafactor", "adafactor", 32),
        ("8-bit Adam", "adam", 8),
    ] {
        print!("{label:<18}");
        let mut accs = Vec::new();
        for task in &GLUE_TASKS {
            let mut per_seed = Vec::new();
            for &s in &seeds {
                let mut cfg = base("cls_tiny", steps, s);
                cfg.optim = parse_optim(kind, bits, "dynamic", true)?;
                cfg.optim.lr = args.get_f64("lr", 1e-3) as f32;
                let mut tr = crate::coordinator::Trainer::new(&rt, cfg)?.with_glue_task(task)?;
                let r = tr.train()?;
                per_seed.push(r.eval_accs.last().map(|&(_, a)| a).unwrap_or(f64::NAN));
            }
            let med = median(&per_seed);
            accs.push(med);
            print!("{:>7.3}", med);
            csv.push_str(&format!("{label},{},{med:.4}\n", task.name));
        }
        println!("{:>7.3}", accs.iter().sum::<f64>() / accs.len() as f64);
    }
    let path = super::write_csv("table4.csv", &csv)?;
    println!("-> {}", path.display());
    Ok(())
}

// ---------------------------------------------------------------- Table 7
pub fn table7(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let steps = args.get_usize("steps", 200);
    let seeds = seeds(args, 3);
    let model = args.get_or("model", "nano_stable");
    println!("Table 7 — AdaGrad vs Adam ({model}, {steps} steps, {} seeds)", seeds.len());
    println!("{:<18} {:>14}", "Optimizer", "Valid ppl");
    let mut csv = String::from("optimizer,median_ppl,unstable_pct\n");
    for (label, kind, bits, lr) in [
        ("32-bit Adam", "adam", 32usize, 1e-3),
        ("8-bit Adam", "adam", 8, 1e-3),
        ("32-bit AdaGrad", "adagrad", 32, 1e-2),
        ("8-bit AdaGrad", "adagrad", 8, 1e-2),
    ] {
        let (med, unstable, _, _) = run_seeds(
            &rt,
            |s| {
                let mut cfg = base(model, steps, s);
                cfg.optim = parse_optim(kind, bits, "dynamic", true).unwrap();
                cfg.optim.lr = args.get_f64("lr", lr) as f32;
                if bits == 8 {
                    cfg.push_emb32();
                }
                cfg
            },
            &seeds,
        )?;
        println!("{label:<18} {:>14.2}  (unstable {unstable:.0}%)", med.exp());
        csv.push_str(&format!("{label},{:.3},{unstable:.1}\n", med.exp()));
    }
    let path = super::write_csv("table7.csv", &csv)?;
    println!("-> {} (paper: 8-bit matches Adam; AdaGrad gap persists in 8-bit)", path.display());
    Ok(())
}

// ---------------------------------------------------------------- Table 8
pub fn table8(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let steps = args.get_usize("steps", 200);
    let seeds = seeds(args, 3);
    let preset = args.get_or("model", "nano");
    let stable_name = format!("{preset}_stable");
    println!(
        "Table 8 — stable-embedding component ablation ({preset}, 8-bit Adam, {steps} steps)"
    );
    println!(
        "{:<12} {:<8} {:<14} {:>12}",
        "LayerNorm", "Xavier", "32-bit state", "Median ppl"
    );
    let mut csv = String::from("layer_norm,xavier,state32,median_ppl,unstable_pct\n");
    for ln in [false, true] {
        for xavier in [false, true] {
            for state32 in [false, true] {
                let (med, unstable, _, _) = run_seeds(
                    &rt,
                    |s| {
                        let mut cfg =
                            base(if ln { &stable_name } else { preset }, steps, s);
                        cfg.optim = parse_optim("adam", 8, "dynamic", true).unwrap();
                        cfg.optim.lr = args.get_f64("lr", 1e-3) as f32;
                        if state32 {
                            cfg.push_emb32();
                        }
                        // decouple init from the graph variant
                        cfg.emb_init_override = Some(if xavier {
                            "xavier_uniform".to_string()
                        } else {
                            // fairseq init N(0, 1/sqrt(d)); d from preset
                            "normal:1.25000000e-01".to_string()
                        });
                        cfg
                    },
                    &seeds,
                )?;
                println!(
                    "{:<12} {:<8} {:<14} {:>12.2}",
                    ln, xavier, state32, med.exp()
                );
                csv.push_str(&format!(
                    "{ln},{xavier},{state32},{:.3},{unstable:.1}\n",
                    med.exp()
                ));
            }
        }
    }
    let path = super::write_csv("table8.csv", &csv)?;
    println!("-> {} (paper: LayerNorm and Xavier both help; 32-bit state neutral at small scale)", path.display());
    Ok(())
}

// ---------------------------------------------------------------- Figure 3
pub fn fig3(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let steps = args.get_usize("steps", 150);
    let model = args.get_or("model", "nano");
    let stable = format!("{model}_stable");
    let seeds = seeds(args, 2);
    let base_lr = args.get_f64("lr", 2e-3) as f32;
    println!("Figure 3 — hyperparameter sensitivity, 32-bit Adam vs 8-bit Adam+StableEmb");
    let mut csv = String::from("axis,value,optimizer,median_ppl,unstable_pct\n");

    type Patch = Box<dyn Fn(&mut RunConfig)>;
    let mut axes: Vec<(&str, f64, Patch)> = Vec::new();
    for mult in [0.5f64, 0.75, 1.0, 1.5, 2.0] {
        axes.push((
            "lr",
            mult,
            Box::new(move |c: &mut RunConfig| c.optim.lr = base_lr * mult as f32),
        ));
    }
    for b1 in [0.85f64, 0.9, 0.95] {
        axes.push(("beta1", b1, Box::new(move |c: &mut RunConfig| c.optim.beta1 = b1 as f32)));
    }
    for b2 in [0.98f64, 0.99, 0.995, 0.999] {
        axes.push(("beta2", b2, Box::new(move |c: &mut RunConfig| c.optim.beta2 = b2 as f32)));
    }
    for eps in [1e-8f64, 1e-7, 1e-6] {
        axes.push(("eps", eps, Box::new(move |c: &mut RunConfig| c.optim.eps = eps as f32)));
    }

    for (axis, value, patch) in &axes {
        for (label, bits) in [("adam32", 32usize), ("adam8", 8)] {
            let mut results = Vec::new();
            for &s in &seeds {
                let mut cfg = base(if bits == 8 { &stable } else { model }, steps, s);
                cfg.optim = parse_optim("adam", bits, "dynamic", true)?;
                cfg.optim.lr = base_lr;
                cfg.optim.beta2 = 0.995;
                cfg.optim.eps = 1e-7;
                if bits == 8 {
                    cfg.push_emb32();
                }
                patch(&mut cfg);
                results.push(run_config(&rt, cfg)?);
            }
            let (med, unstable) = median_over_seeds(&results);
            csv.push_str(&format!(
                "{axis},{value},{label},{:.3},{unstable:.1}\n",
                med.exp()
            ));
        }
        println!("  swept {axis}={value}");
    }
    let path = super::write_csv("fig3.csv", &csv)?;
    println!("-> {} (paper: a steady small gap across all settings)", path.display());
    Ok(())
}
