//! Non-training table generators: Table 2 (memory model), Table 5
//! (optimizer runtime), Table 6 (quantization error).

use anyhow::Result;

use crate::model::memory::{MemoryModel, OptStateKind};
use crate::optim::{build, Bits, OptimConfig, OptimKind};
use crate::quant::error::{abs_quant_error, relative_adam_error};
use crate::quant::Format;
use crate::util::args::Args;
use crate::util::bench::{bench, black_box};
use crate::util::parallel;
use crate::util::rng::Rng;
use crate::util::stats::Welford;

/// Table 2: largest finetunable model per GPU-memory budget, batch size 1,
/// extended with the 4-bit Adam column (Li et al. 2023 footprint) and a
/// ZeRO-1-style 4-shard 8-bit Adam column (only the state term divides by
/// the shard count — weights/grads/master/activations stay replicated).
pub fn table2() -> Result<()> {
    let mm = MemoryModel::default();
    let shards = 4u32;
    println!("Table 2 — largest finetunable model (batch size 1)");
    println!(
        "{:<16} {:<28} {:<28} {:<28} {:<28}",
        "GPU size in GB", "32-bit Adam", "8-bit Adam", "4-bit Adam", "8-bit Adam (4 shards)"
    );
    let mut csv = String::from("gpu_gb,adam32,adam8,adam4,adam8_shard4\n");
    let largest = |budget: f64, kind: OptStateKind| {
        mm.largest_finetunable(budget, kind)
            .map(|m| m.name.to_string())
            .unwrap_or_else(|| "—".into())
    };
    for budget in [6.0, 11.0, 24.0] {
        let m32 = largest(budget, OptStateKind::Adam32);
        let m8 = largest(budget, OptStateKind::Adam8);
        let m4 = largest(budget, OptStateKind::Adam4);
        let m8s = mm
            .largest_finetunable_sharded(budget, OptStateKind::Adam8, shards)
            .map(|m| m.name.to_string())
            .unwrap_or_else(|| "—".into());
        println!("{budget:<16} {m32:<28} {m8:<28} {m4:<28} {m8s:<28}");
        csv.push_str(&format!("{budget},{m32},{m8},{m4},{m8s}\n"));
    }
    let path = super::write_csv("table2.csv", &csv)?;
    println!("-> {}", path.display());
    Ok(())
}

/// Table 5: isolated optimizer runtime, normalized to ms per update per 1B
/// parameters (we measure on a smaller tensor and scale linearly — the
/// update is strictly elementwise/streaming).
pub fn table5(args: &Args) -> Result<()> {
    let n: usize = args.get_usize("n", 4 << 20);
    let budget = std::time::Duration::from_millis(args.get_u64("budget-ms", 1500));
    let mut rng = Rng::new(7);
    let grads: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();

    println!("Table 5 — optimizer runtime, ms per update per 1B params (n = {n})");
    println!(
        "{:<12} {:>16} {:>16} {:>14}",
        "Optimizer", "32-bit (naive)", "32-bit (fused)", "8-bit (ours)"
    );
    let mut csv = String::from("optimizer,ms_32bit_naive,ms_32bit_fused,ms_8bit\n");

    for kind in [OptimKind::Adam, OptimKind::Momentum, OptimKind::Lamb, OptimKind::Lars] {
        let mut row = Vec::new();
        for (bits, single_thread) in [
            (Bits::B32, true),  // "32-bit PyTorch" analogue: single-core
            (Bits::B32, false), // "32-bit Apex" analogue: fused multicore
            (Bits::b8_dynamic(), false),
        ] {
            let mut cfg = OptimConfig::adam(1e-3, bits);
            cfg.kind = kind;
            let mut opt = build(&cfg, n, None);
            let mut params = vec![0.0f32; n];
            let label = format!("{}/{}", kind.name(), bits.describe());
            let run = || {
                bench(&label, budget, 200, || {
                    opt.step(black_box(&mut params), black_box(&grads));
                })
            };
            let res = if single_thread { parallel::with_threads(1, run) } else { run() };
            // scale to 1B params
            let ms_per_1b = res.median_ns * 1e-6 * (1e9 / n as f64);
            row.push(ms_per_1b);
        }
        println!(
            "{:<12} {:>16.1} {:>16.1} {:>14.1}",
            kind.name(),
            row[0],
            row[1],
            row[2]
        );
        csv.push_str(&format!("{},{:.2},{:.2},{:.2}\n", kind.name(), row[0], row[1], row[2]));
    }
    let path = super::write_csv("table5.csv", &csv)?;
    println!("-> {} (paper: 8-bit faster than fused 32-bit for every optimizer)", path.display());
    Ok(())
}

/// Table 6: mean relative Adam error and absolute quantization error for
/// the first Adam state across formats, mean ± SE over draws.
pub fn table6(args: &Args) -> Result<()> {
    let n: usize = args.get_usize("n", 1 << 20);
    let draws: usize = args.get_usize("draws", 5);
    println!("Table 6 — quantization error by format ({draws} draws of {n} states)");
    println!(
        "{:<18} {:>26} {:>30}",
        "Method", "Relative Adam Error", "Absolute Quantization Error"
    );
    let mut csv = String::from("method,rel_adam_err,rel_adam_se,abs_quant_err,abs_quant_se\n");
    for format in [
        Format::Linear,
        Format::Quantile,
        Format::InverseDynamic,
        Format::Dynamic,
    ] {
        let (bq_m, bq_r) = crate::analysis::quantizer_pair(format, true);
        let mut rel = Welford::new();
        let mut abs = Welford::new();
        for d in 0..draws {
            let (m, r) = crate::analysis::synth_adam_states(n, 0xBEEF + d as u64);
            rel.push(relative_adam_error(&bq_m, &bq_r, &m, &r, 1e-8).mean());
            abs.push(abs_quant_error(&bq_m, &m).mean());
        }
        println!(
            "{:<18} {:>17.2}% ± {:.2}% {:>20.3e} ± {:.1e}",
            format.name(),
            rel.mean() * 100.0,
            rel.std_err() * 100.0,
            abs.mean(),
            abs.std_err()
        );
        csv.push_str(&format!(
            "{},{:.6e},{:.6e},{:.6e},{:.6e}\n",
            format.name(),
            rel.mean(),
            rel.std_err(),
            abs.mean(),
            abs.std_err()
        ));
    }
    let path = super::write_csv("table6.csv", &csv)?;
    println!("-> {} (paper ordering: Linear >> Quantile > InvDynamic > Dynamic)", path.display());
    Ok(())
}
