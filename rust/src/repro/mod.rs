//! Table/figure regeneration harness — one function per table and figure
//! of the paper (DESIGN.md §4 experiment index). Each prints the rows to
//! stdout and writes a CSV under `results/`.

pub mod tables_static;
pub mod tables_train;

use std::path::Path;

use anyhow::Result;

/// Write a results CSV (creating `results/`).
pub fn write_csv(name: &str, content: &str) -> Result<std::path::PathBuf> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

/// Dispatch `bitopt8 repro <id>`.
pub fn run(id: &str, args: &crate::util::args::Args) -> Result<()> {
    match id {
        "table1" => tables_train::table1(args),
        "table2" => tables_static::table2(),
        "table3" => tables_train::table3(args),
        "table4" => tables_train::table4(args),
        "table5" => tables_static::table5(args),
        "table6" => tables_static::table6(args),
        "table7" => tables_train::table7(args),
        "table8" => tables_train::table8(args),
        "fig3" => tables_train::fig3(args),
        "all-static" => {
            tables_static::table2()?;
            tables_static::table5(args)?;
            tables_static::table6(args)
        }
        other => anyhow::bail!(
            "unknown experiment {other:?}; known: table1..table8, fig3, all-static \
             (fig2/fig4/fig5/fig6 live under `bitopt8 analyze`)"
        ),
    }
}
