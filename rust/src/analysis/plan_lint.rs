//! Static determinism linter for the phased plan IR.
//!
//! Every [`Phase`](crate::optim::Phase) of a [`StepPlan`] carries a
//! declared [`AccessSet`] — which param/grad/moment slices, named
//! [`Region::Slot`]s, and process-global [`Counter`]s its items and
//! combine touch. This module checks the declarations *statically*
//! (no plan execution, no threads) against the engine's execution
//! contract:
//!
//! * **(a) item disjointness** — no two items of one phase write
//!   overlapping elements of the same region;
//! * **(b) barrier ordering** — a region written in phase `k` is read
//!   only by phase `k`'s combine or by phases after `k`; a same-phase
//!   cross-item read of a written region, or a read of a region nothing
//!   has initialized, is a race;
//! * **(c) counter drains** — every counter a plan increments has a
//!   registered drain point (the trainer's JSONL step records), so
//!   counts can't leak silently into a later step's record;
//! * **(d) deterministic combines** — every combine declares a
//!   fixed-index fold (`util::reduce` order), never completion order;
//! * **(e) capability honesty** — the [`OptimKind`] capability registry
//!   (`supports_stability` / `supports_sharding` / `supports_bits`) is
//!   derived-checked against the plan shapes each kind actually builds.
//!
//! Entry points: [`lint_plan`] for one plan, [`lint_spec`] for every
//! distinct plan a config's [`OptimSpec`] builds over a tensor set, and
//! [`lint_matrix`] for the full kind × bits × stability matrix. The CLI
//! `--lint` mode runs the latter two over every shipped config; a CI
//! lane greps for its `PLAN_LINT ok` summary line.

use std::collections::BTreeSet;
use std::fmt;

use crate::optim::{
    self, validate_config, Bits, Counter, OptimConfig, OptimKind, OptimSpec, Region, StepPlan,
    TensorInfo,
};
use crate::quant::{CodeWidth, Format};

/// One violation of the plan IR's execution contract, with enough
/// context to name the offending phase/region in a test assertion.
#[derive(Clone, Debug, PartialEq)]
pub enum LintError {
    /// A phase shipped without any access declaration (rule a–d inputs
    /// all missing — the strict mode every shipped plan must pass).
    UndeclaredPhase { phase: usize },
    /// Rule (a): two distinct items of the phase write overlapping
    /// elements of `region`.
    OverlappingItemWrites { phase: usize, region: Region },
    /// Rule (b), same-phase half: an item reads elements another item
    /// of the same (unordered) phase writes.
    SamePhaseReadWrite { phase: usize, region: Region },
    /// Rule (b), cross-phase half: `region` is read before any phase
    /// wrote it and it was not declared preset.
    ReadBeforeWrite { phase: usize, region: Region },
    /// The read-only gradient contract: a declared write to `Grads`.
    WriteToReadOnly { phase: usize },
    /// The combine closure and the combine declaration disagree (one
    /// exists without the other).
    UndeclaredCombine { phase: usize },
    /// Rule (d): the combine does not declare a fixed-index fold.
    NonDeterministicCombine { phase: usize },
    /// Rule (c): `counter` is incremented but has no registered drain.
    UndrainedCounter { counter: Counter },
    /// Rule (e): the capability registry and the built plans disagree.
    CapabilityMismatch { kind: OptimKind, detail: String },
}

impl fmt::Display for LintError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LintError::UndeclaredPhase { phase } => {
                write!(f, "phase {phase}: no access declaration")
            }
            LintError::OverlappingItemWrites { phase, region } => {
                write!(f, "phase {phase}: overlapping item writes to {region:?}")
            }
            LintError::SamePhaseReadWrite { phase, region } => {
                write!(f, "phase {phase}: same-phase cross-item read/write race on {region:?}")
            }
            LintError::ReadBeforeWrite { phase, region } => {
                write!(f, "phase {phase}: reads {region:?} before any phase writes it")
            }
            LintError::WriteToReadOnly { phase } => {
                write!(f, "phase {phase}: declares a write to the read-only Grads")
            }
            LintError::UndeclaredCombine { phase } => {
                write!(f, "phase {phase}: combine closure and combine declaration disagree")
            }
            LintError::NonDeterministicCombine { phase } => {
                write!(f, "phase {phase}: combine does not declare a fixed-index fold")
            }
            LintError::UndrainedCounter { counter } => {
                write!(f, "counter {counter:?} is incremented but has no registered drain")
            }
            LintError::CapabilityMismatch { kind, detail } => {
                write!(f, "capability registry vs built plans for {kind:?}: {detail}")
            }
        }
    }
}

/// The counters with a registered drain point: the trainer drains all
/// three (`take_nonfinite_blocks`, `take_clip_events`,
/// `take_unorm_clips`) into every JSONL step record, on the
/// gradient-crash early exit, and between runs.
pub const ALL_DRAINS: [Counter; 3] =
    [Counter::NonfiniteBlocks, Counter::ClipEvents, Counter::UnormClips];

/// Lint one plan against the process's registered drains
/// ([`ALL_DRAINS`]).
pub fn lint_plan(plan: &StepPlan) -> Vec<LintError> {
    lint_plan_with_drains(plan, &ALL_DRAINS)
}

/// Lint one plan, with an explicit drain registry (tests pass an empty
/// one to exercise rule c).
pub fn lint_plan_with_drains(plan: &StepPlan, drains: &[Counter]) -> Vec<LintError> {
    let mut errors = Vec::new();
    // Regions holding defined data before phase 0 runs: the tensors the
    // engine hands in, plus every region any phase declares preset
    // (persistent optimizer state carried across steps).
    let mut initialized: BTreeSet<Region> =
        [Region::Params, Region::Grads, Region::State1, Region::State2].into_iter().collect();
    for k in 0..plan.n_phases() {
        if let Some(access) = plan.phase_access(k) {
            initialized.extend(access.presets.iter().copied());
        }
    }

    let mut counters: Vec<Counter> = Vec::new();
    for k in 0..plan.n_phases() {
        let Some(access) = plan.phase_access(k) else {
            errors.push(LintError::UndeclaredPhase { phase: k });
            continue;
        };
        let n_items = plan.phase_items(k);
        // (a) item-write disjointness.
        if let Some(region) = access.item_write_conflict(n_items) {
            errors.push(LintError::OverlappingItemWrites { phase: k, region });
        }
        // (b) same-phase half: cross-item read of a written region.
        if let Some(region) = access.item_read_write_race(n_items) {
            errors.push(LintError::SamePhaseReadWrite { phase: k, region });
        }
        // Read-only gradient contract.
        if access.writes_grads() {
            errors.push(LintError::WriteToReadOnly { phase: k });
        }
        // (b) cross-phase half: item reads of never-written regions.
        let read_regions: BTreeSet<Region> = access.reads.iter().map(|(r, _)| *r).collect();
        for region in read_regions {
            if !initialized.contains(&region) {
                errors.push(LintError::ReadBeforeWrite { phase: k, region });
            }
        }
        // Combine declaration consistency + (d) determinism + its reads
        // (a combine may read what this phase's items just wrote — the
        // barrier sequences it after them).
        match (&access.combine, plan.phase_has_combine(k)) {
            (None, false) => {}
            (None, true) | (Some(_), false) => {
                errors.push(LintError::UndeclaredCombine { phase: k });
            }
            (Some(c), true) => {
                if !c.deterministic {
                    errors.push(LintError::NonDeterministicCombine { phase: k });
                }
                let combine_reads: BTreeSet<Region> = c.reads.iter().map(|(r, _)| *r).collect();
                for region in combine_reads {
                    if !initialized.contains(&region)
                        && !access.writes.iter().any(|(w, _)| *w == region)
                    {
                        errors.push(LintError::ReadBeforeWrite { phase: k, region });
                    }
                }
            }
        }
        // Past this phase's barrier, its item and combine writes are
        // visible to every later phase.
        initialized.extend(access.writes.iter().map(|(r, _)| *r));
        if let Some(c) = &access.combine {
            initialized.extend(c.writes.iter().map(|(r, _)| *r));
        }
        counters.extend(access.all_counters());
    }

    // (c) every incremented counter needs a registered drain.
    counters.sort();
    counters.dedup();
    for counter in counters {
        if !drains.contains(&counter) {
            errors.push(LintError::UndrainedCounter { counter });
        }
    }
    errors
}

/// Claimed capabilities of one [`OptimKind`] — normally derived from
/// the registry ([`KindCaps::of`]); tests pass deliberately wrong
/// claims to prove [`lint_kind`] catches them.
#[derive(Clone, Copy, Debug)]
pub struct KindCaps {
    pub stability: bool,
    pub sharding: bool,
    pub bits8: bool,
    pub bits4: bool,
}

impl KindCaps {
    pub fn of(kind: OptimKind) -> KindCaps {
        KindCaps {
            stability: kind.supports_stability(),
            sharding: kind.supports_sharding(),
            bits8: kind.supports_8bit(),
            bits4: kind.supports_4bit(),
        }
    }
}

/// Every optimizer kind, in registry order.
pub const ALL_KINDS: [OptimKind; 8] = [
    OptimKind::Adam,
    OptimKind::AdamW,
    OptimKind::Momentum,
    OptimKind::Lamb,
    OptimKind::Lars,
    OptimKind::Adafactor,
    OptimKind::Adagrad,
    OptimKind::Sm3,
];

/// Tensor length used by the capability matrix (a few state blocks plus
/// an exact 64×64 factored shape).
const MATRIX_N: usize = 4096;

/// Rule (e) for one kind: cross-check the claimed `caps` against (1)
/// parse-time acceptance ([`validate_config`]) and (2) the shapes of
/// the plans the kind actually builds, over the bits × stability
/// matrix. Plan-IR violations (rules a–d) in any built plan are
/// reported too.
pub fn lint_kind(kind: OptimKind, caps: &KindCaps) -> Vec<LintError> {
    let mut errors = Vec::new();
    let bits_matrix = [
        Bits::B32,
        Bits::b8_dynamic(),
        Bits::b4_dynamic(),
        Bits::B8 { format: Format::Linear, blockwise: false },
    ];
    // (clip_percentile, max_unorm, skip_zeros) stability presets.
    let stability_matrix =
        [(0.0f32, 0.0f32, false), (95.0, 0.0, false), (0.0, 0.02, false), (95.0, 0.02, true)];
    for bits in bits_matrix {
        for (clip, unorm, skip) in stability_matrix {
            let mut cfg = OptimConfig::adam(0.001, bits);
            cfg.kind = kind;
            cfg.clip_percentile = clip;
            cfg.max_unorm = unorm;
            cfg.skip_zeros = skip;
            let bits_ok = match bits.quantized() {
                None => true,
                Some((_, _, CodeWidth::U8)) => caps.bits8,
                Some((_, _, CodeWidth::U4)) => caps.bits4,
            };
            let expected = bits_ok && (!cfg.stability_on() || caps.stability);
            let accepted = validate_config(&cfg).is_ok();
            if accepted != expected {
                errors.push(LintError::CapabilityMismatch {
                    kind,
                    detail: format!(
                        "validate_config {} {} with stability {:?}, but the capability \
                         claims imply {}",
                        if accepted { "accepts" } else { "rejects" },
                        bits.describe(),
                        (clip, unorm, skip),
                        if expected { "accept" } else { "reject" },
                    ),
                });
                continue;
            }
            if !accepted {
                continue;
            }
            for shape in [None, Some((64usize, 64usize))] {
                lint_built(kind, &cfg, shape, caps, &mut errors);
            }
        }
    }
    errors
}

/// Build one optimizer, take one plan, lint it (rules a–d), and
/// derive-check the plan's shape signature against the claimed caps:
/// grid-partitioned (factored-statistic) phases appear exactly for the
/// unshardable kinds on 2-D tensors, and each counter is declared
/// exactly when its feature is on.
fn lint_built(
    kind: OptimKind,
    cfg: &OptimConfig,
    shape: Option<(usize, usize)>,
    caps: &KindCaps,
    errors: &mut Vec<LintError>,
) {
    let n = MATRIX_N;
    let mut opt = optim::build(cfg, n, shape);
    let mut params = vec![0.0f32; n];
    let grads = vec![0.0f32; n];
    let plan = opt.plan(&mut params, &grads);
    errors.extend(lint_plan(&plan));

    let mut has_grid = false;
    let mut declared: BTreeSet<Counter> = BTreeSet::new();
    for k in 0..plan.n_phases() {
        if let Some(access) = plan.phase_access(k) {
            let mut spans = access.reads.iter().chain(access.writes.iter());
            has_grid |= spans.any(|(_, s)| s.is_grid());
            if let Some(c) = &access.combine {
                let mut spans = c.reads.iter().chain(c.writes.iter());
                has_grid |= spans.any(|(_, s)| s.is_grid());
            }
            declared.extend(access.all_counters());
        }
    }
    let mut mismatch = |detail: String| {
        errors.push(LintError::CapabilityMismatch { kind, detail });
    };
    let expect_grid = shape.is_some() && !caps.sharding;
    if has_grid != expect_grid {
        mismatch(format!(
            "plan for shape {shape:?} {} grid-partitioned phases, but supports_sharding = {}",
            if has_grid { "has" } else { "lacks" },
            caps.sharding,
        ));
    }
    let counter_rules = [
        (Counter::NonfiniteBlocks, cfg.bits.quantized().is_some(), "quantized state"),
        (Counter::ClipEvents, cfg.clip_percentile > 0.0, "clip_percentile > 0"),
        (Counter::UnormClips, cfg.max_unorm > 0.0, "max_unorm > 0"),
    ];
    for (counter, expected, why) in counter_rules {
        if declared.contains(&counter) != expected {
            mismatch(format!(
                "{} plan {} {counter:?}, but it should be declared iff {why}",
                cfg.describe(),
                if expected { "lacks" } else { "declares" },
            ));
        }
    }
}

/// Rule (e) over every kind with its registry-derived caps, plus rules
/// a–d over every plan the matrix builds.
pub fn lint_matrix() -> Vec<LintError> {
    let mut errors = Vec::new();
    for kind in ALL_KINDS {
        errors.extend(lint_kind(kind, &KindCaps::of(kind)));
    }
    errors
}

/// Result of linting every distinct plan an [`OptimSpec`] builds.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Distinct (group, size, shape) plans actually built and linted.
    pub plans: usize,
    pub errors: Vec<LintError>,
}

/// Lint every distinct plan `spec` builds over `tensors`: tensors are
/// resolved to their group config and deduplicated by
/// (group, size, shape) — same key, same plan shape.
pub fn lint_spec(spec: &OptimSpec, tensors: &[TensorInfo]) -> LintReport {
    let mut report = LintReport::default();
    let mut seen: BTreeSet<(usize, usize, Option<(usize, usize)>)> = BTreeSet::new();
    for t in tensors {
        let (cfg, group) = spec.resolve(&t.name);
        if !seen.insert((group, t.size, t.shape)) {
            continue;
        }
        let mut opt = optim::build(&cfg, t.size, t.shape);
        let mut params = vec![0.0f32; t.size];
        let grads = vec![0.0f32; t.size];
        let plan = opt.plan(&mut params, &grads);
        report.plans += 1;
        report.errors.extend(lint_plan(&plan));
    }
    report
}

/// Lint the plans a spec's optimizers rebuild after a *runtime width
/// transition* — the precision controller's promote/demote path. A
/// transition swaps the state buffers under the optimizer (`set_bits`),
/// so the next step's plan is built against a different layout than the
/// one [`lint_spec`] saw; this walks every distinct (group, size, shape)
/// plan through each width the kind supports and re-lints the rebuilt
/// plan. Dedup key matches `lint_spec`'s, with the target width added.
pub fn lint_transitions(spec: &OptimSpec, tensors: &[TensorInfo]) -> LintReport {
    let mut report = LintReport::default();
    let mut seen: BTreeSet<(usize, usize, Option<(usize, usize)>)> = BTreeSet::new();
    for t in tensors {
        let (cfg, group) = spec.resolve(&t.name);
        if !seen.insert((group, t.size, t.shape)) {
            continue;
        }
        if !cfg.kind.supports_8bit() {
            continue; // factored kinds cannot requantize at runtime
        }
        // the quantization template a transition keeps (the controller's
        // `quant_template`): the config's own, else blockwise dynamic
        let (format, blockwise) =
            cfg.bits.quantized().map(|(f, b, _)| (f, b)).unwrap_or((Format::Dynamic, true));
        for to in [4u32, 8, 32] {
            if to == cfg.bits.bit_count() || (to == 4 && !cfg.kind.supports_4bit()) {
                continue;
            }
            let to_bits = match to {
                32 => Bits::B32,
                8 => Bits::B8 { format, blockwise },
                _ => Bits::B4 { format, blockwise },
            };
            let mut opt = optim::build(&cfg, t.size, t.shape);
            if !opt.set_bits(&to_bits) {
                continue;
            }
            let mut params = vec![0.0f32; t.size];
            let grads = vec![0.0f32; t.size];
            let plan = opt.plan(&mut params, &grads);
            report.plans += 1;
            report.errors.extend(lint_plan(&plan));
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{AccessSet, BlockSteps, CombineAccess, Phase, Span};

    fn items<'a>() -> BlockSteps<'a> {
        BlockSteps::from_fn(2, |_| {})
    }

    fn plan_with<'a>(phase: Phase<'a>) -> StepPlan<'a> {
        let mut plan = StepPlan::new();
        plan.push_unchecked(phase);
        plan
    }

    #[test]
    fn rejects_overlapping_item_writes() {
        let access = AccessSet::new().write(Region::Slot("x"), Span::All { lo: 0, hi: 4 });
        let plan = plan_with(Phase::new(items()).with_access(access));
        let errors = lint_plan(&plan);
        assert!(
            errors.iter().any(|e| matches!(
                e,
                LintError::OverlappingItemWrites { phase: 0, region: Region::Slot("x") }
            )),
            "{errors:?}"
        );
    }

    #[test]
    fn rejects_same_phase_cross_item_read_of_written_region() {
        let access = AccessSet::new()
            .write(Region::Slot("x"), Span::Blocked { base: 0, block: 1, n: 2 })
            .read(Region::Slot("x"), Span::All { lo: 0, hi: 2 })
            .preset(Region::Slot("x"));
        let plan = plan_with(Phase::new(items()).with_access(access));
        let errors = lint_plan(&plan);
        assert!(
            errors.iter().any(|e| matches!(
                e,
                LintError::SamePhaseReadWrite { phase: 0, region: Region::Slot("x") }
            )),
            "{errors:?}"
        );
    }

    #[test]
    fn rejects_read_before_any_write() {
        let access = AccessSet::new().read(Region::Slot("y"), Span::All { lo: 0, hi: 1 });
        let plan = plan_with(Phase::new(items()).with_access(access));
        let errors = lint_plan(&plan);
        assert!(
            errors.iter().any(|e| matches!(
                e,
                LintError::ReadBeforeWrite { phase: 0, region: Region::Slot("y") }
            )),
            "{errors:?}"
        );
    }

    #[test]
    fn rejects_writes_to_gradients() {
        let access =
            AccessSet::new().write(Region::Grads, Span::Blocked { base: 0, block: 1, n: 2 });
        let plan = plan_with(Phase::new(items()).with_access(access));
        let errors = lint_plan(&plan);
        assert!(
            errors.iter().any(|e| matches!(e, LintError::WriteToReadOnly { phase: 0 })),
            "{errors:?}"
        );
    }

    #[test]
    fn rejects_undrained_counter() {
        let access = AccessSet::new()
            .write(Region::Params, Span::Blocked { base: 0, block: 1, n: 2 })
            .counter(Counter::NonfiniteBlocks);
        let plan = plan_with(Phase::new(items()).with_access(access));
        assert!(lint_plan(&plan).is_empty(), "drained counter must pass");
        let errors = lint_plan_with_drains(&plan, &[]);
        assert!(
            errors.iter().any(|e| matches!(
                e,
                LintError::UndrainedCounter { counter: Counter::NonfiniteBlocks }
            )),
            "{errors:?}"
        );
    }

    #[test]
    fn rejects_nondeterministic_combine() {
        let access = AccessSet::new()
            .write(Region::Slot("p"), Span::Blocked { base: 0, block: 1, n: 2 })
            .combine(
                CombineAccess::default()
                    .read(Region::Slot("p"), Span::All { lo: 0, hi: 2 })
                    .write(Region::Slot("s"), Span::All { lo: 0, hi: 1 }),
            );
        let plan = plan_with(Phase::with_combine(items(), || {}).with_access(access));
        let errors = lint_plan(&plan);
        assert!(
            errors.iter().any(|e| matches!(e, LintError::NonDeterministicCombine { phase: 0 })),
            "{errors:?}"
        );
    }

    #[test]
    fn rejects_combine_declaration_mismatch() {
        // A combine closure without a combine declaration...
        let access =
            AccessSet::new().write(Region::Slot("p"), Span::Blocked { base: 0, block: 1, n: 2 });
        let plan = plan_with(Phase::with_combine(items(), || {}).with_access(access));
        let errors = lint_plan(&plan);
        assert!(
            errors.iter().any(|e| matches!(e, LintError::UndeclaredCombine { phase: 0 })),
            "{errors:?}"
        );
        // ...and a combine declaration without a combine closure.
        let access = AccessSet::new()
            .write(Region::Slot("p"), Span::Blocked { base: 0, block: 1, n: 2 })
            .combine(CombineAccess::deterministic());
        let plan = plan_with(Phase::new(items()).with_access(access));
        let errors = lint_plan(&plan);
        assert!(
            errors.iter().any(|e| matches!(e, LintError::UndeclaredCombine { phase: 0 })),
            "{errors:?}"
        );
    }

    #[test]
    fn rejects_phase_without_declaration() {
        let plan = plan_with(Phase::new(items()));
        let errors = lint_plan(&plan);
        assert!(
            errors.iter().any(|e| matches!(e, LintError::UndeclaredPhase { phase: 0 })),
            "{errors:?}"
        );
    }

    #[test]
    fn barrier_ordering_accepts_later_phase_reads() {
        // Written in phase 0, read in phase 1: legal. Read in phase 0 of
        // a phase-1 write: rejected.
        let span = Span::Blocked { base: 0, block: 1, n: 2 };
        let w = AccessSet::new().write(Region::Slot("s"), span);
        let r = AccessSet::new().read(Region::Slot("s"), Span::All { lo: 0, hi: 2 });
        let mut ok = StepPlan::new();
        ok.push_unchecked(Phase::new(items()).with_access(w.clone()));
        ok.push_unchecked(Phase::new(items()).with_access(r.clone()));
        assert!(lint_plan(&ok).is_empty());
        let mut bad = StepPlan::new();
        bad.push_unchecked(Phase::new(items()).with_access(r));
        bad.push_unchecked(Phase::new(items()).with_access(w));
        let errors = lint_plan(&bad);
        assert!(
            errors.iter().any(|e| matches!(e, LintError::ReadBeforeWrite { phase: 0, .. })),
            "{errors:?}"
        );
    }

    #[test]
    fn capability_lies_are_detected() {
        // SM3 claims shardable: its factored 2-D plan's grid phases give
        // it away.
        let lying = KindCaps { sharding: true, ..KindCaps::of(OptimKind::Sm3) };
        let errors = lint_kind(OptimKind::Sm3, &lying);
        assert!(
            errors.iter().any(|e| matches!(e, LintError::CapabilityMismatch { .. })),
            "{errors:?}"
        );
        // Adafactor claims 8-bit support: validate_config's rejection
        // contradicts the claim.
        let lying = KindCaps { bits8: true, ..KindCaps::of(OptimKind::Adafactor) };
        let errors = lint_kind(OptimKind::Adafactor, &lying);
        assert!(
            errors.iter().any(|e| matches!(e, LintError::CapabilityMismatch { .. })),
            "{errors:?}"
        );
    }

    #[test]
    fn full_matrix_is_clean() {
        let errors = lint_matrix();
        assert!(errors.is_empty(), "{errors:#?}");
    }
}
