//! Static analysis and quantization-error analysis: [`plan_lint`]
//! statically verifies the phased plan IR's access declarations (the
//! CLI `--lint` mode), [`adam_error`] regenerates the data behind
//! Figures 2, 4, 5, 6 and Table 6 (Appendix D/F), and [`probe`] is the
//! kind-agnostic per-state quant-error probe feeding the runtime
//! precision controller (`optim/precision.rs`).

pub mod adam_error;
pub mod plan_lint;
pub mod probe;

pub use adam_error::{adam_error_maps, per_code_error, AdamErrorMaps};
pub use plan_lint::{lint_matrix, lint_plan, lint_spec, KindCaps, LintError, LintReport};
pub use probe::{resolution_error, roundtrip_error, QuantErrorStats};

use crate::quant::{BlockQuantizer, Codebook, Format, BLOCK};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Synthetic Adam-state sample mimicking LM training statistics: scales
/// vary by 3–5 orders of magnitude *across* tensors/blocks (§2.2's
/// observation), while values within a block share a tensor-local scale
/// with moderate lognormal spread — matching how real per-tensor state
/// distributions look (a block holds adjacent parameters of one tensor).
pub fn synth_adam_states(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut m = Vec::with_capacity(n);
    let mut r = Vec::with_capacity(n);
    let mut i = 0;
    while i < n {
        // per-block scales spanning the paper's 3–5 decades
        let m_scale = 10f64.powf(rng.uniform_range(-4.5, -1.5));
        let g_scale = 10f64.powf(rng.uniform_range(-4.0, -2.0));
        let end = (i + BLOCK).min(n);
        while i < end {
            m.push((rng.normal() * m_scale) as f32);
            // r is a smoothed sum of squares: strictly positive, with
            // lognormal within-block spread around the block scale.
            let spread = 10f64.powf(rng.normal() * 0.35);
            r.push(((g_scale * spread).powi(2)) as f32);
            i += 1;
        }
    }
    (m, r)
}

/// The quantizer pair (signed for m, unsigned for r) for a format.
pub fn quantizer_pair(format: Format, blockwise: bool) -> (BlockQuantizer, BlockQuantizer) {
    let block = if blockwise { BLOCK } else { usize::MAX };
    (
        BlockQuantizer::new(format.signed_codebook(), block),
        BlockQuantizer::new(format.unsigned_codebook(), block),
    )
}

/// Figure 2 / Figure 6 data: dump a codebook's 256 values (sorted).
pub fn codebook_dump(cb: &Codebook) -> Vec<(usize, f32)> {
    cb.values().iter().copied().enumerate().collect()
}

/// Convenience: a quantizer over an explicit codebook.
pub fn quantizer(cb: Codebook, blockwise: bool) -> BlockQuantizer {
    let block = if blockwise { BLOCK } else { usize::MAX };
    BlockQuantizer::new(Arc::new(cb), block)
}
