//! Figure 4 / Figure 5 analysis: where in the 256×256 code space Adam
//! updates live (usage histogram) and how large the quantization-induced
//! Adam error is per code (absolute + relative error maps).
//!
//! For each element we quantize both states, find the (code1, code2) cell,
//! and accumulate |u32−u8| and |u32−u8|/|u32| into that cell, where
//! u = m/(√r + ε) (Appendix D).
//!
//! The maps are built per block through the packed fast paths
//! ([`quantize_block_codes`]/[`dequantize_block_codes`]) with one block of
//! reusable scratch per state — no whole-tensor code or dequantized-value
//! allocations, so the analysis streams over tensors of any size at the
//! same peak memory.

use crate::quant::{dequantize_block_codes, quantize_block_codes, BlockQuantizer};

/// 256×256 maps, row = first-state code, col = second-state code.
pub struct AdamErrorMaps {
    pub n1: usize,
    pub n2: usize,
    pub usage: Vec<u64>,
    pub abs_err_sum: Vec<f64>,
    pub rel_err_sum: Vec<f64>,
}

impl AdamErrorMaps {
    pub fn cell(&self, c1: u8, c2: u8) -> usize {
        c1 as usize * self.n2 + c2 as usize
    }

    pub fn mean_abs(&self, c1: u8, c2: u8) -> f64 {
        let i = self.cell(c1, c2);
        if self.usage[i] == 0 {
            0.0
        } else {
            self.abs_err_sum[i] / self.usage[i] as f64
        }
    }

    /// Overall mean absolute Adam error (the scalar quoted in Appendix D).
    pub fn overall_abs(&self) -> f64 {
        let total: u64 = self.usage.iter().sum();
        if total == 0 {
            0.0
        } else {
            self.abs_err_sum.iter().sum::<f64>() / total as f64
        }
    }

    pub fn overall_rel(&self) -> f64 {
        let total: u64 = self.usage.iter().sum();
        if total == 0 {
            0.0
        } else {
            self.rel_err_sum.iter().sum::<f64>() / total as f64
        }
    }

    /// Overlap statistic plotted in Figure 4: usage-weighted share of
    /// error mass landing in high-usage cells. Lower = errors are rare.
    pub fn high_use_high_error_overlap(&self) -> f64 {
        let total_use: u64 = self.usage.iter().sum();
        let total_err: f64 = self.abs_err_sum.iter().sum();
        if total_use == 0 || total_err <= 0.0 {
            return 0.0;
        }
        // top-decile usage cells
        let mut by_use: Vec<usize> = (0..self.usage.len()).collect();
        by_use.sort_by_key(|&i| std::cmp::Reverse(self.usage[i]));
        let top = &by_use[..by_use.len() / 10];
        top.iter().map(|&i| self.abs_err_sum[i]).sum::<f64>() / total_err
    }

    /// CSV rows "c1,c2,usage,mean_abs,mean_rel" for plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("c1,c2,usage,mean_abs_err,mean_rel_err\n");
        for c1 in 0..self.n1 {
            for c2 in 0..self.n2 {
                let i = c1 * self.n2 + c2;
                if self.usage[i] == 0 {
                    continue;
                }
                let u = self.usage[i];
                out.push_str(&format!(
                    "{},{},{},{:.6e},{:.6e}\n",
                    c1,
                    c2,
                    u,
                    self.abs_err_sum[i] / u as f64,
                    self.rel_err_sum[i] / u as f64
                ));
            }
        }
        out
    }
}

/// Build the Figure 4 maps for a quantizer pair on given Adam states.
pub fn adam_error_maps(
    bq_m: &BlockQuantizer,
    bq_r: &BlockQuantizer,
    m: &[f32],
    r: &[f32],
    eps: f32,
) -> AdamErrorMaps {
    assert_eq!(m.len(), r.len());
    let n = m.len();
    let (n1, n2) = (bq_m.codebook.len(), bq_r.codebook.len());
    let mut maps = AdamErrorMaps {
        n1,
        n2,
        usage: vec![0; n1 * n2],
        abs_err_sum: vec![0.0; n1 * n2],
        rel_err_sum: vec![0.0; n1 * n2],
    };
    if n == 0 {
        return maps;
    }
    // One block of scratch per state, reused across blocks: packed codes
    // plus the dequantized values. The per-block results are identical to
    // whole-tensor quantize/dequantize (blocks are independent).
    let bm = bq_m.block.min(n);
    let br = bq_r.block.min(n);
    let (wm, wr) = (bq_m.width, bq_r.width);
    let mut mc = vec![0u8; wm.bytes_for(bm)];
    let mut rc = vec![0u8; wr.bytes_for(br)];
    let mut dm = vec![0.0f32; bm];
    let mut dr = vec![0.0f32; br];
    let (mut m_lo, mut m_hi) = (0usize, 0usize);
    let (mut r_lo, mut r_hi) = (0usize, 0usize);
    for i in 0..n {
        if i >= m_hi {
            m_lo = i;
            m_hi = (i + bm).min(n);
            let len = m_hi - m_lo;
            let bytes = &mut mc[..wm.bytes_for(len)];
            let am = quantize_block_codes(&bq_m.codebook, wm, &m[m_lo..m_hi], bytes);
            dequantize_block_codes(&bq_m.codebook, wm, bytes, am, &mut dm[..len]);
        }
        if i >= r_hi {
            r_lo = i;
            r_hi = (i + br).min(n);
            let len = r_hi - r_lo;
            let bytes = &mut rc[..wr.bytes_for(len)];
            let am = quantize_block_codes(&bq_r.codebook, wr, &r[r_lo..r_hi], bytes);
            dequantize_block_codes(&bq_r.codebook, wr, bytes, am, &mut dr[..len]);
        }
        let u32v = m[i] / (r[i].max(0.0).sqrt() + eps);
        let u8v = dm[i - m_lo] / (dr[i - r_lo].max(0.0).sqrt() + eps);
        let cell = maps.cell(wm.code_at(&mc, i - m_lo), wr.code_at(&rc, i - r_lo));
        maps.usage[cell] += 1;
        let abs = (u32v - u8v).abs() as f64;
        maps.abs_err_sum[cell] += abs;
        if u32v.abs() > 1e-12 {
            maps.rel_err_sum[cell] += abs / u32v.abs() as f64;
        }
    }
    maps
}

/// Figure 5: mean absolute Adam error per first-state code (256 buckets),
/// with the codes normalized to [-1, 1] by index.
pub fn per_code_error(
    bq_m: &BlockQuantizer,
    bq_r: &BlockQuantizer,
    m: &[f32],
    r: &[f32],
    eps: f32,
) -> Vec<(f32, f64, u64)> {
    let maps = adam_error_maps(bq_m, bq_r, m, r, eps);
    let n1 = maps.n1;
    (0..n1)
        .map(|c1| {
            let mut use_sum = 0u64;
            let mut err_sum = 0.0;
            for c2 in 0..maps.n2 {
                let i = c1 * maps.n2 + c2;
                use_sum += maps.usage[i];
                err_sum += maps.abs_err_sum[i];
            }
            let norm_pos = 2.0 * c1 as f32 / (n1 - 1) as f32 - 1.0;
            let mean = if use_sum == 0 { 0.0 } else { err_sum / use_sum as f64 };
            (norm_pos, mean, use_sum)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{quantizer_pair, synth_adam_states};
    use crate::quant::Format;

    #[test]
    fn maps_accumulate_all_elements() {
        let (m, r) = synth_adam_states(20_000, 1);
        let (bm, br) = quantizer_pair(Format::Dynamic, true);
        let maps = adam_error_maps(&bm, &br, &m, &r, 1e-8);
        assert_eq!(maps.usage.iter().sum::<u64>(), 20_000);
        assert!(maps.overall_abs().is_finite());
    }

    #[test]
    fn blockwise_dynamic_has_lower_overlap_than_linear() {
        // Figure 4's qualitative claim.
        let (m, r) = synth_adam_states(60_000, 2);
        let (bm_d, br_d) = quantizer_pair(Format::Dynamic, true);
        let (bm_l, br_l) = quantizer_pair(Format::Linear, true);
        let d = adam_error_maps(&bm_d, &br_d, &m, &r, 1e-8);
        let l = adam_error_maps(&bm_l, &br_l, &m, &r, 1e-8);
        assert!(
            d.overall_rel() < l.overall_rel(),
            "dynamic rel {} vs linear rel {}",
            d.overall_rel(),
            l.overall_rel()
        );
    }

    #[test]
    fn per_code_has_256_rows_and_positions_in_unit_range() {
        let (m, r) = synth_adam_states(10_000, 3);
        let (bm, br) = quantizer_pair(Format::Dynamic, true);
        let rows = per_code_error(&bm, &br, &m, &r, 1e-8);
        assert_eq!(rows.len(), 256);
        assert!(rows.iter().all(|&(p, _, _)| (-1.0..=1.0).contains(&p)));
    }

    #[test]
    fn csv_is_parsable() {
        let (m, r) = synth_adam_states(5_000, 4);
        let (bm, br) = quantizer_pair(Format::Dynamic, true);
        let csv = adam_error_maps(&bm, &br, &m, &r, 1e-8).to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap().split(',').count(), 5);
        assert!(csv.lines().count() > 10);
    }
}
