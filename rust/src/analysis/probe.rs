//! Kind-agnostic per-state quantization-error probes — the precision
//! controller's sensors, generalizing the Adam-only Figure-4/5 analysis in
//! [`super::adam_error`] to any optimizer's stored state tensors.
//!
//! Two complementary measurements:
//!
//! * [`resolution_error`] — how coarsely the *current* storage width
//!   resolves the live values. The stored state **is** the quantized value
//!   (a round-trip against itself is zero by the idempotency contract), so
//!   what can be measured is local codebook resolution: per element, half
//!   the gap to the nearest neighbouring level — scaled by the block
//!   absmax — relative to the element's dequantized magnitude. A gradient
//!   spike that inflates a block's absmax pushes mass down into coarse
//!   low-magnitude codes (and onto the zero code), raising this measure:
//!   the controller's promote signal.
//! * [`roundtrip_error`] — the error a state *would* suffer if stored at a
//!   narrower target width: stream each block through quantize/dequantize
//!   scratch at the target width and compare against the current values.
//!   The controller's demote guard.
//!
//! Both keep the `adam_error` streaming discipline: at most one block of
//! scratch per call, no whole-tensor code or value allocations.

use crate::optim::StateTensor;
use crate::quant::{dequantize_block_codes, quantize_block_codes, Codebook, CodeWidth, BLOCK};

/// Aggregate error statistics for one state tensor.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantErrorStats {
    /// Mean per-element relative error (each element capped at 1.0).
    pub mean_rel: f64,
    /// Largest single-element relative error (capped at 1.0).
    pub max_rel: f64,
    /// Fraction of elements sitting on the zero level of a block whose
    /// absmax is non-zero — for [`resolution_error`] the "crushed by an
    /// inflated absmax" share, for [`roundtrip_error`] the share of
    /// non-zero values the target width would collapse to zero.
    pub zero_frac: f64,
    /// Elements measured.
    pub elements: usize,
}

impl QuantErrorStats {
    /// Scalar promote score: resolution error plus crushed-to-zero mass,
    /// clamped to [0, 1]. Healthy 8-bit states sit near 0.02; healthy
    /// 4-bit near 0.3; spike-degraded blocks approach 1.
    pub fn score(&self) -> f64 {
        (self.mean_rel + self.zero_frac).min(1.0)
    }

    fn finish(sum: f64, max: f64, zeros: usize, n: usize) -> QuantErrorStats {
        QuantErrorStats {
            mean_rel: if n == 0 { 0.0 } else { sum / n as f64 },
            max_rel: max,
            zero_frac: if n == 0 { 0.0 } else { zeros as f64 / n as f64 },
            elements: n,
        }
    }
}

/// Half the gap from each codebook level to its nearest neighbour (the
/// level's resolution radius). Codebook values are sorted ascending.
fn half_gaps(cb: &Codebook) -> Vec<f64> {
    let vals = cb.values();
    (0..vals.len())
        .map(|c| {
            let below =
                if c > 0 { (vals[c] - vals[c - 1]) as f64 } else { f64::INFINITY };
            let above = if c + 1 < vals.len() {
                (vals[c + 1] - vals[c]) as f64
            } else {
                f64::INFINITY
            };
            0.5 * below.min(above)
        })
        .collect()
}

/// Resolution error of a quantized state at its *current* width; `None`
/// for 32-bit states (exact storage). Per element on a non-empty block
/// (absmax > 0): `min(1, half_gap(code) · absmax / |value|)`, with exact
/// zero-level elements contributing 0 to the mean but counted in
/// `zero_frac`. Streams over the stored codes directly — no scratch.
pub fn resolution_error(st: &StateTensor) -> Option<QuantErrorStats> {
    let (q, cb) = match st {
        StateTensor::Quant { q, codebook } => (q, codebook),
        StateTensor::F32(_) => return None,
    };
    let gaps = half_gaps(cb);
    let (mut sum, mut max) = (0.0f64, 0.0f64);
    let (mut zeros, mut n) = (0usize, 0usize);
    for b in 0..q.n_blocks() {
        let absmax = q.absmax[b] as f64;
        if absmax <= 0.0 {
            continue; // nothing stored in this block yet
        }
        let (lo, hi) = q.block_range(b);
        for i in lo..hi {
            let c = q.codes.get(i) as usize;
            let v = (cb.decode(c as u8) as f64 * absmax).abs();
            n += 1;
            if v == 0.0 {
                zeros += 1;
                continue; // zero is represented exactly
            }
            let rel = (gaps[c] * absmax / v).min(1.0);
            sum += rel;
            max = max.max(rel);
        }
    }
    Some(QuantErrorStats::finish(sum, max, zeros, n))
}

/// Round-trip error the state would suffer stored at `width` with
/// `target_cb`: per block, dequantize the current values into scratch
/// (32-bit states read in place), quantize at the target width, dequantize
/// again, and compare. Per element `min(1, |x − x̂| / |x|)`; exact-zero
/// inputs contribute 0; non-zero inputs that collapse to 0 count into
/// `zero_frac`.
pub fn roundtrip_error(
    st: &StateTensor,
    target_cb: &Codebook,
    width: CodeWidth,
) -> QuantErrorStats {
    let (mut sum, mut max) = (0.0f64, 0.0f64);
    let (mut zeros, mut n) = (0usize, 0usize);
    let mut measure = |xs: &[f32], codes: &mut [u8], hat: &mut [f32]| {
        let bytes = &mut codes[..width.bytes_for(xs.len())];
        let am = quantize_block_codes(target_cb, width, xs, bytes);
        dequantize_block_codes(target_cb, width, bytes, am, &mut hat[..xs.len()]);
        for (&x, &xh) in xs.iter().zip(hat.iter()) {
            n += 1;
            if x == 0.0 {
                continue;
            }
            if xh == 0.0 {
                zeros += 1;
            }
            let rel = (((x - xh).abs() as f64) / (x.abs() as f64)).min(1.0);
            sum += rel;
            max = max.max(rel);
        }
    };
    match st {
        StateTensor::F32(v) => {
            let block = BLOCK.min(v.len().max(1));
            let mut codes = vec![0u8; width.bytes_for(block)];
            let mut hat = vec![0.0f32; block];
            for xs in v.chunks(block) {
                measure(xs, &mut codes, &mut hat);
            }
        }
        StateTensor::Quant { q, codebook } => {
            let block = q.block.min(q.len.max(1));
            let src_w = q.width();
            let mut src = vec![0.0f32; block];
            let mut src_bytes = vec![0u8; src_w.bytes_for(block)];
            let mut codes = vec![0u8; width.bytes_for(block)];
            let mut hat = vec![0.0f32; block];
            for b in 0..q.n_blocks() {
                let (lo, hi) = q.block_range(b);
                let len = hi - lo;
                let (blo, bhi) = q.code_byte_range(b);
                src_bytes[..bhi - blo].copy_from_slice(&q.codes.as_bytes()[blo..bhi]);
                dequantize_block_codes(
                    codebook,
                    src_w,
                    &src_bytes[..bhi - blo],
                    q.absmax[b],
                    &mut src[..len],
                );
                measure(&src[..len], &mut codes, &mut hat);
            }
        }
    }
    QuantErrorStats::finish(sum, max, zeros, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{make_state, Bits};
    use crate::quant::Format;
    use crate::util::rng::Rng;

    fn synth(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
    }

    fn quant_state(bits: Bits, vals: &[f32]) -> crate::optim::StateTensor {
        let mut st = make_state(&bits, vals.len(), true);
        st.load_f32(vals);
        st
    }

    #[test]
    fn resolution_is_none_for_f32_and_coarser_at_4bit() {
        let vals = synth(8192, 1);
        assert!(resolution_error(&StateTensor::F32(vals.clone())).is_none());
        let s8 = resolution_error(&quant_state(Bits::b8_dynamic(), &vals)).unwrap();
        let s4 = resolution_error(&quant_state(Bits::b4_dynamic(), &vals)).unwrap();
        assert_eq!(s8.elements, 8192);
        assert!(s8.mean_rel > 0.0 && s8.mean_rel < s4.mean_rel, "{s8:?} vs {s4:?}");
        assert!(s4.mean_rel <= 0.5 + 1e-9);
    }

    #[test]
    fn inflated_absmax_raises_the_promote_score() {
        // One spiked element per block inflates absmax 1000x; everything
        // else is crushed toward the low codes / the zero level.
        let mut vals = synth(8192, 2);
        let calm = resolution_error(&quant_state(Bits::b4_dynamic(), &vals)).unwrap();
        for b in 0..vals.len() / 2048 {
            vals[b * 2048] = 100.0;
        }
        let spiked = resolution_error(&quant_state(Bits::b4_dynamic(), &vals)).unwrap();
        assert!(
            spiked.score() > calm.score(),
            "spiked {} vs calm {}",
            spiked.score(),
            calm.score()
        );
    }

    #[test]
    fn roundtrip_at_own_width_is_zero() {
        // q(dq(q(x))) == q(x): re-quantizing a state's own values at its
        // own width reproduces it exactly.
        let vals = synth(8192, 3);
        let st = quant_state(Bits::b8_dynamic(), &vals);
        let cb = Format::Dynamic.codebook(CodeWidth::U8, true);
        let s = roundtrip_error(&st, &cb, CodeWidth::U8);
        assert_eq!(s.mean_rel, 0.0, "{s:?}");
        assert_eq!(s.zero_frac, 0.0);
    }

    #[test]
    fn roundtrip_to_narrower_width_reports_loss() {
        let vals = synth(8192, 4);
        let st = StateTensor::F32(vals);
        let cb8 = Format::Dynamic.codebook(CodeWidth::U8, true);
        let cb4 = Format::Dynamic.codebook(CodeWidth::U4, true);
        let s8 = roundtrip_error(&st, &cb8, CodeWidth::U8);
        let s4 = roundtrip_error(&st, &cb4, CodeWidth::U4);
        assert!(s8.mean_rel > 0.0);
        assert!(s4.mean_rel > s8.mean_rel, "{s4:?} vs {s8:?}");
    }
}
