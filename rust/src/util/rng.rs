//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so we implement the small set of
//! generators the experiments need: SplitMix64 for seeding, xoshiro256++ as
//! the workhorse generator, Box–Muller normals, and a Zipf sampler for the
//! synthetic corpus. All generators are fully deterministic from a `u64`
//! seed so every experiment in EXPERIMENTS.md is exactly replayable.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller normal.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Construct from a seed; state expanded with SplitMix64 per the
    /// xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-tensor / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Unbiased via rejection (Lemire-style).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std) f32s.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f64) {
        for v in out.iter_mut() {
            *v = (self.normal() * std) as f32;
        }
    }

    /// Fill a slice with U(-a, a) f32s.
    pub fn fill_uniform_sym(&mut self, out: &mut [f32], a: f64) {
        for v in out.iter_mut() {
            *v = self.uniform_range(-a, a) as f32;
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Serializable state (for checkpoints).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s, spare_normal: None }
    }
}

/// Zipf(α) sampler over {0, .., n-1} by inverse-CDF with a precomputed
/// cumulative table. The synthetic corpus uses this to mimic the highly
/// non-uniform token distribution the paper's stable embedding layer
/// addresses (Appendix C).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // binary search for first cdf[i] >= u
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).expect("cdf is finite"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let mut r = Rng::new(13);
        let z = Zipf::new(100, 1.2);
        let mut counts = vec![0usize; 100];
        for _ in 0..200_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn rng_state_roundtrip() {
        let mut a = Rng::new(5);
        a.next_u64();
        let mut b = Rng::from_state(a.state());
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
