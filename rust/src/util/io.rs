//! Binary serialization helpers for checkpoints and state dumps.
//!
//! Format: little-endian, length-prefixed sections. Simple, versioned, and
//! dependency-free (no serde in the offline registry).

use std::io::{Read, Write};

pub fn write_u32<W: Write>(w: &mut W, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}
pub fn write_u64<W: Write>(w: &mut W, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}
pub fn write_f32_slice<W: Write>(w: &mut W, v: &[f32]) -> std::io::Result<()> {
    write_u64(w, v.len() as u64)?;
    // Write in chunks to avoid per-element syscalls.
    let mut buf = Vec::with_capacity(v.len().min(1 << 16) * 4);
    for chunk in v.chunks(1 << 14) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}
pub fn write_f64<W: Write>(w: &mut W, v: f64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}
pub fn write_f64_slice<W: Write>(w: &mut W, v: &[f64]) -> std::io::Result<()> {
    write_u64(w, v.len() as u64)?;
    let mut buf = Vec::with_capacity(v.len().min(1 << 16) * 8);
    for chunk in v.chunks(1 << 13) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}
pub fn write_u8_slice<W: Write>(w: &mut W, v: &[u8]) -> std::io::Result<()> {
    write_u64(w, v.len() as u64)?;
    w.write_all(v)
}
pub fn write_str<W: Write>(w: &mut W, s: &str) -> std::io::Result<()> {
    write_u8_slice(w, s.as_bytes())
}

pub fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
pub fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
pub fn read_f32_slice<R: Read>(r: &mut R) -> std::io::Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
pub fn read_f64<R: Read>(r: &mut R) -> std::io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}
pub fn read_f64_slice<R: Read>(r: &mut R) -> std::io::Result<Vec<f64>> {
    let n = read_u64(r)? as usize;
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}
pub fn read_u8_slice<R: Read>(r: &mut R) -> std::io::Result<Vec<u8>> {
    let n = read_u64(r)? as usize;
    let mut bytes = vec![0u8; n];
    r.read_exact(&mut bytes)?;
    Ok(bytes)
}
pub fn read_str<R: Read>(r: &mut R) -> std::io::Result<String> {
    let bytes = read_u8_slice(r)?;
    String::from_utf8(bytes).map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

/// Write a CSV row (no quoting needed for our numeric tables).
pub fn csv_row(cols: &[String]) -> String {
    cols.join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut buf = Vec::new();
        write_u32(&mut buf, 0xDEADBEEF).unwrap();
        write_u64(&mut buf, u64::MAX - 3).unwrap();
        write_f32_slice(&mut buf, &[1.5, -2.25, 0.0, f32::MIN_POSITIVE]).unwrap();
        write_f64(&mut buf, -0.1f64).unwrap();
        write_f64_slice(&mut buf, &[1e-300, 2.5, f64::MAX]).unwrap();
        write_u8_slice(&mut buf, &[1, 2, 3]).unwrap();
        write_str(&mut buf, "hello/путь").unwrap();

        let mut r = buf.as_slice();
        assert_eq!(read_u32(&mut r).unwrap(), 0xDEADBEEF);
        assert_eq!(read_u64(&mut r).unwrap(), u64::MAX - 3);
        assert_eq!(read_f32_slice(&mut r).unwrap(), vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
        assert_eq!(read_f64(&mut r).unwrap(), -0.1f64);
        assert_eq!(read_f64_slice(&mut r).unwrap(), vec![1e-300, 2.5, f64::MAX]);
        assert_eq!(read_u8_slice(&mut r).unwrap(), vec![1, 2, 3]);
        assert_eq!(read_str(&mut r).unwrap(), "hello/путь");
        assert!(r.is_empty());
    }

    #[test]
    fn truncated_input_errors() {
        let mut buf = Vec::new();
        write_f32_slice(&mut buf, &[1.0, 2.0]).unwrap();
        buf.truncate(buf.len() - 1);
        assert!(read_f32_slice(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn large_f32_slice_roundtrip() {
        let v: Vec<f32> = (0..100_000).map(|i| i as f32 * 0.5).collect();
        let mut buf = Vec::new();
        write_f32_slice(&mut buf, &v).unwrap();
        assert_eq!(read_f32_slice(&mut buf.as_slice()).unwrap(), v);
    }
}
