//! The canonical deterministic two-phase reduction.
//!
//! Every tensor-wide reduction in the optimizer engine (LARS/LAMB trust
//! ratios, Adafactor's RMS clip, factored statistics) is computed the same
//! way: **phase 1** produces one partial per fixed-size chunk (serial,
//! in-order, f64 accumulation), **phase 2** folds the partials in chunk
//! order. Both phases are order-fixed, so the result is bit-identical no
//! matter how the chunk partials are scheduled across threads — the same
//! contract the block-kernel engine gives elementwise updates.
//!
//! The fused engine runs phase 1 as pool items inside its per-step batch
//! (`optim::state::StepPlan`); [`l2_norm`] is the standalone convenience
//! that runs both phases immediately on the pool.

use crate::util::parallel;

/// Chunk size of the canonical reduction: the quantization block size, so
/// that reduction partials line up one-to-one with the engine's block work
/// items (the phased plans' single-writer contract depends on this).
pub const CHUNK: usize = crate::quant::BLOCK;

/// Number of partials for a tensor of `len` elements.
pub fn n_chunks(len: usize) -> usize {
    len.div_ceil(CHUNK).max(1)
}

/// Element range `[lo, hi)` of chunk `c`.
pub fn chunk_bounds(len: usize, c: usize) -> (usize, usize) {
    let lo = c * CHUNK;
    (lo.min(len), (lo + CHUNK).min(len))
}

/// Phase-1 kernel: in-order f64 sum of squares of one chunk.
pub fn sum_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| v as f64 * v as f64).sum::<f64>()
}

/// Phase-2 kernel: fold partials in chunk order (the fixed order is what
/// makes the two-phase reduction deterministic at every thread count).
pub fn fold(partials: &[f64]) -> f64 {
    partials.iter().sum::<f64>()
}

/// ‖x‖₂ via the canonical two-phase reduction, phase 1 parallel on the
/// worker pool.
pub fn l2_norm(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let partials = parallel::par_map(n_chunks(x.len()), |c| {
        let (lo, hi) = chunk_bounds(x.len(), c);
        sum_sq(&x[lo..hi])
    });
    fold(&partials).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn chunk_bounds_cover_exactly() {
        for len in [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 7] {
            let nc = n_chunks(len);
            let mut covered = 0usize;
            for c in 0..nc {
                let (lo, hi) = chunk_bounds(len, c);
                assert_eq!(lo, covered.min(len));
                covered = hi;
            }
            assert_eq!(covered.min(len), len);
        }
    }

    #[test]
    fn l2_norm_matches_naive() {
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..200_000).map(|_| rng.normal() as f32).collect();
        let naive: f64 = x.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
        assert!((l2_norm(&x) - naive).abs() < 1e-6 * naive);
    }

    #[test]
    fn l2_norm_is_thread_count_invariant() {
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
        let one = parallel::with_threads(1, || l2_norm(&x));
        let four = parallel::with_threads(4, || l2_norm(&x));
        assert_eq!(one.to_bits(), four.to_bits());
    }

    #[test]
    fn two_phase_equals_standalone() {
        // The fused engine computes partials itself and folds them; that
        // must equal l2_norm exactly.
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..3 * CHUNK + 123).map(|_| rng.normal() as f32).collect();
        let partials: Vec<f64> = (0..n_chunks(x.len()))
            .map(|c| {
                let (lo, hi) = chunk_bounds(x.len(), c);
                sum_sq(&x[lo..hi])
            })
            .collect();
        assert_eq!(fold(&partials).sqrt().to_bits(), l2_norm(&x).to_bits());
    }
}
