//! Minimal JSON parser + writer (serde_json stand-in).
//!
//! Parses the artifact manifest emitted by `python/compile/aot.py` and
//! writes JSONL metrics. Supports the full JSON grammar except `\u` escapes
//! beyond the BMP surrogate pairing (sufficient for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) so output is canonical.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` convenience; Null if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // ---- writer ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for metric records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"artifacts":[{"name":"train_step","path":"artifacts/a.hlo.txt","params":[{"shape":[2,3],"init":"xavier"}],"block":2048,"ok":true,"x":null}]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(
            v.get("artifacts").as_arr().unwrap()[0].get("name").as_str(),
            Some("train_step")
        );
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(Json::parse("2048").unwrap().as_usize(), Some(2048));
    }

    #[test]
    fn strings_with_escapes() {
        let v = Json::parse(r#""a\nb\"c\\dA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\"c\\dA"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3]]").unwrap();
        assert_eq!(v.as_arr().unwrap()[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }

    #[test]
    fn get_on_missing_key_is_null() {
        let v = Json::parse("{\"a\":1}").unwrap();
        assert_eq!(v.get("b"), &Json::Null);
    }
}
