//! Chunked data-parallelism over std scoped threads (rayon stand-in).
//!
//! The 8-bit optimizer hot loop is embarrassingly parallel over quantization
//! blocks; this module gives it multi-core scaling without external crates.
//! Block-wise quantization needs *no cross-core synchronization* (the
//! paper's §2.1 throughput argument), so a plain chunk split is exact.

/// Number of worker threads to use (capped, respects BITOPT8_THREADS).
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("BITOPT8_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, chunk)` over disjoint mutable chunks of `data`,
/// `chunk_len` elements each (last chunk may be short), across threads.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync + Send,
{
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = num_threads().min(n_chunks.max(1));
    if threads <= 1 || n_chunks <= 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    // Split the chunk index space evenly across threads; each thread walks
    // its own contiguous run of chunks.
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    let per = chunks.len().div_ceil(threads);
    let mut groups: Vec<Vec<(usize, &mut [T])>> = Vec::new();
    let mut it = chunks.into_iter();
    loop {
        let g: Vec<_> = it.by_ref().take(per).collect();
        if g.is_empty() {
            break;
        }
        groups.push(g);
    }
    std::thread::scope(|s| {
        for group in groups {
            s.spawn(|| {
                for (i, c) in group {
                    f(i, c);
                }
            });
        }
    });
}

/// Run `f(i, a_chunk, b_chunk)` over paired disjoint chunks of two slices
/// with independent chunk lengths (e.g. 2048 codes + 1 absmax per block).
pub fn par_chunks_pair_mut<A: Send, B: Send, F>(
    a: &mut [A],
    ca: usize,
    b: &mut [B],
    cb: usize,
    f: F,
) where
    F: Fn(usize, &mut [A], &mut [B]) + Sync + Send,
{
    assert!(ca > 0 && cb > 0);
    let n_chunks = a.len().div_ceil(ca);
    assert_eq!(n_chunks.max(1), b.len().div_ceil(cb).max(1), "chunk counts differ");
    let pairs: Vec<(usize, (&mut [A], &mut [B]))> = a
        .chunks_mut(ca)
        .zip(b.chunks_mut(cb))
        .enumerate()
        .map(|(i, p)| (i, p))
        .collect();
    let threads = num_threads().min(pairs.len().max(1));
    if threads <= 1 || pairs.len() <= 1 {
        for (i, (pa, pb)) in pairs {
            f(i, pa, pb);
        }
        return;
    }
    let per = pairs.len().div_ceil(threads);
    let mut groups: Vec<Vec<(usize, (&mut [A], &mut [B]))>> = Vec::new();
    let mut it = pairs.into_iter();
    loop {
        let g: Vec<_> = it.by_ref().take(per).collect();
        if g.is_empty() {
            break;
        }
        groups.push(g);
    }
    std::thread::scope(|s| {
        for group in groups {
            s.spawn(|| {
                for (i, (pa, pb)) in group {
                    f(i, pa, pb);
                }
            });
        }
    });
}

/// Parallel map over an index range, collecting results in order.
pub fn par_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync + Send,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(threads);
    let slices: Vec<(usize, &mut [Option<R>])> = {
        let mut v = Vec::new();
        let mut rest = out.as_mut_slice();
        let mut start = 0;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            v.push((start, head));
            start += take;
            rest = tail;
        }
        v
    };
    let fref = &f;
    std::thread::scope(|s| {
        for (start, slot) in slices {
            s.spawn(move || {
                for (j, cell) in slot.iter_mut().enumerate() {
                    *cell = Some(fref(start + j));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

/// Run two independent closures on two disjoint mutable slices in parallel.
pub fn join<A: Send, B: Send>(fa: impl FnOnce() -> A + Send, fb: impl FnOnce() -> B + Send) -> (A, B) {
    let mut ra = None;
    let mut rb = None;
    std::thread::scope(|s| {
        s.spawn(|| ra = Some(fa()));
        rb = Some(fb());
    });
    (ra.unwrap(), rb.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_mut_touches_every_element_once() {
        let mut data = vec![0u32; 10_000];
        par_chunks_mut(&mut data, 257, |i, c| {
            for v in c.iter_mut() {
                *v += 1 + i as u32 * 0; // each element exactly once
            }
        });
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn par_chunks_chunk_indices_are_correct() {
        let mut data = vec![0usize; 1000];
        par_chunks_mut(&mut data, 100, |i, c| {
            for v in c.iter_mut() {
                *v = i;
            }
        });
        for (j, &v) in data.iter().enumerate() {
            assert_eq!(v, j / 100);
        }
    }

    #[test]
    fn par_map_ordering() {
        let out = par_map(1000, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn join_runs_both() {
        let (a, b) = join(|| 2 + 2, || "ok");
        assert_eq!(a, 4);
        assert_eq!(b, "ok");
    }

    #[test]
    fn par_chunks_short_input() {
        let mut data = vec![0u8; 3];
        par_chunks_mut(&mut data, 1024, |_, c| {
            for v in c.iter_mut() {
                *v = 7;
            }
        });
        assert_eq!(data, vec![7, 7, 7]);
    }
}
