//! Chunked data-parallelism over a persistent worker pool (rayon stand-in).
//!
//! The 8-bit optimizer hot loop is embarrassingly parallel over quantization
//! blocks; this module gives it multi-core scaling without external crates.
//! Block-wise quantization needs *no cross-core synchronization* (the
//! paper's §2.1 throughput argument), so a plain chunk split is exact.
//!
//! Unlike the original `std::thread::scope`-per-call design, workers are
//! spawned once (lazily, process-wide) and parked between calls, so the
//! per-`step()` dispatch cost is a mutex hand-off instead of OS thread
//! creation — the difference between "parallel for big tensors" and
//! "parallel for every tensor of a real model". `BITOPT8_THREADS` is
//! resolved once at pool init; use [`set_num_threads`]/[`with_threads`] to
//! change the degree at runtime (benches, parity tests).
//!
//! Two submission modes share the pool:
//!
//! * **Blocking** ([`run_indexed`] and friends) — the submitter
//!   participates and returns when the batch drains, which is what lets
//!   tasks borrow stack data.
//! * **Detached** ([`submit`] → [`BatchHandle`]) — the batch starts on the
//!   workers and the submitting thread keeps running (producing more
//!   tensors' gradients, driving serial PJRT dispatches) until it `wait`s.
//!   The streaming optimizer step is built on this.
//!
//! Several batches may be in flight at once (a queue, drained in
//! submission order); workers scan for unclaimed work and park when there
//! is none.
//!
//! Determinism: every primitive partitions work identically at every thread
//! count, and items never share mutable state, so results are bit-identical
//! whether they run inline, on 1 worker, or on 64.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

/// Raw mutable pointer the pool is allowed to share across threads.
///
/// Safety contract (on the code constructing one): distinct task indices
/// must touch disjoint memory through it, and the batch must not outlive
/// the pointee (the pool's submit call blocks until every task finished,
/// which is what makes borrowing stack data sound).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// A slice view that many phase closures may capture by copy, for code
/// (the phased optimizer plans) where the borrow checker cannot see that
/// accesses are disjoint-per-item within a phase and sequenced by a
/// barrier across phases.
///
/// Safety contract (on the code constructing one): within one phase,
/// distinct item indices touch disjoint ranges; a range written in phase k
/// is only read in phases > k (the engine's barrier provides the
/// happens-before edge); and every access happens while the source slice
/// outlives the plan (the pool blocks until each batch drains).
#[derive(Clone, Copy)]
pub struct Shared<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for Shared<T> {}
unsafe impl<T: Send> Sync for Shared<T> {}

impl<T> Shared<T> {
    pub fn new(s: &mut [T]) -> Shared<T> {
        Shared { ptr: s.as_mut_ptr(), len: s.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Shared view of `[lo, hi)`. The caller picks the result lifetime.
    ///
    /// # Safety
    /// The type-level contract: the range must not be written concurrently,
    /// the source slice must outlive the chosen `'r`, and `hi <= len`.
    pub unsafe fn range<'r>(&self, lo: usize, hi: usize) -> &'r [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts(self.ptr.add(lo), hi - lo)
    }

    /// Mutable view of `[lo, hi)`. The caller picks the result lifetime.
    ///
    /// # Safety
    /// The type-level contract: this item must be the range's only accessor
    /// within its phase, the source slice must outlive the chosen `'r`, and
    /// `hi <= len`.
    pub unsafe fn range_mut<'r>(&self, lo: usize, hi: usize) -> &'r mut [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }

    /// # Safety
    /// As [`Shared::range`], for the single element `i`.
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// # Safety
    /// As [`Shared::range_mut`], for the single element `i`.
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }
}

/// Lifetime-erased pointer to a borrowed batch closure. See [`SendPtr`]
/// contract.
#[derive(Clone, Copy)]
struct TaskFn(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskFn {}
unsafe impl Sync for TaskFn {}

/// The closure a batch runs. Blocking submissions borrow it from the
/// submitter's stack frame (the submitter outlives the batch by
/// construction); detached submissions move it into the batch,
/// lifetime-erased — the [`BatchHandle`] blocks in `wait`/`Drop` before the
/// erased borrows can end.
enum BatchFn {
    Borrowed(TaskFn),
    Owned(Box<dyn Fn(usize) + Sync + Send>),
}

impl BatchFn {
    /// # Safety
    /// Only call while a claimed index `< n` is in flight (see the comment
    /// in [`Batch::work`]): that is what keeps the pointee and any erased
    /// borrows alive.
    unsafe fn call(&self, i: usize) {
        match self {
            BatchFn::Borrowed(f) => (*f.0)(i),
            BatchFn::Owned(f) => f(i),
        }
    }
}

/// Lock helper that shrugs off poisoning: pool state stays consistent
/// across task panics (panics are caught per task and re-thrown on the
/// waiting thread, which may unwind while a lock-holding caller is live).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Done {
    finished: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// One batch: `n` tasks claimed off a shared atomic counter.
struct Batch {
    f: BatchFn,
    n: usize,
    /// How many pool workers may join (the waiter participates on top).
    cap: usize,
    next: AtomicUsize,
    joined: AtomicUsize,
    done: Mutex<Done>,
    done_cv: Condvar,
}

impl Batch {
    /// Claim and run tasks until the index space is exhausted.
    fn work(&self) {
        let mut finished = 0usize;
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            // SAFETY: the closure may only be invoked while a claimed
            // index < n is in flight: its completion has not been counted
            // yet, so `done.finished < n` and the waiter is still blocked
            // in `wait_done`, keeping the closure (and everything it
            // borrows) alive. A late worker that finds the index space
            // exhausted never touches the closure.
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| unsafe { self.f.call(i) })) {
                if panic.is_none() {
                    panic = Some(p);
                }
            }
            finished += 1;
        }
        if finished > 0 {
            let mut done = lock(&self.done);
            done.finished += finished;
            if done.panic.is_none() {
                done.panic = panic;
            }
            if done.finished >= self.n {
                self.done_cv.notify_all();
            }
        }
    }

    /// Whether this batch still has unclaimed indices a new worker could
    /// take (claimed-but-running tasks don't count).
    fn has_unclaimed(&self) -> bool {
        self.next.load(Ordering::Relaxed) < self.n
    }
}

/// Batches currently in flight, in submission order. Workers scan for the
/// first batch with unclaimed work; each batch is removed by its waiter
/// once every index finished.
struct JobQueue {
    batches: Vec<Arc<Batch>>,
}

struct PoolShared {
    job: Mutex<JobQueue>,
    work_cv: Condvar,
}

/// The process-wide worker pool.
pub struct Pool {
    shared: Arc<PoolShared>,
    /// Worker threads spawned so far (grown on demand).
    spawned: Mutex<usize>,
    /// Effective parallelism for the next batch.
    threads: AtomicUsize,
}

thread_local! {
    /// Set while this thread is executing pool tasks; nested parallel calls
    /// then run inline (sequentially) instead of re-entering the pool.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn worker_main(shared: Arc<PoolShared>) {
    IN_WORKER.with(|c| c.set(true));
    loop {
        let batch = {
            let mut q = lock(&shared.job);
            loop {
                // earliest batch with unclaimed work and a free join slot;
                // drained-but-running batches are skipped via their cursor
                let ready = q
                    .batches
                    .iter()
                    .find(|b| b.has_unclaimed() && b.joined.load(Ordering::Relaxed) < b.cap);
                if let Some(b) = ready {
                    break b.clone();
                }
                q = shared.work_cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        if batch.joined.fetch_add(1, Ordering::Relaxed) < batch.cap {
            batch.work();
        }
        // `batch` drops here — workers never park holding an Arc, so a
        // detached batch's owned closure is freed promptly after its
        // waiter dequeues it.
    }
}

impl Pool {
    fn new() -> Pool {
        Pool {
            shared: Arc::new(PoolShared {
                job: Mutex::new(JobQueue { batches: Vec::new() }),
                work_cv: Condvar::new(),
            }),
            spawned: Mutex::new(0),
            threads: AtomicUsize::new(default_threads()),
        }
    }

    fn ensure_workers(&self, helpers: usize) {
        let mut spawned = lock(&self.spawned);
        while *spawned < helpers {
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name(format!("bitopt8-pool-{}", *spawned))
                .spawn(move || worker_main(shared))
                .expect("spawn pool worker");
            *spawned += 1;
        }
    }

    /// Install a batch into the queue and wake the workers (non-blocking).
    fn enqueue(&self, batch: Arc<Batch>, helpers: usize) {
        self.ensure_workers(helpers);
        {
            let mut q = lock(&self.shared.job);
            q.batches.push(batch);
        }
        self.shared.work_cv.notify_all();
    }

    /// Participate in `batch`'s remaining work, block until every index
    /// finished, dequeue it, and return the first task panic (if any).
    fn wait_done(&self, batch: &Arc<Batch>) -> Option<Box<dyn std::any::Any + Send>> {
        let was_worker = IN_WORKER.with(|c| c.replace(true));
        batch.work();
        IN_WORKER.with(|c| c.set(was_worker));

        let panic = {
            let mut done = lock(&batch.done);
            while done.finished < batch.n {
                done = batch.done_cv.wait(done).unwrap_or_else(|e| e.into_inner());
            }
            done.panic.take()
        };
        {
            let mut q = lock(&self.shared.job);
            if let Some(pos) = q.batches.iter().position(|b| Arc::ptr_eq(b, batch)) {
                q.batches.remove(pos);
            }
        }
        panic
    }

    /// Run `f(0..n)` across the submitter plus up to `threads - 1` workers,
    /// blocking until every index has finished (or re-throwing the first
    /// task panic).
    fn run_batch(&self, f: &(dyn Fn(usize) + Sync), n: usize, threads: usize) {
        // SAFETY: lifetime erasure only; this call blocks in `wait_done`
        // until every task finished, and no task runs after that.
        let erased: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let batch = Arc::new(Batch {
            f: BatchFn::Borrowed(TaskFn(erased)),
            n,
            cap: threads - 1,
            next: AtomicUsize::new(0),
            joined: AtomicUsize::new(0),
            done: Mutex::new(Done { finished: 0, panic: None }),
            done_cv: Condvar::new(),
        });
        self.enqueue(batch.clone(), threads - 1);
        if let Some(p) = self.wait_done(&batch) {
            resume_unwind(p);
        }
    }
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// The lazily-initialized process-wide pool.
pub fn pool() -> &'static Pool {
    POOL.get_or_init(Pool::new)
}

/// Initial thread count: `BITOPT8_THREADS` (read once, at pool init) or the
/// hardware parallelism.
fn default_threads() -> usize {
    if let Ok(s) = std::env::var("BITOPT8_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Current effective worker count (cached — no env lookup on the hot path).
pub fn num_threads() -> usize {
    pool().threads.load(Ordering::Relaxed)
}

/// Change the effective worker count for subsequent calls (workers are
/// grown on demand; shrinking just leaves the extras parked).
pub fn set_num_threads(n: usize) {
    pool().threads.store(n.max(1), Ordering::Relaxed);
}

/// Run `f` with the thread count temporarily set to `n` (restored on exit,
/// including on panic). The setting is process-global, so concurrent
/// callers racing on it still get *correct* results — every primitive is
/// deterministic in the thread count — just an arbitrary parallelism.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_num_threads(self.0);
        }
    }
    let _restore = Restore(num_threads());
    set_num_threads(n);
    f()
}

/// Core primitive: call `f(i)` for every `i in 0..n` across the pool,
/// returning when all are done. Each index runs exactly once; panics are
/// re-thrown here after the batch drains. Calls from inside a pool task
/// run inline (no nested parallelism).
pub fn run_indexed<F: Fn(usize) + Sync>(n: usize, f: F) {
    if n == 0 {
        return;
    }
    let threads = num_threads().min(n);
    if threads <= 1 || n == 1 || IN_WORKER.with(|c| c.get()) {
        for i in 0..n {
            f(i);
        }
        return;
    }
    pool().run_batch(&f, n, threads);
}

/// A detached batch in flight on the pool. The submitting thread is free
/// to do other work while the workers crunch (the streaming optimizer step
/// drives the serial PJRT dispatches this way); [`BatchHandle::wait`] joins
/// the batch — the caller participates in draining it — and re-throws the
/// first task panic.
///
/// Dropping the handle also waits: the closure may borrow data of lifetime
/// `'s`, so the batch must never outlive the handle (see the [`submit`]
/// safety contract).
pub(crate) struct BatchHandle<'s> {
    batch: Option<Arc<Batch>>,
    _borrow: std::marker::PhantomData<&'s ()>,
}

impl<'s> BatchHandle<'s> {
    /// A handle with nothing left in flight (empty or inline-run batches).
    fn complete() -> BatchHandle<'s> {
        BatchHandle { batch: None, _borrow: std::marker::PhantomData }
    }

    /// True once every task has finished (never blocks). A done batch still
    /// needs [`BatchHandle::wait`] to surface panics and free its slot.
    pub fn is_done(&self) -> bool {
        match &self.batch {
            None => true,
            Some(b) => lock(&b.done).finished >= b.n,
        }
    }

    /// Block until every task finished — participating in the remaining
    /// work — then re-throw the first task panic, if any.
    pub fn wait(mut self) {
        if let Some(p) = self.drain() {
            resume_unwind(p);
        }
    }

    fn drain(&mut self) -> Option<Box<dyn std::any::Any + Send>> {
        let batch = self.batch.take()?;
        pool().wait_done(&batch)
    }
}

impl Drop for BatchHandle<'_> {
    fn drop(&mut self) {
        if let Some(p) = self.drain() {
            // re-throw task panics unless we are already unwinding (a
            // double panic would abort)
            if !std::thread::panicking() {
                resume_unwind(p);
            }
        }
    }
}

/// Start `f(0..n)` on the pool WITHOUT blocking: the calling thread keeps
/// running (producing the next tensor's gradient, driving serial I/O)
/// while up to `threads - 1` workers crunch. Wait on (or drop) the handle
/// to join. Several detached batches may be in flight at once.
///
/// With one thread, or when called from inside a pool task, the batch runs
/// inline here and the handle comes back already complete — same results,
/// no overlap.
///
/// Crate-internal: the streaming engine (`optim::engine::StreamingStep`)
/// is the supported consumer.
///
/// # Safety
///
/// The closure is lifetime-erased into the pool, so the returned handle
/// must be waited on (or dropped — `Drop` waits) before `'s` ends. The
/// caller must guarantee the handle cannot leak: `mem::forget`-ing it
/// while `f` borrows non-`'static` data would let tasks run after those
/// borrows die (use-after-free). A structurally-owned handle that is
/// always joined (the `StreamTensor` pattern) satisfies this.
pub(crate) unsafe fn submit<'s, F>(n: usize, f: F) -> BatchHandle<'s>
where
    F: Fn(usize) + Sync + Send + 's,
{
    if n == 0 {
        return BatchHandle::complete();
    }
    let threads = num_threads();
    if threads <= 1 || IN_WORKER.with(|c| c.get()) {
        for i in 0..n {
            f(i);
        }
        return BatchHandle::complete();
    }
    // SAFETY: lifetime erasure only — the handle's `wait`/`Drop` blocks
    // until every task finished, and the handle cannot outlive `'s`, so
    // the closure is never called after its borrows end.
    let owned = unsafe {
        std::mem::transmute::<
            Box<dyn Fn(usize) + Sync + Send + 's>,
            Box<dyn Fn(usize) + Sync + Send + 'static>,
        >(Box::new(f))
    };
    let batch = Arc::new(Batch {
        f: BatchFn::Owned(owned),
        n,
        cap: threads - 1,
        next: AtomicUsize::new(0),
        joined: AtomicUsize::new(0),
        done: Mutex::new(Done { finished: 0, panic: None }),
        done_cv: Condvar::new(),
    });
    pool().enqueue(batch.clone(), threads - 1);
    BatchHandle { batch: Some(batch), _borrow: std::marker::PhantomData }
}

/// Run a heterogeneous set of one-shot tasks on the pool, blocking until
/// all complete. The fused multi-tensor optimizer step feeds every
/// (tensor, block) work item of one training step through this.
pub fn submit_all<'s>(tasks: Vec<Box<dyn FnOnce() + Send + 's>>) {
    let n = tasks.len();
    if n == 0 {
        return;
    }
    let slots: Vec<Mutex<Option<Box<dyn FnOnce() + Send + 's>>>> =
        tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    run_indexed(n, |i| {
        if let Some(task) = lock(&slots[i]).take() {
            task();
        }
    });
}

/// Run `f(chunk_index, chunk)` over disjoint mutable chunks of `data`,
/// `chunk_len` elements each (last chunk may be short), across the pool.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync + Send,
{
    assert!(chunk_len > 0);
    let len = data.len();
    if len == 0 {
        return;
    }
    let n_chunks = len.div_ceil(chunk_len);
    let base = SendPtr(data.as_mut_ptr());
    run_indexed(n_chunks, move |i| {
        let lo = i * chunk_len;
        let n = chunk_len.min(len - lo);
        // SAFETY: chunk i covers [lo, lo + n) — disjoint across indices,
        // each index claimed exactly once, and `data` outlives the call.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), n) };
        f(i, chunk);
    });
}

/// Run `f(i, a_chunk, b_chunk)` over paired disjoint chunks of two slices
/// with independent chunk lengths (e.g. 2048 codes + 1 absmax per block).
pub fn par_chunks_pair_mut<A: Send, B: Send, F>(
    a: &mut [A],
    ca: usize,
    b: &mut [B],
    cb: usize,
    f: F,
) where
    F: Fn(usize, &mut [A], &mut [B]) + Sync + Send,
{
    assert!(ca > 0 && cb > 0);
    let (la, lb) = (a.len(), b.len());
    let n_chunks = la.div_ceil(ca);
    assert_eq!(n_chunks.max(1), lb.div_ceil(cb).max(1), "chunk counts differ");
    if la == 0 {
        return;
    }
    let pa = SendPtr(a.as_mut_ptr());
    let pb = SendPtr(b.as_mut_ptr());
    run_indexed(n_chunks, move |i| {
        let (lo_a, lo_b) = (i * ca, i * cb);
        let (na, nb) = (ca.min(la - lo_a), cb.min(lb - lo_b));
        // SAFETY: as in `par_chunks_mut`, per-index ranges are disjoint in
        // both slices and the borrows outlive the blocking call.
        let sa = unsafe { std::slice::from_raw_parts_mut(pa.0.add(lo_a), na) };
        let sb = unsafe { std::slice::from_raw_parts_mut(pb.0.add(lo_b), nb) };
        f(i, sa, sb);
    });
}

/// Parallel map over an index range, collecting results in order.
pub fn par_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync + Send,
{
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let base = SendPtr(out.as_mut_ptr());
    run_indexed(n, move |i| {
        // SAFETY: one slot per index, written exactly once.
        unsafe { *base.0.add(i) = Some(f(i)) };
    });
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

/// Run two independent closures in parallel (pool-backed).
pub fn join<A: Send, B: Send>(
    fa: impl FnOnce() -> A + Send,
    fb: impl FnOnce() -> B + Send,
) -> (A, B) {
    let mut ra = None;
    let mut rb = None;
    {
        let (pra, prb) = (&mut ra, &mut rb);
        let ta = Box::new(move || *pra = Some(fa())) as Box<dyn FnOnce() + Send + '_>;
        let tb = Box::new(move || *prb = Some(fb())) as Box<dyn FnOnce() + Send + '_>;
        submit_all(vec![ta, tb]);
    }
    (ra.unwrap(), rb.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Serializes tests that touch the process-global thread count (the
    /// default test harness runs tests concurrently; without this,
    /// `with_threads_restores_count` could observe another test's
    /// temporary setting).
    static THREADS_LOCK: Mutex<()> = Mutex::new(());

    fn threads_locked() -> MutexGuard<'static, ()> {
        THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Test wrapper for the unsafe `submit`: every handle in this module
    /// is waited on or dropped in scope (never leaked), which is the
    /// entire safety contract.
    fn submit_t<'s, F: Fn(usize) + Sync + Send + 's>(n: usize, f: F) -> BatchHandle<'s> {
        // SAFETY: see above — no test leaks its handle.
        unsafe { submit(n, f) }
    }

    #[test]
    fn par_chunks_mut_touches_every_element_once() {
        let mut data = vec![0u32; 10_000];
        par_chunks_mut(&mut data, 257, |_, c| {
            for v in c.iter_mut() {
                *v += 1; // each element exactly once
            }
        });
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn par_chunks_chunk_indices_are_correct() {
        let mut data = vec![0usize; 1000];
        par_chunks_mut(&mut data, 100, |i, c| {
            for v in c.iter_mut() {
                *v = i;
            }
        });
        for (j, &v) in data.iter().enumerate() {
            assert_eq!(v, j / 100);
        }
    }

    #[test]
    fn par_map_ordering() {
        let out = par_map(1000, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn join_runs_both() {
        let (a, b) = join(|| 2 + 2, || "ok");
        assert_eq!(a, 4);
        assert_eq!(b, "ok");
    }

    #[test]
    fn par_chunks_short_input() {
        let mut data = vec![0u8; 3];
        par_chunks_mut(&mut data, 1024, |_, c| {
            for v in c.iter_mut() {
                *v = 7;
            }
        });
        assert_eq!(data, vec![7, 7, 7]);
    }

    #[test]
    fn submit_all_runs_every_task_once() {
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..100)
            .map(|i| {
                let c = &counter;
                Box::new(move || {
                    c.fetch_add(i + 1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        submit_all(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 100 * 101 / 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn nested_parallel_calls_run_inline_without_deadlock() {
        let _g = threads_locked();
        let out = with_threads(4, || {
            par_map(16, |i| {
                // nested call from (potentially) a worker thread
                let inner = par_map(8, move |j| i * 8 + j);
                inner.into_iter().sum::<usize>()
            })
        });
        let total: usize = out.into_iter().sum();
        assert_eq!(total, (0..128).sum::<usize>());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn pool_is_reused_across_many_batches() {
        let _g = threads_locked();
        with_threads(4, || {
            for round in 0..200 {
                let mut data = vec![0usize; 513];
                par_chunks_mut(&mut data, 32, |_, c| {
                    for v in c.iter_mut() {
                        *v = round;
                    }
                });
                assert!(data.iter().all(|&v| v == round));
            }
        });
    }

    #[test]
    fn with_threads_restores_count() {
        let _g = threads_locked();
        let before = num_threads();
        with_threads(3, || assert_eq!(num_threads(), 3));
        assert_eq!(num_threads(), before);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn results_identical_across_thread_counts() {
        let _g = threads_locked();
        let run = |threads: usize| {
            with_threads(threads, || {
                par_map(1000, |i| {
                    let x = (i as f32).sqrt().sin();
                    x.to_bits()
                })
            })
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(4));
        assert_eq!(one, run(9));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn submit_runs_every_index_and_wait_joins() {
        let _g = threads_locked();
        with_threads(4, || {
            let counter = AtomicUsize::new(0);
            let h = submit_t(100, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
            h.wait();
            assert_eq!(counter.load(Ordering::Relaxed), 100);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn submit_does_not_block_the_submitter() {
        let _g = threads_locked();
        with_threads(4, || {
            let gate = AtomicUsize::new(0);
            let h = submit_t(8, |_| {
                while gate.load(Ordering::Acquire) == 0 {
                    std::thread::yield_now();
                }
            });
            // if submit had blocked until the batch drained, the gate
            // would never open — deadlock instead of a passing test
            gate.store(1, Ordering::Release);
            h.wait();
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn concurrent_detached_batches_all_complete() {
        let _g = threads_locked();
        with_threads(4, || {
            let counters: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
            let handles: Vec<BatchHandle<'_>> = counters
                .iter()
                .map(|c| {
                    submit_t(64, move |_| {
                        c.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect();
            for h in handles {
                h.wait();
            }
            for c in &counters {
                assert_eq!(c.load(Ordering::Relaxed), 64);
            }
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn blocking_and_detached_batches_interleave() {
        // A blocking run_indexed issued while a detached batch is still in
        // flight must not lose either batch's work.
        let _g = threads_locked();
        with_threads(4, || {
            let detached = AtomicUsize::new(0);
            let h = submit_t(500, |_| {
                detached.fetch_add(1, Ordering::Relaxed);
            });
            let blocking = AtomicUsize::new(0);
            run_indexed(500, |_| {
                blocking.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(blocking.load(Ordering::Relaxed), 500);
            h.wait();
            assert_eq!(detached.load(Ordering::Relaxed), 500);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn dropping_a_handle_waits_for_the_batch() {
        let _g = threads_locked();
        with_threads(4, || {
            let counter = AtomicUsize::new(0);
            {
                let _h = submit_t(200, |_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            } // drop must block until the batch drains
            assert_eq!(counter.load(Ordering::Relaxed), 200);
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn is_done_reflects_batch_state() {
        let _g = threads_locked();
        with_threads(4, || {
            let gate = AtomicUsize::new(0);
            let h = submit_t(4, |_| {
                while gate.load(Ordering::Acquire) == 0 {
                    std::thread::yield_now();
                }
            });
            assert!(!h.is_done(), "tasks cannot finish before the gate opens");
            gate.store(1, Ordering::Release);
            h.wait();
            let empty = submit_t(0, |_| {});
            assert!(empty.is_done());
            empty.wait();
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn submit_panics_rethrow_at_wait_and_pool_survives() {
        let _g = threads_locked();
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            with_threads(4, || {
                submit_t(32, |i| {
                    if i == 7 {
                        panic!("boom in detached task");
                    }
                })
                .wait();
            });
        }));
        assert!(caught.is_err(), "detached task panic must reach wait()");
        let mut data = vec![0u32; 1024];
        with_threads(4, || {
            par_chunks_mut(&mut data, 64, |_, c| {
                for v in c.iter_mut() {
                    *v += 1;
                }
            });
        });
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn submit_runs_inline_with_one_thread() {
        let _g = threads_locked();
        with_threads(1, || {
            let counter = AtomicUsize::new(0);
            let h = submit_t(50, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
            // inline execution: complete before wait
            assert!(h.is_done());
            assert_eq!(counter.load(Ordering::Relaxed), 50);
            h.wait();
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns pool workers / spin-waits: not Miri-friendly
    fn panics_propagate_and_pool_survives() {
        let _g = threads_locked();
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            with_threads(4, || {
                run_indexed(64, |i| {
                    if i == 37 {
                        panic!("boom in task");
                    }
                });
            });
        }));
        assert!(caught.is_err(), "worker panic must reach the submitter");
        // the pool must stay functional afterwards
        let mut data = vec![0u32; 4096];
        with_threads(4, || {
            par_chunks_mut(&mut data, 64, |_, c| {
                for v in c.iter_mut() {
                    *v += 1;
                }
            });
        });
        assert!(data.iter().all(|&v| v == 1));
    }
}
