//! Tiny CLI argument parser (clap stand-in).
//!
//! Supports `subcommand --flag --key value --key=value positional` shapes,
//! which covers the `bitopt8 train/repro/analyze/bench` surface.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv (excluding program name). The first non-dash token becomes
    /// the subcommand; later non-dash tokens are positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--config", "cfg.toml", "--steps=100", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.toml"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["repro", "table1", "--seeds", "3"]);
        assert_eq!(a.subcommand.as_deref(), Some("repro"));
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.get_u64("seeds", 0), 3);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b"]);
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_f64("lr", 0.1), 0.1);
        assert_eq!(a.get_or("mode", "native"), "native");
    }

    #[test]
    fn negative_number_as_value() {
        // "--lr -3" would be ambiguous; values use = form for negatives.
        let a = parse(&["x", "--lr=-3.5"]);
        assert_eq!(a.get_f64("lr", 0.0), -3.5);
    }
}
