//! Portable fixed-width lanes for the block-kernel hot path.
//!
//! The paper's CUDA kernels process each quantization block at full vector
//! width; our portable equivalent is *lane chunking*: the inner loops over
//! a block (codebook decode, absmax scan, encode, elementwise optimizer
//! rules) are restructured around fixed-size `[f32; LANES]` chunks — plain
//! arrays with fixed trip-count inner loops, which the autovectorizer
//! lowers to SIMD reliably on stable Rust (no `std::simd`, no new deps).
//!
//! Contract: lane kernels perform the *identical* per-element IEEE
//! arithmetic as their scalar counterparts, in the same element order
//! within each lane chunk — rustc never reassociates float ops or
//! contracts mul+add into FMA, so autovectorization changes instruction
//! *shape*, not results. Every lane path is therefore bit-identical to the
//! scalar path; `rust/tests/simd_parity.rs` and the `pool_parity`
//! scalar-vs-lane fleets pin this.
//!
//! [`set_force_scalar`] routes every lane-aware path through its scalar
//! tail loop instead, turning the scalar implementation into a
//! whole-pipeline oracle (parity tests) and a benchmark baseline
//! (`benches/fused_step.rs` `simd_sweep`). The flag is a process-global
//! atomic — worker-pool threads must observe it, so a thread-local would
//! not do — read once per block, not per element.

use std::sync::atomic::{AtomicBool, Ordering};

/// Lane width of every vectorized block kernel: 8 × f32 = one 256-bit
/// vector register (two 128-bit ops on narrower targets — still the shape
/// autovectorizers handle best).
pub const LANES: usize = 8;

/// Process-global "pretend we have no lanes" switch (see module docs).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// True when lane paths are disabled and every kernel must take its scalar
/// loop. Checked once per block by the lane-aware entry points.
#[inline(always)]
pub fn scalar_forced() -> bool {
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Globally enable / disable the scalar fallback. Prefer
/// [`with_forced_scalar`] which restores the previous value.
pub fn set_force_scalar(force: bool) {
    FORCE_SCALAR.store(force, Ordering::Relaxed);
}

/// Run `f` with every lane path forced onto its scalar loop, restoring the
/// previous setting afterwards (even on panic) — the parity-test and
/// baseline-benchmark entry point. Tests that toggle this process-global
/// flag should serialize the same way thread-count tests do.
pub fn with_forced_scalar<R>(f: impl FnOnce() -> R) -> R {
    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            FORCE_SCALAR.store(self.0, Ordering::Relaxed);
        }
    }
    let _restore = Restore(FORCE_SCALAR.swap(true, Ordering::Relaxed));
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forced_scalar_restores_on_exit() {
        let before = scalar_forced();
        let inside = with_forced_scalar(scalar_forced);
        assert!(inside);
        assert_eq!(scalar_forced(), before);
    }

    #[test]
    fn forced_scalar_restores_on_panic() {
        let before = scalar_forced();
        let r = std::panic::catch_unwind(|| with_forced_scalar(|| panic!("boom")));
        assert!(r.is_err());
        assert_eq!(scalar_forced(), before);
    }
}
