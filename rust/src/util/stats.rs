//! Small statistics toolkit: mean/median/percentiles/standard error, plus a
//! streaming Welford accumulator. Used by the benchmark harness and the
//! table generators (the paper reports medians over seeds and mean±SE for
//! quantization errors, Table 6).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn std_err(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Median (average of middle two for even n); NaN-free input expected.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("no NaNs in median input"));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Linear-interpolated percentile, p in [0, 100]. Non-finite inputs are
/// filtered out (the gnorm clip feeds this from live training telemetry,
/// where a single NaN must not panic the whole run); NaN when no finite
/// values remain.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Streaming mean/variance (Welford). Numerically stable for long streams;
/// used by the metrics sink and the quantization-error sweeps.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let new_mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = new_mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn std_err(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std_dev() / (self.n as f64).sqrt()
        }
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_ignores_non_finite() {
        // A stray NaN/inf from live telemetry must not panic or poison the
        // quantile — it's simply not part of the distribution.
        let xs = [f64::NAN, 1.0, 2.0, f64::INFINITY, 3.0, 4.0, f64::NEG_INFINITY];
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn percentile_of_nothing_finite_is_nan() {
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile(&[f64::NAN, f64::INFINITY], 50.0).is_nan());
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.37).sin() * 5.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_matches_sequential() {
        let xs: Vec<f64> = (0..500).map(|i| (i as f64).cos()).collect();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..200] {
            a.push(x);
        }
        for &x in &xs[200..] {
            b.push(x);
        }
        a.merge(&b);
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((a.mean() - w.mean()).abs() < 1e-12);
        assert!((a.variance() - w.variance()).abs() < 1e-10);
    }

    #[test]
    fn std_err_shrinks_with_n() {
        let a: Vec<f64> = (0..100).map(|i| (i % 7) as f64).collect();
        let b: Vec<f64> = (0..10_000).map(|i| (i % 7) as f64).collect();
        assert!(std_err(&b) < std_err(&a));
    }
}
