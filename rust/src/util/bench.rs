//! In-tree micro-benchmark harness (criterion stand-in).
//!
//! `cargo bench` targets in `benches/` are plain `harness = false` binaries
//! that call [`bench`]; it warms up, runs timed iterations until a wall
//! budget or iteration cap is hit, and reports median / mean / p10 / p90.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} median {:>12} mean {:>12} p90   ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p90_ns),
            self.iters
        )
    }

    /// Throughput helper: elements processed per second given per-iter count.
    pub fn throughput(&self, elems_per_iter: usize) -> f64 {
        elems_per_iter as f64 / (self.median_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark a closure. Runs `warmup` untimed iterations, then timed
/// iterations until `budget` elapses (min 5, max `max_iters`).
pub fn bench<F: FnMut()>(name: &str, budget: Duration, max_iters: usize, mut f: F) -> BenchResult {
    // Warmup: 2 runs or until 10% of the budget spent.
    let warm_start = Instant::now();
    for _ in 0..2 {
        f();
        if warm_start.elapsed() > budget / 10 {
            break;
        }
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while (samples.len() < 5 || start.elapsed() < budget) && samples.len() < max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    BenchResult {
        name: name.to_string(),
        iters: n,
        median_ns: samples[n / 2],
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p10_ns: samples[(n as f64 * 0.1) as usize],
        p90_ns: samples[((n as f64 * 0.9) as usize).min(n - 1)],
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("spin", Duration::from_millis(30), 1_000, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.iters >= 5);
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2_000_000_000.0).contains(" s"));
    }
}
