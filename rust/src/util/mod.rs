//! Dependency-free substrates: PRNG, statistics, parallelism, JSON, CLI
//! args, binary IO, and a micro-benchmark harness.
//!
//! The offline registry only resolves the `xla` crate closure, so the usual
//! ecosystem crates (rand, rayon, serde, clap, criterion) are re-implemented
//! here at the scale this project needs.

pub mod args;
pub mod bench;
pub mod io;
pub mod json;
pub mod lanes;
pub mod parallel;
pub mod reduce;
pub mod rng;
pub mod stats;
