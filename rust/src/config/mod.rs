//! Typed run configuration: model, optimizer, data, schedule, engine.
//!
//! Loaded from a TOML file (`configs/*.toml`), overridable from the CLI
//! (`--lr 0.01 --optimizer adam8 ...`). Every experiment in
//! EXPERIMENTS.md is a RunConfig.

pub mod toml;

use anyhow::{anyhow, Result};

use crate::optim::{Bits, OptimConfig, OptimKind};
use crate::quant::Format;
use crate::util::args::Args;
use toml::TomlDoc;

/// Which engine performs the optimizer update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Fused multi-threaded Rust path (production hot path).
    Native,
    /// AOT Pallas/HLO artifacts executed via PJRT (the L1 kernels).
    Hlo,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "native" => Some(Engine::Native),
            "hlo" => Some(Engine::Hlo),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::Hlo => "hlo",
        }
    }
}

/// Learning-rate schedule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Constant,
    /// Linear warmup over `warmup` steps then linear decay to 10% at `total`.
    WarmupLinear { warmup: usize, total: usize },
}

impl Schedule {
    pub fn lr_at(&self, base: f32, step: usize) -> f32 {
        match *self {
            Schedule::Constant => base,
            Schedule::WarmupLinear { warmup, total } => {
                if step < warmup {
                    base * (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let p = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
                    base * (1.0 - 0.9 * p.min(1.0))
                }
            }
        }
    }
}

/// A full training-run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Manifest model name, e.g. "tiny" or "tiny_stable".
    pub model: String,
    pub optim: OptimConfig,
    /// 32-bit optimizer state for embedding tensors (§2.3 policy).
    pub emb32: bool,
    /// Override the token-embedding init (Table 8 ablates Xavier vs the
    /// fairseq normal init independently of the LayerNorm graph change).
    pub emb_init_override: Option<String>,
    pub steps: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub seed: u64,
    pub grad_clip: f32,
    pub schedule: Schedule,
    pub engine: Engine,
    pub artifacts_dir: String,
    /// Corpus noise level (LM difficulty).
    pub data_noise: f64,
    pub log_jsonl: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "tiny".into(),
            optim: OptimConfig::adam(1e-3, Bits::B32),
            emb32: false,
            emb_init_override: None,
            steps: 200,
            eval_every: 50,
            eval_batches: 8,
            seed: 42,
            grad_clip: 1.0,
            schedule: Schedule::Constant,
            engine: Engine::Native,
            artifacts_dir: "artifacts".into(),
            data_noise: 0.25,
            log_jsonl: None,
        }
    }
}

impl RunConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let d = TomlDoc::parse(text)?;
        let mut cfg = RunConfig::default();
        cfg.model = d.str_or("model", "name", &cfg.model);
        cfg.emb32 = d.bool_or("model", "emb32", cfg.emb32);
        cfg.steps = d.usize_or("train", "steps", cfg.steps);
        cfg.eval_every = d.usize_or("train", "eval_every", cfg.eval_every);
        cfg.eval_batches = d.usize_or("train", "eval_batches", cfg.eval_batches);
        cfg.seed = d.usize_or("train", "seed", cfg.seed as usize) as u64;
        cfg.grad_clip = d.f64_or("train", "grad_clip", cfg.grad_clip as f64) as f32;
        cfg.data_noise = d.f64_or("data", "noise", cfg.data_noise);
        cfg.artifacts_dir = d.str_or("train", "artifacts_dir", &cfg.artifacts_dir);
        let engine = d.str_or("train", "engine", cfg.engine.name());
        cfg.engine = Engine::parse(&engine).ok_or_else(|| anyhow!("bad engine {engine:?}"))?;

        let warmup = d.usize_or("train", "warmup", 0);
        cfg.schedule = if warmup > 0 {
            Schedule::WarmupLinear { warmup, total: cfg.steps }
        } else {
            Schedule::Constant
        };

        cfg.optim = parse_optim(
            &d.str_or("optimizer", "kind", "adam"),
            d.usize_or("optimizer", "bits", 32),
            &d.str_or("optimizer", "format", "dynamic"),
            d.bool_or("optimizer", "blockwise", true),
        )?;
        cfg.optim.lr = d.f64_or("optimizer", "lr", cfg.optim.lr as f64) as f32;
        cfg.optim.beta1 = d.f64_or("optimizer", "beta1", cfg.optim.beta1 as f64) as f32;
        cfg.optim.beta2 = d.f64_or("optimizer", "beta2", cfg.optim.beta2 as f64) as f32;
        cfg.optim.eps = d.f64_or("optimizer", "eps", cfg.optim.eps as f64) as f32;
        cfg.optim.weight_decay =
            d.f64_or("optimizer", "weight_decay", cfg.optim.weight_decay as f64) as f32;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path}: {e}"))?;
        Self::from_toml(&text)
    }

    /// Apply `--key value` CLI overrides on top of the file config.
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(m) = a.get("model") {
            self.model = m.to_string();
        }
        if let Some(o) = a.get("optimizer") {
            // shorthand: adam | adam8 | momentum8 | adafactor | ...
            let (kind, bits) = match o.strip_suffix('8') {
                Some(base) => (base, 8),
                None => (o, 32),
            };
            self.optim = parse_optim(
                kind,
                bits,
                a.get_or("format", "dynamic"),
                !a.flag("tensorwise"),
            )?;
        }
        if let Some(v) = a.get("lr") {
            self.optim.lr = v.parse()?;
        }
        if let Some(v) = a.get("beta1") {
            self.optim.beta1 = v.parse()?;
        }
        if let Some(v) = a.get("beta2") {
            self.optim.beta2 = v.parse()?;
        }
        if let Some(v) = a.get("eps") {
            self.optim.eps = v.parse()?;
        }
        if let Some(v) = a.get("steps") {
            self.steps = v.parse()?;
        }
        if let Some(v) = a.get("seed") {
            self.seed = v.parse()?;
        }
        if let Some(v) = a.get("engine") {
            self.engine = Engine::parse(v).ok_or_else(|| anyhow!("bad engine {v:?}"))?;
        }
        if let Some(v) = a.get("artifacts") {
            self.artifacts_dir = v.to_string();
        }
        if a.flag("emb32") {
            self.emb32 = true;
        }
        if let Some(v) = a.get("log") {
            self.log_jsonl = Some(v.to_string());
        }
        Ok(())
    }

    pub fn describe(&self) -> String {
        format!(
            "{} | {} | steps={} seed={} engine={} emb32={}",
            self.model,
            self.optim.describe(),
            self.steps,
            self.seed,
            self.engine.name(),
            self.emb32
        )
    }
}

/// Build an OptimConfig from string pieces (shared by TOML + CLI paths).
pub fn parse_optim(kind: &str, bits: usize, format: &str, blockwise: bool) -> Result<OptimConfig> {
    let kind = OptimKind::parse(kind).ok_or_else(|| anyhow!("unknown optimizer {kind:?}"))?;
    let format = Format::parse(format).ok_or_else(|| anyhow!("unknown format {format:?}"))?;
    let bits = match bits {
        32 => Bits::B32,
        8 => Bits::B8 { format, blockwise },
        other => return Err(anyhow!("bits must be 8 or 32, got {other}")),
    };
    let mut cfg = OptimConfig::adam(1e-3, bits);
    cfg.kind = kind;
    if kind == OptimKind::Momentum || kind == OptimKind::Lars {
        cfg.beta1 = 0.9;
        cfg.beta2 = 0.0;
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip_with_toml() {
        let cfg = RunConfig::from_toml(
            r#"
[model]
name = "tiny_stable"
emb32 = true

[optimizer]
kind = "adam"
bits = 8
lr = 0.0163
beta2 = 0.995

[train]
steps = 300
warmup = 30
engine = "native"
"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "tiny_stable");
        assert!(cfg.emb32);
        assert_eq!(cfg.optim.bits, Bits::b8_dynamic());
        assert!((cfg.optim.lr - 0.0163).abs() < 1e-9);
        assert_eq!(cfg.steps, 300);
        assert!(matches!(cfg.schedule, Schedule::WarmupLinear { warmup: 30, total: 300 }));
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            ["train", "--optimizer", "adam8", "--lr", "0.01", "--steps", "5", "--emb32"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optim.bits, Bits::b8_dynamic());
        assert_eq!(cfg.steps, 5);
        assert!(cfg.emb32);
    }

    #[test]
    fn schedule_warmup_then_decay() {
        let s = Schedule::WarmupLinear { warmup: 10, total: 110 };
        assert!(s.lr_at(1.0, 0) < 0.2);
        assert!((s.lr_at(1.0, 9) - 1.0).abs() < 1e-6);
        assert!(s.lr_at(1.0, 60) < 1.0);
        assert!(s.lr_at(1.0, 109) >= 0.1 - 1e-6);
    }

    #[test]
    fn parse_optim_rejects_bad_bits() {
        assert!(parse_optim("adam", 16, "dynamic", true).is_err());
        assert!(parse_optim("bogus", 8, "dynamic", true).is_err());
    }
}
