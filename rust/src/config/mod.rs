//! Typed run configuration: model, optimizer (base config + parameter
//! groups), data, schedule, engine.
//!
//! Loaded from a TOML file (`configs/*.toml`), overridable from the CLI
//! (`--lr 0.01 --optimizer adam8 --override "embed.*:bits=32" ...`). Every
//! experiment in EXPERIMENTS.md is a RunConfig.
//!
//! # TOML reference
//!
//! ```toml
//! [model]
//! name = "tiny_stable"      # manifest model
//! emb32 = true              # sugar: append the §2.3 stable-embedding
//!                           # override (embed.tok|embed.pos -> bits = 32)
//!
//! [optimizer]               # the BASE config every tensor starts from
//! kind = "adam"             # adam|adamw|momentum|lamb|lars|adafactor|adagrad|sm3
//! bits = 8                  # state precision: 32, 8, or 4 (16-level
//!                           # packed codes per Li et al. 2023)
//! format = "dynamic"        # dynamic|linear|quantile|inverse-dynamic
//!                           # (every format has an 8-bit and a 4-bit codebook)
//! blockwise = true          # block-wise (§2.1) vs tensor-wide normalization
//! lr = 1.6e-2
//! beta1 = 0.9
//! beta2 = 0.995
//! eps = 1e-7
//! weight_decay = 0.0
//! clip_percentile = 0.0     # percentile gradient clipping over a rolling
//!                           # 100-step gnorm window (bnb-style); 0 = off,
//!                           # active in (0, 100] — e.g. 95
//! max_unorm = 0.0           # clip the applied update when its norm
//!                           # exceeds max_unorm * param norm; 0 = off
//! skip_zeros = false        # leave moments/params untouched where the
//!                           # gradient is exactly zero (sparse updates)
//!
//! # Parameter groups: ordered overrides on the base config, first match
//! # wins (glob patterns: `*`, `?`, `|` alternation). Any subset of
//! # bits/format/blockwise/lr/weight_decay/beta1/beta2/eps/
//! # clip_percentile/max_unorm/skip_zeros/shards/bits_min/bits_max may be
//! # set; `shards` is the placement axis (engine layer 5) — it partitions
//! # the group's quantized state across N ZeRO-style shards without
//! # changing the math. `bits_min`/`bits_max` bound the runtime precision
//! # controller's transitions (layer 6, `[precision]` below) without
//! # changing the starting width.
//! [[optimizer.group]]
//! pattern = "embed.tok|embed.pos"
//! bits = 32                 # stable-embedding policy, spelled explicitly
//!
//! [[optimizer.group]]
//! pattern = "lm_head"
//! lr = 6e-3
//!
//! [[optimizer.group]]
//! pattern = "block?.attn.*"  # 4-bit states for the attention projections
//! bits = 4                   # format/blockwise inherit from the base
//! shards = 4                 # partition this group's state across 4 shards
//!
//! [placement]               # ZeRO-style state placement (engine layer 5)
//! shards = 1                # default shard count for every group that does
//!                           # not set its own `shards =`; 1..=64. N-shard
//!                           # runs are bit-identical to N = 1 — placement
//!                           # only moves state, it never changes the math.
//!                           # With shards > 1, checkpoints are written as a
//!                           # v5 manifest (`ck.bin`) plus one file per shard
//!                           # (`ck.bin.shard00`, `ck.bin.shard01`, ...);
//!                           # any layout restores into any other (states
//!                           # are keyed by tensor name, not shard), so an
//!                           # N-shard checkpoint reshards freely into M.
//!
//! [train]
//! steps = 300
//! warmup = 30               # 0 = constant LR schedule
//! eval_every = 50
//! eval_batches = 8
//! seed = 42
//! grad_clip = 1.0
//! engine = "native"         # native | hlo
//! artifacts_dir = "artifacts"
//!
//! [data]
//! noise = 0.25
//!
//! [fault]                   # deterministic gradient-fault injection, used
//!                           # by the stability-stress configs; all off by
//!                           # default (0 = disabled)
//! spike_every = 0           # every Nth step, scale all gradients ...
//! spike_scale = 100.0       # ... by this factor
//! zero_stride = 0           # zero every Nth gradient element (skip_zeros)
//! nan_at = 0                # poison one gradient element at step N
//!
//! [precision]               # layer-6 adaptive precision controller
//!                           # (`optim::precision`); omit the table to run
//!                           # static widths. Native engine only. Tensors
//!                           # walk the 4 <-> 8 <-> 32 rung ladder between
//!                           # each group's bits_min/bits_max bounds;
//!                           # transitions requantize losslessly from the
//!                           # 32-bit working values and are logged to the
//!                           # JSONL `groups` stream.
//! cadence = 25              # review every N steps
//! promote_error = 0.6       # promote a rung when a state's measured
//!                           # resolution-error score exceeds this
//! demote_error = 0.1        # demote only when requantizing at the
//!                           # narrower width keeps mean relative error
//!                           # strictly below this (0 disables demotion)
//! spike_factor = 4.0        # promote when a tensor's window-max gradient
//!                           # norm exceeds this multiple of its rolling
//!                           # median norm
//! hysteresis = 2            # consecutive quiet reviews before a demotion
//! ```
//!
//! CLI: `--override "pattern:key=val[,key=val]"` adds groups ahead of the
//! file's (`;` separates several), `--emb32` appends the stable-embedding
//! sugar, `--shards N` overrides `[placement] shards`,
//! `--precision-policy "key=val[,key=val]"` enables the adaptive precision
//! controller over the defaults (`--precision-policy off` disables a
//! file-enabled one). Unsupported
//! combinations (e.g. `adafactor` with `bits = 8`, `quantile` without
//! block-wise normalization, or `shards > 1` on a factored optimizer) are
//! rejected at parse time.
//!
//! Beyond parse-time validation, `bitopt8 --lint [--configs DIR]` runs the
//! static plan linter ([`crate::analysis::plan_lint`]) over every
//! `configs/*.toml`: each distinct optimizer plan the spec resolves to is
//! checked for disjoint item writes, barrier-ordered reads, drained
//! telemetry counters, and deterministic combines, plus the full
//! kind × bits × stability capability matrix. CI runs it on every push.

pub mod toml;

use anyhow::{anyhow, ensure, Result};

use crate::optim::{Bits, GroupOverride, OptimConfig, OptimKind, OptimSpec, PrecisionPolicy};
use crate::quant::Format;
use crate::util::args::Args;
use toml::TomlDoc;

/// Which engine performs the optimizer update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Fused multi-threaded Rust path (production hot path).
    Native,
    /// AOT Pallas/HLO artifacts executed via PJRT (the L1 kernels).
    Hlo,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "native" => Some(Engine::Native),
            "hlo" => Some(Engine::Hlo),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::Hlo => "hlo",
        }
    }
}

/// Learning-rate schedule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Constant,
    /// Linear warmup over `warmup` steps then linear decay to 10% at `total`.
    WarmupLinear { warmup: usize, total: usize },
}

impl Schedule {
    pub fn lr_at(&self, base: f32, step: usize) -> f32 {
        match *self {
            Schedule::Constant => base,
            Schedule::WarmupLinear { warmup, total } => {
                if step < warmup {
                    base * (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let p = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
                    base * (1.0 - 0.9 * p.min(1.0))
                }
            }
        }
    }
}

/// Deterministic gradient-fault injection (`[fault]` in TOML). Drives the
/// stability-stress configs: spikes exercise percentile clipping, strided
/// zeros exercise `skip_zeros`, and a one-shot NaN exercises the non-finite
/// crash path. All fields default to 0 (disabled); step numbering is
/// 1-based (the first training step is step 1).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultConfig {
    /// Every `spike_every`-th step, multiply all gradients by `spike_scale`.
    pub spike_every: usize,
    pub spike_scale: f32,
    /// Zero every `zero_stride`-th gradient element of every tensor.
    pub zero_stride: usize,
    /// At step `nan_at`, set the first gradient element to NaN.
    pub nan_at: usize,
}

impl FaultConfig {
    pub fn any(&self) -> bool {
        self.spike_every > 0 || self.zero_stride > 0 || self.nan_at > 0
    }

    /// Corrupt `grads` in place for 1-based training step `step`.
    pub fn apply(&self, step: usize, grads: &mut [Vec<f32>]) {
        if self.spike_every > 0 && step % self.spike_every == 0 {
            for g in grads.iter_mut() {
                for v in g.iter_mut() {
                    *v *= self.spike_scale;
                }
            }
        }
        if self.zero_stride > 0 {
            for g in grads.iter_mut() {
                for v in g.iter_mut().step_by(self.zero_stride) {
                    *v = 0.0;
                }
            }
        }
        if self.nan_at == step {
            if let Some(v) = grads.iter_mut().flat_map(|g| g.iter_mut()).next() {
                *v = f32::NAN;
            }
        }
    }
}

/// A full training-run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Manifest model name, e.g. "tiny" or "tiny_stable".
    pub model: String,
    /// Base optimizer config (the default parameter group).
    pub optim: OptimConfig,
    /// Ordered per-group overrides (first matching pattern wins); together
    /// with `optim` this forms the run's `OptimSpec`. The historical
    /// `emb32` flag is [`RunConfig::push_emb32`] sugar appending the §2.3
    /// stable-embedding override.
    pub groups: Vec<GroupOverride>,
    /// Override the token-embedding init (Table 8 ablates Xavier vs the
    /// fairseq normal init independently of the LayerNorm graph change).
    pub emb_init_override: Option<String>,
    pub steps: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub seed: u64,
    pub grad_clip: f32,
    pub schedule: Schedule,
    pub engine: Engine,
    pub artifacts_dir: String,
    /// Corpus noise level (LM difficulty).
    pub data_noise: f64,
    /// Default placement shard count (`[placement] shards`); groups may
    /// override per-group. 1 = placement off.
    pub shards: u32,
    pub log_jsonl: Option<String>,
    /// Deterministic gradient-fault injection (stress configs).
    pub fault: FaultConfig,
    /// Adaptive precision controller policy (`[precision]` /
    /// `--precision-policy`); `None` = static widths.
    pub precision: Option<PrecisionPolicy>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "tiny".into(),
            optim: OptimConfig::adam(1e-3, Bits::B32),
            groups: Vec::new(),
            emb_init_override: None,
            steps: 200,
            eval_every: 50,
            eval_batches: 8,
            seed: 42,
            grad_clip: 1.0,
            schedule: Schedule::Constant,
            engine: Engine::Native,
            artifacts_dir: "artifacts".into(),
            data_noise: 0.25,
            shards: 1,
            log_jsonl: None,
            fault: FaultConfig::default(),
            precision: None,
        }
    }
}

impl RunConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let d = TomlDoc::parse(text)?;
        let mut cfg = RunConfig::default();
        cfg.model = d.str_or("model", "name", &cfg.model);
        cfg.steps = d.usize_or("train", "steps", cfg.steps);
        cfg.eval_every = d.usize_or("train", "eval_every", cfg.eval_every);
        cfg.eval_batches = d.usize_or("train", "eval_batches", cfg.eval_batches);
        cfg.seed = d.usize_or("train", "seed", cfg.seed as usize) as u64;
        cfg.grad_clip = d.f64_or("train", "grad_clip", cfg.grad_clip as f64) as f32;
        cfg.data_noise = d.f64_or("data", "noise", cfg.data_noise);
        cfg.artifacts_dir = d.str_or("train", "artifacts_dir", &cfg.artifacts_dir);
        let engine = d.str_or("train", "engine", cfg.engine.name());
        cfg.engine = Engine::parse(&engine).ok_or_else(|| anyhow!("bad engine {engine:?}"))?;

        let warmup = d.usize_or("train", "warmup", 0);
        cfg.schedule = if warmup > 0 {
            Schedule::WarmupLinear { warmup, total: cfg.steps }
        } else {
            Schedule::Constant
        };

        cfg.optim = parse_optim(
            &d.str_or("optimizer", "kind", "adam"),
            d.usize_or("optimizer", "bits", 32),
            &d.str_or("optimizer", "format", "dynamic"),
            d.bool_or("optimizer", "blockwise", true),
        )?;
        cfg.optim.lr = d.f64_or("optimizer", "lr", cfg.optim.lr as f64) as f32;
        cfg.optim.beta1 = d.f64_or("optimizer", "beta1", cfg.optim.beta1 as f64) as f32;
        cfg.optim.beta2 = d.f64_or("optimizer", "beta2", cfg.optim.beta2 as f64) as f32;
        cfg.optim.eps = d.f64_or("optimizer", "eps", cfg.optim.eps as f64) as f32;
        cfg.optim.weight_decay =
            d.f64_or("optimizer", "weight_decay", cfg.optim.weight_decay as f64) as f32;
        cfg.optim.clip_percentile =
            d.f64_or("optimizer", "clip_percentile", cfg.optim.clip_percentile as f64) as f32;
        cfg.optim.max_unorm =
            d.f64_or("optimizer", "max_unorm", cfg.optim.max_unorm as f64) as f32;
        cfg.optim.skip_zeros = d.bool_or("optimizer", "skip_zeros", cfg.optim.skip_zeros);

        cfg.shards = d.usize_or("placement", "shards", cfg.shards as usize) as u32;

        cfg.fault.spike_every = d.usize_or("fault", "spike_every", 0);
        cfg.fault.spike_scale = d.f64_or("fault", "spike_scale", 100.0) as f32;
        cfg.fault.zero_stride = d.usize_or("fault", "zero_stride", 0);
        cfg.fault.nan_at = d.usize_or("fault", "nan_at", 0);

        // [precision]: presence of the table enables the controller; unset
        // keys fall back to the policy defaults.
        if d.sections.contains_key("precision") {
            let mut p = PrecisionPolicy::default();
            p.cadence = d.usize_or("precision", "cadence", p.cadence);
            p.promote_error = d.f64_or("precision", "promote_error", p.promote_error);
            p.demote_error = d.f64_or("precision", "demote_error", p.demote_error);
            p.spike_factor = d.f64_or("precision", "spike_factor", p.spike_factor);
            p.hysteresis = d.usize_or("precision", "hysteresis", p.hysteresis as usize) as u32;
            p.validate()?;
            cfg.precision = Some(p);
        }

        // Parameter groups, in declaration order; the `emb32` sugar (lowest
        // priority — explicit groups win on first-match) goes last. A
        // single-bracket [optimizer.group] would land in `sections` and be
        // silently dropped — catch the typo here.
        if d.sections.contains_key("optimizer.group") {
            return Err(anyhow!(
                "[optimizer.group] must be an array-of-tables: write [[optimizer.group]]"
            ));
        }
        for table in d.tables("optimizer.group") {
            cfg.groups.push(GroupOverride::from_table(table)?);
        }
        if d.bool_or("model", "emb32", false) {
            cfg.push_emb32();
        }
        ensure!(
            cfg.precision.is_none() || cfg.engine == Engine::Native,
            "[precision] requires the native engine: HLO mirrors bake the state width \
             into the compiled artifact and cannot requantize at runtime"
        );
        cfg.optim_spec().validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path}: {e}"))?;
        Self::from_toml(&text)
    }

    /// The run's optimizer spec: base config + parameter groups + default
    /// placement shard count.
    pub fn optim_spec(&self) -> OptimSpec {
        let mut spec = OptimSpec::with_groups(self.optim, self.groups.clone());
        spec.default_shards = self.shards;
        spec
    }

    /// Append the §2.3 stable-embedding policy (the historical `emb32`
    /// flag) as a group override: 32-bit state for the embedding tensors.
    pub fn push_emb32(&mut self) {
        self.groups.push(GroupOverride::emb32());
    }

    /// Apply `--key value` CLI overrides on top of the file config.
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(m) = a.get("model") {
            self.model = m.to_string();
        }
        if let Some(o) = a.get("optimizer") {
            // shorthand: adam | adam8 | adam4 | momentum8 | adafactor | ...
            let (kind, bits) = if let Some(base) = o.strip_suffix('8') {
                (base, 8)
            } else if let Some(base) = o.strip_suffix('4') {
                (base, 4)
            } else {
                (o, 32)
            };
            self.optim = parse_optim(
                kind,
                bits,
                a.get_or("format", "dynamic"),
                !a.flag("tensorwise"),
            )?;
        }
        if let Some(v) = a.get("lr") {
            self.optim.lr = v.parse()?;
        }
        if let Some(v) = a.get("beta1") {
            self.optim.beta1 = v.parse()?;
        }
        if let Some(v) = a.get("beta2") {
            self.optim.beta2 = v.parse()?;
        }
        if let Some(v) = a.get("eps") {
            self.optim.eps = v.parse()?;
        }
        if let Some(v) = a.get("steps") {
            self.steps = v.parse()?;
        }
        if let Some(v) = a.get("seed") {
            self.seed = v.parse()?;
        }
        if let Some(v) = a.get("engine") {
            self.engine = Engine::parse(v).ok_or_else(|| anyhow!("bad engine {v:?}"))?;
        }
        if let Some(v) = a.get("artifacts") {
            self.artifacts_dir = v.to_string();
        }
        // CLI groups take precedence over the file's (first match wins), so
        // they are *prepended* in their own declaration order.
        if let Some(v) = a.get("override") {
            let mut cli: Vec<GroupOverride> = Vec::new();
            for part in v.split(';') {
                let part = part.trim();
                if !part.is_empty() {
                    cli.push(GroupOverride::parse(part)?);
                }
            }
            cli.append(&mut self.groups);
            self.groups = cli;
        }
        if a.flag("emb32") {
            self.push_emb32();
        }
        if let Some(v) = a.get("shards") {
            self.shards = v.parse()?;
        }
        if let Some(v) = a.get("log") {
            self.log_jsonl = Some(v.to_string());
        }
        if let Some(v) = a.get("precision-policy") {
            self.precision =
                if v == "off" { None } else { Some(PrecisionPolicy::parse(v)?) };
        }
        ensure!(
            self.precision.is_none() || self.engine == Engine::Native,
            "--precision-policy requires the native engine: HLO mirrors bake the state \
             width into the compiled artifact and cannot requantize at runtime"
        );
        self.optim_spec().validate()?;
        Ok(())
    }

    pub fn describe(&self) -> String {
        let groups = if self.groups.is_empty() {
            "-".to_string()
        } else {
            self.groups.iter().map(|g| g.describe()).collect::<Vec<_>>().join(" ")
        };
        let placement = if self.shards > 1 {
            format!(" shards={}", self.shards)
        } else {
            String::new()
        };
        let precision = match &self.precision {
            Some(p) => format!(" precision(cadence={})", p.cadence),
            None => String::new(),
        };
        format!(
            "{} | {} | steps={} seed={} engine={}{}{} groups={}",
            self.model,
            self.optim.describe(),
            self.steps,
            self.seed,
            self.engine.name(),
            placement,
            precision,
            groups
        )
    }
}

/// Build an OptimConfig from string pieces (shared by TOML + CLI paths).
/// Unsupported combinations are rejected here — parse time — rather than
/// silently falling back at construction.
pub fn parse_optim(kind: &str, bits: usize, format: &str, blockwise: bool) -> Result<OptimConfig> {
    let kind = OptimKind::parse(kind).ok_or_else(|| anyhow!("unknown optimizer {kind:?}"))?;
    let format = Format::parse(format).ok_or_else(|| anyhow!("unknown format {format:?}"))?;
    let bits = match bits {
        32 => Bits::B32,
        8 => Bits::B8 { format, blockwise },
        4 => Bits::B4 { format, blockwise },
        other => return Err(anyhow!("bits must be 4, 8 or 32, got {other}")),
    };
    let mut cfg = OptimConfig::adam(1e-3, bits);
    cfg.kind = kind;
    if kind == OptimKind::Momentum || kind == OptimKind::Lars {
        cfg.beta1 = 0.9;
        cfg.beta2 = 0.0;
    }
    crate::optim::validate_config(&cfg)?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip_with_toml() {
        let cfg = RunConfig::from_toml(
            r#"
[model]
name = "tiny_stable"
emb32 = true

[optimizer]
kind = "adam"
bits = 8
lr = 0.0163
beta2 = 0.995

[train]
steps = 300
warmup = 30
engine = "native"
"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "tiny_stable");
        assert_eq!(cfg.groups.len(), 1, "emb32 sugar appended");
        assert_eq!(cfg.groups[0].describe(), "embed.tok|embed.pos:bits=32");
        assert_eq!(cfg.optim.bits, Bits::b8_dynamic());
        assert!((cfg.optim.lr - 0.0163).abs() < 1e-9);
        assert_eq!(cfg.steps, 300);
        assert!(matches!(cfg.schedule, Schedule::WarmupLinear { warmup: 30, total: 300 }));
    }

    #[test]
    fn toml_group_tables_parse_in_order() {
        let cfg = RunConfig::from_toml(
            r#"
[optimizer]
kind = "adam"
bits = 8

[[optimizer.group]]
pattern = "embed.tok|embed.pos"
bits = 32

[[optimizer.group]]
pattern = "lm_head"
lr = 0.006
"#,
        )
        .unwrap();
        assert_eq!(cfg.groups.len(), 2);
        let spec = cfg.optim_spec();
        assert_eq!(spec.resolve("embed.tok").0.bits, Bits::B32);
        assert_eq!(spec.resolve("lm_head").1, 2);
        assert!((spec.resolve("lm_head").0.lr - 0.006).abs() < 1e-9);
        assert_eq!(spec.resolve("block0.attn.wq").1, 0);
    }

    #[test]
    fn toml_rejects_invalid_combos_at_parse_time() {
        // adafactor cannot run 8-bit states
        let err = RunConfig::from_toml("[optimizer]\nkind = \"adafactor\"\nbits = 8\n")
            .unwrap_err();
        assert!(format!("{err:#}").contains("adafactor"), "{err:#}");
        // quantile requires blockwise normalization
        assert!(RunConfig::from_toml(
            "[optimizer]\nkind = \"adam\"\nbits = 8\nformat = \"quantile\"\nblockwise = false\n"
        )
        .is_err());
        // a group resolving to an unsupported combo is also caught
        assert!(RunConfig::from_toml(
            "[optimizer]\nkind = \"adafactor\"\n\n[[optimizer.group]]\npattern = \"embed.*\"\nbits = 8\n"
        )
        .is_err());
        // bad group key
        assert!(RunConfig::from_toml(
            "[[optimizer.group]]\npattern = \"x\"\nbogus = 1\n"
        )
        .is_err());
        // single-bracket typo would silently drop the group — rejected
        let err = RunConfig::from_toml(
            "[optimizer.group]\npattern = \"embed.tok|embed.pos\"\nbits = 32\n",
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("[[optimizer.group]]"), "{err:#}");
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            ["train", "--optimizer", "adam8", "--lr", "0.01", "--steps", "5", "--emb32"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optim.bits, Bits::b8_dynamic());
        assert_eq!(cfg.steps, 5);
        assert_eq!(cfg.groups.len(), 1);
        assert_eq!(cfg.groups[0].describe(), "embed.tok|embed.pos:bits=32");
    }

    #[test]
    fn cli_override_flag_prepends_groups() {
        let mut cfg = RunConfig::default();
        cfg.optim = parse_optim("adam", 8, "dynamic", true).unwrap();
        cfg.groups.push(GroupOverride::parse("embed.*:bits=32").unwrap());
        let args = Args::parse(
            ["train", "--override", "embed.tok:lr=0.5;lm_head:bits=32"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.groups.len(), 3);
        // CLI groups come first: embed.tok hits the CLI lr override, not
        // the file's 32-bit group
        let spec = cfg.optim_spec();
        let (tok, g) = spec.resolve("embed.tok");
        assert_eq!(g, 1);
        assert_eq!(tok.bits, Bits::b8_dynamic());
        assert!((tok.lr - 0.5).abs() < 1e-9);
        assert_eq!(spec.resolve("embed.pos").1, 3, "file group still matches");
        assert_eq!(spec.resolve("lm_head").0.bits, Bits::B32);
    }

    #[test]
    fn stability_and_fault_keys_from_toml() {
        let cfg = RunConfig::from_toml(
            r#"
[optimizer]
kind = "momentum"
bits = 8
clip_percentile = 95.0
max_unorm = 0.02
skip_zeros = true

[[optimizer.group]]
pattern = "lm_head"
clip_percentile = 0.0

[fault]
spike_every = 16
spike_scale = 50.0
zero_stride = 3
nan_at = 7
"#,
        )
        .unwrap();
        assert!((cfg.optim.clip_percentile - 95.0).abs() < 1e-6);
        assert!((cfg.optim.max_unorm - 0.02).abs() < 1e-9);
        assert!(cfg.optim.skip_zeros);
        let spec = cfg.optim_spec();
        assert_eq!(spec.resolve("lm_head").0.clip_percentile, 0.0);
        assert!(spec.resolve("block0.attn.wq").0.stability_on());
        assert_eq!(cfg.fault.spike_every, 16);
        assert!((cfg.fault.spike_scale - 50.0).abs() < 1e-6);
        assert_eq!(cfg.fault.zero_stride, 3);
        assert_eq!(cfg.fault.nan_at, 7);
        // out-of-range knobs and unsupported kinds fail at parse time
        assert!(RunConfig::from_toml(
            "[optimizer]\nkind = \"adam\"\nclip_percentile = 101.0\n"
        )
        .is_err());
        assert!(RunConfig::from_toml(
            "[optimizer]\nkind = \"lamb\"\nclip_percentile = 95.0\n"
        )
        .is_err());
    }

    #[test]
    fn fault_injection_is_deterministic_per_step() {
        let fault = FaultConfig { spike_every: 4, spike_scale: 10.0, zero_stride: 2, nan_at: 3 };
        assert!(fault.any());
        // step 1: zero_stride only
        let mut g = vec![vec![1.0f32, 2.0, 3.0, 4.0]];
        fault.apply(1, &mut g);
        assert_eq!(g[0], vec![0.0, 2.0, 0.0, 4.0]);
        // step 3: NaN lands on the first element (after zeroing)
        let mut g = vec![vec![1.0f32, 2.0]];
        fault.apply(3, &mut g);
        assert!(g[0][0].is_nan());
        // step 4: spike multiplies before the zero stride wipes evens
        let mut g = vec![vec![1.0f32, 2.0]];
        fault.apply(4, &mut g);
        assert_eq!(g[0], vec![0.0, 20.0]);
        assert!(!FaultConfig::default().any());
    }

    #[test]
    fn schedule_warmup_then_decay() {
        let s = Schedule::WarmupLinear { warmup: 10, total: 110 };
        assert!(s.lr_at(1.0, 0) < 0.2);
        assert!((s.lr_at(1.0, 9) - 1.0).abs() < 1e-6);
        assert!(s.lr_at(1.0, 60) < 1.0);
        assert!(s.lr_at(1.0, 109) >= 0.1 - 1e-6);
    }

    #[test]
    fn parse_optim_rejects_bad_bits() {
        assert!(parse_optim("adam", 16, "dynamic", true).is_err());
        assert!(parse_optim("bogus", 8, "dynamic", true).is_err());
        assert!(parse_optim("adafactor", 8, "dynamic", true).is_err());
        assert!(parse_optim("sm3", 8, "dynamic", true).is_err());
        assert!(parse_optim("adafactor", 32, "dynamic", true).is_ok());
        // 4-bit follows the same capability rules
        assert!(parse_optim("adafactor", 4, "dynamic", true).is_err());
        assert!(parse_optim("sm3", 4, "dynamic", true).is_err());
        let cfg = parse_optim("adam", 4, "dynamic", true).unwrap();
        assert_eq!(cfg.bits, Bits::b4_dynamic());
    }

    #[test]
    fn bits4_from_toml_and_cli() {
        // base precision straight from TOML
        let cfg = RunConfig::from_toml("[optimizer]\nkind = \"adam\"\nbits = 4\n").unwrap();
        assert_eq!(cfg.optim.bits, Bits::b4_dynamic());
        // group override from TOML
        let cfg = RunConfig::from_toml(
            "[optimizer]\nkind = \"adam\"\nbits = 8\n\n\
             [[optimizer.group]]\npattern = \"block?.attn.*\"\nbits = 4\n",
        )
        .unwrap();
        let spec = cfg.optim_spec();
        assert_eq!(spec.resolve("block0.attn.wq").0.bits, Bits::b4_dynamic());
        assert_eq!(spec.resolve("lm_head").0.bits, Bits::b8_dynamic());
        // CLI --override and the adam4 shorthand
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            ["train", "--optimizer", "adam4", "--override", "embed.*:bits=8"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optim.bits, Bits::b4_dynamic());
        let spec = cfg.optim_spec();
        assert_eq!(spec.resolve("embed.tok").0.bits, Bits::b8_dynamic());
        assert_eq!(spec.resolve("block0.attn.wq").0.bits, Bits::b4_dynamic());
        // a 4-bit group resolving onto a factored optimizer still fails
        assert!(RunConfig::from_toml(
            "[optimizer]\nkind = \"adafactor\"\n\n[[optimizer.group]]\npattern = \"embed.*\"\nbits = 4\n"
        )
        .is_err());
    }

    #[test]
    fn precision_policy_from_toml_and_cli() {
        // [precision] presence enables; unset keys keep defaults.
        let cfg = RunConfig::from_toml(
            "[optimizer]\nkind = \"adam\"\nbits = 4\n\n\
             [precision]\ncadence = 10\nspike_factor = 8.0\n",
        )
        .unwrap();
        let p = cfg.precision.unwrap();
        assert_eq!(p.cadence, 10);
        assert_eq!(p.spike_factor, 8.0);
        assert_eq!(p.demote_error, PrecisionPolicy::default().demote_error);
        assert!(cfg.describe().contains("precision(cadence=10)"), "{}", cfg.describe());

        // no table -> static widths
        let cfg = RunConfig::from_toml("[optimizer]\nkind = \"adam\"\nbits = 8\n").unwrap();
        assert!(cfg.precision.is_none());

        // invalid values fail at parse time; HLO engine is rejected
        assert!(RunConfig::from_toml("[precision]\ncadence = 0\n").is_err());
        assert!(RunConfig::from_toml("[train]\nengine = \"hlo\"\n\n[precision]\ncadence = 5\n")
            .is_err());

        // CLI enables over defaults and can disable a file policy
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            ["train", "--precision-policy", "cadence=5,hysteresis=3"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        let p = cfg.precision.unwrap();
        assert_eq!((p.cadence, p.hysteresis), (5, 3));
        let mut cfg = RunConfig::default();
        cfg.precision = Some(PrecisionPolicy::default());
        let args = Args::parse(
            ["train", "--precision-policy", "off"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert!(cfg.precision.is_none());
    }

    #[test]
    fn precision_bounds_group_keys_from_toml() {
        let cfg = RunConfig::from_toml(
            "[optimizer]\nkind = \"adam\"\nbits = 4\n\n\
             [[optimizer.group]]\npattern = \"embed.*\"\nbits_max = 8\n",
        )
        .unwrap();
        assert_eq!(cfg.groups[0].bits_max, Some(8));
        assert!(cfg.groups[0].describe().contains("bits_max=8"));
        // a floor above the resolved starting width is contradictory
        assert!(RunConfig::from_toml(
            "[optimizer]\nkind = \"adam\"\nbits = 4\n\n\
             [[optimizer.group]]\npattern = \"x\"\nbits_min = 8\n"
        )
        .is_err());
        // bounds must be valid widths
        assert!(RunConfig::from_toml(
            "[[optimizer.group]]\npattern = \"x\"\nbits_max = 16\n"
        )
        .is_err());
    }

    #[test]
    fn placement_shards_from_toml_and_cli() {
        // [placement] sets the spec-wide default; groups can override.
        let cfg = RunConfig::from_toml(
            "[optimizer]\nkind = \"adam\"\nbits = 8\n\n\
             [placement]\nshards = 2\n\n\
             [[optimizer.group]]\npattern = \"block?.*\"\nshards = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.shards, 2);
        let spec = cfg.optim_spec();
        assert_eq!(spec.default_shards, 2);
        assert_eq!(spec.shards_of(0), 2, "default group inherits [placement]");
        assert_eq!(spec.shards_of(1), 4, "group override wins");
        assert!(cfg.describe().contains("shards=2"));

        // --shards overrides the file and is re-validated.
        let mut cfg = RunConfig::default();
        let args =
            Args::parse(["train", "--shards", "4"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.optim_spec().default_shards, 4);

        // out-of-range and unshardable-optimizer placements fail at parse time
        assert!(RunConfig::from_toml("[placement]\nshards = 0\n").is_err());
        assert!(RunConfig::from_toml("[placement]\nshards = 65\n").is_err());
        let err = RunConfig::from_toml(
            "[optimizer]\nkind = \"adafactor\"\n\n[placement]\nshards = 2\n",
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("shardable"), "{err:#}");
        assert!(RunConfig::from_toml(
            "[optimizer]\nkind = \"sm3\"\n\n[[optimizer.group]]\npattern = \"x\"\nshards = 2\n"
        )
        .is_err());
        let mut cfg = RunConfig::default();
        let args =
            Args::parse(["train", "--shards", "99"].iter().map(|s| s.to_string()));
        assert!(cfg.apply_args(&args).is_err());
    }
}
