//! Minimal TOML-subset parser (the `toml` crate is not in the offline
//! registry). Supports what run configs need: `[section]` headers,
//! `key = value` with strings, integers, floats, booleans, and comments.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section -> key -> value. Top-level keys live under "".
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .ok_or_else(|| anyhow!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(|v| v.as_i64())
            .map(|i| i as usize)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Option<TomlValue> {
    if let Some(body) = v.strip_prefix('"') {
        return body.strip_suffix('"').map(|s| TomlValue::Str(s.to_string()));
    }
    match v {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    if !v.contains('.') && !v.contains('e') && !v.contains('E') {
        if let Ok(i) = v.parse::<i64>() {
            return Some(TomlValue::Int(i));
        }
    }
    v.parse::<f64>().ok().map(TomlValue::Float)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# run config
engine = "native"

[model]
preset = "tiny"        # preset name
stable_embedding = true

[optimizer]
kind = "adam"
lr = 1.6e-2
bits = 8
steps = 300
"#;

    #[test]
    fn parse_sections_and_types() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.str_or("", "engine", "?"), "native");
        assert_eq!(d.str_or("model", "preset", "?"), "tiny");
        assert!(d.bool_or("model", "stable_embedding", false));
        assert_eq!(d.f64_or("optimizer", "lr", 0.0), 1.6e-2);
        assert_eq!(d.usize_or("optimizer", "bits", 0), 8);
        assert_eq!(d.usize_or("optimizer", "steps", 0), 300);
    }

    #[test]
    fn defaults_for_missing() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.f64_or("optimizer", "nope", 7.5), 7.5);
        assert_eq!(d.str_or("nope", "nope", "dflt"), "dflt");
    }

    #[test]
    fn comments_and_hash_in_string() {
        let d = TomlDoc::parse("name = \"a#b\" # trailing").unwrap();
        assert_eq!(d.str_or("", "name", ""), "a#b");
    }

    #[test]
    fn errors_on_garbage() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
    }
}
