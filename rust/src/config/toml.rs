//! Minimal TOML-subset parser (the `toml` crate is not in the offline
//! registry). Supports what run configs need: `[section]` headers,
//! `[[section]]` array-of-tables headers (parameter groups), `key = value`
//! with strings, integers, floats, booleans, and comments.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// One table's worth of key/value pairs.
pub type Table = BTreeMap<String, TomlValue>;

/// Parsed document: section -> key -> value. Top-level keys live under "".
/// `[[name]]` headers append tables to `arrays[name]` instead (TOML
/// array-of-tables; used by `[[optimizer.group]]`).
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, Table>,
    pub arrays: BTreeMap<String, Vec<Table>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        let mut in_array = false;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[") {
                let name = name
                    .strip_suffix("]]")
                    .ok_or_else(|| anyhow!("line {}: unterminated [[section]]", lineno + 1))?;
                section = name.trim().to_string();
                doc.arrays.entry(section.clone()).or_default().push(Table::new());
                in_array = true;
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                in_array = false;
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .ok_or_else(|| anyhow!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            let table = if in_array {
                doc.arrays.get_mut(&section).and_then(|v| v.last_mut()).expect("open array table")
            } else {
                doc.sections.entry(section.clone()).or_default()
            };
            table.insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    /// The tables of a `[[name]]` array-of-tables (empty if absent).
    pub fn tables(&self, name: &str) -> &[Table] {
        self.arrays.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(|v| v.as_i64())
            .map(|i| i as usize)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Option<TomlValue> {
    if let Some(body) = v.strip_prefix('"') {
        return body.strip_suffix('"').map(|s| TomlValue::Str(s.to_string()));
    }
    match v {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    if !v.contains('.') && !v.contains('e') && !v.contains('E') {
        if let Ok(i) = v.parse::<i64>() {
            return Some(TomlValue::Int(i));
        }
    }
    v.parse::<f64>().ok().map(TomlValue::Float)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# run config
engine = "native"

[model]
preset = "tiny"        # preset name
stable_embedding = true

[optimizer]
kind = "adam"
lr = 1.6e-2
bits = 8
steps = 300
"#;

    #[test]
    fn parse_sections_and_types() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.str_or("", "engine", "?"), "native");
        assert_eq!(d.str_or("model", "preset", "?"), "tiny");
        assert!(d.bool_or("model", "stable_embedding", false));
        assert_eq!(d.f64_or("optimizer", "lr", 0.0), 1.6e-2);
        assert_eq!(d.usize_or("optimizer", "bits", 0), 8);
        assert_eq!(d.usize_or("optimizer", "steps", 0), 300);
    }

    #[test]
    fn defaults_for_missing() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.f64_or("optimizer", "nope", 7.5), 7.5);
        assert_eq!(d.str_or("nope", "nope", "dflt"), "dflt");
    }

    #[test]
    fn comments_and_hash_in_string() {
        let d = TomlDoc::parse("name = \"a#b\" # trailing").unwrap();
        assert_eq!(d.str_or("", "name", ""), "a#b");
    }

    #[test]
    fn errors_on_garbage() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("[[unterminated").is_err());
    }

    #[test]
    fn array_of_tables() {
        let d = TomlDoc::parse(
            r#"
[optimizer]
kind = "adam"

[[optimizer.group]]
pattern = "embed.*"
bits = 32

[[optimizer.group]]
pattern = "head"
lr = 0.01

[train]
steps = 5
"#,
        )
        .unwrap();
        let groups = d.tables("optimizer.group");
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].get("pattern").and_then(|v| v.as_str()), Some("embed.*"));
        assert_eq!(groups[0].get("bits").and_then(|v| v.as_i64()), Some(32));
        assert_eq!(groups[1].get("lr").and_then(|v| v.as_f64()), Some(0.01));
        // surrounding plain sections are unaffected
        assert_eq!(d.str_or("optimizer", "kind", "?"), "adam");
        assert_eq!(d.usize_or("train", "steps", 0), 5);
        assert!(d.tables("nope").is_empty());
    }
}
