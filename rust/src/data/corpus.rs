//! Synthetic LM corpus: Zipfian unigram marginals + learnable Markov
//! structure.
//!
//! Natural-language corpora have (a) heavily skewed token frequencies —
//! exactly what destabilizes embedding gradients per Appendix C — and (b)
//! predictable local structure that lets a transformer reduce loss well
//! below the unigram entropy. The generator mixes a deterministic
//! per-token successor map (learnable signal) with Zipf(α) noise:
//!
//!   next = succ[cur]           with prob 1 − noise
//!   next ~ Zipf(α)             otherwise
//!
//! The optimal cross-entropy is ≈ H(noise) + noise·H(Zipf) < log V, so a
//! training run has real headroom and a divergent run is unmistakable.

use crate::util::rng::{Rng, Zipf};

#[derive(Clone)]
pub struct Corpus {
    vocab: usize,
    noise: f64,
    succ: Vec<u32>,
    zipf: Zipf,
}

impl Corpus {
    /// Standard corpus: α = 1.1, 25% noise.
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        Corpus::with_params(vocab, seed, 1.1, 0.25)
    }

    pub fn with_params(vocab: usize, seed: u64, alpha: f64, noise: f64) -> Corpus {
        assert!(vocab >= 4);
        let mut rng = Rng::new(seed ^ 0xC0_4F_05);
        // Random permutation as the successor map (Fisher–Yates) — every
        // token has exactly one "correct" next token.
        let mut succ: Vec<u32> = (0..vocab as u32).collect();
        for i in (1..vocab).rev() {
            let j = rng.below((i + 1) as u64) as usize;
            succ.swap(i, j);
        }
        Corpus { vocab, noise, succ, zipf: Zipf::new(vocab, alpha) }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Sample one continuation token.
    #[inline]
    pub fn next_token(&self, cur: u32, rng: &mut Rng) -> u32 {
        if rng.coin(self.noise) {
            self.zipf.sample(rng) as u32
        } else {
            self.succ[cur as usize]
        }
    }

    /// Fill `out` with `batch` sequences of `seq` tokens each (row-major),
    /// as i32 for the int32 HLO token inputs.
    pub fn fill_batch(&self, rng: &mut Rng, out: &mut [i32], batch: usize, seq: usize) {
        assert_eq!(out.len(), batch * seq);
        for b in 0..batch {
            let mut cur = self.zipf.sample(rng) as u32;
            for s in 0..seq {
                out[b * seq + s] = cur as i32;
                cur = self.next_token(cur, rng);
            }
        }
    }

    /// Allocate-and-fill convenience.
    pub fn batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = vec![0i32; batch * seq];
        self.fill_batch(rng, &mut out, batch, seq);
        out
    }

    /// Approximate floor on the per-token cross-entropy (nats): the
    /// conditional entropy of the generator given the previous token,
    /// H ≈ h(p) + p·H(Zipf) with h the binary entropy of the noise coin.
    pub fn entropy_floor(&self) -> f64 {
        let p = self.noise;
        let h_coin = if p > 0.0 && p < 1.0 {
            -(1.0 - p) * (1.0 - p).ln() - p * p.ln()
        } else {
            0.0
        };
        // Zipf entropy from the unnormalized weights.
        let alpha = 1.1;
        let total: f64 = (1..=self.vocab).map(|i| 1.0 / (i as f64).powf(alpha)).sum();
        let hz: f64 = (1..=self.vocab)
            .map(|i| {
                let q = (1.0 / (i as f64).powf(alpha)) / total;
                -q * q.ln()
            })
            .sum();
        h_coin + p * hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_tokens_in_range() {
        let c = Corpus::new(512, 7);
        let mut rng = Rng::new(1);
        let b = c.batch(&mut rng, 8, 65);
        assert_eq!(b.len(), 8 * 65);
        assert!(b.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn marginals_are_zipfian_skewed() {
        let c = Corpus::new(256, 9);
        let mut rng = Rng::new(2);
        let mut counts = vec![0usize; 256];
        for _ in 0..200 {
            for &t in &c.batch(&mut rng, 4, 128) {
                counts[t as usize] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        let total: usize = counts.iter().sum();
        assert!(top10 as f64 / total as f64 > 0.1, "not skewed enough");
    }

    #[test]
    fn structure_is_learnable() {
        // Successor map: given cur, the modal next token is succ[cur].
        let c = Corpus::with_params(128, 3, 1.1, 0.25);
        let mut rng = Rng::new(3);
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            let b = c.batch(&mut rng, 2, 64);
            for row in b.chunks(64) {
                for w in row.windows(2) {
                    total += 1;
                    if c.succ[w[0] as usize] == w[1] as u32 {
                        correct += 1;
                    }
                }
            }
        }
        let frac = correct as f64 / total as f64;
        assert!(frac > 0.7, "successor followed only {frac}");
    }

    #[test]
    fn deterministic_given_seed() {
        let c = Corpus::new(512, 42);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        assert_eq!(c.batch(&mut r1, 4, 32), c.batch(&mut r2, 4, 32));
    }

    #[test]
    fn entropy_floor_below_log_vocab() {
        let c = Corpus::new(512, 1);
        let h = c.entropy_floor();
        assert!(h > 0.0 && h < (512f64).ln(), "floor {h}");
    }
}
