//! Synthetic data pipeline.
//!
//! The paper trains on CC-100/RoBERTa-corpus-scale text; offline we build
//! the closest synthetic equivalent that exercises the same code paths
//! (DESIGN.md §Substitutions): a Zipfian token stream with learnable
//! Markov structure for language modeling, and a family of GLUE-like
//! classification tasks for the Table 4 workload.

pub mod corpus;
pub mod glue;

pub use corpus::Corpus;
pub use glue::{GlueTask, GLUE_TASKS};
