//! GLUE-like synthetic classification tasks (the Table 4 workload).
//!
//! Eight tasks named after the GLUE suite, with sizes/difficulties scaled
//! so the per-task accuracy spread resembles the paper's Table 4 (large
//! tasks near ceiling, CoLA-like tasks noisy). Each task is a trigger-token
//! detection problem: the label is determined by which of `n_classes`
//! class-specific trigger-token groups dominates the sequence, with label
//! noise flipping a fraction of examples.

use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct GlueTask {
    pub name: &'static str,
    pub n_classes: usize,
    pub train_examples: usize,
    pub eval_examples: usize,
    /// Probability an example's label is resampled uniformly (difficulty).
    pub label_noise: f64,
    /// Trigger tokens injected per example (signal strength).
    pub triggers_per_example: usize,
}

/// The eight GLUE datasets of Table 4, as synthetic analogues. STS-B (a
/// regression task) is substituted with 4-way classification — documented
/// in DESIGN.md §Substitutions.
pub const GLUE_TASKS: [GlueTask; 8] = [
    GlueTask { name: "MNLI", n_classes: 3, train_examples: 6000, eval_examples: 512, label_noise: 0.05, triggers_per_example: 6 },
    GlueTask { name: "QNLI", n_classes: 2, train_examples: 4000, eval_examples: 512, label_noise: 0.04, triggers_per_example: 6 },
    GlueTask { name: "QQP", n_classes: 2, train_examples: 6000, eval_examples: 512, label_noise: 0.06, triggers_per_example: 6 },
    GlueTask { name: "RTE", n_classes: 2, train_examples: 800, eval_examples: 256, label_noise: 0.12, triggers_per_example: 4 },
    GlueTask { name: "SST-2", n_classes: 2, train_examples: 3000, eval_examples: 512, label_noise: 0.03, triggers_per_example: 8 },
    GlueTask { name: "MRPC", n_classes: 2, train_examples: 1200, eval_examples: 256, label_noise: 0.08, triggers_per_example: 5 },
    GlueTask { name: "CoLA", n_classes: 2, train_examples: 2000, eval_examples: 256, label_noise: 0.20, triggers_per_example: 3 },
    GlueTask { name: "STS-B", n_classes: 4, train_examples: 2000, eval_examples: 256, label_noise: 0.10, triggers_per_example: 5 },
];

pub struct GlueDataset {
    pub task: GlueTask,
    pub vocab: usize,
    pub seq_len: usize,
    pub train_tokens: Vec<i32>,
    pub train_labels: Vec<i32>,
    pub eval_tokens: Vec<i32>,
    pub eval_labels: Vec<i32>,
}

impl GlueDataset {
    /// Materialize a task. Trigger tokens for class c live in the id range
    /// [vocab - n_classes*8 + c*8, +8); the rest of the sequence is
    /// Zipfian filler.
    pub fn generate(task: &GlueTask, vocab: usize, seq_len: usize, seed: u64) -> GlueDataset {
        assert!(vocab > task.n_classes * 8 + 16);
        let mut rng = Rng::new(seed ^ 0x61_4C_55_45);
        let zipf = Zipf::new(vocab - task.n_classes * 8, 1.1);
        let gen = |n: usize, rng: &mut Rng| {
            let mut toks = Vec::with_capacity(n * seq_len);
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                let label = rng.below(task.n_classes as u64) as usize;
                let mut row: Vec<i32> =
                    (0..seq_len).map(|_| zipf.sample(rng) as i32).collect();
                // inject class triggers at random positions
                for _ in 0..task.triggers_per_example {
                    let pos = rng.below(seq_len as u64) as usize;
                    let trig = vocab - task.n_classes * 8 + label * 8
                        + rng.below(8) as usize;
                    row[pos] = trig as i32;
                }
                let observed = if rng.coin(task.label_noise) {
                    rng.below(task.n_classes as u64) as usize
                } else {
                    label
                };
                toks.extend_from_slice(&row);
                labels.push(observed as i32);
            }
            (toks, labels)
        };
        let (train_tokens, train_labels) = gen(task.train_examples, &mut rng);
        let (eval_tokens, eval_labels) = gen(task.eval_examples, &mut rng);
        GlueDataset {
            task: task.clone(),
            vocab,
            seq_len,
            train_tokens,
            train_labels,
            eval_tokens,
            eval_labels,
        }
    }

    pub fn n_train(&self) -> usize {
        self.train_labels.len()
    }

    /// Copy batch `idx` (wrapping) into the provided buffers.
    pub fn train_batch(
        &self,
        rng: &mut Rng,
        batch: usize,
        tokens_out: &mut Vec<i32>,
        labels_out: &mut Vec<i32>,
    ) {
        tokens_out.clear();
        labels_out.clear();
        for _ in 0..batch {
            let i = rng.below(self.n_train() as u64) as usize;
            tokens_out
                .extend_from_slice(&self.train_tokens[i * self.seq_len..(i + 1) * self.seq_len]);
            labels_out.push(self.train_labels[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_tasks_with_glue_names() {
        let names: Vec<_> = GLUE_TASKS.iter().map(|t| t.name).collect();
        assert_eq!(names, vec!["MNLI", "QNLI", "QQP", "RTE", "SST-2", "MRPC", "CoLA", "STS-B"]);
    }

    #[test]
    fn dataset_shapes() {
        let ds = GlueDataset::generate(&GLUE_TASKS[4], 1024, 64, 1);
        assert_eq!(ds.train_tokens.len(), ds.n_train() * 64);
        assert_eq!(ds.eval_tokens.len(), ds.eval_labels.len() * 64);
        assert!(ds.train_labels.iter().all(|&l| (0..2).contains(&l)));
    }

    #[test]
    fn triggers_make_task_solvable_by_counting() {
        // A bag-of-triggers classifier should beat chance comfortably.
        let task = &GLUE_TASKS[4]; // SST-2
        let ds = GlueDataset::generate(task, 1024, 64, 2);
        let base = 1024 - task.n_classes * 8;
        let mut correct = 0;
        for (i, &label) in ds.eval_labels.iter().enumerate() {
            let row = &ds.eval_tokens[i * 64..(i + 1) * 64];
            let mut counts = vec![0usize; task.n_classes];
            for &t in row {
                let t = t as usize;
                if t >= base {
                    counts[(t - base) / 8] += 1;
                }
            }
            let pred = counts
                .iter()
                .enumerate()
                .max_by_key(|(_, &c)| c)
                .unwrap()
                .0;
            if pred == label as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.eval_labels.len() as f64;
        assert!(acc > 0.85, "bag-of-triggers acc {acc}");
    }

    #[test]
    fn batches_are_deterministic_per_seed() {
        let ds = GlueDataset::generate(&GLUE_TASKS[0], 1024, 32, 3);
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let (mut t1, mut l1, mut t2, mut l2) = (vec![], vec![], vec![], vec![]);
        ds.train_batch(&mut r1, 8, &mut t1, &mut l1);
        ds.train_batch(&mut r2, 8, &mut t2, &mut l2);
        assert_eq!(t1, t2);
        assert_eq!(l1, l2);
    }
}
