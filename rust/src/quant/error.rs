//! Quantization-error metrics (Table 6, Appendix D/F).
//!
//! Two quantities from the paper:
//!  * **absolute quantization error** — E |x − dequant(quant(x))| per state;
//!  * **relative Adam error** — E |u32 − u8| / |u32| with
//!    u = m̂ / (sqrt(r̂) + ε), comparing the Adam update computed from exact
//!    states vs quantized states.

use super::blockwise::BlockQuantizer;
use crate::util::stats::Welford;

/// Mean absolute round-trip error of a quantizer on `data`.
pub fn abs_quant_error(bq: &BlockQuantizer, data: &[f32]) -> Welford {
    let y = bq.dequantize(&bq.quantize(data));
    let mut w = Welford::new();
    for (a, b) in data.iter().zip(&y) {
        w.push((a - b).abs() as f64);
    }
    w
}

/// Relative Adam error: quantize the two Adam states with `bq_m` / `bq_r`,
/// compute both updates and accumulate |u32−u8| / |u32| over elements where
/// the exact update is non-negligible.
pub fn relative_adam_error(
    bq_m: &BlockQuantizer,
    bq_r: &BlockQuantizer,
    m: &[f32],
    r: &[f32],
    eps: f32,
) -> Welford {
    assert_eq!(m.len(), r.len());
    let mq = bq_m.dequantize(&bq_m.quantize(m));
    let rq = bq_r.dequantize(&bq_r.quantize(r));
    let mut w = Welford::new();
    for i in 0..m.len() {
        let u32v = m[i] / (r[i].max(0.0).sqrt() + eps);
        let u8v = mq[i] / (rq[i].max(0.0).sqrt() + eps);
        let denom = u32v.abs();
        if denom > 1e-12 {
            w.push(((u32v - u8v).abs() / denom) as f64);
        }
    }
    w
}

/// Absolute Adam error |u32 − u8| (used by the Figure 4/5 analysis).
pub fn abs_adam_error(
    bq_m: &BlockQuantizer,
    bq_r: &BlockQuantizer,
    m: &[f32],
    r: &[f32],
    eps: f32,
) -> Welford {
    let mq = bq_m.dequantize(&bq_m.quantize(m));
    let rq = bq_r.dequantize(&bq_r.quantize(r));
    let mut w = Welford::new();
    for i in 0..m.len() {
        let u32v = m[i] / (r[i].max(0.0).sqrt() + eps);
        let u8v = mq[i] / (rq[i].max(0.0).sqrt() + eps);
        w.push((u32v - u8v).abs() as f64);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise::BLOCK;
    use crate::quant::dynamic_tree::{dynamic_signed, dynamic_unsigned};
    use crate::quant::linear::{linear_signed, linear_unsigned};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// Synthetic Adam states: m ~ small normal, r ~ squared small normal —
    /// spans several orders of magnitude like real training (§2.2).
    fn adam_states(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let m: Vec<f32> = (0..n).map(|_| (rng.normal() * 1e-3) as f32).collect();
        let r: Vec<f32> = (0..n)
            .map(|_| {
                let g = rng.normal() * 10f64.powf(rng.uniform_range(-4.0, -2.0));
                (g * g) as f32
            })
            .collect();
        (m, r)
    }

    #[test]
    fn dynamic_beats_linear_on_relative_adam_error() {
        // Table 6 ordering: Linear >> Dynamic in relative Adam error.
        let (m, r) = adam_states(100_000, 42);
        let dyn_m = BlockQuantizer::new(Arc::new(dynamic_signed()), BLOCK);
        let dyn_r = BlockQuantizer::new(Arc::new(dynamic_unsigned()), BLOCK);
        let lin_m = BlockQuantizer::new(Arc::new(linear_signed()), BLOCK);
        let lin_r = BlockQuantizer::new(Arc::new(linear_unsigned()), BLOCK);
        let e_dyn = relative_adam_error(&dyn_m, &dyn_r, &m, &r, 1e-8).mean();
        let e_lin = relative_adam_error(&lin_m, &lin_r, &m, &r, 1e-8).mean();
        assert!(
            e_dyn * 3.0 < e_lin,
            "dynamic {e_dyn:.4} should be ≪ linear {e_lin:.4}"
        );
    }

    #[test]
    fn blockwise_not_worse_than_tensor_wide_with_outliers() {
        let (mut m, _r) = adam_states(32_768, 43);
        // inject outliers every ~5000 elements
        for i in (0..m.len()).step_by(5000) {
            m[i] = 0.3;
        }
        let cb = Arc::new(dynamic_signed());
        let cbr = Arc::new(dynamic_unsigned());
        let bw_m = BlockQuantizer::new(cb.clone(), BLOCK);
        let tw_m = BlockQuantizer::tensor_wide(cb);
        let bw_r = BlockQuantizer::new(cbr.clone(), BLOCK);
        let tw_r = BlockQuantizer::tensor_wide(cbr);
        let e_bw = abs_quant_error(&bw_m, &m).mean();
        let e_tw = abs_quant_error(&tw_m, &m).mean();
        assert!(e_bw < e_tw, "blockwise {e_bw:.3e} vs tensor-wide {e_tw:.3e}");
        let _ = (bw_r, tw_r);
    }

    #[test]
    fn error_metrics_are_finite_and_nonnegative() {
        let (m, r) = adam_states(10_000, 44);
        let bq_m = BlockQuantizer::new(Arc::new(dynamic_signed()), BLOCK);
        let bq_r = BlockQuantizer::new(Arc::new(dynamic_unsigned()), BLOCK);
        for w in [
            abs_quant_error(&bq_m, &m),
            relative_adam_error(&bq_m, &bq_r, &m, &r, 1e-8),
            abs_adam_error(&bq_m, &bq_r, &m, &r, 1e-8),
        ] {
            assert!(w.mean().is_finite());
            assert!(w.mean() >= 0.0);
            assert!(w.count() > 0);
        }
    }
}
