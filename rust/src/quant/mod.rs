//! The paper's numeric-format substrate: 8-bit non-linear quantization.
//!
//! * [`codebook`] — the `Q^map` abstraction + nearest / stochastic encode.
//! * [`dynamic_tree`] — dynamic (tree) quantization, signed / unsigned /
//!   inverse variants (§1.3, §2.2, Appendix F.1).
//! * [`linear`] — linear baseline (Table 3 ablation, Table 6).
//! * [`quantile`] — lossy minimum-entropy encoding (Appendix F.2).
//! * [`sram_quantiles`] — fast approximate quantile estimation (Appendix G).
//! * [`blockwise`] — block-wise normalization machinery (§2.1).
//! * [`error`] — quantization / Adam error metrics (Table 6, Appendix D).

pub mod blockwise;
pub mod codebook;
pub mod dynamic_tree;
pub mod error;
pub mod linear;
pub mod quantile;
pub mod sram_quantiles;

pub use blockwise::{BlockQuantizer, Quantized, BLOCK};
pub use codebook::Codebook;

use std::sync::{Arc, OnceLock};

/// The quantization formats the paper evaluates (Tables 3 & 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// Dynamic (tree) quantization — the paper's method.
    Dynamic,
    /// Linear quantization — ablation baseline.
    Linear,
    /// Quantile quantization (Appendix F.2).
    Quantile,
    /// Inverse dynamic quantization (Appendix F.1).
    InverseDynamic,
}

impl Format {
    pub fn parse(s: &str) -> Option<Format> {
        match s {
            "dynamic" => Some(Format::Dynamic),
            "linear" => Some(Format::Linear),
            "quantile" => Some(Format::Quantile),
            "inverse-dynamic" | "inverse_dynamic" => Some(Format::InverseDynamic),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Format::Dynamic => "dynamic",
            Format::Linear => "linear",
            Format::Quantile => "quantile",
            Format::InverseDynamic => "inverse-dynamic",
        }
    }

    fn index(&self) -> usize {
        match self {
            Format::Dynamic => 0,
            Format::Linear => 1,
            Format::Quantile => 2,
            Format::InverseDynamic => 3,
        }
    }

    /// Codebook for signed state tensors (momentum / first Adam state).
    ///
    /// Memoized process-wide: building a codebook includes its 16K-entry
    /// LUT (and, for `Quantile`, a 1M-sample Monte-Carlo draw), which used
    /// to be re-done once per parameter tensor.
    pub fn signed_codebook(&self) -> Arc<Codebook> {
        static CACHE: [OnceLock<Arc<Codebook>>; 4] = [const { OnceLock::new() }; 4];
        CACHE[self.index()]
            .get_or_init(|| {
                Arc::new(match self {
                    Format::Dynamic => dynamic_tree::dynamic_signed(),
                    Format::Linear => linear::linear_signed(),
                    Format::Quantile => quantile::quantile_normal(),
                    Format::InverseDynamic => dynamic_tree::inverse_dynamic_signed(),
                })
            })
            .clone()
    }

    /// Codebook for non-negative state tensors (second Adam state, AdaGrad
    /// accumulator). Memoized like [`Format::signed_codebook`].
    pub fn unsigned_codebook(&self) -> Arc<Codebook> {
        static CACHE: [OnceLock<Arc<Codebook>>; 4] = [const { OnceLock::new() }; 4];
        CACHE[self.index()]
            .get_or_init(|| {
                Arc::new(match self {
                    Format::Dynamic => dynamic_tree::dynamic_unsigned(),
                    Format::Linear => linear::linear_unsigned(),
                    // Quantile of the squared-normal (chi²₁) distribution.
                    Format::Quantile => {
                        use crate::util::rng::Rng;
                        let mut rng = Rng::new(0x51_51_51);
                        let data: Vec<f32> = (0..1_000_000)
                            .map(|_| {
                                let g = rng.normal();
                                (g * g) as f32
                            })
                            .collect();
                        quantile::quantile_from_data(&data)
                    }
                    Format::InverseDynamic => dynamic_tree::inverse_dynamic_unsigned(),
                })
            })
            .clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parse_roundtrip() {
        for f in [Format::Dynamic, Format::Linear, Format::Quantile, Format::InverseDynamic] {
            assert_eq!(Format::parse(f.name()), Some(f));
        }
        assert_eq!(Format::parse("bogus"), None);
    }

    #[test]
    fn codebooks_construct_for_all_formats() {
        for f in [Format::Dynamic, Format::Linear, Format::Quantile, Format::InverseDynamic] {
            assert!(f.signed_codebook().len() > 100);
            assert!(f.unsigned_codebook().len() > 100);
        }
    }

    #[test]
    fn codebooks_are_memoized_per_format() {
        for f in [Format::Dynamic, Format::Linear, Format::Quantile, Format::InverseDynamic] {
            assert!(Arc::ptr_eq(&f.signed_codebook(), &f.signed_codebook()));
            assert!(Arc::ptr_eq(&f.unsigned_codebook(), &f.unsigned_codebook()));
        }
        // distinct formats must not collide in the cache
        assert!(!Arc::ptr_eq(
            &Format::Dynamic.signed_codebook(),
            &Format::Linear.signed_codebook()
        ));
    }
}
