//! The paper's numeric-format substrate: non-linear quantization at a
//! parameterized code width (8-bit per the source paper, 4-bit per Li et
//! al. 2023).
//!
//! * [`codebook`] — the `Q^map` abstraction + nearest / stochastic encode.
//! * [`codebuf`] — packed code storage ([`CodeWidth::U8`] byte-per-code,
//!   [`CodeWidth::U4`] two-codes-per-byte).
//! * [`dynamic_tree`] — dynamic (tree) quantization, signed / unsigned /
//!   inverse variants (§1.3, §2.2, Appendix F.1) at 256 or 16 levels.
//! * [`linear`] — linear baseline (Table 3 ablation, Table 6).
//! * [`quantile`] — lossy minimum-entropy encoding (Appendix F.2).
//! * [`sram_quantiles`] — fast approximate quantile estimation (Appendix G).
//! * [`blockwise`] — width-generic block-wise normalization machinery
//!   (§2.1).
//! * [`error`] — quantization / Adam error metrics (Table 6, Appendix D).

pub mod blockwise;
pub mod codebook;
pub mod codebuf;
pub mod dynamic_tree;
pub mod error;
pub mod linear;
pub mod quantile;
pub mod sram_quantiles;

pub use blockwise::{
    dequantize_block_codes, quantize_block_codes, take_nonfinite_blocks, BlockQuantizer,
    Quantized, BLOCK,
};
pub use codebook::Codebook;
pub use codebuf::{CodeBuf, CodeWidth};

use std::sync::{Arc, OnceLock};

/// The quantization formats the paper evaluates (Tables 3 & 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// Dynamic (tree) quantization — the paper's method.
    Dynamic,
    /// Linear quantization — ablation baseline.
    Linear,
    /// Quantile quantization (Appendix F.2).
    Quantile,
    /// Inverse dynamic quantization (Appendix F.1).
    InverseDynamic,
}

impl Format {
    pub fn parse(s: &str) -> Option<Format> {
        match s {
            "dynamic" => Some(Format::Dynamic),
            "linear" => Some(Format::Linear),
            "quantile" => Some(Format::Quantile),
            "inverse-dynamic" | "inverse_dynamic" => Some(Format::InverseDynamic),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Format::Dynamic => "dynamic",
            Format::Linear => "linear",
            Format::Quantile => "quantile",
            Format::InverseDynamic => "inverse-dynamic",
        }
    }

    fn index(&self) -> usize {
        match self {
            Format::Dynamic => 0,
            Format::Linear => 1,
            Format::Quantile => 2,
            Format::InverseDynamic => 3,
        }
    }

    /// Codebook for signed state tensors (momentum / first Adam state).
    ///
    /// Memoized process-wide: building a codebook includes its 16K-entry
    /// LUT (and, for `Quantile`, a 1M-sample Monte-Carlo draw), which used
    /// to be re-done once per parameter tensor.
    pub fn signed_codebook(&self) -> Arc<Codebook> {
        static CACHE: [OnceLock<Arc<Codebook>>; 4] = [const { OnceLock::new() }; 4];
        CACHE[self.index()]
            .get_or_init(|| {
                Arc::new(match self {
                    Format::Dynamic => dynamic_tree::dynamic_signed(),
                    Format::Linear => linear::linear_signed(),
                    Format::Quantile => quantile::quantile_normal(),
                    Format::InverseDynamic => dynamic_tree::inverse_dynamic_signed(),
                })
            })
            .clone()
    }

    /// Codebook for non-negative state tensors (second Adam state, AdaGrad
    /// accumulator). Memoized like [`Format::signed_codebook`].
    pub fn unsigned_codebook(&self) -> Arc<Codebook> {
        static CACHE: [OnceLock<Arc<Codebook>>; 4] = [const { OnceLock::new() }; 4];
        CACHE[self.index()]
            .get_or_init(|| {
                Arc::new(match self {
                    Format::Dynamic => dynamic_tree::dynamic_unsigned(),
                    Format::Linear => linear::linear_unsigned(),
                    // Quantile of the squared-normal (chi²₁) distribution.
                    Format::Quantile => quantile::quantile_from_data(&chi2_sample()),
                    Format::InverseDynamic => dynamic_tree::inverse_dynamic_unsigned(),
                })
            })
            .clone()
    }

    /// 16-level signed codebook (4-bit packed state, Li et al. 2023).
    /// Memoized like the 8-bit variants.
    pub fn signed_codebook4(&self) -> Arc<Codebook> {
        static CACHE: [OnceLock<Arc<Codebook>>; 4] = [const { OnceLock::new() }; 4];
        CACHE[self.index()]
            .get_or_init(|| {
                Arc::new(match self {
                    Format::Dynamic => dynamic_tree::dynamic_signed4(),
                    Format::Linear => linear::linear_signed4(),
                    Format::Quantile => quantile::quantile_normal_levels(16),
                    Format::InverseDynamic => dynamic_tree::inverse_dynamic_signed4(),
                })
            })
            .clone()
    }

    /// 16-level unsigned codebook (4-bit packed state).
    pub fn unsigned_codebook4(&self) -> Arc<Codebook> {
        static CACHE: [OnceLock<Arc<Codebook>>; 4] = [const { OnceLock::new() }; 4];
        CACHE[self.index()]
            .get_or_init(|| {
                Arc::new(match self {
                    Format::Dynamic => dynamic_tree::dynamic_unsigned4(),
                    Format::Linear => linear::linear_unsigned4(),
                    Format::Quantile => {
                        quantile::quantile_from_data_levels(&chi2_sample(), 16)
                    }
                    Format::InverseDynamic => dynamic_tree::inverse_dynamic_unsigned4(),
                })
            })
            .clone()
    }

    /// Width-dispatching codebook lookup — the one entry point the
    /// optimizer substrate uses, so state construction is width-agnostic.
    pub fn codebook(&self, width: CodeWidth, signed: bool) -> Arc<Codebook> {
        match (width, signed) {
            (CodeWidth::U8, true) => self.signed_codebook(),
            (CodeWidth::U8, false) => self.unsigned_codebook(),
            (CodeWidth::U4, true) => self.signed_codebook4(),
            (CodeWidth::U4, false) => self.unsigned_codebook4(),
        }
    }
}

/// Deterministic chi²₁ sample for the unsigned quantile codebooks.
fn chi2_sample() -> Vec<f32> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(0x51_51_51);
    (0..1_000_000)
        .map(|_| {
            let g = rng.normal();
            (g * g) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parse_roundtrip() {
        for f in [Format::Dynamic, Format::Linear, Format::Quantile, Format::InverseDynamic] {
            assert_eq!(Format::parse(f.name()), Some(f));
        }
        assert_eq!(Format::parse("bogus"), None);
    }

    #[test]
    fn codebooks_construct_for_all_formats() {
        for f in [Format::Dynamic, Format::Linear, Format::Quantile, Format::InverseDynamic] {
            assert!(f.signed_codebook().len() > 100);
            assert!(f.unsigned_codebook().len() > 100);
        }
    }

    #[test]
    fn four_bit_codebooks_fit_their_width_for_all_formats() {
        for f in [Format::Dynamic, Format::Linear, Format::Quantile, Format::InverseDynamic] {
            for signed in [true, false] {
                let cb = f.codebook(CodeWidth::U4, signed);
                assert!(
                    cb.len() <= CodeWidth::U4.max_levels(),
                    "{} {:?} has {} levels",
                    f.name(),
                    signed,
                    cb.len()
                );
                assert!(cb.len() >= 12, "{} unexpectedly coarse", f.name());
                // width dispatch is memoized per (format, width, signedness)
                assert!(Arc::ptr_eq(&cb, &f.codebook(CodeWidth::U4, signed)));
                // and never collides with the 8-bit cache
                assert!(!Arc::ptr_eq(&cb, &f.codebook(CodeWidth::U8, signed)));
            }
        }
    }

    #[test]
    fn codebooks_are_memoized_per_format() {
        for f in [Format::Dynamic, Format::Linear, Format::Quantile, Format::InverseDynamic] {
            assert!(Arc::ptr_eq(&f.signed_codebook(), &f.signed_codebook()));
            assert!(Arc::ptr_eq(&f.unsigned_codebook(), &f.unsigned_codebook()));
        }
        // distinct formats must not collide in the cache
        assert!(!Arc::ptr_eq(
            &Format::Dynamic.signed_codebook(),
            &Format::Linear.signed_codebook()
        ));
    }
}
