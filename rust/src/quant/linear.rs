//! Linear quantization codebooks — the ablation baseline (Table 3) and the
//! "Linear" row of Table 6. Equally spaced representable values.
//!
//! Equal spacing gives these codebooks a one-multiply closed-form encode
//! candidate (`round(x·scale) + offset`), so they carry analytic + batched
//! encoders like the dynamic trees do: the candidate step vectorizes
//! across lanes and `Codebook::resolve_candidate` pins every code
//! bit-identical to the reference midpoint search.

use super::codebook::Codebook;
use crate::util::lanes::LANES;

/// Closed-form code-index candidate for an equally spaced codebook with
/// values { (i - offset)/scale }. `as usize` is a saturating cast, so NaN
/// and -inf land on 0 and +inf on the top code — the reference results —
/// and the exact fixup in `Codebook::resolve_candidate` absorbs the
/// (≤1 ulp) rounding slack everywhere else.
#[inline(always)]
fn linear_candidate(x: f32, scale: f32, offset: f32) -> usize {
    ((x * scale).round() + offset) as usize
}

fn candidate_linear_signed(x: f32) -> usize {
    linear_candidate(x, 127.0, 127.0)
}

fn candidate_linear_unsigned(x: f32) -> usize {
    linear_candidate(x, 255.0, 0.0)
}

fn candidate_linear_signed4(x: f32) -> usize {
    linear_candidate(x, 7.0, 7.0)
}

fn candidate_linear_unsigned4(x: f32) -> usize {
    linear_candidate(x, 15.0, 0.0)
}

fn batch_linear_signed(xs: &[f32; LANES]) -> [usize; LANES] {
    let mut out = [0usize; LANES];
    for l in 0..LANES {
        out[l] = linear_candidate(xs[l], 127.0, 127.0);
    }
    out
}

fn batch_linear_unsigned(xs: &[f32; LANES]) -> [usize; LANES] {
    let mut out = [0usize; LANES];
    for l in 0..LANES {
        out[l] = linear_candidate(xs[l], 255.0, 0.0);
    }
    out
}

fn batch_linear_signed4(xs: &[f32; LANES]) -> [usize; LANES] {
    let mut out = [0usize; LANES];
    for l in 0..LANES {
        out[l] = linear_candidate(xs[l], 7.0, 7.0);
    }
    out
}

fn batch_linear_unsigned4(xs: &[f32; LANES]) -> [usize; LANES] {
    let mut out = [0usize; LANES];
    for l in 0..LANES {
        out[l] = linear_candidate(xs[l], 15.0, 0.0);
    }
    out
}

/// Signed linear: 255 values { i/127 : i = -127..=127 }. Includes exact
/// -1, 0, +1 (symmetric; one 8-bit code is unused, as in symmetric int8).
pub fn linear_signed() -> Codebook {
    let vals: Vec<f32> = (-127..=127).map(|i| i as f32 / 127.0).collect();
    Codebook::new_analytic_batched(
        "linear_signed",
        vals,
        candidate_linear_signed,
        batch_linear_signed,
    )
}

/// Unsigned linear: 256 values { i/255 : i = 0..=255 }.
pub fn linear_unsigned() -> Codebook {
    let vals: Vec<f32> = (0..=255).map(|i| i as f32 / 255.0).collect();
    Codebook::new_analytic_batched(
        "linear_unsigned",
        vals,
        candidate_linear_unsigned,
        batch_linear_unsigned,
    )
}

/// Signed linear at 16-level resolution: 15 values { i/7 : i = -7..=7 }
/// (symmetric int4 analogue — one 4-bit code unused).
pub fn linear_signed4() -> Codebook {
    let vals: Vec<f32> = (-7..=7).map(|i| i as f32 / 7.0).collect();
    Codebook::new_analytic_batched(
        "linear_signed4",
        vals,
        candidate_linear_signed4,
        batch_linear_signed4,
    )
}

/// Unsigned linear at 16-level resolution: { i/15 : i = 0..=15 }.
pub fn linear_unsigned4() -> Codebook {
    let vals: Vec<f32> = (0..=15).map(|i| i as f32 / 15.0).collect();
    Codebook::new_analytic_batched(
        "linear_unsigned4",
        vals,
        candidate_linear_unsigned4,
        batch_linear_unsigned4,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(linear_signed().len(), 255);
        assert_eq!(linear_unsigned().len(), 256);
        assert_eq!(linear_signed4().len(), 15);
        assert_eq!(linear_unsigned4().len(), 16);
    }

    #[test]
    fn four_bit_endpoints_and_zero() {
        let s = linear_signed4();
        assert!(s.values().contains(&-1.0) && s.values().contains(&0.0));
        assert!(s.values().contains(&1.0) && s.all_distinct());
        let u = linear_unsigned4();
        assert_eq!(u.values()[0], 0.0);
        assert_eq!(*u.values().last().unwrap(), 1.0);
    }

    #[test]
    fn signed_endpoints_and_zero() {
        let cb = linear_signed();
        assert!(cb.values().contains(&-1.0));
        assert!(cb.values().contains(&0.0));
        assert!(cb.values().contains(&1.0));
        assert!(cb.all_distinct());
    }

    #[test]
    fn uniform_spacing() {
        let cb = linear_signed();
        let gaps: Vec<f32> = cb.values().windows(2).map(|w| w[1] - w[0]).collect();
        let g0 = gaps[0];
        assert!(gaps.iter().all(|g| (g - g0).abs() < 1e-6));
    }

    #[test]
    fn unsigned_covers_unit_interval() {
        let cb = linear_unsigned();
        assert_eq!(cb.values()[0], 0.0);
        assert_eq!(*cb.values().last().unwrap(), 1.0);
    }

    #[test]
    fn linear_small_value_error_is_poor_vs_dynamic() {
        // The paper's motivation: linear wastes precision on small values.
        let lin = linear_unsigned();
        let dyn_u = super::super::dynamic_tree::dynamic_unsigned();
        let x = 3e-4f32;
        let err_lin = (lin.nearest(x) - x).abs();
        let err_dyn = (dyn_u.nearest(x) - x).abs();
        assert!(err_dyn < err_lin, "dyn {err_dyn} vs lin {err_lin}");
    }
}
