//! Linear quantization codebooks — the ablation baseline (Table 3) and the
//! "Linear" row of Table 6. Equally spaced representable values.

use super::codebook::Codebook;

/// Signed linear: 255 values { i/127 : i = -127..=127 }. Includes exact
/// -1, 0, +1 (symmetric; one 8-bit code is unused, as in symmetric int8).
pub fn linear_signed() -> Codebook {
    let vals: Vec<f32> = (-127..=127).map(|i| i as f32 / 127.0).collect();
    Codebook::new("linear_signed", vals)
}

/// Unsigned linear: 256 values { i/255 : i = 0..=255 }.
pub fn linear_unsigned() -> Codebook {
    let vals: Vec<f32> = (0..=255).map(|i| i as f32 / 255.0).collect();
    Codebook::new("linear_unsigned", vals)
}

/// Signed linear at 16-level resolution: 15 values { i/7 : i = -7..=7 }
/// (symmetric int4 analogue — one 4-bit code unused).
pub fn linear_signed4() -> Codebook {
    let vals: Vec<f32> = (-7..=7).map(|i| i as f32 / 7.0).collect();
    Codebook::new("linear_signed4", vals)
}

/// Unsigned linear at 16-level resolution: { i/15 : i = 0..=15 }.
pub fn linear_unsigned4() -> Codebook {
    let vals: Vec<f32> = (0..=15).map(|i| i as f32 / 15.0).collect();
    Codebook::new("linear_unsigned4", vals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(linear_signed().len(), 255);
        assert_eq!(linear_unsigned().len(), 256);
        assert_eq!(linear_signed4().len(), 15);
        assert_eq!(linear_unsigned4().len(), 16);
    }

    #[test]
    fn four_bit_endpoints_and_zero() {
        let s = linear_signed4();
        assert!(s.values().contains(&-1.0) && s.values().contains(&0.0));
        assert!(s.values().contains(&1.0) && s.all_distinct());
        let u = linear_unsigned4();
        assert_eq!(u.values()[0], 0.0);
        assert_eq!(*u.values().last().unwrap(), 1.0);
    }

    #[test]
    fn signed_endpoints_and_zero() {
        let cb = linear_signed();
        assert!(cb.values().contains(&-1.0));
        assert!(cb.values().contains(&0.0));
        assert!(cb.values().contains(&1.0));
        assert!(cb.all_distinct());
    }

    #[test]
    fn uniform_spacing() {
        let cb = linear_signed();
        let gaps: Vec<f32> = cb.values().windows(2).map(|w| w[1] - w[0]).collect();
        let g0 = gaps[0];
        assert!(gaps.iter().all(|g| (g - g0).abs() < 1e-6));
    }

    #[test]
    fn unsigned_covers_unit_interval() {
        let cb = linear_unsigned();
        assert_eq!(cb.values()[0], 0.0);
        assert_eq!(*cb.values().last().unwrap(), 1.0);
    }

    #[test]
    fn linear_small_value_error_is_poor_vs_dynamic() {
        // The paper's motivation: linear wastes precision on small values.
        let lin = linear_unsigned();
        let dyn_u = super::super::dynamic_tree::dynamic_unsigned();
        let x = 3e-4f32;
        let err_lin = (lin.nearest(x) - x).abs();
        let err_dyn = (dyn_u.nearest(x) - x).abs();
        assert!(err_dyn < err_lin, "dyn {err_dyn} vs lin {err_lin}");
    }
}
