//! SRAM-Quantiles (Appendix G): fast approximate estimation of the 256
//! sample quantiles needed by quantile quantization.
//!
//! Idea from the paper: full-tensor sorting thrashes DRAM; instead, find the
//! eCDF of *subsets that fit in SRAM* (4096 values on the paper's GPU; here
//! a cache-resident chunk), read the 257 equally spaced quantiles of each
//! subset, and average the per-subset quantiles — the arithmetic mean is an
//! unbiased estimator and subset sample quantiles are asymptotically
//! unbiased (Chen & Kelton 2001), so more subsets ⇒ better estimates.

use crate::util::parallel;

/// Subset size — the paper's SRAM budget (≈4096 f32 per core).
pub const SRAM_CHUNK: usize = 4096;

/// Estimate `k` equally spaced quantiles of `data` (Eq. 5 uses k = 2^8 + 1
/// boundary quantiles). Chunks are processed independently (in parallel)
/// and their quantile vectors averaged.
pub fn estimate_quantiles(data: &[f32], k: usize) -> Vec<f32> {
    assert!(k >= 2);
    assert!(!data.is_empty());
    let n_chunks = data.len().div_ceil(SRAM_CHUNK);
    let partials: Vec<Vec<f64>> = parallel::par_map(n_chunks, |c| {
        let lo = c * SRAM_CHUNK;
        let hi = (lo + SRAM_CHUNK).min(data.len());
        let mut chunk: Vec<f32> = data[lo..hi].to_vec();
        chunk.sort_by(|a, b| a.partial_cmp(b).expect("finite input"));
        chunk_quantiles(&chunk, k)
    });
    // Average per-quantile across chunks (atomic adds in the paper; a plain
    // reduction here).
    let mut acc = vec![0.0f64; k];
    for p in &partials {
        for (a, v) in acc.iter_mut().zip(p) {
            *a += v;
        }
    }
    let inv = 1.0 / partials.len() as f64;
    acc.into_iter().map(|v| (v * inv) as f32).collect()
}

/// Exact quantiles by full sort — the slow baseline SRAM-Quantiles is
/// benchmarked against (`benches/quantiles.rs`).
pub fn exact_quantiles(data: &[f32], k: usize) -> Vec<f32> {
    let mut sorted: Vec<f32> = data.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite input"));
    chunk_quantiles(&sorted, k)
        .into_iter()
        .map(|v| v as f32)
        .collect()
}

/// `k` equally spaced quantiles of an already-sorted slice, with linear
/// interpolation between order statistics.
fn chunk_quantiles(sorted: &[f32], k: usize) -> Vec<f64> {
    let n = sorted.len();
    (0..k)
        .map(|i| {
            let q = i as f64 / (k - 1) as f64;
            let rank = q * (n - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            if lo == hi {
                sorted[lo] as f64
            } else {
                let w = rank - lo as f64;
                sorted[lo] as f64 * (1.0 - w) + sorted[hi] as f64 * w
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_quantiles_of_uniform_grid() {
        let data: Vec<f32> = (0..1001).map(|i| i as f32 / 1000.0).collect();
        let q = exact_quantiles(&data, 5);
        let expect = [0.0, 0.25, 0.5, 0.75, 1.0];
        for (a, b) in q.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn estimate_close_to_exact_for_normal_data() {
        let mut rng = Rng::new(99);
        let data: Vec<f32> = (0..200_000).map(|_| rng.normal() as f32).collect();
        let est = estimate_quantiles(&data, 257);
        let exact = exact_quantiles(&data, 257);
        // Compare interior quantiles (extremes have high estimator variance).
        let mut max_err = 0.0f32;
        for i in 8..249 {
            max_err = max_err.max((est[i] - exact[i]).abs());
        }
        assert!(max_err < 0.05, "max interior error {max_err}");
    }

    #[test]
    fn estimates_are_monotone() {
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..50_000).map(|_| (rng.normal() * 3.0) as f32).collect();
        let est = estimate_quantiles(&data, 257);
        for w in est.windows(2) {
            assert!(w[1] >= w[0] - 1e-6);
        }
    }

    #[test]
    fn short_input_works() {
        let q = estimate_quantiles(&[1.0, 2.0, 3.0], 3);
        assert_eq!(q.len(), 3);
        assert!((q[0] - 1.0).abs() < 1e-6 && (q[2] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn deterministic() {
        let mut rng = Rng::new(5);
        let data: Vec<f32> = (0..30_000).map(|_| rng.normal() as f32).collect();
        assert_eq!(estimate_quantiles(&data, 65), estimate_quantiles(&data, 65));
    }
}
