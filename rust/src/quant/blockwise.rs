//! Block-wise quantization (paper §2.1), width-generic.
//!
//! The input tensor is viewed as a flat sequence chunked into blocks of
//! B = 2048 elements. Each block is normalized by its own absolute maximum
//! `N_b = max|T_b|` and quantized independently (Eq. 4). Consequences the
//! tests pin down:
//!   * blocks are independent — no cross-block synchronization (throughput),
//!   * an outlier only perturbs its own block (stability),
//!   * the per-block max is quantized with *zero* error (absmax/N_b = ±1 and
//!     ±1 is in the codebook).
//!
//! Codes are stored packed in a [`CodeBuf`]: one byte per code at
//! [`CodeWidth::U8`] (the paper's layout) or two codes per byte at
//! [`CodeWidth::U4`] (Li et al. 2023). All block-partition arithmetic is
//! width-agnostic; the packed fast paths ([`quantize_block_codes`],
//! [`dequantize_block_codes`]) encode/decode straight between f32 scratch
//! and packed storage without an intermediate unpacked buffer.
//!
//! # Lane layout and the scalar-tail contract
//!
//! The packed fast paths are *lane-chunked* (see [`crate::util::lanes`]):
//! each block is processed as consecutive
//! [`LANES`](crate::util::lanes::LANES)-wide `[f32; 8]` chunks — the
//! absmax scan as lane-wise maxima with one horizontal reduce per block,
//! decode as a gather from the codebook's contiguous value table
//! ([`Codebook::values`]) fused with U4 nibble unpacking, encode through
//! [`Codebook::encode_lanes`] (batched analytic candidate + exact midpoint
//! fixup) fused with U4 nibble packing — followed by a *scalar tail* of
//! `len % LANES` elements (U4 tails also absorb the odd element whose dead
//! high nibble stays zero). Lane chunks perform the identical per-element
//! IEEE arithmetic as the tail loops, in the same element order, so the
//! output is bit-identical however a block is split; forcing
//! [`lanes::scalar_forced`](crate::util::lanes::scalar_forced) routes
//! whole blocks through the tail code, which is what the parity tests
//! (`rust/tests/simd_parity.rs`, the `pool_parity` scalar-vs-lane fleets)
//! diff against and what the `simd_sweep` benchmark uses as its baseline.
//!
//! The absmax scan skips non-finite elements — one NaN/±inf gradient must
//! not poison `N_b` and silently zero (or NaN) every code in its block —
//! and counts affected blocks in a process-global telemetry counter
//! ([`take_nonfinite_blocks`]) that the trainer drains into its existing
//! `grad_crash` signal.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::codebook::Codebook;
use super::codebuf::{CodeBuf, CodeWidth};
use crate::util::lanes::{self, LANES};
use crate::util::parallel;

/// Blocks whose absmax scan saw at least one non-finite element since the
/// last [`take_nonfinite_blocks`] call. Process-global for the same reason
/// the scan itself runs on pool workers; drained once per optimizer step.
static NONFINITE_BLOCKS: AtomicU64 = AtomicU64::new(0);

/// Drain the non-finite-block telemetry counter (returns the count since
/// the previous drain). The trainer reports a positive count through the
/// same `grad_crash` channel as a non-finite gradient norm.
pub fn take_nonfinite_blocks() -> u64 {
    NONFINITE_BLOCKS.swap(0, Ordering::Relaxed)
}

/// Test-only: bump the non-finite-block counter, so drain-path regression
/// tests can verify a crashed step's count never leaks into the next
/// step's record.
#[cfg(test)]
pub(crate) fn bump_nonfinite_for_test(n: u64) {
    NONFINITE_BLOCKS.fetch_add(n, Ordering::Relaxed);
}

/// The paper's block size.
pub const BLOCK: usize = 2048;

/// A quantized tensor: packed codes plus one f32 absmax per block.
/// Memory: `bits/8` bytes/element + 4/B bytes/element overhead (≈1.002
/// bytes/element at 8-bit B=2048, ≈0.502 at 4-bit).
#[derive(Clone, Debug)]
pub struct Quantized {
    pub codes: CodeBuf,
    pub absmax: Vec<f32>,
    pub len: usize,
    pub block: usize,
}

impl Quantized {
    pub fn zeros(len: usize, block: usize, zero_code: u8, width: CodeWidth) -> Quantized {
        // U4 blocks must start on byte boundaries so the parallel block
        // engine never has two blocks sharing a byte: any block size works
        // for a single-block tensor, multi-block tensors need an even one.
        assert!(
            width == CodeWidth::U8 || block % 2 == 0 || len <= block,
            "4-bit packing needs an even block size (got {block} for {len} elements)"
        );
        let n_blocks = len.div_ceil(block).max(1);
        Quantized {
            codes: CodeBuf::filled(width, len, zero_code),
            absmax: vec![0.0; n_blocks],
            len,
            block,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.absmax.len()
    }

    /// Element range `[lo, hi)` covered by block `b` (last block may be
    /// short) — the one place the block partition arithmetic lives.
    pub fn block_range(&self, b: usize) -> (usize, usize) {
        let lo = b * self.block;
        (lo, (lo + self.block).min(self.len))
    }

    /// Packed-byte range of block `b` within `codes.as_bytes()`.
    pub fn code_byte_range(&self, b: usize) -> (usize, usize) {
        let (lo, hi) = self.block_range(b);
        let width = self.codes.width();
        (width.bytes_for(lo), width.bytes_for(lo) + width.bytes_for(hi - lo))
    }

    /// Code width of the stored codes.
    pub fn width(&self) -> CodeWidth {
        self.codes.width()
    }

    /// Total storage in bytes (packed codes + absmax).
    pub fn bytes(&self) -> usize {
        self.codes.storage_bytes() + self.absmax.len() * 4
    }
}

/// Quantizer = codebook + block size + code width. `block >= len`
/// degenerates to the tensor-wide normalization of plain dynamic
/// quantization (§1.2), which is exactly the ablation baseline in Table 3.
#[derive(Clone)]
pub struct BlockQuantizer {
    pub codebook: Arc<Codebook>,
    pub block: usize,
    pub width: CodeWidth,
}

impl BlockQuantizer {
    /// Byte-per-code quantizer (the paper's 8-bit layout).
    pub fn new(codebook: Arc<Codebook>, block: usize) -> Self {
        Self::with_width(codebook, block, CodeWidth::U8)
    }

    /// Width-generic constructor; the codebook must be indexable at the
    /// chosen width.
    pub fn with_width(codebook: Arc<Codebook>, block: usize, width: CodeWidth) -> Self {
        assert!(block > 0);
        assert!(
            codebook.len() <= width.max_levels(),
            "codebook {} has {} levels, max {} at {:?}",
            codebook.name(),
            codebook.len(),
            width.max_levels(),
            width
        );
        Self { codebook, block, width }
    }

    /// Tensor-wide variant (single normalization constant).
    pub fn tensor_wide(codebook: Arc<Codebook>) -> Self {
        Self { codebook, block: usize::MAX, width: CodeWidth::U8 }
    }

    fn effective_block(&self, len: usize) -> usize {
        self.block.min(len.max(1))
    }

    /// Quantize a full tensor (parallel over blocks).
    pub fn quantize(&self, x: &[f32]) -> Quantized {
        let block = self.effective_block(x.len());
        let zero = self.codebook.encode(0.0);
        let mut q = Quantized::zeros(x.len(), block, zero, self.width);
        self.quantize_into(x, &mut q);
        q
    }

    /// Re-quantize into existing storage (hot path — no allocation). Width
    /// and block size are taken from `q` itself, so the encoding codebook
    /// must fit `q`'s width even if this quantizer was declared wider.
    pub fn quantize_into(&self, x: &[f32], q: &mut Quantized) {
        assert_eq!(x.len(), q.len);
        let block = q.block;
        let width = q.codes.width();
        assert!(
            self.codebook.len() <= width.max_levels(),
            "codebook {} has {} levels, max {} at {:?}",
            self.codebook.name(),
            self.codebook.len(),
            width.max_levels(),
            width
        );
        let block_bytes = width.bytes_for(block.min(q.len.max(1)));
        let cb = &*self.codebook;
        parallel::par_chunks_pair_mut(
            q.codes.as_mut_bytes(),
            block_bytes.max(1),
            &mut q.absmax,
            1,
            |b, bytes, am| {
                let lo = b * block;
                let hi = (lo + block).min(x.len());
                am[0] = quantize_block_codes(cb, width, &x[lo..hi], bytes);
            },
        );
    }

    /// Dequantize a full tensor.
    pub fn dequantize(&self, q: &Quantized) -> Vec<f32> {
        let mut out = vec![0.0f32; q.len];
        self.dequantize_into(q, &mut out);
        out
    }

    pub fn dequantize_into(&self, q: &Quantized, out: &mut [f32]) {
        assert_eq!(out.len(), q.len);
        let cb = &*self.codebook;
        let width = q.codes.width();
        let bytes = q.codes.as_bytes();
        let absmax = &q.absmax;
        let block = q.block;
        parallel::par_chunks_mut(out, block, |b, o| {
            let lo = b * block;
            let blo = width.bytes_for(lo);
            let bhi = blo + width.bytes_for(o.len());
            dequantize_block_codes(cb, width, &bytes[blo..bhi], absmax[b], o);
        });
    }
}

/// Absolute maximum of one block (the normalization constant `N_b`).
///
/// Non-finite elements are skipped — `|NaN|` and `|±inf|` both fail
/// `a <= f32::MAX` — so a single bad gradient cannot poison the block's
/// normalization constant; blocks containing any are counted for the
/// `grad_crash` telemetry ([`take_nonfinite_blocks`]). Lane-chunked:
/// [`LANES`] running maxima with one horizontal reduce per block. f32 max
/// is exact, so lane-striping the scan is bit-identical to the in-order
/// scalar tail loop at every split.
#[inline]
fn block_absmax(xs: &[f32]) -> f32 {
    let mut absmax = 0.0f32;
    let mut nonfinite = 0u32;
    let main = if lanes::scalar_forced() { 0 } else { xs.len() - xs.len() % LANES };
    let mut acc = [0.0f32; LANES];
    for chunk in xs[..main].chunks_exact(LANES) {
        for l in 0..LANES {
            let a = chunk[l].abs();
            if a <= f32::MAX {
                if a > acc[l] {
                    acc[l] = a;
                }
            } else {
                nonfinite += 1;
            }
        }
    }
    for l in 0..LANES {
        if acc[l] > absmax {
            absmax = acc[l];
        }
    }
    for &v in &xs[main..] {
        let a = v.abs();
        if a <= f32::MAX {
            if a > absmax {
                absmax = a;
            }
        } else {
            nonfinite += 1;
        }
    }
    if nonfinite > 0 {
        NONFINITE_BLOCKS.fetch_add(1, Ordering::Relaxed);
    }
    absmax
}

/// Quantize one block into *unpacked* one-byte codes: returns the block
/// absmax (the normalization constant stored alongside the codes).
#[inline]
pub fn quantize_block(cb: &Codebook, xs: &[f32], codes: &mut [u8]) -> f32 {
    debug_assert_eq!(xs.len(), codes.len());
    let absmax = block_absmax(xs);
    // All-zero (or empty) block: store absmax 0; normalization uses 1.0 so
    // every element encodes the exact-zero code.
    let inv = if absmax > 0.0 { 1.0 / absmax } else { 1.0 };
    for (c, &v) in codes.iter_mut().zip(xs) {
        *c = cb.encode(v * inv);
    }
    absmax
}

/// Dequantize one block of *unpacked* codes: codebook lookup then
/// denormalize by absmax.
#[inline]
pub fn dequantize_block(cb: &Codebook, codes: &[u8], absmax: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = cb.decode(c) * absmax;
    }
}

/// Width-generic block quantize straight into packed storage bytes
/// (`bytes.len() == width.bytes_for(xs.len())`). At `U4` two encodes are
/// fused per output byte; an odd tail leaves its dead high nibble zero so
/// storage stays canonical for bitwise comparison.
///
/// Lane-chunked (module docs): `LANES` normalizations + batched encode per
/// chunk (4 packed bytes per chunk at `U4`), scalar tail for the
/// remainder; bit-identical to [`quantize_block`] on the whole block.
#[inline]
pub fn quantize_block_codes(
    cb: &Codebook,
    width: CodeWidth,
    xs: &[f32],
    bytes: &mut [u8],
) -> f32 {
    match width {
        CodeWidth::U8 => {
            if lanes::scalar_forced() {
                return quantize_block(cb, xs, bytes);
            }
            debug_assert_eq!(xs.len(), bytes.len());
            let absmax = block_absmax(xs);
            let inv = if absmax > 0.0 { 1.0 / absmax } else { 1.0 };
            let main = xs.len() - xs.len() % LANES;
            let (x_main, x_tail) = xs.split_at(main);
            let (b_main, b_tail) = bytes.split_at_mut(main);
            for (xc, bc) in x_main.chunks_exact(LANES).zip(b_main.chunks_exact_mut(LANES)) {
                let mut scaled = [0.0f32; LANES];
                for l in 0..LANES {
                    scaled[l] = xc[l] * inv;
                }
                let mut codes = [0u8; LANES];
                cb.encode_lanes(&scaled, &mut codes);
                bc.copy_from_slice(&codes);
            }
            for (c, &v) in b_tail.iter_mut().zip(x_tail) {
                *c = cb.encode(v * inv);
            }
            absmax
        }
        CodeWidth::U4 => {
            debug_assert_eq!(bytes.len(), xs.len().div_ceil(2));
            debug_assert!(cb.len() <= 16, "codebook too large for 4-bit codes");
            let absmax = block_absmax(xs);
            let inv = if absmax > 0.0 { 1.0 / absmax } else { 1.0 };
            // LANES is even, so the lane main is pair-aligned: each chunk
            // packs into exactly LANES/2 bytes and the tail starts on a
            // byte boundary.
            let main = if lanes::scalar_forced() { 0 } else { xs.len() - xs.len() % LANES };
            let (x_main, x_tail) = xs.split_at(main);
            let (b_main, b_tail) = bytes.split_at_mut(main / 2);
            for (xc, bc) in x_main.chunks_exact(LANES).zip(b_main.chunks_exact_mut(LANES / 2)) {
                let mut scaled = [0.0f32; LANES];
                for l in 0..LANES {
                    scaled[l] = xc[l] * inv;
                }
                let mut codes = [0u8; LANES];
                cb.encode_lanes(&scaled, &mut codes);
                for l in 0..LANES / 2 {
                    bc[l] = codes[2 * l] | (codes[2 * l + 1] << 4);
                }
            }
            let mut pairs = x_tail.chunks_exact(2);
            for (b, pair) in b_tail.iter_mut().zip(&mut pairs) {
                *b = cb.encode(pair[0] * inv) | (cb.encode(pair[1] * inv) << 4);
            }
            if let [last] = pairs.remainder() {
                b_tail[x_tail.len() / 2] = cb.encode(last * inv);
            }
            absmax
        }
    }
}

/// Width-generic block dequantize straight from packed storage bytes.
///
/// Lane-chunked (module docs): decode is a gather from the codebook's
/// contiguous value table fused with the denormalize multiply (and, at
/// `U4`, with nibble unpacking); scalar tail for the remainder.
/// Bit-identical to [`dequantize_block`] on the whole block.
#[inline]
pub fn dequantize_block_codes(
    cb: &Codebook,
    width: CodeWidth,
    bytes: &[u8],
    absmax: f32,
    out: &mut [f32],
) {
    match width {
        CodeWidth::U8 => {
            if lanes::scalar_forced() {
                return dequantize_block(cb, bytes, absmax, out);
            }
            debug_assert_eq!(bytes.len(), out.len());
            let table = cb.values();
            let main = out.len() - out.len() % LANES;
            let (o_main, o_tail) = out.split_at_mut(main);
            let (b_main, b_tail) = bytes.split_at(main);
            for (oc, bc) in o_main.chunks_exact_mut(LANES).zip(b_main.chunks_exact(LANES)) {
                for l in 0..LANES {
                    oc[l] = table[bc[l] as usize] * absmax;
                }
            }
            for (o, &c) in o_tail.iter_mut().zip(b_tail) {
                *o = cb.decode(c) * absmax;
            }
        }
        CodeWidth::U4 => {
            debug_assert_eq!(bytes.len(), out.len().div_ceil(2));
            let table = cb.values();
            let n = out.len();
            let main = if lanes::scalar_forced() { 0 } else { n - n % LANES };
            let (o_main, o_tail) = out.split_at_mut(main);
            let (b_main, b_tail) = bytes.split_at(main / 2);
            for (oc, bc) in o_main.chunks_exact_mut(LANES).zip(b_main.chunks_exact(LANES / 2)) {
                for l in 0..LANES / 2 {
                    let b = bc[l];
                    oc[2 * l] = table[(b & 0x0F) as usize] * absmax;
                    oc[2 * l + 1] = table[(b >> 4) as usize] * absmax;
                }
            }
            let tn = o_tail.len();
            let mut pairs = o_tail.chunks_exact_mut(2);
            for (pair, &b) in (&mut pairs).zip(b_tail) {
                pair[0] = cb.decode(b & 0x0F) * absmax;
                pair[1] = cb.decode(b >> 4) * absmax;
            }
            if tn % 2 == 1 {
                o_tail[tn - 1] = cb.decode(b_tail[tn / 2] & 0x0F) * absmax;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::dynamic_tree::{
        dynamic_signed, dynamic_signed4, dynamic_unsigned, dynamic_unsigned4,
    };
    use crate::quant::linear::linear_signed;
    use crate::util::rng::Rng;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * 0.01).collect()
    }

    #[test]
    fn roundtrip_error_is_small_for_dynamic() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), BLOCK);
        let x = data(10_000, 1);
        let y = bq.dequantize(&bq.quantize(&x));
        let max_rel: f32 = x
            .iter()
            .zip(&y)
            .filter(|(a, _)| a.abs() > 1e-5)
            .map(|(a, b)| ((a - b) / a).abs())
            .fold(0.0, f32::max);
        assert!(max_rel < 0.2, "max relative error {max_rel}");
    }

    #[test]
    fn block_absmax_is_exact() {
        // §2.1: "block-wise quantization approximates outlier values without
        // any error" — the per-block max must round-trip exactly, at every
        // code width (±1 is in every codebook).
        for width in [CodeWidth::U8, CodeWidth::U4] {
            let cb = match width {
                CodeWidth::U8 => dynamic_signed(),
                CodeWidth::U4 => dynamic_signed4(),
            };
            let bq = BlockQuantizer::with_width(Arc::new(cb), 256, width);
            let mut x = data(2048, 2);
            x[100] = 7.25; // outlier in block 0
            x[1500] = -3.5; // negative outlier in block 5
            let q = bq.quantize(&x);
            let y = bq.dequantize(&q);
            assert_eq!(y[100], 7.25, "{width:?}");
            assert_eq!(y[1500], -3.5, "{width:?}");
        }
    }

    #[test]
    fn outlier_confined_to_its_block() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), 256);
        let x = data(2048, 3);
        let q_clean = bq.quantize(&x);
        let mut x_out = x.clone();
        x_out[0] = 1e4; // enormous outlier in block 0
        let q_dirty = bq.quantize(&x_out);
        // codes in every block other than block 0 are identical
        assert_eq!(
            &q_clean.codes.as_bytes()[256..],
            &q_dirty.codes.as_bytes()[256..]
        );
        assert_eq!(&q_clean.absmax[1..], &q_dirty.absmax[1..]);
        // block 0 degraded, as expected
        assert_ne!(
            &q_clean.codes.as_bytes()[..256],
            &q_dirty.codes.as_bytes()[..256]
        );
    }

    #[test]
    fn tensor_wide_outlier_degrades_everything() {
        // Contrast case from §2.1: with tensor-wide normalization the
        // outlier squashes all other values toward zero codes.
        let bq = BlockQuantizer::tensor_wide(Arc::new(linear_signed()));
        let x = data(2048, 4);
        let mut x_out = x.clone();
        x_out[0] = 1e4;
        let q = bq.quantize(&x_out);
        let zero = bq.codebook.encode(0.0);
        let zeros = q.codes.to_codes()[1..].iter().filter(|&&c| c == zero).count();
        assert!(zeros > 2000, "only {zeros} squashed to zero");
    }

    #[test]
    fn blocks_are_independent() {
        // quantizing the concatenation == concatenating block quantizations
        let cb = Arc::new(dynamic_signed());
        let bq = BlockQuantizer::new(cb.clone(), 128);
        let x = data(1024, 5);
        let q_full = bq.quantize(&x);
        for b in 0..8 {
            let lo = b * 128;
            let q_b = bq.quantize(&x[lo..lo + 128]);
            assert_eq!(
                &q_full.codes.as_bytes()[lo..lo + 128],
                q_b.codes.as_bytes()
            );
            assert!((q_full.absmax[b] - q_b.absmax[0]).abs() == 0.0);
        }
    }

    #[test]
    fn ragged_tail_block() {
        for width in [CodeWidth::U8, CodeWidth::U4] {
            let cb = match width {
                CodeWidth::U8 => dynamic_signed(),
                CodeWidth::U4 => dynamic_signed4(),
            };
            let bq = BlockQuantizer::with_width(Arc::new(cb), 100, width);
            let x = data(257, 6);
            let q = bq.quantize(&x);
            assert_eq!(q.n_blocks(), 3, "{width:?}");
            let y = bq.dequantize(&q);
            assert_eq!(y.len(), 257);
        }
    }

    #[test]
    fn all_zero_tensor() {
        for (cb, width) in [
            (dynamic_unsigned(), CodeWidth::U8),
            (dynamic_unsigned4(), CodeWidth::U4),
        ] {
            let bq = BlockQuantizer::with_width(Arc::new(cb), BLOCK, width);
            let x = vec![0.0f32; 5000];
            let q = bq.quantize(&x);
            let y = bq.dequantize(&q);
            assert!(y.iter().all(|&v| v == 0.0), "{width:?}");
        }
    }

    #[test]
    fn quantize_into_matches_quantize() {
        for width in [CodeWidth::U8, CodeWidth::U4] {
            let cb = match width {
                CodeWidth::U8 => dynamic_signed(),
                CodeWidth::U4 => dynamic_signed4(),
            };
            let bq = BlockQuantizer::with_width(Arc::new(cb), 512, width);
            let x = data(4096, 7);
            let q1 = bq.quantize(&x);
            let mut q2 =
                Quantized::zeros(x.len(), 512, bq.codebook.encode(0.0), width);
            bq.quantize_into(&x, &mut q2);
            assert_eq!(q1.codes, q2.codes, "{width:?}");
            assert_eq!(q1.absmax, q2.absmax);
        }
    }

    #[test]
    fn idempotent_roundtrip() {
        for width in [CodeWidth::U8, CodeWidth::U4] {
            let cb = match width {
                CodeWidth::U8 => dynamic_signed(),
                CodeWidth::U4 => dynamic_signed4(),
            };
            let bq = BlockQuantizer::with_width(Arc::new(cb), 512, width);
            let x = data(4096, 8);
            let q1 = bq.quantize(&x);
            let y1 = bq.dequantize(&q1);
            let q2 = bq.quantize(&y1);
            assert_eq!(q1.codes, q2.codes, "{width:?}");
            assert_eq!(bq.dequantize(&q2), y1);
        }
    }

    #[test]
    fn memory_overhead_tracks_code_width() {
        let x = data(1 << 20, 9);
        let bq8 = BlockQuantizer::new(Arc::new(dynamic_signed()), BLOCK);
        let q8 = bq8.quantize(&x);
        let bpe8 = q8.bytes() as f64 / x.len() as f64;
        assert!(bpe8 < 1.01, "{bpe8}");
        let bq4 =
            BlockQuantizer::with_width(Arc::new(dynamic_signed4()), BLOCK, CodeWidth::U4);
        let q4 = bq4.quantize(&x);
        let bpe4 = q4.bytes() as f64 / x.len() as f64;
        assert!(bpe4 < 0.51, "{bpe4}");
    }

    #[test]
    fn nonfinite_elements_do_not_poison_block_absmax() {
        // A NaN or ±inf element must not enter the normalization constant
        // (inf used to set absmax = inf, squashing every code in the block
        // to zero); the block's finite elements quantize exactly as if the
        // bad elements were absent, and the telemetry counter records the
        // affected blocks.
        let cb = Arc::new(dynamic_signed());
        let bq = BlockQuantizer::new(cb.clone(), 256);
        let clean = data(512, 20);
        let mut dirty = clean.clone();
        dirty[3] = f32::NAN;
        dirty[200] = f32::INFINITY;
        dirty[300] = f32::NEG_INFINITY; // block 1
        take_nonfinite_blocks();
        let q_clean = bq.quantize(&clean);
        assert_eq!(take_nonfinite_blocks(), 0);
        let q_dirty = bq.quantize(&dirty);
        assert!(take_nonfinite_blocks() >= 2, "both dirty blocks counted");
        assert_eq!(q_clean.absmax, q_dirty.absmax, "absmax ignores non-finite");
        let y_clean = bq.dequantize(&q_clean);
        let y_dirty = bq.dequantize(&q_dirty);
        for i in 0..512 {
            if dirty[i].is_finite() {
                assert_eq!(y_clean[i], y_dirty[i], "finite element {i} disturbed");
            }
        }
    }

    #[test]
    fn lane_path_matches_forced_scalar_path() {
        // Smoke check here (the exhaustive sweep lives in
        // rust/tests/simd_parity.rs): packed quantize + dequantize must be
        // bitwise invariant to the forced-scalar toggle.
        for (cb, width) in [
            (dynamic_signed(), CodeWidth::U8),
            (dynamic_signed4(), CodeWidth::U4),
        ] {
            for n in [5usize, 64, 101, 2048] {
                let xs = data(n, 30 + n as u64);
                let mut packed = vec![0u8; width.bytes_for(n)];
                let am = quantize_block_codes(&cb, width, &xs, &mut packed);
                let mut packed_s = vec![0u8; width.bytes_for(n)];
                let am_s = crate::util::lanes::with_forced_scalar(|| {
                    quantize_block_codes(&cb, width, &xs, &mut packed_s)
                });
                assert_eq!(am.to_bits(), am_s.to_bits(), "{width:?} n={n}");
                assert_eq!(packed, packed_s, "{width:?} n={n}");
                let mut out = vec![0.0f32; n];
                dequantize_block_codes(&cb, width, &packed, am, &mut out);
                let mut out_s = vec![0.0f32; n];
                crate::util::lanes::with_forced_scalar(|| {
                    dequantize_block_codes(&cb, width, &packed_s, am_s, &mut out_s)
                });
                let same = out.iter().zip(&out_s).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "{width:?} n={n}");
            }
        }
    }

    #[test]
    fn packed_block_helpers_match_unpacked_path() {
        // quantize_block_codes/dequantize_block_codes at U4 must agree with
        // encode-then-pack / unpack-then-decode elementwise, odd tails
        // included.
        let cb = dynamic_signed4();
        for n in [1usize, 2, 7, 64, 101] {
            let xs = data(n, 10 + n as u64);
            let mut packed = vec![0u8; n.div_ceil(2)];
            let am = quantize_block_codes(&cb, CodeWidth::U4, &xs, &mut packed);
            // reference: unpacked encode
            let mut codes = vec![0u8; n];
            let am_ref = quantize_block(&cb, &xs, &mut codes);
            assert_eq!(am, am_ref);
            let buf = CodeBuf::from_codes(CodeWidth::U4, &codes);
            assert_eq!(buf.as_bytes(), &packed[..], "n={n}");
            // and back
            let mut out = vec![0.0f32; n];
            dequantize_block_codes(&cb, CodeWidth::U4, &packed, am, &mut out);
            let mut out_ref = vec![0.0f32; n];
            dequantize_block(&cb, &codes, am_ref, &mut out_ref);
            assert_eq!(out, out_ref, "n={n}");
        }
    }
}
