//! Block-wise quantization (paper §2.1).
//!
//! The input tensor is viewed as a flat sequence chunked into blocks of
//! B = 2048 elements. Each block is normalized by its own absolute maximum
//! `N_b = max|T_b|` and quantized independently (Eq. 4). Consequences the
//! tests pin down:
//!   * blocks are independent — no cross-block synchronization (throughput),
//!   * an outlier only perturbs its own block (stability),
//!   * the per-block max is quantized with *zero* error (absmax/N_b = ±1 and
//!     ±1 is in the codebook).

use std::sync::Arc;

use super::codebook::Codebook;
use crate::util::parallel;

/// The paper's block size.
pub const BLOCK: usize = 2048;

/// An 8-bit quantized tensor: one code per element plus one f32 absmax per
/// block. Memory: 1 byte/element + 4/B bytes/element overhead (≈1.002
/// bytes/element at B=2048).
#[derive(Clone, Debug)]
pub struct Quantized {
    pub codes: Vec<u8>,
    pub absmax: Vec<f32>,
    pub len: usize,
    pub block: usize,
}

impl Quantized {
    pub fn zeros(len: usize, block: usize, zero_code: u8) -> Quantized {
        let n_blocks = len.div_ceil(block).max(1);
        Quantized {
            codes: vec![zero_code; len],
            absmax: vec![0.0; n_blocks],
            len,
            block,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.absmax.len()
    }

    /// Element range `[lo, hi)` covered by block `b` (last block may be
    /// short) — the one place the block partition arithmetic lives.
    pub fn block_range(&self, b: usize) -> (usize, usize) {
        let lo = b * self.block;
        (lo, (lo + self.block).min(self.len))
    }

    /// Total storage in bytes (codes + absmax).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.absmax.len() * 4
    }
}

/// Quantizer = codebook + block size. `block >= len` degenerates to the
/// tensor-wide normalization of plain dynamic quantization (§1.2), which is
/// exactly the ablation baseline in Table 3.
#[derive(Clone)]
pub struct BlockQuantizer {
    pub codebook: Arc<Codebook>,
    pub block: usize,
}

impl BlockQuantizer {
    pub fn new(codebook: Arc<Codebook>, block: usize) -> Self {
        assert!(block > 0);
        Self { codebook, block }
    }

    /// Tensor-wide variant (single normalization constant).
    pub fn tensor_wide(codebook: Arc<Codebook>) -> Self {
        Self { codebook, block: usize::MAX }
    }

    fn effective_block(&self, len: usize) -> usize {
        self.block.min(len.max(1))
    }

    /// Quantize a full tensor (parallel over blocks).
    pub fn quantize(&self, x: &[f32]) -> Quantized {
        let block = self.effective_block(x.len());
        let zero = self.codebook.encode(0.0);
        let mut q = Quantized::zeros(x.len(), block, zero);
        self.quantize_into(x, &mut q);
        q
    }

    /// Re-quantize into existing storage (hot path — no allocation).
    pub fn quantize_into(&self, x: &[f32], q: &mut Quantized) {
        assert_eq!(x.len(), q.len);
        let block = q.block;
        let cb = &*self.codebook;
        parallel::par_chunks_pair_mut(&mut q.codes, block, &mut q.absmax, 1, |b, codes, am| {
            let lo = b * block;
            let xs = &x[lo..lo + codes.len()];
            am[0] = quantize_block(cb, xs, codes);
        });
    }

    /// Dequantize a full tensor.
    pub fn dequantize(&self, q: &Quantized) -> Vec<f32> {
        let mut out = vec![0.0f32; q.len];
        self.dequantize_into(q, &mut out);
        out
    }

    pub fn dequantize_into(&self, q: &Quantized, out: &mut [f32]) {
        assert_eq!(out.len(), q.len);
        let cb = &*self.codebook;
        let codes = &q.codes;
        let absmax = &q.absmax;
        let block = q.block;
        parallel::par_chunks_mut(out, block, |b, o| {
            let lo = b * block;
            dequantize_block(cb, &codes[lo..lo + o.len()], absmax[b], o);
        });
    }
}

/// Quantize one block: returns the block absmax (the normalization
/// constant stored alongside the codes).
#[inline]
pub fn quantize_block(cb: &Codebook, xs: &[f32], codes: &mut [u8]) -> f32 {
    debug_assert_eq!(xs.len(), codes.len());
    let mut absmax = 0.0f32;
    for &v in xs {
        let a = v.abs();
        if a > absmax {
            absmax = a;
        }
    }
    // All-zero (or empty) block: store absmax 0; normalization uses 1.0 so
    // every element encodes the exact-zero code.
    let inv = if absmax > 0.0 { 1.0 / absmax } else { 1.0 };
    for (c, &v) in codes.iter_mut().zip(xs) {
        *c = cb.encode(v * inv);
    }
    absmax
}

/// Dequantize one block: codebook lookup then denormalize by absmax.
#[inline]
pub fn dequantize_block(cb: &Codebook, codes: &[u8], absmax: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = cb.decode(c) * absmax;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::dynamic_tree::{dynamic_signed, dynamic_unsigned};
    use crate::quant::linear::linear_signed;
    use crate::util::rng::Rng;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * 0.01).collect()
    }

    #[test]
    fn roundtrip_error_is_small_for_dynamic() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), BLOCK);
        let x = data(10_000, 1);
        let y = bq.dequantize(&bq.quantize(&x));
        let max_rel: f32 = x
            .iter()
            .zip(&y)
            .filter(|(a, _)| a.abs() > 1e-5)
            .map(|(a, b)| ((a - b) / a).abs())
            .fold(0.0, f32::max);
        assert!(max_rel < 0.2, "max relative error {max_rel}");
    }

    #[test]
    fn block_absmax_is_exact() {
        // §2.1: "block-wise quantization approximates outlier values without
        // any error" — the per-block max must round-trip exactly.
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), 256);
        let mut x = data(2048, 2);
        x[100] = 7.25; // outlier in block 0
        x[1500] = -3.5; // negative outlier in block 5
        let q = bq.quantize(&x);
        let y = bq.dequantize(&q);
        assert_eq!(y[100], 7.25);
        assert_eq!(y[1500], -3.5);
    }

    #[test]
    fn outlier_confined_to_its_block() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), 256);
        let x = data(2048, 3);
        let q_clean = bq.quantize(&x);
        let mut x_out = x.clone();
        x_out[0] = 1e4; // enormous outlier in block 0
        let q_dirty = bq.quantize(&x_out);
        // codes in every block other than block 0 are identical
        assert_eq!(&q_clean.codes[256..], &q_dirty.codes[256..]);
        assert_eq!(&q_clean.absmax[1..], &q_dirty.absmax[1..]);
        // block 0 degraded, as expected
        assert_ne!(&q_clean.codes[..256], &q_dirty.codes[..256]);
    }

    #[test]
    fn tensor_wide_outlier_degrades_everything() {
        // Contrast case from §2.1: with tensor-wide normalization the
        // outlier squashes all other values toward zero codes.
        let bq = BlockQuantizer::tensor_wide(Arc::new(linear_signed()));
        let x = data(2048, 4);
        let mut x_out = x.clone();
        x_out[0] = 1e4;
        let q = bq.quantize(&x_out);
        let zero = bq.codebook.encode(0.0);
        let zeros = q.codes[1..].iter().filter(|&&c| c == zero).count();
        assert!(zeros > 2000, "only {zeros} squashed to zero");
    }

    #[test]
    fn blocks_are_independent() {
        // quantizing the concatenation == concatenating block quantizations
        let cb = Arc::new(dynamic_signed());
        let bq = BlockQuantizer::new(cb.clone(), 128);
        let x = data(1024, 5);
        let q_full = bq.quantize(&x);
        for b in 0..8 {
            let lo = b * 128;
            let q_b = bq.quantize(&x[lo..lo + 128]);
            assert_eq!(&q_full.codes[lo..lo + 128], &q_b.codes[..]);
            assert!((q_full.absmax[b] - q_b.absmax[0]).abs() == 0.0);
        }
    }

    #[test]
    fn ragged_tail_block() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), 100);
        let x = data(257, 6);
        let q = bq.quantize(&x);
        assert_eq!(q.n_blocks(), 3);
        let y = bq.dequantize(&q);
        assert_eq!(y.len(), 257);
    }

    #[test]
    fn all_zero_tensor() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_unsigned()), BLOCK);
        let x = vec![0.0f32; 5000];
        let q = bq.quantize(&x);
        let y = bq.dequantize(&q);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantize_into_matches_quantize() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), 512);
        let x = data(4096, 7);
        let q1 = bq.quantize(&x);
        let mut q2 = Quantized::zeros(x.len(), 512, bq.codebook.encode(0.0));
        bq.quantize_into(&x, &mut q2);
        assert_eq!(q1.codes, q2.codes);
        assert_eq!(q1.absmax, q2.absmax);
    }

    #[test]
    fn idempotent_roundtrip() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), 512);
        let x = data(4096, 8);
        let q1 = bq.quantize(&x);
        let y1 = bq.dequantize(&q1);
        let q2 = bq.quantize(&y1);
        assert_eq!(q1.codes, q2.codes);
        assert_eq!(bq.dequantize(&q2), y1);
    }

    #[test]
    fn memory_overhead_is_just_over_1_byte_per_element() {
        let bq = BlockQuantizer::new(Arc::new(dynamic_signed()), BLOCK);
        let x = data(1 << 20, 9);
        let q = bq.quantize(&x);
        let bytes_per_elem = q.bytes() as f64 / x.len() as f64;
        assert!(bytes_per_elem < 1.01, "{bytes_per_elem}");
    }
}
