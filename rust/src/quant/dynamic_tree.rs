//! Dynamic tree quantization codebooks (paper §1.3, §2.2, Appendix F.1).
//!
//! The data type (Figure 2): sign bit, then a unary exponent (each leading
//! zero bit divides the magnitude by 10), an indicator bit, and linear
//! fraction bits for the remaining positions. Rather than decode bytes
//! bit-by-bit at runtime, we materialize the 256 representable values once
//! as a [`Codebook`]; storage is the index into it (the paper does the
//! same — quantization is index lookup either way).
//!
//! Construction (shared *verbatim* with `python/compile/kernels/codebooks.py`
//! so the native Rust engine and the Pallas/HLO engine agree bit-for-bit;
//! all arithmetic in f64, cast to f32 at the end):
//!
//! * decade `e` (= number of leading zero bits) spans `(0.1, 1.0] · 10^-e`;
//! * a decade with `f` fraction bits contributes the `2^f` midpoints of
//!   `linspace(0.1, 1.0, 2^f + 1)` scaled by `10^-e` — except the top
//!   decade, where the largest midpoint is replaced by an exact `1.0` so
//!   that absmax-normalized maxima quantize with *zero error* (§2.1);
//! * `0.0` and the denormal-like `1e-7` ("large exponent 10^-7", §1.3)
//!   fill the remaining codes.
//!
//! Signed layout: 7 value bits ⇒ decades e=0..6 with f = 6-e fraction bits,
//! mirrored for the sign: 2·127 + 2 = 256 codes.
//! Unsigned layout (§2.2): the sign bit is re-purposed as one extra *fixed*
//! fraction bit ⇒ decades e=0..6 with f = 7-e: 254 + 2 = 256 codes.
//!
//! The construction is *decade-count generic*: the same recipe at 3 decades
//! yields the 16-level codebooks of *Memory Efficient Optimizers with 4-bit
//! States* (Li et al. 2023) — signed: 2·7 + 2 = 16 codes with a 1e-3
//! denormal, unsigned: 14 + 2 = 16 — served by [`dynamic_signed4`] /
//! [`dynamic_unsigned4`] (and the inverse variants) for
//! [`CodeWidth::U4`](super::codebuf::CodeWidth::U4) packed state.

use super::codebook::Codebook;
use crate::util::lanes::LANES;

/// Midpoints of `linspace(0.1, 1.0, n+1)`, computed in f64.
fn decade_midpoints(n: usize) -> Vec<f64> {
    let lo = 0.1f64;
    let hi = 1.0f64;
    let step = (hi - lo) / n as f64;
    (0..n)
        .map(|i| {
            let a = lo + step * i as f64;
            let b = lo + step * (i + 1) as f64;
            0.5 * (a + b)
        })
        .collect()
}

/// Decade scales as decimal literals — parsed identically by Rust and
/// Python, so both languages build bit-identical f32 codebooks.
const DECADE_SCALE: [f64; 7] = [1.0, 1e-1, 1e-2, 1e-3, 1e-4, 1e-5, 1e-6];

fn tree_magnitudes(decades: usize, extra_fraction_bit: bool, inverse: bool) -> Vec<f64> {
    debug_assert!(decades >= 1 && decades <= DECADE_SCALE.len());
    let top = decades - 1;
    let mut out = Vec::new();
    for e in 0..decades {
        // fraction bits for this decade; inverse swaps which decade is rich.
        let f = if inverse { e.min(top) } else { top - e } + usize::from(extra_fraction_bit);
        let n = 1usize << f;
        let mids = decade_midpoints(n);
        let scale = DECADE_SCALE[e];
        for (i, m) in mids.iter().enumerate() {
            // Top decade: replace the largest midpoint with exact 1.0 so the
            // block absmax is representable without error.
            if e == 0 && i == n - 1 {
                out.push(1.0);
            } else {
                out.push(m * scale);
            }
        }
    }
    out
}

/// Analytic encode (no LUT, no full binary search): the dynamic tree's
/// closed-form structure — decimal decades × uniformly spaced in-decade
/// midpoints — lets a code-index *candidate* be computed in O(1) from the
/// float's exponent and mantissa. `Codebook::encode` then resolves the
/// candidate exactly (≤±1) against the true decision boundaries, so the
/// result is pinned bit-for-bit to `Codebook::encode_reference`.
///
/// Position of magnitude `ax` within the ascending positive values
/// `[10^-decades, tree magnitudes…]`: 0 for the denormal-like code, else
/// derived from the decade `e` (number of leading-zero exponent bits in
/// Figure 2) and the linear in-decade slot `k`. Decade-count generic: the
/// 8-bit layouts use `decades = 7`, the 4-bit ones `decades = 3`.
fn magnitude_pos(ax: f64, decades: usize, extra_fraction_bit: bool) -> usize {
    let top: u32 = (decades - 1) as u32 + u32::from(extra_fraction_bit);
    // the denormal-like code sits one decade below the smallest magnitude
    if ax <= DECADE_SCALE[decades - 1] * 0.1 {
        return 0;
    }
    // Decade from the binary exponent: floor(log2 ax) is exact bit math on
    // the f64 representation; ×log10(2) approximates -log10(ax) to within
    // one decade, and one comparison per side lands it exactly in
    // (0.1·10⁻ᵉ, 10⁻ᵉ].
    let e2 = ((ax.to_bits() >> 52) as i64 - 1023) as f64;
    let guess = (-(e2 * std::f64::consts::LOG10_2)).floor() as i64;
    let mut e = guess.clamp(0, (decades - 1) as i64) as usize;
    while e > 0 && ax > DECADE_SCALE[e] {
        e -= 1;
    }
    while e < decades - 1 && ax <= DECADE_SCALE[e] * 0.1 {
        e += 1;
    }
    // In-decade slot: values sit at 0.1 + step·(k + ½) (midpoints of the
    // uniform linspace), so the nearest slot is floor of the rescaled
    // mantissa part.
    let nd = 1usize << (top - e as u32);
    let step = 0.9 / nd as f64;
    let t = (ax / DECADE_SCALE[e] - 0.1) / step;
    let k = (t.floor() as i64).clamp(0, nd as i64 - 1) as usize;
    // Decades e' > e hold 2^(top-e') magnitudes each; +1 for the denormal
    // code. Both sums telescope to the same closed forms at every decade
    // count.
    if extra_fraction_bit {
        nd - 1 + k
    } else {
        nd + k
    }
}

/// Candidate code index for a signed layout at `decades` decades (sorted:
/// M negatives ↓, 0.0 at M, the denormal at M+1, M positives ↑, where
/// M = 2^decades - 1 magnitudes per sign).
fn candidate_signed_at(x: f32, decades: usize) -> usize {
    let m = (1usize << decades) - 1;
    if x.is_nan() {
        return 0; // encode_reference: no midpoint compares ≤ NaN
    }
    if x == 0.0 {
        return m;
    }
    let pos = magnitude_pos(x.abs() as f64, decades, false);
    if x > 0.0 {
        m + 1 + pos
    } else {
        m - pos
    }
}

/// Candidate code index for an unsigned layout (sorted: 0.0, denormal,
/// magnitudes ↑).
fn candidate_unsigned_at(x: f32, decades: usize) -> usize {
    if x.is_nan() || x <= 0.0 {
        return 0;
    }
    1 + magnitude_pos(x as f64, decades, true)
}

/// Candidate for [`dynamic_signed`] (127 negatives ↓, 0.0 at 127, 1e-7 at
/// 128, 127 positives ↑).
fn candidate_signed(x: f32) -> usize {
    candidate_signed_at(x, 7)
}

/// Candidate for [`dynamic_unsigned`] (0.0, 1e-7, 254 magnitudes ↑).
fn candidate_unsigned(x: f32) -> usize {
    candidate_unsigned_at(x, 7)
}

/// Candidate for [`dynamic_signed4`] (7 negatives ↓, 0.0 at 7, 1e-3 at 8,
/// 7 positives ↑).
fn candidate_signed4(x: f32) -> usize {
    candidate_signed_at(x, 3)
}

/// Candidate for [`dynamic_unsigned4`] (0.0, 1e-3, 14 magnitudes ↑).
fn candidate_unsigned4(x: f32) -> usize {
    candidate_unsigned_at(x, 3)
}

/// Lane-batched signed candidate: the exponent/bit-math candidate step of
/// [`candidate_signed_at`] run across [`LANES`] inputs in one fixed-width
/// loop (the shape the autovectorizer lowers; the decade count is a const
/// generic because `Codebook` stores the batch encoder as a plain `fn`
/// pointer, which cannot capture a runtime decade count). Each lane calls
/// the *same* scalar candidate chain, so lane codes are identical to
/// scalar codes by construction — and either way the exact midpoint fixup
/// in `Codebook::resolve_candidate` pins the final code bit-for-bit.
fn batch_signed<const DECADES: usize>(xs: &[f32; LANES]) -> [usize; LANES] {
    let mut out = [0usize; LANES];
    for l in 0..LANES {
        out[l] = candidate_signed_at(xs[l], DECADES);
    }
    out
}

/// Lane-batched unsigned candidate (see [`batch_signed`]).
fn batch_unsigned<const DECADES: usize>(xs: &[f32; LANES]) -> [usize; LANES] {
    let mut out = [0usize; LANES];
    for l in 0..LANES {
        out[l] = candidate_unsigned_at(xs[l], DECADES);
    }
    out
}

/// Assemble a signed codebook from tree magnitudes: ± every magnitude,
/// 0.0, and the denormal-like filler.
fn signed_values(mags: &[f64], denormal: f32) -> Vec<f32> {
    let mut vals: Vec<f32> = Vec::with_capacity(2 * mags.len() + 2);
    for &m in mags {
        vals.push(m as f32);
        vals.push(-m as f32);
    }
    vals.push(0.0);
    vals.push(denormal);
    vals
}

/// Assemble an unsigned codebook: magnitudes, 0.0, denormal filler.
fn unsigned_values(mags: &[f64], denormal: f32) -> Vec<f32> {
    let mut vals: Vec<f32> = mags.iter().map(|&m| m as f32).collect();
    vals.push(0.0);
    vals.push(denormal);
    vals
}

/// Signed dynamic tree quantization ("dynamic quantization" for the first
/// Adam state / momentum). 256 values: ±(127 tree magnitudes), 0, 1e-7.
pub fn dynamic_signed() -> Codebook {
    let mags = tree_magnitudes(7, false, false);
    debug_assert_eq!(mags.len(), 127);
    Codebook::new_analytic_batched(
        "dynamic_signed",
        signed_values(&mags, 1e-7),
        candidate_signed,
        batch_signed::<7>,
    )
}

/// Unsigned dynamic quantization (§2.2): sign bit re-purposed as a fixed
/// fraction bit, for the strictly-positive second Adam state.
pub fn dynamic_unsigned() -> Codebook {
    let mags = tree_magnitudes(7, true, false);
    debug_assert_eq!(mags.len(), 254);
    Codebook::new_analytic_batched(
        "dynamic_unsigned",
        unsigned_values(&mags, 1e-7),
        candidate_unsigned,
        batch_unsigned::<7>,
    )
}

/// Signed 16-level dynamic tree (Li et al. 2023): 3 decades, 7 magnitudes
/// per sign, 0, and a 1e-3 denormal — 16 codes for 4-bit packed state.
pub fn dynamic_signed4() -> Codebook {
    let mags = tree_magnitudes(3, false, false);
    debug_assert_eq!(mags.len(), 7);
    Codebook::new_analytic_batched(
        "dynamic_signed4",
        signed_values(&mags, 1e-3),
        candidate_signed4,
        batch_signed::<3>,
    )
}

/// Unsigned 16-level dynamic tree: the sign bit re-purposed as an extra
/// fraction bit, 14 magnitudes + 0 + 1e-3 = 16 codes.
pub fn dynamic_unsigned4() -> Codebook {
    let mags = tree_magnitudes(3, true, false);
    debug_assert_eq!(mags.len(), 14);
    Codebook::new_analytic_batched(
        "dynamic_unsigned4",
        unsigned_values(&mags, 1e-3),
        candidate_unsigned4,
        batch_unsigned::<3>,
    )
}

/// Inverse dynamic quantization (Appendix F.1): exponent direction swapped —
/// most fraction bits go to the *smallest* decade. The e=0 decade already
/// contributes an exact 1.0, so the filler code sits one decade below the
/// smallest tree magnitude.
pub fn inverse_dynamic_signed() -> Codebook {
    let mags = tree_magnitudes(7, false, true);
    debug_assert_eq!(mags.len(), 127);
    Codebook::new("inverse_dynamic_signed", signed_values(&mags, 1e-8))
}

/// Inverse dynamic, unsigned variant.
pub fn inverse_dynamic_unsigned() -> Codebook {
    let mags = tree_magnitudes(7, true, true);
    debug_assert_eq!(mags.len(), 254);
    Codebook::new("inverse_dynamic_unsigned", unsigned_values(&mags, 1e-8))
}

/// Inverse dynamic at 16 levels (4-bit state).
pub fn inverse_dynamic_signed4() -> Codebook {
    let mags = tree_magnitudes(3, false, true);
    debug_assert_eq!(mags.len(), 7);
    Codebook::new("inverse_dynamic_signed4", signed_values(&mags, 1e-4))
}

/// Inverse dynamic unsigned at 16 levels.
pub fn inverse_dynamic_unsigned4() -> Codebook {
    let mags = tree_magnitudes(3, true, true);
    debug_assert_eq!(mags.len(), 14);
    Codebook::new("inverse_dynamic_unsigned4", unsigned_values(&mags, 1e-4))
}

/// Decode the dynamic-tree *bit pattern* semantics for exposition (Figure 2
/// regeneration): returns (sign, exponent_zeros, fraction_bits) per byte.
pub fn describe_bit_pattern(byte: u8) -> (i8, u32, u8) {
    let sign = if byte & 0x80 != 0 { -1 } else { 1 };
    let low7 = byte & 0x7F;
    if low7 == 0 {
        return (sign, 7, 0); // all-zero payload: the 0 / 1e-7 codes
    }
    let zeros = low7.leading_zeros() - 1; // leading zeros within 7 bits (u8 minus sign bit)
    let frac_bits = 6 - zeros; // bits after the indicator
    let frac = low7 & ((1u8 << frac_bits).wrapping_sub(1));
    (sign, zeros, frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_are_256() {
        assert_eq!(dynamic_signed().len(), 256);
        assert_eq!(dynamic_unsigned().len(), 256);
        assert_eq!(inverse_dynamic_signed().len(), 256);
        assert_eq!(inverse_dynamic_unsigned().len(), 256);
    }

    #[test]
    fn four_bit_sizes_are_16() {
        assert_eq!(dynamic_signed4().len(), 16);
        assert_eq!(dynamic_unsigned4().len(), 16);
        assert_eq!(inverse_dynamic_signed4().len(), 16);
        assert_eq!(inverse_dynamic_unsigned4().len(), 16);
    }

    #[test]
    fn all_values_distinct_and_sorted() {
        for cb in [
            dynamic_signed(),
            dynamic_unsigned(),
            inverse_dynamic_signed(),
            inverse_dynamic_unsigned(),
            dynamic_signed4(),
            dynamic_unsigned4(),
            inverse_dynamic_signed4(),
            inverse_dynamic_unsigned4(),
        ] {
            assert!(cb.all_distinct(), "{}", cb.name());
        }
    }

    #[test]
    fn four_bit_trees_keep_the_anchor_codes() {
        // exact ±1 (zero-error absmax), exact 0, and a denormal one decade
        // below the smallest magnitude — same anchors as the 8-bit layout
        let s = dynamic_signed4();
        assert!(s.values().contains(&1.0) && s.values().contains(&-1.0));
        assert!(s.values().contains(&0.0));
        assert_eq!(s.max_abs(), 1.0);
        let smallest_pos = s
            .values()
            .iter()
            .filter(|&&v| v > 0.0)
            .fold(f32::INFINITY, |m, &v| m.min(v));
        assert!(smallest_pos <= 1.5e-3, "{smallest_pos}");
        let u = dynamic_unsigned4();
        assert!(u.values().iter().all(|&v| v >= 0.0));
        assert!(u.values().contains(&1.0) && u.values().contains(&0.0));
    }

    #[test]
    fn four_bit_unsigned_has_double_top_decade_resolution() {
        let count = |cb: &Codebook| {
            cb.values()
                .iter()
                .filter(|&&v| v > 0.1 && v <= 1.0)
                .count()
        };
        assert_eq!(count(&dynamic_unsigned4()), 2 * count(&dynamic_signed4()));
    }

    #[test]
    fn signed_contains_plus_minus_one_and_zero() {
        let cb = dynamic_signed();
        assert!(cb.values().contains(&1.0));
        assert!(cb.values().contains(&-1.0));
        assert!(cb.values().contains(&0.0));
    }

    #[test]
    fn unsigned_is_nonnegative_with_one_and_zero() {
        let cb = dynamic_unsigned();
        assert!(cb.values().iter().all(|&v| v >= 0.0));
        assert!(cb.values().contains(&1.0));
        assert!(cb.values().contains(&0.0));
    }

    #[test]
    fn seven_orders_of_magnitude() {
        // paper §1.3: "numbers can have a large exponent 10^-7"
        let cb = dynamic_signed();
        let smallest_pos = cb
            .values()
            .iter()
            .filter(|&&v| v > 0.0)
            .fold(f32::INFINITY, |m, &v| m.min(v));
        assert!(smallest_pos <= 1.5e-7, "{smallest_pos}");
        assert_eq!(cb.max_abs(), 1.0);
    }

    #[test]
    fn top_decade_precision_about_1_over_63() {
        // paper §1.3: "precision as high as 1/63"
        let cb = dynamic_signed();
        let top: Vec<f32> = cb
            .values()
            .iter()
            .copied()
            .filter(|&v| v > 0.1 && v <= 1.0)
            .collect();
        assert_eq!(top.len(), 64);
        let max_gap = top.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
        assert!(max_gap < 0.05, "max_gap={max_gap}"); // ~0.9/63 + end fixup
    }

    #[test]
    fn unsigned_has_double_resolution_of_signed_top_decade() {
        let count = |cb: &Codebook| {
            cb.values()
                .iter()
                .filter(|&&v| v > 0.1 && v <= 1.0)
                .count()
        };
        assert_eq!(count(&dynamic_unsigned()), 2 * count(&dynamic_signed()));
    }

    #[test]
    fn inverse_is_rich_at_small_magnitudes() {
        let dense_small = |cb: &Codebook| {
            cb.values()
                .iter()
                .filter(|&&v| v > 0.0 && v < 1e-5)
                .count()
        };
        assert!(dense_small(&inverse_dynamic_signed()) > dense_small(&dynamic_signed()));
    }

    #[test]
    fn signed_is_symmetric_ex_zero_denormal() {
        let cb = dynamic_signed();
        for &v in cb.values() {
            if v > 1.5e-7 {
                assert!(
                    cb.values().contains(&(-v)),
                    "missing mirror of {v}"
                );
            }
        }
    }

    #[test]
    fn analytic_encode_matches_reference_densely() {
        // The analytic candidate + fixup must reproduce nearest-midpoint
        // search exactly across the full dynamic range (log-uniform sweep,
        // both signs), not just at the curated probes of the codebook test.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xD74);
        for cb in [
            dynamic_signed(),
            dynamic_unsigned(),
            dynamic_signed4(),
            dynamic_unsigned4(),
        ] {
            for _ in 0..200_000 {
                // magnitude log-uniform in [1e-12, 10), sign ± at random
                let exp = rng.uniform_range(-12.0, 1.0);
                let mag = 10f64.powf(exp) as f32;
                let x = if rng.uniform() < 0.5 { mag } else { -mag };
                assert_eq!(
                    cb.encode(x),
                    cb.encode_reference(x),
                    "{}: x = {x} ({:#010x})",
                    cb.name(),
                    x.to_bits()
                );
            }
        }
    }

    #[test]
    fn analytic_candidate_stays_within_fixup_margin() {
        // The fixup in `Codebook::encode` is O(1) only because the bit-math
        // candidate lands next to the true code (±1 in the interior, one
        // more near decade boundaries); pin that margin.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xD75);
        let signed = dynamic_signed();
        let unsigned = dynamic_unsigned();
        for _ in 0..100_000 {
            let exp = rng.uniform_range(-12.0, 1.0);
            let mag = 10f64.powf(exp) as f32;
            let x = if rng.uniform() < 0.5 { mag } else { -mag };
            let ds = candidate_signed(x) as i64 - signed.encode_reference(x) as i64;
            assert!(ds.abs() <= 2, "signed candidate off by {ds} at {x}");
            let du = candidate_unsigned(x) as i64 - unsigned.encode_reference(x) as i64;
            assert!(du.abs() <= 2, "unsigned candidate off by {du} at {x}");
        }
    }

    #[test]
    fn bit_pattern_decode_covers_all_bytes() {
        for b in 0..=255u8 {
            let (sign, zeros, frac) = describe_bit_pattern(b);
            assert!(sign == 1 || sign == -1);
            assert!(zeros <= 7);
            if zeros < 7 {
                assert!(u32::from(frac) < (1u32 << (6 - zeros)));
            }
        }
    }
}
