//! Quantile quantization (Appendix F.2): a lossy minimum-entropy encoding.
//!
//! The codebook values are the midpoints between 2^k + 1 equally spaced
//! quantiles of the input distribution (Eq. 5), so every code is used
//! equally often. Quantiles are estimated with SRAM-Quantiles (Appendix G).

use super::codebook::Codebook;
use super::sram_quantiles::estimate_quantiles;

/// Build a 256-value quantile codebook from sample data, normalized into
/// [-1, 1] by the max-abs of the codebook (the paper normalizes values from
/// the standard normal the same way for Figure 6).
pub fn quantile_from_data(data: &[f32]) -> Codebook {
    quantile_from_data_levels(data, 256)
}

/// Level-generic quantile codebook: `levels` midpoints of `levels + 1`
/// equally spaced quantiles (Eq. 5 at 2^k levels; `levels = 16` is the
/// 4-bit variant).
pub fn quantile_from_data_levels(data: &[f32], levels: usize) -> Codebook {
    assert!(!data.is_empty());
    assert!((2..=256).contains(&levels), "levels must be in 2..=256");
    // 2^k + 1 boundary quantiles -> 2^k midpoints (Eq. 5).
    let qs = estimate_quantiles(data, levels + 1);
    let mut vals: Vec<f32> = qs.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
    debug_assert_eq!(vals.len(), levels);
    let name = if levels <= 16 { "quantile4" } else { "quantile" };
    let max_abs = vals.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(f32::MIN_POSITIVE);
    for v in vals.iter_mut() {
        *v /= max_abs;
    }
    // De-duplicate (heavy-tailed data can repeat midpoints after f32
    // rounding); keep the codebook strictly sorted.
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    vals.dedup();
    Codebook::new(name, vals)
}

/// Quantile codebook for the standard normal distribution, via a large
/// deterministic sample — the generic "Quantile" row of Table 6 / Figure 6.
pub fn quantile_normal() -> Codebook {
    quantile_normal_levels(256)
}

/// Standard-normal quantile codebook at an arbitrary level count
/// (`levels = 16` backs the 4-bit signed quantile format).
pub fn quantile_normal_levels(levels: usize) -> Codebook {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(0x9e3779b9);
    let data: Vec<f32> = (0..1_000_000).map(|_| rng.normal() as f32).collect();
    quantile_from_data_levels(&data, levels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn codebook_has_close_to_256_values_in_unit_range() {
        let cb = quantile_normal();
        assert!(cb.len() >= 250, "len {}", cb.len());
        assert!(cb.max_abs() <= 1.0 + 1e-6);
        assert!(cb.all_distinct());
    }

    #[test]
    fn codes_are_used_nearly_uniformly_on_matching_data() {
        // Minimum-entropy property: on data from the same distribution each
        // code should be hit with roughly equal frequency.
        let cb = quantile_normal();
        let mut rng = Rng::new(77);
        let mut counts = vec![0usize; cb.len()];
        let n = 256 * 400;
        // Normalize samples the same way the codebook was normalized: the
        // codebook spans the sample range [-max_abs, max_abs] mapped to
        // [-1, 1]; use a fresh sample's absmax as proxy normalizer.
        let sample: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let absmax = sample.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for &x in &sample {
            counts[cb.encode(x / absmax) as usize] += 1;
        }
        let used = counts.iter().filter(|&&c| c > 0).count();
        assert!(used as f64 > cb.len() as f64 * 0.9, "used {used}");
        // No single code should dominate.
        let max_frac = *counts.iter().max().unwrap() as f64 / n as f64;
        assert!(max_frac < 0.03, "max code frequency {max_frac}");
    }

    #[test]
    fn dense_near_mode_sparse_in_tails() {
        let cb = quantile_normal();
        let near0 = cb.values().iter().filter(|v| v.abs() < 0.1).count();
        let tail = cb.values().iter().filter(|v| v.abs() > 0.8).count();
        assert!(near0 > tail, "near0={near0} tail={tail}");
    }

    #[test]
    fn sixteen_level_codebook_fits_4bit_codes() {
        let cb = quantile_normal_levels(16);
        assert!(cb.len() <= 16 && cb.len() >= 12, "len {}", cb.len());
        assert!(cb.all_distinct());
        assert!(cb.max_abs() <= 1.0 + 1e-6);
        assert_eq!(cb.name(), "quantile4");
    }

    #[test]
    fn from_data_handles_skewed_input() {
        let mut rng = Rng::new(8);
        let data: Vec<f32> = (0..50_000)
            .map(|_| (rng.normal().abs().powi(3)) as f32)
            .collect();
        let cb = quantile_from_data(&data);
        assert!(cb.len() > 100);
        assert!(cb.all_distinct());
    }
}
