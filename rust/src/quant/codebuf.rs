//! Packed code storage — the width-generic backbone of the quantized
//! substrate.
//!
//! The paper's pipeline stores one 8-bit code per element; *Memory
//! Efficient Optimizers with 4-bit States* (Li et al. 2023) shows the same
//! dynamic-tree recipe works at 16 levels, halving the footprint. To make
//! code width a parameter instead of an assumption, quantized tensors
//! store their codes in a [`CodeBuf`]: a byte buffer plus a [`CodeWidth`]
//! deciding how codes map onto bytes.
//!
//! * [`CodeWidth::U8`] — one code per byte (the paper's layout).
//! * [`CodeWidth::U4`] — two codes per byte: element `2k` in the low
//!   nibble of byte `k`, element `2k + 1` in the high nibble. An
//!   odd-length buffer leaves the final high nibble zero, so equal code
//!   sequences always produce byte-identical buffers (the parity tests
//!   compare storage bitwise).
//!
//! Block-parallel safety: the block engine hands each quantization block
//! its own byte sub-range of the buffer. For `U4` this is race-free only
//! if blocks start on byte boundaries, i.e. at even element offsets —
//! which [`crate::quant::Quantized`] guarantees by requiring an even block
//! size whenever the tensor spans more than one block.

/// How many bits one stored code occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodeWidth {
    /// One byte per code (up to 256 codebook levels).
    U8,
    /// Two codes per byte (up to 16 codebook levels).
    U4,
}

impl CodeWidth {
    /// Bits per stored code.
    pub fn bits(self) -> u32 {
        match self {
            CodeWidth::U8 => 8,
            CodeWidth::U4 => 4,
        }
    }

    /// Largest codebook this width can index.
    pub fn max_levels(self) -> usize {
        match self {
            CodeWidth::U8 => 256,
            CodeWidth::U4 => 16,
        }
    }

    /// Storage bytes for `n` codes. Also the byte offset of element `n`
    /// when `n` is a valid packing boundary (any `n` for `U8`, even `n`
    /// for `U4`).
    pub fn bytes_for(self, n: usize) -> usize {
        match self {
            CodeWidth::U8 => n,
            CodeWidth::U4 => n.div_ceil(2),
        }
    }

    /// Code of element `i` in a raw packed byte slice at this width — the
    /// free-function twin of [`CodeBuf::get`] for block-local scratch
    /// buffers that never wrap their bytes in a `CodeBuf`.
    #[inline(always)]
    pub fn code_at(self, bytes: &[u8], i: usize) -> u8 {
        match self {
            CodeWidth::U8 => bytes[i],
            CodeWidth::U4 => {
                let b = bytes[i / 2];
                if i % 2 == 0 {
                    b & 0x0F
                } else {
                    b >> 4
                }
            }
        }
    }
}

/// A sequence of `len` codes packed at a given [`CodeWidth`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodeBuf {
    bytes: Vec<u8>,
    len: usize,
    width: CodeWidth,
}

impl CodeBuf {
    /// A buffer of `len` copies of `code`.
    pub fn filled(width: CodeWidth, len: usize, code: u8) -> CodeBuf {
        debug_assert!((code as usize) < width.max_levels(), "code exceeds width");
        let byte = match width {
            CodeWidth::U8 => code,
            CodeWidth::U4 => code | (code << 4),
        };
        let mut bytes = vec![byte; width.bytes_for(len)];
        if width == CodeWidth::U4 && len % 2 == 1 {
            // keep the unused final high nibble canonically zero
            *bytes.last_mut().expect("odd len > 0") = code;
        }
        CodeBuf { bytes, len, width }
    }

    /// Pack a slice of one-byte codes.
    pub fn from_codes(width: CodeWidth, codes: &[u8]) -> CodeBuf {
        let mut buf = CodeBuf::filled(width, codes.len(), 0);
        buf.write_range(0, codes);
        buf
    }

    /// Number of codes (elements), independent of packing.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn width(&self) -> CodeWidth {
        self.width
    }

    /// Storage footprint in bytes.
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Raw packed storage.
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Raw packed storage, mutable — the block engine chunks this for
    /// parallel per-block work (see the module docs for the `U4` aliasing
    /// contract).
    pub fn as_mut_bytes(&mut self) -> &mut [u8] {
        &mut self.bytes
    }

    /// Code at element `i`.
    #[inline(always)]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        self.width.code_at(&self.bytes, i)
    }

    /// Store code `c` at element `i`.
    #[inline(always)]
    pub fn set(&mut self, i: usize, c: u8) {
        debug_assert!(i < self.len);
        debug_assert!((c as usize) < self.width.max_levels(), "code exceeds width");
        match self.width {
            CodeWidth::U8 => self.bytes[i] = c,
            CodeWidth::U4 => {
                let b = &mut self.bytes[i / 2];
                if i % 2 == 0 {
                    *b = (*b & 0xF0) | c;
                } else {
                    *b = (*b & 0x0F) | (c << 4);
                }
            }
        }
    }

    /// Unpack elements `[lo, lo + out.len())` into one-byte codes. Handles
    /// arbitrary (odd, byte-straddling) ranges.
    pub fn read_range(&self, lo: usize, out: &mut [u8]) {
        assert!(lo + out.len() <= self.len, "read_range out of bounds");
        match self.width {
            CodeWidth::U8 => out.copy_from_slice(&self.bytes[lo..lo + out.len()]),
            CodeWidth::U4 => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = self.get(lo + k);
                }
            }
        }
    }

    /// Pack one-byte `codes` into elements `[lo, lo + codes.len())`.
    /// Handles arbitrary (odd, byte-straddling) ranges.
    pub fn write_range(&mut self, lo: usize, codes: &[u8]) {
        assert!(lo + codes.len() <= self.len, "write_range out of bounds");
        match self.width {
            CodeWidth::U8 => self.bytes[lo..lo + codes.len()].copy_from_slice(codes),
            CodeWidth::U4 => {
                for (k, &c) in codes.iter().enumerate() {
                    self.set(lo + k, c);
                }
            }
        }
    }

    /// The whole buffer as one-byte codes.
    pub fn to_codes(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.len];
        self.read_range(0, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_codes(n: usize, levels: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.uniform() * levels as f64) as u8).collect()
    }

    #[test]
    fn widths_account_storage() {
        assert_eq!(CodeWidth::U8.bytes_for(5), 5);
        assert_eq!(CodeWidth::U4.bytes_for(5), 3);
        assert_eq!(CodeWidth::U4.bytes_for(4), 2);
        assert_eq!(CodeWidth::U4.bytes_for(0), 0);
        assert_eq!(CodeWidth::U4.max_levels(), 16);
        assert_eq!(CodeWidth::U4.bits(), 4);
    }

    #[test]
    fn roundtrip_identity_even_and_odd_lengths() {
        for width in [CodeWidth::U8, CodeWidth::U4] {
            for n in [0usize, 1, 2, 3, 7, 8, 255, 256, 2047, 2048, 2049] {
                let codes = random_codes(n, width.max_levels(), n as u64 + 1);
                let buf = CodeBuf::from_codes(width, &codes);
                assert_eq!(buf.len(), n);
                assert_eq!(buf.storage_bytes(), width.bytes_for(n));
                assert_eq!(buf.to_codes(), codes, "{width:?} n={n}");
            }
        }
    }

    #[test]
    fn ranged_reads_and_writes_straddle_bytes() {
        // every (lo, len) sub-range of an odd-length U4 buffer round-trips,
        // including ranges that start mid-byte
        let n = 33;
        let codes = random_codes(n, 16, 9);
        let buf = CodeBuf::from_codes(CodeWidth::U4, &codes);
        for lo in 0..n {
            for len in 0..=(n - lo) {
                let mut out = vec![0u8; len];
                buf.read_range(lo, &mut out);
                assert_eq!(&out[..], &codes[lo..lo + len], "lo={lo} len={len}");
            }
        }
        // mid-byte writes only touch their own elements
        let mut buf = CodeBuf::filled(CodeWidth::U4, n, 5);
        buf.write_range(3, &[9, 10, 11]);
        let got = buf.to_codes();
        for (i, &c) in got.iter().enumerate() {
            let want = match i {
                3 => 9,
                4 => 10,
                5 => 11,
                _ => 5,
            };
            assert_eq!(c, want, "element {i}");
        }
    }

    #[test]
    fn equal_codes_give_byte_identical_buffers() {
        // the canonical-zero tail nibble: packing the same odd-length code
        // sequence into buffers with different histories must agree bitwise
        let codes = random_codes(41, 16, 4);
        let a = CodeBuf::from_codes(CodeWidth::U4, &codes);
        let mut b = CodeBuf::filled(CodeWidth::U4, 41, 15);
        b.write_range(0, &codes);
        // b's tail high nibble still holds 15 from the fill — get/set level
        // equality holds, storage differs only in the dead nibble
        assert_eq!(a.to_codes(), b.to_codes());
        // filled() itself zeroes the dead nibble
        let f = CodeBuf::filled(CodeWidth::U4, 41, 0);
        assert_eq!(*f.as_bytes().last().unwrap(), 0);
    }

    #[test]
    fn get_set_agree_with_packing() {
        let mut buf = CodeBuf::filled(CodeWidth::U4, 10, 0);
        buf.set(0, 0xA);
        buf.set(1, 0xB);
        assert_eq!(buf.as_bytes()[0], 0xBA, "low nibble = even element");
        assert_eq!(buf.get(0), 0xA);
        assert_eq!(buf.get(1), 0xB);
        assert_eq!(CodeWidth::U4.code_at(buf.as_bytes(), 0), 0xA);
        assert_eq!(CodeWidth::U4.code_at(buf.as_bytes(), 1), 0xB);
    }
}
