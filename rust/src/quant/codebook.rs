//! Quantization codebooks (`Q^map` in the paper, §1.2).
//!
//! A codebook is a sorted list of representable values in [-1, 1] (or
//! [0, 1] for unsigned codes) — any count up to 256 works, so the same
//! abstraction serves 8-bit (256-level) and 4-bit (16-level) code widths.
//! Quantization of a normalized input is
//! nearest-value search (Eq. 3/4); we implement it as a binary search over
//! the midpoints between adjacent codebook entries, which is exactly
//! arg-min over an ordered codebook.

use crate::util::lanes::LANES;
use crate::util::rng::Rng;

/// LUT resolution: top bits of the monotone integer view of an f32
/// (sign + 8 exponent + 5 mantissa bits => 16384 buckets, 32 KiB table).
const LUT_BITS: u32 = 14;
const LUT_SIZE: usize = 1 << LUT_BITS;

/// Lane-batched analytic candidate: computes [`LANES`] code-index
/// candidates at once from the bit structure of the inputs. Accuracy
/// contract is the same as the scalar `analytic` candidate — each lane is
/// resolved exactly against the midpoints by [`Codebook::resolve_candidate`],
/// so candidate quality affects fixup iterations, never the result.
pub type BatchCandidate = fn(&[f32; LANES]) -> [usize; LANES];

#[derive(Clone, Debug)]
pub struct Codebook {
    /// Sorted representable values.
    values: Vec<f32>,
    /// Decision boundaries: midpoint between values[i] and values[i+1].
    midpoints: Vec<f32>,
    /// Per-bucket (lo, hi) code range — the §Perf fast path: most buckets
    /// resolve to a single code, the rest to a 1–3 step binary search.
    /// Empty when the codebook has an analytic encoder instead.
    lut: Vec<(u8, u8)>,
    /// Analytic O(1) code-index candidate (exponent/mantissa bit math),
    /// exact after a ≤±1 fixup against `midpoints` — replaces the LUT for
    /// codebooks with closed-form structure (the dynamic-tree formats).
    analytic: Option<fn(f32) -> usize>,
    /// Lane-batched variant of `analytic` used by [`Codebook::encode_lanes`]
    /// — the candidate step of the block encode running across lanes.
    batch: Option<BatchCandidate>,
    name: &'static str,
}

/// Monotone mapping from f32 bit patterns to u32 (total order matching <=
/// on the floats, NaNs aside).
#[inline(always)]
fn monotone_bits(x: f32) -> u32 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b ^ 0x8000_0000
    }
}

/// Inverse of [`monotone_bits`].
fn from_monotone(m: u32) -> f32 {
    let b = if m & 0x8000_0000 != 0 { m ^ 0x8000_0000 } else { !m };
    f32::from_bits(b)
}

impl Codebook {
    pub fn new(name: &'static str, values: Vec<f32>) -> Codebook {
        Self::build(name, values, None, None)
    }

    /// Codebook with an analytic encode: `candidate(x)` computes a code
    /// index from the bit structure of `x` in O(1), accurate to ±1;
    /// [`Codebook::encode`] resolves it exactly against the midpoints. No
    /// bucket LUT is built (32 KiB and its cache pressure saved per
    /// codebook).
    pub fn new_analytic(
        name: &'static str,
        values: Vec<f32>,
        candidate: fn(f32) -> usize,
    ) -> Codebook {
        Self::build(name, values, Some(candidate), None)
    }

    /// Analytic codebook that additionally carries a lane-batched candidate
    /// for the vectorized block encode. `batch` must agree with `candidate`
    /// on NaN/zero handling (both feed the same exact fixup, so disagreement
    /// costs iterations, not correctness).
    pub fn new_analytic_batched(
        name: &'static str,
        values: Vec<f32>,
        candidate: fn(f32) -> usize,
        batch: BatchCandidate,
    ) -> Codebook {
        Self::build(name, values, Some(candidate), Some(batch))
    }

    fn build(
        name: &'static str,
        mut values: Vec<f32>,
        analytic: Option<fn(f32) -> usize>,
        batch: Option<BatchCandidate>,
    ) -> Codebook {
        assert!(!values.is_empty() && values.len() <= 256, "codebook size");
        values.sort_by(|a, b| a.partial_cmp(b).expect("finite codebook"));
        let midpoints = values
            .windows(2)
            .map(|w| 0.5 * (w[0] + w[1]))
            .collect::<Vec<f32>>();
        // Build the bucket LUT: for each bucket of the monotone-bits space,
        // the code range spanned by its value interval [lo_f, hi_f].
        // Skipped when an analytic encoder supersedes it.
        let encode_exact =
            |mids: &[f32], x: f32| -> u8 { mids.partition_point(|&m| m <= x) as u8 };
        let shift = 32 - LUT_BITS;
        let lut = if analytic.is_some() {
            Vec::new()
        } else {
            (0..LUT_SIZE)
                .map(|bucket| {
                    let lo_bits = (bucket as u32) << shift;
                    let hi_bits = lo_bits | ((1u32 << shift) - 1);
                    let lo_f = from_monotone(lo_bits);
                    let hi_f = from_monotone(hi_bits);
                    let c_lo = if lo_f.is_nan() { 0 } else { encode_exact(&midpoints, lo_f) };
                    let c_hi = if hi_f.is_nan() {
                        (values.len() - 1) as u8
                    } else {
                        encode_exact(&midpoints, hi_f)
                    };
                    (c_lo.min(c_hi), c_lo.max(c_hi))
                })
                .collect()
        };
        Codebook { values, midpoints, lut, analytic, batch, name }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Decode a code index to its representable value.
    #[inline(always)]
    pub fn decode(&self, code: u8) -> f32 {
        self.values[code as usize]
    }

    /// Nearest-value quantization of a normalized input (Eq. 3).
    ///
    /// Branchless binary search over the midpoints: after the loop `lo` is
    /// the number of midpoints strictly below `x`, i.e. the arg-min index.
    /// Ties at an exact midpoint round up (toward the larger value), which
    /// matches `searchsorted(side="right")` in the Pallas kernel so the
    /// native and HLO engines agree bit-for-bit.
    #[inline(always)]
    pub fn encode(&self, x: f32) -> u8 {
        if let Some(candidate) = self.analytic {
            // Analytic fast path: O(1) bit-math candidate, then the exact
            // fixup in `resolve_candidate`.
            return self.resolve_candidate(candidate(x), x);
        }
        // Fast path: bucket LUT on the monotone integer view. Exact — the
        // bucket's (lo, hi) code range brackets the answer; equal bounds
        // (the common case) need no search at all.
        let bucket = (monotone_bits(x) >> (32 - LUT_BITS)) as usize;
        let (lo, hi) = self.lut[bucket];
        if lo == hi {
            return lo;
        }
        // Narrow binary search within [lo, hi].
        lo + self.midpoints[lo as usize..hi as usize].partition_point(|&m| m <= x) as u8
    }

    /// Resolve an approximate code-index candidate for `x` exactly against
    /// the decision boundaries: walk the midpoints until the arg-min
    /// invariant holds, so the result is bit-identical to
    /// `encode_reference` (including its ties-round-up rule) for *any*
    /// candidate — quality only affects iteration count (≤±1 for the
    /// analytic candidates). The loops also keep NaN/±inf on the reference
    /// behavior: every comparison is false for NaN, so a NaN input returns
    /// its candidate unchanged (the analytic candidates map NaN to 0, the
    /// reference result).
    #[inline(always)]
    pub fn resolve_candidate(&self, candidate: usize, x: f32) -> u8 {
        let mut c = candidate.min(self.values.len() - 1);
        while c > 0 && self.midpoints[c - 1] > x {
            c -= 1;
        }
        while c < self.midpoints.len() && self.midpoints[c] <= x {
            c += 1;
        }
        c as u8
    }

    /// Encode [`LANES`] already-normalized inputs at once — the lane step
    /// of the vectorized block encode. The candidate stage runs across
    /// lanes (batched bit math when the codebook registered one); each lane
    /// then goes through the same exact midpoint fixup as [`Codebook::encode`],
    /// so the codes are bit-identical to encoding each lane individually.
    /// Codebooks without an analytic form fall back to the per-lane LUT
    /// encode (still exact, just not batched).
    #[inline]
    pub fn encode_lanes(&self, xs: &[f32; LANES], out: &mut [u8; LANES]) {
        if let Some(batch) = self.batch {
            let cands = batch(xs);
            for l in 0..LANES {
                out[l] = self.resolve_candidate(cands[l], xs[l]);
            }
        } else {
            for l in 0..LANES {
                out[l] = self.encode(xs[l]);
            }
        }
    }

    /// Reference encode (no LUT) — used by tests to pin LUT exactness.
    pub fn encode_reference(&self, x: f32) -> u8 {
        self.midpoints.partition_point(|&m| m <= x) as u8
    }

    /// Stochastic rounding: round to one of the two bracketing values with
    /// probability proportional to proximity (Appendix H discussion).
    pub fn encode_stochastic(&self, x: f32, rng: &mut Rng) -> u8 {
        let i = self.encode(x) as usize;
        let v = self.values[i];
        // Find the bracketing neighbour on the other side of x.
        let j = if x > v {
            (i + 1).min(self.values.len() - 1)
        } else if x < v && i > 0 {
            i - 1
        } else {
            i
        };
        if i == j {
            return i as u8;
        }
        let (a, b) = (self.values[i.min(j)], self.values[i.max(j)]);
        let gap = (b - a) as f64;
        if gap <= 0.0 {
            return i as u8;
        }
        // P(round up) = distance from lower value.
        let p_up = ((x - a) as f64 / gap).clamp(0.0, 1.0);
        if rng.uniform() < p_up {
            i.max(j) as u8
        } else {
            i.min(j) as u8
        }
    }

    /// Round-trip: quantize then decode.
    #[inline(always)]
    pub fn nearest(&self, x: f32) -> f32 {
        self.decode(self.encode(x))
    }

    /// Max absolute value in the codebook (1.0 for our formats).
    pub fn max_abs(&self) -> f32 {
        self.values
            .iter()
            .fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// True if every value appears exactly once.
    pub fn all_distinct(&self) -> bool {
        self.values.windows(2).all(|w| w[0] < w[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple() -> Codebook {
        Codebook::new("simple", vec![-1.0, -0.5, 0.0, 0.25, 1.0])
    }

    #[test]
    fn encode_is_argmin() {
        let cb = simple();
        // brute force argmin must agree everywhere
        let mut x = -1.5f32;
        while x <= 1.5 {
            let brute = cb
                .values()
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    let da = (*a - x).abs();
                    let db = (*b - x).abs();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap()
                .0;
            let got = cb.encode(x) as usize;
            let d_brute = (cb.values()[brute] - x).abs();
            let d_got = (cb.values()[got] - x).abs();
            assert!(
                (d_got - d_brute).abs() < 1e-7,
                "x={x} got={got} brute={brute}"
            );
            x += 0.013;
        }
    }

    #[test]
    fn codebook_values_encode_to_themselves() {
        let cb = simple();
        for (i, &v) in cb.values().iter().enumerate() {
            assert_eq!(cb.encode(v) as usize, i);
            assert_eq!(cb.nearest(v), v);
        }
    }

    #[test]
    fn out_of_range_clamps_to_ends() {
        let cb = simple();
        assert_eq!(cb.encode(-9.0), 0);
        assert_eq!(cb.encode(9.0) as usize, cb.len() - 1);
    }

    #[test]
    fn idempotence() {
        let cb = simple();
        let mut x = -1.2f32;
        while x < 1.2 {
            let q1 = cb.encode(x);
            let q2 = cb.encode(cb.decode(q1));
            assert_eq!(q1, q2, "x={x}");
            x += 0.017;
        }
    }

    #[test]
    fn stochastic_is_unbiased_between_neighbours() {
        let cb = simple();
        let mut rng = Rng::new(1234);
        // x = 0.125 sits halfway between 0.0 and 0.25
        let mut ups = 0;
        let n = 20_000;
        for _ in 0..n {
            let c = cb.encode_stochastic(0.125, &mut rng);
            if cb.decode(c) == 0.25 {
                ups += 1;
            }
        }
        let frac = ups as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn stochastic_exact_value_never_moves() {
        let cb = simple();
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(cb.decode(cb.encode_stochastic(0.25, &mut rng)), 0.25);
        }
    }

    #[test]
    fn encode_lanes_matches_scalar_encode() {
        // The batched candidate + shared fixup must agree with the scalar
        // encode lane-for-lane, on dense probes and on the special values
        // (NaN stays at code 0, ±inf clamp to the ends, ±0 agree).
        for cb in [
            crate::quant::dynamic_tree::dynamic_signed(),
            crate::quant::dynamic_tree::dynamic_unsigned(),
            crate::quant::dynamic_tree::dynamic_signed4(),
            crate::quant::dynamic_tree::dynamic_unsigned4(),
            crate::quant::linear::linear_signed(),
            crate::quant::linear::linear_unsigned(),
            simple(),
        ] {
            let mut rng = Rng::new(42);
            let mut out = [0u8; LANES];
            for _ in 0..4000 {
                let mut xs = [0.0f32; LANES];
                for x in xs.iter_mut() {
                    *x = (rng.normal() * rng.uniform_range(1e-9, 2.0)) as f32;
                }
                cb.encode_lanes(&xs, &mut out);
                for l in 0..LANES {
                    assert_eq!(out[l], cb.encode(xs[l]), "{}: x={}", cb.name(), xs[l]);
                }
            }
            let specials = [
                f32::NAN,
                0.0,
                -0.0,
                f32::INFINITY,
                f32::NEG_INFINITY,
                1.0,
                -1.0,
                1e-30,
            ];
            cb.encode_lanes(&specials, &mut out);
            for l in 0..LANES {
                assert_eq!(out[l], cb.encode(specials[l]), "{}: special lane {l}", cb.name());
            }
        }
    }

    #[test]
    fn lut_encode_matches_reference_exhaustively() {
        // Pin the §Perf fast paths — the bucket LUT *and* the analytic
        // dynamic-tree encode — to the reference bit-for-bit on every
        // codebook, sweeping values, decision boundaries, decade
        // boundaries, and denormals.
        for cb in [
            crate::quant::dynamic_tree::dynamic_signed(),
            crate::quant::dynamic_tree::dynamic_unsigned(),
            crate::quant::dynamic_tree::inverse_dynamic_signed(),
            crate::quant::dynamic_tree::inverse_dynamic_unsigned(),
            crate::quant::dynamic_tree::dynamic_signed4(),
            crate::quant::dynamic_tree::dynamic_unsigned4(),
            crate::quant::dynamic_tree::inverse_dynamic_signed4(),
            crate::quant::dynamic_tree::inverse_dynamic_unsigned4(),
            crate::quant::linear::linear_signed(),
            crate::quant::linear::linear_unsigned(),
            crate::quant::linear::linear_signed4(),
            crate::quant::linear::linear_unsigned4(),
            simple(),
        ] {
            let mut probes: Vec<f32> = Vec::new();
            // decimal decade boundaries (the analytic encode's hardest
            // inputs), both signs, ± a few ulps
            for e in 0..=9i32 {
                let bits = 10f32.powi(-e).to_bits() as i64;
                for d in -3i64..=3 {
                    let v = f32::from_bits((bits + d).clamp(0, u32::MAX as i64) as u32);
                    probes.push(v);
                    probes.push(-v);
                }
            }
            // subnormals and extremes
            probes.extend_from_slice(&[
                f32::MIN_POSITIVE,
                -f32::MIN_POSITIVE,
                1e-45,
                -1e-45,
                f32::MAX,
                f32::MIN,
                3.4e38,
            ]);
            for &v in cb.values() {
                for d in [-2i32, -1, 0, 1, 2] {
                    // nudge by ulps around each representable value
                    let b = v.to_bits() as i64 + d as i64;
                    probes.push(f32::from_bits(b.clamp(0, u32::MAX as i64) as u32));
                }
            }
            for w in cb.values().windows(2) {
                let m = 0.5 * (w[0] + w[1]);
                for d in [-1i64, 0, 1] {
                    probes.push(f32::from_bits((m.to_bits() as i64 + d) as u32));
                }
            }
            let mut rng = Rng::new(1);
            for _ in 0..20_000 {
                probes.push((rng.normal() * rng.uniform_range(1e-9, 2.0)) as f32);
            }
            probes.extend_from_slice(&[0.0, -0.0, 1.0, -1.0, 5.0, -5.0, 1e-30, -1e-30]);
            for x in probes {
                if !x.is_finite() {
                    continue;
                }
                assert_eq!(
                    cb.encode(x),
                    cb.encode_reference(x),
                    "{}: x = {x} ({:#010x})",
                    cb.name(),
                    x.to_bits()
                );
            }
        }
    }
}
