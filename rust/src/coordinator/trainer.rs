//! The training coordinator: owns parameters, the data pipeline and the
//! step loop; drives the AOT train/eval artifacts through PJRT and applies
//! optimizer updates through the model-level [`ParamOptimizer`], which owns
//! every tensor's optimizer (resolved from the run's `OptimSpec`: base
//! config + parameter-group overrides) with either engine:
//!
//! * `Engine::Native` — the fused multi-threaded Rust 8-bit optimizer
//!   (production hot path; `optim::*`).
//! * `Engine::Hlo` — the AOT Pallas kernels (`adam8_n*.hlo.txt`), i.e. the
//!   L1 layer executing through PJRT. Tensors whose *resolved* group
//!   config is 32-bit (stable-embedding §2.3) or has no HLO artifact fall
//!   back to the native path; `RunResult::hlo_updated_tensors` reports how
//!   many went through HLO so tests can assert the path is exercised.
//!
//! When both engines are active the step is *overlapped*: the native
//! tensors stream onto the worker pool (group-aware admission order) while
//! this thread drives the serial PJRT dispatches, so the pool is busy
//! during every HLO round-trip instead of idling until the HLO pass ends.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{Engine, RunConfig};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::metrics::JsonlSink;
use crate::coordinator::stability::StabilityDetector;
use crate::data::{corpus::Corpus, glue::GlueDataset};
use crate::optim::{
    GroupReport, HloDispatch, HloEnv, ParamOptimizer, PrecisionController, TensorInfo,
};
use crate::runtime::{self, ModelEntry, Runtime};
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub model: ModelEntry,
    pub cfg: RunConfig,
    pub params: Vec<Vec<f32>>,
    /// Per-tensor optimizers + HLO mirrors, grouped by the run's OptimSpec.
    popt: ParamOptimizer,
    corpus: Option<Corpus>,
    glue: Option<GlueDataset>,
    data_rng: Rng,
    eval_seed: u64,
    pub detector: StabilityDetector,
    metrics: Option<JsonlSink>,
    /// Layer-6 adaptive precision controller (`[precision]` config);
    /// `None` = static widths.
    precision: Option<PrecisionController>,
    pub step: usize,
}

#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub losses: Vec<f64>,
    pub evals: Vec<(usize, f64)>,
    pub eval_accs: Vec<(usize, f64)>,
    pub unstable: bool,
    pub reason: Option<&'static str>,
    pub final_eval: f64,
    pub state_bytes: usize,
    /// Per parameter group: (label, optimizer-state bytes).
    pub group_state_bytes: Vec<(String, usize)>,
    /// Largest per-shard state footprint — with ZeRO-style placement this,
    /// not `state_bytes`, bounds one worker's memory (equal to
    /// `state_bytes` when placement is off).
    pub max_shard_state_bytes: usize,
    /// Per parameter group: (label, max per-shard state bytes) — the
    /// sharded counterpart of `group_state_bytes`.
    pub group_max_shard_bytes: Vec<(String, usize)>,
    /// Global placement shard count (1 = placement off).
    pub shards: usize,
    pub wall_secs: f64,
    pub steps_done: usize,
    pub hlo_updated_tensors: usize,
    /// Width transitions the adaptive precision controller applied (0 when
    /// the controller is off or never fired).
    pub precision_transitions: usize,
    /// Largest total optimizer-state footprint reached during the run —
    /// equals `state_bytes` for static-width runs; with the adaptive
    /// controller it is the high-water mark across promotions.
    pub peak_state_bytes: usize,
}

impl RunResult {
    pub fn ppl(&self) -> f64 {
        self.final_eval.exp()
    }
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: RunConfig) -> Result<Trainer<'rt>> {
        let manifest = rt.manifest()?;
        let model = manifest.model(&cfg.model)?.clone();
        let mut seed_rng = Rng::new(cfg.seed);
        let mut init_rng = seed_rng.fork(1);
        let data_rng = seed_rng.fork(2);
        let eval_seed = seed_rng.fork(3).next_u64();

        // Parameters from the manifest init contract (with the optional
        // Table 8 embedding-init override).
        let params: Vec<Vec<f32>> = model
            .params
            .iter()
            .map(|p| {
                if p.name == "embed.tok" {
                    if let Some(init) = &cfg.emb_init_override {
                        let mut spec = p.clone();
                        spec.init = init.clone();
                        return runtime::init_param(&spec, &mut init_rng);
                    }
                }
                runtime::init_param(p, &mut init_rng)
            })
            .collect();

        // Per-tensor optimizers through the parameter-group surface: each
        // tensor's effective config (precision, hyperparameters, HLO
        // artifact eligibility) is resolved from the spec at build time.
        let tensors: Vec<TensorInfo> = model
            .params
            .iter()
            .map(|p| TensorInfo {
                name: p.name.clone(),
                size: p.size,
                shape: if p.shape.len() == 2 { Some((p.shape[0], p.shape[1])) } else { None },
                padded: p.padded,
            })
            .collect();
        let artifact_for =
            |kind: &str, size: usize| manifest.update_artifact(kind, size).map(str::to_string);
        let hlo_env = if cfg.engine == Engine::Hlo {
            Some(HloEnv { block: manifest.block, artifact_for: &artifact_for })
        } else {
            None
        };
        let popt = ParamOptimizer::build(cfg.optim_spec(), &tensors, hlo_env)
            .with_context(|| format!("building optimizer for model {:?}", model.name))?;

        let (corpus, glue) = if model.task == "lm" {
            (Some(Corpus::with_params(model.vocab, cfg.seed, 1.1, cfg.data_noise)), None)
        } else {
            let task = crate::data::glue::GLUE_TASKS
                .iter()
                .find(|t| t.n_classes == model.n_classes)
                .cloned()
                .unwrap_or(crate::data::glue::GLUE_TASKS[4].clone());
            (None, Some(GlueDataset::generate(&task, model.vocab, model.seq_len, cfg.seed)))
        };

        let mut metrics = match &cfg.log_jsonl {
            Some(path) => Some(JsonlSink::create(path)?),
            None => None,
        };
        if let Some(sink) = metrics.as_mut() {
            let entries: Vec<Json> = popt
                .group_reports()
                .iter()
                .map(|g| {
                    obj(vec![
                        ("group", s(&g.label)),
                        ("config", s(&g.config)),
                        ("bits", num(g.bits as f64)),
                        ("tensors", num(g.tensors as f64)),
                        ("params", num(g.params as f64)),
                        ("state_bytes", num(g.state_bytes as f64)),
                        ("bytes_per_param", num(g.bytes_per_param())),
                        ("clip_percentile", num(g.clip_percentile as f64)),
                        ("max_unorm", num(g.max_unorm as f64)),
                        ("skip_zeros", Json::Bool(g.skip_zeros)),
                        ("shards", num(g.shards as f64)),
                        (
                            "shard_state_bytes",
                            Json::Arr(
                                g.shard_state_bytes
                                    .iter()
                                    .map(|&b| num(b as f64))
                                    .collect(),
                            ),
                        ),
                        ("max_shard_bytes", num(g.max_shard_bytes() as f64)),
                    ])
                })
                .collect();
            sink.record("groups", vec![("groups", Json::Arr(entries))])?;
        }

        // Adaptive precision controller: per-tensor bounds resolve against
        // the freshly-built optimizer (HLO mirrors and 32-bit-only kinds
        // come back pinned, so the controller simply never touches them).
        let precision = cfg.precision.map(|policy| PrecisionController::new(policy, &popt));

        Ok(Trainer {
            rt,
            model,
            cfg,
            params,
            popt,
            corpus,
            glue,
            data_rng,
            eval_seed,
            detector: StabilityDetector::new(),
            metrics,
            precision,
            step: 0,
        })
    }

    /// Use a specific GLUE task (Table 4 runs).
    pub fn with_glue_task(mut self, task: &crate::data::glue::GlueTask) -> Result<Self> {
        anyhow::ensure!(self.model.task == "cls", "glue task on a cls model only");
        anyhow::ensure!(
            task.n_classes <= self.model.n_classes,
            "task has more classes than the model head"
        );
        self.glue = Some(GlueDataset::generate(
            task,
            self.model.vocab,
            self.model.seq_len,
            self.cfg.seed,
        ));
        Ok(self)
    }

    /// The model-level optimizer (group layout, per-tensor configs).
    pub fn param_optimizer(&self) -> &ParamOptimizer {
        &self.popt
    }

    pub fn state_bytes(&self) -> usize {
        self.popt.state_bytes()
    }

    /// Per parameter group: tensor count, params, state bytes.
    pub fn group_reports(&self) -> Vec<GroupReport> {
        self.popt.group_reports()
    }

    pub fn n_params(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Build the LM token batch [B, S+1] (train) from a given rng.
    fn lm_batch(&self, rng: &mut Rng) -> Vec<i32> {
        let c = self.corpus.as_ref().expect("lm task");
        c.batch(rng, self.model.batch, self.model.seq_len + 1)
    }

    /// One training step; returns the loss.
    pub fn train_step(&mut self) -> Result<f64> {
        // Default-group scheduled LR (metrics; per-group LRs are set below).
        let step_lr = self.cfg.schedule.lr_at(self.cfg.optim.lr, self.step);

        // ---- forward/backward through the AOT train artifact -------------
        let mut inputs: Vec<runtime::Literal> = Vec::with_capacity(self.params.len() + 2);
        for (vals, spec) in self.params.iter().zip(&self.model.params) {
            inputs.push(runtime::lit_f32_shaped(vals, &spec.shape)?);
        }
        let mut rng = self.data_rng.clone();
        let is_lm = self.model.task == "lm";
        if is_lm {
            let toks = self.lm_batch(&mut rng);
            inputs.push(runtime::lit_i32_2d(&toks, self.model.batch, self.model.seq_len + 1)?);
        } else {
            let (mut toks, mut labels) = (Vec::new(), Vec::new());
            self.glue
                .as_ref()
                .expect("cls task")
                .train_batch(&mut rng, self.model.batch, &mut toks, &mut labels);
            inputs.push(runtime::lit_i32_2d(&toks, self.model.batch, self.model.seq_len)?);
            inputs.push(runtime::lit_i32(&labels));
        }
        self.data_rng = rng;

        let outputs = self
            .rt
            .run(&self.model.train, &inputs)
            .with_context(|| format!("train step on {}", self.model.train))?;
        let n_aux = if is_lm { 1 } else { 2 };
        anyhow::ensure!(
            outputs.len() == n_aux + self.params.len(),
            "expected {} outputs, got {}",
            n_aux + self.params.len(),
            outputs.len()
        );
        let loss = runtime::scalar_of(&outputs[0])? as f64;
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(self.params.len());
        for out in &outputs[n_aux..] {
            grads.push(runtime::f32_of(out)?);
        }

        // ---- fault injection (stress configs; off by default) ------------
        if self.cfg.fault.any() {
            self.cfg.fault.apply(self.step + 1, &mut grads);
        }

        // ---- gradient hygiene --------------------------------------------
        let (nonfinite, sq, tensor_sq) = grad_stats(&grads);
        if nonfinite > 0 {
            // A crashed step must still leave a trace in the loss curve:
            // record it with a `grad_crash` marker instead of vanishing
            // from the JSONL stream. The count distinguishes a single
            // flipped element from a fully-poisoned backward pass.
            self.detector.report_grad_crash();
            self.step += 1;
            // Even though the update never ran, drain the update-phase
            // counters on this early exit: anything left from earlier
            // activity must not surface in the next successful step's
            // record as if that step produced it.
            Self::drain_counters();
            // The controller still observes the crash (per-tensor norms of
            // the finite values; the crash flag latches until the next
            // review promotes), but reviews only run on successful steps —
            // the update that a transition would requantize never ran.
            if let Some(ctl) = self.precision.as_mut() {
                ctl.observe_step(&tensor_sq, 0, 0, true);
            }
            if let Some(sink) = self.metrics.as_mut() {
                let marker = vec![
                    ("grad_crash", Json::Bool(true)),
                    ("nonfinite_grads", num(nonfinite as f64)),
                ];
                sink.step(self.step, loss, step_lr as f64, marker)?;
            }
            return Ok(loss);
        }
        let gnorm = sq.sqrt();
        if self.cfg.grad_clip > 0.0 && gnorm > self.cfg.grad_clip as f64 {
            let scale = (self.cfg.grad_clip as f64 / gnorm) as f32;
            for g in grads.iter_mut() {
                for v in g.iter_mut() {
                    *v *= scale;
                }
            }
        }

        // ---- optimizer update (native + HLO engines, overlapped) ---------
        // Per-group LR scheduling: each tensor's LR comes from its group's
        // base LR through the run schedule.
        let schedule = self.cfg.schedule;
        let step = self.step;
        self.popt.schedule_lr(|base| schedule.lr_at(base, step));
        // Pre-drain the non-finite-block and stability counters so the
        // post-step readings are scoped to this step's update work.
        Self::drain_counters();
        if self.popt.n_hlo() == 0 {
            // Pure native run: the fused step's one-pool-batch-per-phase
            // dispatch is strictly better when there is nothing to overlap.
            // Bit-identical to streaming and to serial stepping.
            self.popt.step_native(&mut self.params, &grads);
        } else {
            // HLO engine active: stream the native tensors onto the worker
            // pool (group-aware admission: 32-bit groups first, then
            // descending size) and drive the serial PJRT dispatches on
            // THIS thread meanwhile — the runtime is not thread-safe, but
            // the pool no longer idles through every HLO round-trip.
            let rt = self.rt;
            let (mut stream, mut dispatches) = self.popt.stream_native(&mut self.params, &grads);
            stream.admit_all();
            for d in dispatches.iter_mut() {
                Self::hlo_dispatch(rt, d)?;
                // let drained native phases progress between round-trips
                stream.poll();
            }
            stream.finish();
        }

        // ---- quantization hygiene ----------------------------------------
        // The block absmax scan skips non-finite elements (one bad value
        // must not zero a whole block's codes) and counts affected blocks;
        // any hit during this step's update is the same crash condition as
        // a non-finite gradient norm, reported through the same channel.
        // Stability telemetry rides along: how many tensors had their
        // gradient clipped by the percentile phase / their update clipped
        // by max_unorm during this step's fused batch.
        let (bad_blocks, clip_events, unorm_clips) = Self::drain_counters();
        if bad_blocks > 0 {
            self.detector.report_grad_crash();
        }
        self.detector.observe(loss);
        self.step += 1;

        // ---- adaptive precision (layer 6) --------------------------------
        // Feed the controller this step's deterministic signals (raw
        // per-tensor gradient norms — pre-clip — plus the drained clip and
        // crash telemetry) and run a review on the policy cadence. Every
        // transition requantizes that tensor's states losslessly from their
        // 32-bit working values and lands in the JSONL `groups` stream.
        let mut transitions = Vec::new();
        if let Some(ctl) = self.precision.as_mut() {
            ctl.observe_step(&tensor_sq, clip_events, unorm_clips, bad_blocks > 0);
            if ctl.due(self.step) {
                transitions = ctl.review(self.step, &mut self.popt);
            }
        }
        if let Some(sink) = self.metrics.as_mut() {
            for t in &transitions {
                sink.record(
                    "groups",
                    vec![
                        ("step", num(t.step as f64)),
                        ("tensor", s(&t.tensor)),
                        ("from_bits", num(t.from_bits as f64)),
                        ("to_bits", num(t.to_bits as f64)),
                        ("trigger", s(t.trigger)),
                    ],
                )?;
            }
        }

        if let Some(sink) = self.metrics.as_mut() {
            let mut extras = vec![("gnorm", num(gnorm))];
            if clip_events > 0 {
                extras.push(("clip_events", num(clip_events as f64)));
            }
            if unorm_clips > 0 {
                extras.push(("unorm_clips", num(unorm_clips as f64)));
            }
            if bad_blocks > 0 {
                extras.push(("grad_crash", Json::Bool(true)));
                extras.push(("nonfinite_blocks", num(bad_blocks as f64)));
            }
            sink.step(self.step, loss, step_lr as f64, extras)?;
        }
        Ok(loss)
    }

    /// Apply one HLO-engine tensor's update through its PJRT artifact. The
    /// artifact and the hyperparameter vector both come from the tensor's
    /// *resolved* group config (not any global config). Runs on the calling
    /// thread — PJRT is not thread-safe — while the native stream crunches
    /// on the worker pool.
    fn hlo_dispatch(rt: &Runtime, d: &mut HloDispatch<'_>) -> Result<()> {
        d.opt.set_t(d.opt.t() + 1);
        let t = d.opt.t();
        let lr = d.opt.lr();
        let ocfg = &d.cfg;
        let st = &mut *d.mirror;
        let hp: [f32; 8] = if st.single_state {
            [lr, ocfg.beta1, ocfg.weight_decay, if t <= 1 { 1.0 } else { 0.0 }, 0.0, 0.0, 0.0, 0.0]
        } else {
            let bias1 = 1.0 - ocfg.beta1.powi(t as i32);
            let bias2 = 1.0 - ocfg.beta2.powi(t as i32);
            [lr, ocfg.beta1, ocfg.beta2, ocfg.eps, ocfg.weight_decay, bias1, bias2, 0.0]
        };
        let mut inputs = vec![
            runtime::lit_f32(&hp),
            runtime::lit_f32(d.params.as_slice()),
            runtime::lit_f32(d.grads),
            runtime::lit_u8(&st.codes1)?,
            runtime::lit_f32(&st.absmax1),
        ];
        if !st.single_state {
            inputs.push(runtime::lit_u8(&st.codes2)?);
            inputs.push(runtime::lit_f32(&st.absmax2));
        }
        let outputs = rt.run(&st.artifact, &inputs)?;
        *d.params = runtime::f32_of(&outputs[0])?;
        st.codes1 = runtime::u8_of(&outputs[1])?;
        st.absmax1 = runtime::f32_of(&outputs[2])?;
        if !st.single_state {
            st.codes2 = runtime::u8_of(&outputs[3])?;
            st.absmax2 = runtime::f32_of(&outputs[4])?;
        }
        Ok(())
    }

    /// Drain all three process-global update counters in one place:
    /// non-finite quantization blocks, percentile-clip events, and
    /// max_unorm clips. Returns the drained `(bad_blocks, clip_events,
    /// unorm_clips)`. This is the registered drain point that rule (c) of
    /// [`crate::analysis::plan_lint`] refers to — every counter a plan may
    /// increment must be covered here, so adding a counter without
    /// extending this drain fails the linter.
    fn drain_counters() -> (u64, u64, u64) {
        (
            crate::quant::blockwise::take_nonfinite_blocks(),
            crate::optim::take_clip_events(),
            crate::optim::take_unorm_clips(),
        )
    }

    /// Evaluation loss (and accuracy for cls) on held-out batches.
    pub fn evaluate(&mut self) -> Result<(f64, Option<f64>)> {
        let mut rng = Rng::new(self.eval_seed);
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        for _ in 0..self.cfg.eval_batches.max(1) {
            let mut inputs: Vec<runtime::Literal> = Vec::with_capacity(self.params.len() + 2);
            for (vals, spec) in self.params.iter().zip(&self.model.params) {
                inputs.push(runtime::lit_f32_shaped(vals, &spec.shape)?);
            }
            if self.model.task == "lm" {
                let toks = self.lm_batch(&mut rng);
                inputs.push(runtime::lit_i32_2d(
                    &toks,
                    self.model.batch,
                    self.model.seq_len + 1,
                )?);
            } else {
                let ds = self.glue.as_ref().expect("cls");
                // fixed eval set, batch-sized windows (wrapping)
                let n = ds.eval_labels.len();
                let b = self.model.batch;
                let start = (losses.len() * b) % n;
                let mut toks = Vec::with_capacity(b * self.model.seq_len);
                let mut labels = Vec::with_capacity(b);
                for k in 0..b {
                    let idx = (start + k) % n;
                    toks.extend_from_slice(
                        &ds.eval_tokens[idx * ds.seq_len..(idx + 1) * ds.seq_len],
                    );
                    labels.push(ds.eval_labels[idx]);
                }
                inputs.push(runtime::lit_i32_2d(&toks, b, self.model.seq_len)?);
                inputs.push(runtime::lit_i32(&labels));
            }
            let outputs = self.rt.run(&self.model.eval, &inputs)?;
            losses.push(runtime::scalar_of(&outputs[0])? as f64);
            if self.model.task != "lm" {
                accs.push(runtime::scalar_of(&outputs[1])? as f64);
            }
        }
        let mean_loss = crate::util::stats::mean(&losses);
        let mean_acc = if accs.is_empty() { None } else { Some(crate::util::stats::mean(&accs)) };
        Ok((mean_loss, mean_acc))
    }

    /// Run the configured number of steps (stopping early on instability).
    pub fn train(&mut self) -> Result<RunResult> {
        let t0 = Instant::now();
        // Between-runs hygiene: a prior trainer in this process (sweeps,
        // seed medians, tests) may have left counter residue — e.g. a run
        // that ended on the grad-crash early exit. Start from zero so this
        // run's first step only reports its own events.
        Self::drain_counters();
        let reports = self.popt.group_reports();
        let mut res = RunResult {
            state_bytes: self.state_bytes(),
            group_state_bytes: reports
                .iter()
                .map(|g| (g.label.clone(), g.state_bytes))
                .collect(),
            max_shard_state_bytes: self.popt.max_shard_state_bytes(),
            group_max_shard_bytes: reports
                .iter()
                .map(|g| (g.label.clone(), g.max_shard_bytes()))
                .collect(),
            shards: self.popt.shard_layout().n_shards,
            hlo_updated_tensors: self.popt.n_hlo(),
            ..Default::default()
        };
        for _ in 0..self.cfg.steps {
            let loss = self.train_step()?;
            res.losses.push(loss);
            if self.detector.is_unstable() {
                break;
            }
            if self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0 {
                let (el, acc) = self.evaluate()?;
                res.evals.push((self.step, el));
                if let Some(a) = acc {
                    res.eval_accs.push((self.step, a));
                }
            }
        }
        // Post-loop eval — unless the loop's last iteration already
        // evaluated at this step (when `steps` is a multiple of
        // `eval_every`, this used to push the same step's eval twice and
        // pay a second full eval pass).
        let evaluated_here = res.evals.last().map(|&(s, _)| s) == Some(self.step);
        if !self.detector.is_unstable() && !evaluated_here {
            let (el, acc) = self.evaluate()?;
            res.evals.push((self.step, el));
            if let Some(a) = acc {
                res.eval_accs.push((self.step, a));
            }
        }
        res.unstable = self.detector.is_unstable();
        res.reason = self.detector.reason();
        res.final_eval = res.evals.last().map(|&(_, l)| l).unwrap_or(f64::NAN);
        res.steps_done = self.step;
        res.precision_transitions =
            self.precision.as_ref().map_or(0, |c| c.transitions().len());
        res.peak_state_bytes = match &self.precision {
            Some(c) => c.peak_state_bytes().max(self.state_bytes()),
            None => res.state_bytes,
        };
        res.wall_secs = t0.elapsed().as_secs_f64();
        if let Some(m) = self.metrics.as_mut() {
            m.flush()?;
        }
        Ok(res)
    }

    /// Capture a checkpoint (params + per-tensor optimizer states keyed by
    /// tensor name and group + step + data RNG). Refuses on the HLO engine:
    /// HLO tensors keep their moments in the PJRT-side state mirrors, which
    /// the checkpoint format does not carry — capturing would silently
    /// record the zero-initialized native states instead.
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        anyhow::ensure!(
            self.popt.n_hlo() == 0,
            "checkpointing is not supported with Engine::Hlo ({} tensors hold their \
             optimizer state in HLO mirrors)",
            self.popt.n_hlo()
        );
        Ok(Checkpoint::capture(
            self.step as u64,
            &self.data_rng,
            &self.params,
            &self.popt,
            self.precision.as_ref(),
        ))
    }

    /// Capture a checkpoint and write it to disk in the layout matching the
    /// run's placement: with `shards > 1` this emits the v5 manifest plus one
    /// file per shard (written shard-parallel off the worker pool, mirroring
    /// the tensor→shard assignment), otherwise the monolithic v4 file.
    /// Either layout restores into any placement via [`Checkpoint::load`].
    pub fn save_checkpoint<P: AsRef<std::path::Path>>(&self, path: P) -> Result<()> {
        let ck = self.checkpoint()?;
        let layout = self.popt.shard_layout();
        if layout.n_shards > 1 {
            ck.save_sharded(path, &layout.assignment, layout.n_shards)
        } else {
            ck.save(path)
        }
    }

    /// Restore a checkpoint captured from an equivalently-configured run
    /// (tensors are matched by name; 8-bit states requantize losslessly).
    /// The stability detector is reset: history from any discarded
    /// post-checkpoint steps must not leak into the resumed run.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        anyhow::ensure!(
            self.popt.n_hlo() == 0,
            "restoring is not supported with Engine::Hlo ({} tensors hold their \
             optimizer state in HLO mirrors)",
            self.popt.n_hlo()
        );
        ck.restore(&mut self.params, &mut self.popt, self.precision.as_mut())?;
        self.data_rng = Rng::from_state(ck.rng_state);
        self.step = ck.step as usize;
        self.detector = StabilityDetector::new();
        Ok(())
    }

    /// The adaptive-precision controller, when the run has one
    /// (`[precision]` / `--precision-policy`).
    pub fn precision_controller(&self) -> Option<&PrecisionController> {
        self.precision.as_ref()
    }

    /// Dequantized snapshots of every optimizer state (Figure 4 capture).
    pub fn state_snapshot(&self) -> Vec<(String, Vec<f32>)> {
        self.popt.state_snapshot()
    }
}

/// Gradient-hygiene scan: the number of non-finite values, the global
/// squared l2 norm over the *finite* values, and the per-tensor squared
/// norms (the precision controller's spike signal). The count (not just a
/// verdict bit) goes into the `grad_crash` JSONL record — one flipped bit
/// and a fully-NaN backward pass are very different failures, and the old
/// early-exit scan could not tell them apart. The finite-only norm stays
/// usable for diagnostics even on a crashed step (the previous version
/// returned a truncated partial norm).
///
/// Determinism contract: the *global* accumulator keeps the exact
/// element-order f64 addition sequence it always had — the per-tensor
/// sums are separate accumulators in the same loop, never folded into the
/// global — so the gradient-clip threshold comparison is bitwise
/// unchanged by this telemetry and independent of thread count.
pub(crate) fn grad_stats(grads: &[Vec<f32>]) -> (u64, f64, Vec<f64>) {
    let mut nonfinite = 0u64;
    let mut sq = 0.0f64;
    let mut tensor_sq = Vec::with_capacity(grads.len());
    for g in grads {
        let mut tsq = 0.0f64;
        for &v in g {
            if v.is_finite() {
                let v2 = v as f64 * v as f64;
                sq += v2;
                tsq += v2;
            } else {
                nonfinite += 1;
            }
        }
        tensor_sq.push(tsq);
    }
    (nonfinite, sq, tensor_sq)
}

/// Convenience used by the repro harness: run one config end to end.
pub fn run_config(rt: &Runtime, cfg: RunConfig) -> Result<RunResult> {
    let mut tr = Trainer::new(rt, cfg)?;
    tr.train()
}

/// Reduce a set of seeds to the paper's reporting convention: median over
/// *successful* runs + instability percentage.
pub fn median_over_seeds(results: &[RunResult]) -> (f64, f64) {
    let ok: Vec<f64> = results
        .iter()
        .filter(|r| !r.unstable && r.final_eval.is_finite())
        .map(|r| r.final_eval)
        .collect();
    let unstable_pct = 100.0 * (results.len() - ok.len()) as f64 / results.len().max(1) as f64;
    let med = crate::util::stats::median(&ok);
    (med, unstable_pct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_stats_computes_global_sq_norm() {
        let g = vec![vec![3.0f32], vec![4.0f32]];
        let (nonfinite, sq, tensor_sq) = grad_stats(&g);
        assert_eq!(nonfinite, 0);
        assert!((sq - 25.0).abs() < 1e-12);
        assert_eq!(tensor_sq, vec![9.0, 16.0], "per-tensor sums for the controller");
        let (nonfinite, sq, tensor_sq) = grad_stats(&[]);
        assert_eq!(nonfinite, 0);
        assert_eq!(sq, 0.0);
        assert!(tensor_sq.is_empty());
    }

    #[test]
    fn grad_stats_counts_every_non_finite_value() {
        // The count must cover the whole gradient set (a flipped bit vs a
        // fully-NaN backward pass are different failures), and the norm
        // must stay clean — finite values only, never polluted by Inf/NaN.
        let g = vec![vec![1.0f32, f32::NAN, 2.0], vec![f32::INFINITY; 1000]];
        let (nonfinite, sq, tensor_sq) = grad_stats(&g);
        assert_eq!(nonfinite, 1001);
        assert!((sq - 5.0).abs() < 1e-12, "norm over finite values only, got {sq}");
        assert!((tensor_sq[0] - 5.0).abs() < 1e-12, "per-tensor sums skip non-finite too");
        assert_eq!(tensor_sq[1], 0.0);
    }

    #[test]
    fn drain_counters_covers_all_three_and_resets() {
        // Regression for the grad-crash leak: counts accumulated before an
        // early exit must be consumed by the drain, never surfacing in the
        // next step's record. Inject known amounts into all three counters
        // and check one drain returns at least them (other tests in this
        // process may add their own concurrently — the injected amounts
        // are lower bounds, not exact values).
        crate::optim::stability::bump_counters_for_test(3, 2);
        crate::quant::blockwise::bump_nonfinite_for_test(5);
        let (bad, clips, unorms) = Trainer::drain_counters();
        assert!(bad >= 5, "nonfinite blocks not drained: {bad}");
        assert!(clips >= 3, "clip events not drained: {clips}");
        assert!(unorms >= 2, "unorm clips not drained: {unorms}");
        // The drain is a swap-to-zero: our injection must not be
        // observable a second time.
        let (bad2, clips2, unorms2) = Trainer::drain_counters();
        assert!(bad2 < 5 && clips2 < 3 && unorms2 < 2, "{bad2} {clips2} {unorms2}");
    }
}
