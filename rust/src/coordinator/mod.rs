//! L3 coordinator: the training framework around the AOT artifacts.
//!
//! * [`trainer`] — parameter/optimizer ownership + the step loop.
//! * [`stability`] — divergence detection (Table 3 "Unstable %").
//! * [`metrics`] — JSONL metrics sink.
//! * [`checkpoint`] — save/restore (lossless for 8-bit states).

pub mod checkpoint;
pub mod metrics;
pub mod stability;
pub mod trainer;

pub use checkpoint::{Checkpoint, CtlCheckpoint};
pub use stability::StabilityDetector;
pub use trainer::{median_over_seeds, run_config, RunResult, Trainer};
