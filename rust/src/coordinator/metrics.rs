//! JSONL metrics sink — one JSON object per line; records append within a
//! run, and [`JsonlSink::create`] starts each run on a fresh file (a
//! re-used `--log-jsonl` path used to silently interleave two runs'
//! records, including two `"groups"` headers, in one file). The experiment
//! harness and examples tail these files to build loss curves.
//!
//! The `"groups"` header record carries the placement axis alongside each
//! group's quantization config: `shards`, the per-shard `shard_state_bytes`
//! array, and `max_shard_bytes` (the footprint a single shard must hold —
//! what ZeRO-style sharding actually bounds).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{num, obj, s, Json};

pub struct JsonlSink {
    w: BufWriter<File>,
}

impl JsonlSink {
    /// Open `path` for a new run, truncating any previous run's records.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<JsonlSink> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let f = File::create(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        Ok(JsonlSink { w: BufWriter::new(f) })
    }

    pub fn write(&mut self, record: &Json) -> Result<()> {
        self.w.write_all(record.to_string().as_bytes())?;
        self.w.write_all(b"\n")?;
        Ok(())
    }

    /// Convenience: a training-step record.
    pub fn step(&mut self, step: usize, loss: f64, lr: f64, extra: Vec<(&str, Json)>) -> Result<()> {
        let mut pairs = vec![
            ("kind", s("step")),
            ("step", num(step as f64)),
            ("loss", num(loss)),
            ("lr", num(lr)),
        ];
        pairs.extend(extra);
        self.write(&obj(pairs))
    }

    /// A typed one-off record (e.g. the run-start "groups" record carrying
    /// the per-parameter-group layout and state bytes).
    pub fn record(&mut self, kind: &str, extra: Vec<(&str, Json)>) -> Result<()> {
        let mut pairs = vec![("kind", s(kind))];
        pairs.extend(extra);
        self.write(&obj(pairs))
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        let _ = self.w.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_parseable_lines() {
        let dir = std::env::temp_dir().join(format!("bitopt8_metrics_{}", std::process::id()));
        let path = dir.join("m.jsonl");
        {
            let mut sink = JsonlSink::create(&path).unwrap();
            sink.step(1, 6.5, 1e-3, vec![("ppl", num(665.0))]).unwrap();
            sink.step(2, 6.4, 1e-3, vec![]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let rec = Json::parse(lines[0]).unwrap();
        assert_eq!(rec.get("step").as_usize(), Some(1));
        assert_eq!(rec.get("ppl").as_f64(), Some(665.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn create_truncates_a_previous_runs_file() {
        // regression: append-mode create silently interleaved two runs'
        // records (including two "groups" headers) in one file
        let dir = std::env::temp_dir().join(format!("bitopt8_metrics_tr_{}", std::process::id()));
        let path = dir.join("m.jsonl");
        {
            let mut sink = JsonlSink::create(&path).unwrap();
            sink.record("groups", vec![("groups", Json::Arr(Vec::new()))]).unwrap();
            sink.step(1, 6.5, 1e-3, vec![]).unwrap();
            sink.step(2, 6.4, 1e-3, vec![]).unwrap();
        }
        {
            let mut sink = JsonlSink::create(&path).unwrap();
            sink.record("groups", vec![("groups", Json::Arr(Vec::new()))]).unwrap();
            sink.step(1, 7.0, 1e-3, vec![]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "second run must start fresh:\n{text}");
        assert_eq!(text.lines().filter(|l| l.contains("\"groups\"")).count(), 1);
        let step = Json::parse(text.lines().nth(1).unwrap()).unwrap();
        assert_eq!(step.get("loss").as_f64(), Some(7.0));
        std::fs::remove_dir_all(&dir).ok();
    }
}
