//! Training-stability detection (Table 3's "Unstable %").
//!
//! The paper counts a run as unsuccessful if it "crashes due to exploding
//! gradients or diverges in the loss". We operationalize that as:
//!   * any non-finite loss or gradient (the "crash"), or
//!   * loss exceeding `initial + margin` nats for `patience` consecutive
//!     observations after a short grace period (the "divergence"), or
//!   * loss above a hard ceiling.

#[derive(Clone, Debug)]
pub struct StabilityDetector {
    initial: Option<f64>,
    bad_streak: usize,
    steps_seen: usize,
    pub margin: f64,
    pub patience: usize,
    pub grace: usize,
    pub hard_ceiling: f64,
    verdict: Option<&'static str>,
}

impl Default for StabilityDetector {
    fn default() -> Self {
        StabilityDetector {
            initial: None,
            bad_streak: 0,
            steps_seen: 0,
            margin: 2.0,
            patience: 5,
            grace: 5,
            hard_ceiling: 30.0,
            verdict: None,
        }
    }
}

impl StabilityDetector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one training loss; returns true while the run is healthy.
    pub fn observe(&mut self, loss: f64) -> bool {
        if self.verdict.is_some() {
            return false;
        }
        self.steps_seen += 1;
        if !loss.is_finite() {
            self.verdict = Some("non-finite loss");
            return false;
        }
        if loss > self.hard_ceiling {
            self.verdict = Some("loss above hard ceiling");
            return false;
        }
        let initial = *self.initial.get_or_insert(loss);
        if self.steps_seen > self.grace && loss > initial + self.margin {
            self.bad_streak += 1;
            if self.bad_streak >= self.patience {
                self.verdict = Some("sustained divergence above initial loss");
                return false;
            }
        } else {
            self.bad_streak = 0;
        }
        true
    }

    /// Report a gradient crash (non-finite grads) directly.
    pub fn report_grad_crash(&mut self) {
        self.verdict = Some("non-finite gradients");
    }

    pub fn is_unstable(&self) -> bool {
        self.verdict.is_some()
    }

    pub fn reason(&self) -> Option<&'static str> {
        self.verdict
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_run_stays_stable() {
        let mut d = StabilityDetector::new();
        for i in 0..100 {
            assert!(d.observe(6.0 - i as f64 * 0.01));
        }
        assert!(!d.is_unstable());
    }

    #[test]
    fn nan_is_immediately_unstable() {
        let mut d = StabilityDetector::new();
        d.observe(6.0);
        assert!(!d.observe(f64::NAN));
        assert!(d.is_unstable());
        assert_eq!(d.reason(), Some("non-finite loss"));
    }

    #[test]
    fn sustained_divergence_trips_after_patience() {
        let mut d = StabilityDetector::new();
        for _ in 0..10 {
            d.observe(6.0);
        }
        for i in 0..d.patience {
            let healthy = d.observe(9.5);
            if i < d.patience - 1 {
                assert!(healthy, "tripped too early at {i}");
            }
        }
        assert!(d.is_unstable());
    }

    #[test]
    fn transient_spike_is_forgiven() {
        let mut d = StabilityDetector::new();
        for _ in 0..10 {
            d.observe(6.0);
        }
        d.observe(9.5); // single spike
        for _ in 0..20 {
            assert!(d.observe(5.5));
        }
        assert!(!d.is_unstable());
    }

    #[test]
    fn hard_ceiling() {
        let mut d = StabilityDetector::new();
        assert!(!d.observe(1e6));
        assert!(d.is_unstable());
    }

    #[test]
    fn grad_crash() {
        let mut d = StabilityDetector::new();
        d.observe(6.0);
        d.report_grad_crash();
        assert!(d.is_unstable());
    }

    #[test]
    fn divergence_counting_starts_strictly_after_grace() {
        // Elevated losses during the grace window must not feed the bad
        // streak: with grace = patience = 5, diverged observations at steps
        // 2..=5 fall inside the window (steps_seen <= grace) and count for
        // nothing; counting starts at step 6, so the streak reaches
        // patience only at step 10. An off-by-one (`>=` instead of `>`)
        // would let step 5 count and trip a step early.
        let mut d = StabilityDetector::new();
        assert_eq!((d.grace, d.patience), (5, 5), "test assumes the defaults");
        assert!(d.observe(6.0)); // step 1 pins `initial`
        for step in 2..=9 {
            assert!(d.observe(9.5), "tripped at step {step} (grace not honored)");
        }
        assert!(!d.observe(9.5), "fifth post-grace divergence must trip");
        assert_eq!(d.reason(), Some("sustained divergence above initial loss"));
    }

    #[test]
    fn verdict_latches_through_recovery() {
        // Once tripped, later healthy losses must not un-trip the verdict
        // (the run already diverged; Table 3 counts it as unsuccessful) and
        // observe() keeps returning false without re-evaluating.
        let mut d = StabilityDetector::new();
        for _ in 0..10 {
            d.observe(6.0);
        }
        for _ in 0..d.patience {
            d.observe(9.5);
        }
        assert!(d.is_unstable());
        let reason = d.reason();
        for _ in 0..50 {
            assert!(!d.observe(5.0), "latched verdict must keep reporting unhealthy");
        }
        assert!(d.is_unstable());
        assert_eq!(d.reason(), reason, "recovery must not rewrite the verdict");
    }

    #[test]
    fn hard_ceiling_takes_precedence_over_divergence() {
        // A loss above the ceiling trips immediately — on the very first
        // observation (before `initial` even exists, so the divergence rule
        // could never apply) and ahead of an in-flight divergence streak.
        let mut d = StabilityDetector::new();
        assert!(!d.observe(31.0));
        assert_eq!(d.reason(), Some("loss above hard ceiling"));

        let mut d = StabilityDetector::new();
        for _ in 0..10 {
            d.observe(6.0);
        }
        for _ in 0..d.patience - 1 {
            d.observe(9.5); // streak one short of tripping divergence
        }
        assert!(!d.observe(100.0));
        assert_eq!(d.reason(), Some("loss above hard ceiling"));
    }
}
