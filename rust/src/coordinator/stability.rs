//! Training-stability detection (Table 3's "Unstable %").
//!
//! The paper counts a run as unsuccessful if it "crashes due to exploding
//! gradients or diverges in the loss". We operationalize that as:
//!   * any non-finite loss or gradient (the "crash"), or
//!   * loss exceeding `initial + margin` nats for `patience` consecutive
//!     observations after a short grace period (the "divergence"), or
//!   * loss above a hard ceiling.

#[derive(Clone, Debug)]
pub struct StabilityDetector {
    initial: Option<f64>,
    bad_streak: usize,
    steps_seen: usize,
    pub margin: f64,
    pub patience: usize,
    pub grace: usize,
    pub hard_ceiling: f64,
    verdict: Option<&'static str>,
}

impl Default for StabilityDetector {
    fn default() -> Self {
        StabilityDetector {
            initial: None,
            bad_streak: 0,
            steps_seen: 0,
            margin: 2.0,
            patience: 5,
            grace: 5,
            hard_ceiling: 30.0,
            verdict: None,
        }
    }
}

impl StabilityDetector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one training loss; returns true while the run is healthy.
    pub fn observe(&mut self, loss: f64) -> bool {
        if self.verdict.is_some() {
            return false;
        }
        self.steps_seen += 1;
        if !loss.is_finite() {
            self.verdict = Some("non-finite loss");
            return false;
        }
        if loss > self.hard_ceiling {
            self.verdict = Some("loss above hard ceiling");
            return false;
        }
        let initial = *self.initial.get_or_insert(loss);
        if self.steps_seen > self.grace && loss > initial + self.margin {
            self.bad_streak += 1;
            if self.bad_streak >= self.patience {
                self.verdict = Some("sustained divergence above initial loss");
                return false;
            }
        } else {
            self.bad_streak = 0;
        }
        true
    }

    /// Report a gradient crash (non-finite grads) directly.
    pub fn report_grad_crash(&mut self) {
        self.verdict = Some("non-finite gradients");
    }

    pub fn is_unstable(&self) -> bool {
        self.verdict.is_some()
    }

    pub fn reason(&self) -> Option<&'static str> {
        self.verdict
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_run_stays_stable() {
        let mut d = StabilityDetector::new();
        for i in 0..100 {
            assert!(d.observe(6.0 - i as f64 * 0.01));
        }
        assert!(!d.is_unstable());
    }

    #[test]
    fn nan_is_immediately_unstable() {
        let mut d = StabilityDetector::new();
        d.observe(6.0);
        assert!(!d.observe(f64::NAN));
        assert!(d.is_unstable());
        assert_eq!(d.reason(), Some("non-finite loss"));
    }

    #[test]
    fn sustained_divergence_trips_after_patience() {
        let mut d = StabilityDetector::new();
        for _ in 0..10 {
            d.observe(6.0);
        }
        for i in 0..d.patience {
            let healthy = d.observe(9.5);
            if i < d.patience - 1 {
                assert!(healthy, "tripped too early at {i}");
            }
        }
        assert!(d.is_unstable());
    }

    #[test]
    fn transient_spike_is_forgiven() {
        let mut d = StabilityDetector::new();
        for _ in 0..10 {
            d.observe(6.0);
        }
        d.observe(9.5); // single spike
        for _ in 0..20 {
            assert!(d.observe(5.5));
        }
        assert!(!d.is_unstable());
    }

    #[test]
    fn hard_ceiling() {
        let mut d = StabilityDetector::new();
        assert!(!d.observe(1e6));
        assert!(d.is_unstable());
    }

    #[test]
    fn grad_crash() {
        let mut d = StabilityDetector::new();
        d.observe(6.0);
        d.report_grad_crash();
        assert!(d.is_unstable());
    }
}
