//! Checkpointing: parameters + optimizer state + step + RNG, keyed by
//! tensor name and parameter group.
//!
//! Format v3 additionally records each tensor's resolved state precision
//! (32/8/4 bits) so tooling can audit mixed-width layouts without the
//! config; v2 files (no precision field) still load, reporting 0 for it.
//! Format v4 additionally persists each tensor's rolling gradient-norm
//! history (the percentile-clipping window) so a resumed run makes the
//! same clip decisions the uninterrupted run would have; v2/v3 files load
//! with an empty history.
//! Format v5 is the *sharded* layout ([`Checkpoint::save_sharded`]): a
//! small manifest at the checkpoint path plus one shard file per
//! placement shard (`<name>.shardNN`), each carrying its shard's tensors
//! in the v4 per-tensor layout and written concurrently off the worker
//! pool via detached batches — save I/O scales with shard count. Because
//! state is keyed by tensor+group (never by shard), an N-shard v5
//! checkpoint restores into any M-shard layout (*resharding*); monolithic
//! v2–v4 files keep loading unchanged.
//! Format v6 is the *adaptive* layout, written only when a
//! [`PrecisionController`] is attached: an explicit monolithic/sharded
//! discriminator (v2–v5 encode the layout in the version number; v6
//! covers both) followed by the controller's review window — per-tensor
//! f64 gradient-norm histories, quiet-review counters, and the global
//! clip/crash flags — then the tensor payload in the v4 per-tensor
//! layout (shard files stay v5-format). On restore with a controller
//! attached, each tensor is first moved to its captured `state_bits`
//! width (promotions/demotions travel with the file), then states load
//! as usual; without a controller the width field stays informational
//! and restore behaves exactly like v2–v5.
//!
//! Quantized states are stored *dequantized* (f32). This is lossless:
//! quantization is idempotent (`q(dq(q(x))) == q(x)`, pinned by the quant
//! property tests), and the per-block absmax of a dequantized block equals
//! the stored absmax exactly, so re-quantizing on load reproduces the
//! codes bit-for-bit — at any code width, since restore requantizes into
//! the live state's own packed buffer. Restore matches tensors **by name**
//! (not position), so a checkpoint survives reorderings of the tensor list
//! and mixed 4/8/32-bit group layouts restore each tensor at its own
//! precision.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, ensure, Context, Result};

use crate::optim::{ParamOptimizer, PrecisionController, TensorCtlState};
use crate::util::io::*;
use crate::util::parallel;
use crate::util::rng::Rng;

const MAGIC: u32 = 0xB1707_8_0;
const VERSION: u32 = 4;
/// The sharded manifest-plus-shard-files layout.
const VERSION_SHARDED: u32 = 5;
/// The adaptive-precision layout (explicit layout discriminator +
/// controller window); written only when a controller is attached.
const VERSION_ADAPTIVE: u32 = 6;
/// Oldest version [`Checkpoint::load`] still reads.
const MIN_VERSION: u32 = 2;

/// One tensor's checkpoint payload.
pub struct TensorCheckpoint {
    pub name: String,
    /// Parameter-group index at capture time (informational).
    pub group: u64,
    /// Resolved state precision at capture time (32/8/4; 0 when loaded
    /// from a v2 file, which predates the field). Restore always goes
    /// through the dequantized f32 payload; adaptive (v6 + controller)
    /// restores additionally move the live tensor back to this width
    /// first, so a resumed run requantizes exactly what the saved run
    /// held.
    pub state_bits: u32,
    pub params: Vec<f32>,
    /// Named dequantized optimizer states.
    pub states: Vec<(String, Vec<f32>)>,
    /// Rolling gradient-norm history (oldest first) when the tensor's
    /// config has percentile clipping on; empty otherwise (and for files
    /// predating v4). Clip decisions depend on this window, so dropping it
    /// across a restore would change the resumed trajectory.
    pub gnorm: Vec<f32>,
}

/// Write one tensor's payload in the v4 per-tensor layout (shared by the
/// monolithic file and each v5 shard file).
fn write_tensor<W: Write>(w: &mut W, t: &TensorCheckpoint) -> Result<()> {
    write_str(w, &t.name)?;
    write_u64(w, t.group)?;
    write_u32(w, t.state_bits)?;
    write_f32_slice(w, &t.params)?;
    write_u64(w, t.states.len() as u64)?;
    for (name, vals) in &t.states {
        write_str(w, name)?;
        write_f32_slice(w, vals)?;
    }
    write_f32_slice(w, &t.gnorm)?;
    Ok(())
}

/// Read one tensor's payload, honoring the version gates (v2 predates
/// `state_bits`, v2/v3 predate the gnorm history).
fn read_tensor<R: Read>(r: &mut R, version: u32) -> Result<TensorCheckpoint> {
    let name = read_str(r)?;
    let group = read_u64(r)?;
    let state_bits = if version >= 3 { read_u32(r)? } else { 0 };
    let params = read_f32_slice(r)?;
    let k = read_u64(r)? as usize;
    let mut states = Vec::with_capacity(k);
    for _ in 0..k {
        let sname = read_str(r)?;
        states.push((sname, read_f32_slice(r)?));
    }
    let gnorm = if version >= 4 { read_f32_slice(r)? } else { Vec::new() };
    Ok(TensorCheckpoint { name, group, state_bits, params, states, gnorm })
}

/// The shard-file name for a checkpoint at `path` (manifest-relative:
/// only the file name is recorded in the manifest).
fn shard_file_name(path: &Path, shard: usize) -> String {
    let base = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "checkpoint".to_string());
    format!("{base}.shard{shard:02}")
}

/// Serialize one shard's tensors to its own file (runs on a pool worker
/// during [`Checkpoint::save_sharded`]).
fn write_shard_file(
    path: &Path,
    shard: usize,
    members: &[usize],
    tensors: &[TensorCheckpoint],
) -> Result<()> {
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    write_u32(&mut w, MAGIC)?;
    write_u32(&mut w, VERSION_SHARDED)?;
    write_u64(&mut w, shard as u64)?;
    write_u64(&mut w, members.len() as u64)?;
    for &i in members {
        write_tensor(&mut w, &tensors[i])?;
    }
    Ok(())
}

/// The precision controller's review window (format v6): what
/// [`PrecisionController::snapshot`] captures, keyed by tensor name so it
/// survives shard-major reordering the same way the tensor list does.
pub struct CtlCheckpoint {
    pub window_clips: u64,
    pub window_crash: bool,
    pub tensors: Vec<(String, TensorCtlState)>,
}

fn write_ctl<W: Write>(w: &mut W, ctl: &CtlCheckpoint) -> Result<()> {
    write_u64(w, ctl.window_clips)?;
    write_u32(w, ctl.window_crash as u32)?;
    write_u64(w, ctl.tensors.len() as u64)?;
    for (name, s) in &ctl.tensors {
        write_str(w, name)?;
        // f64, not f32: the controller's spike decisions compare f64
        // medians, and rounding the window could flip a post-restore
        // review that the uninterrupted run would not have made
        write_f64_slice(w, &s.hist)?;
        write_u32(w, s.quiet)?;
        write_f64(w, s.max_since_review)?;
    }
    Ok(())
}

fn read_ctl<R: Read>(r: &mut R) -> Result<CtlCheckpoint> {
    let window_clips = read_u64(r)?;
    let window_crash = read_u32(r)? != 0;
    let n = read_u64(r)? as usize;
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        let name = read_str(r)?;
        let hist = read_f64_slice(r)?;
        let quiet = read_u32(r)?;
        let max_since_review = read_f64(r)?;
        tensors.push((name, TensorCtlState { hist, quiet, max_since_review }));
    }
    Ok(CtlCheckpoint { window_clips, window_crash, tensors })
}

pub struct Checkpoint {
    pub step: u64,
    pub rng_state: [u64; 4],
    pub tensors: Vec<TensorCheckpoint>,
    /// Precision-controller window (v6 files only; `None` for v2–v5 and
    /// for captures without a controller).
    pub ctl: Option<CtlCheckpoint>,
}

impl Checkpoint {
    pub fn capture(
        step: u64,
        rng: &Rng,
        params: &[Vec<f32>],
        popt: &ParamOptimizer,
        ctl: Option<&PrecisionController>,
    ) -> Checkpoint {
        assert_eq!(params.len(), popt.n_tensors(), "params/optimizer tensor count");
        let tensors: Vec<TensorCheckpoint> = (0..popt.n_tensors())
            .map(|i| TensorCheckpoint {
                name: popt.tensor_name(i).to_string(),
                group: popt.group_of(i) as u64,
                state_bits: popt.tensor_cfg(i).bits.bit_count(),
                params: params[i].clone(),
                states: popt
                    .opt(i)
                    .states()
                    .into_iter()
                    .map(|(n, s)| (n.to_string(), s.to_f32()))
                    .collect(),
                gnorm: popt.opt(i).gnorm_history().unwrap_or_default(),
            })
            .collect();
        let ctl = ctl.map(|c| {
            let (states, window_clips, window_crash) = c.snapshot();
            CtlCheckpoint {
                window_clips,
                window_crash,
                tensors: states
                    .into_iter()
                    .enumerate()
                    .map(|(i, s)| (popt.tensor_name(i).to_string(), s))
                    .collect(),
            }
        });
        Checkpoint { step, rng_state: rng.state(), tensors, ctl }
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let f = File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut w = BufWriter::new(f);
        write_u32(&mut w, MAGIC)?;
        // static checkpoints keep writing v4 byte-for-byte; only a
        // controller-bearing capture opts into the v6 layout
        write_u32(&mut w, if self.ctl.is_some() { VERSION_ADAPTIVE } else { VERSION })?;
        write_u64(&mut w, self.step)?;
        for st in self.rng_state {
            write_u64(&mut w, st)?;
        }
        if let Some(ctl) = &self.ctl {
            write_u32(&mut w, 0)?; // layout 0: monolithic
            write_ctl(&mut w, ctl)?;
        }
        write_u64(&mut w, self.tensors.len() as u64)?;
        for t in &self.tensors {
            write_tensor(&mut w, t)?;
        }
        Ok(())
    }

    /// Shard-parallel save (format v5): one file per placement shard,
    /// written concurrently off the worker pool via detached batches, plus
    /// a small manifest at `path` naming them. The manifest is written
    /// *after* every shard file succeeded, so a manifest on disk implies a
    /// complete checkpoint. `assignment` is the tensor → shard map (the
    /// live [`ShardLayout`](crate::optim::ShardLayout)'s); restore is still
    /// keyed by tensor+group, so the saved layout does not constrain the
    /// layout restored into (resharding).
    pub fn save_sharded<P: AsRef<Path>>(
        &self,
        path: P,
        assignment: &[usize],
        n_shards: usize,
    ) -> Result<()> {
        let path = path.as_ref();
        ensure!(n_shards >= 1, "save_sharded needs n_shards >= 1, got {n_shards}");
        ensure!(
            assignment.len() == self.tensors.len(),
            "shard assignment covers {} tensors, checkpoint has {}",
            assignment.len(),
            self.tensors.len()
        );
        ensure!(
            assignment.iter().all(|&s| s < n_shards),
            "shard assignment references a shard >= {n_shards}"
        );
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        for (i, &s) in assignment.iter().enumerate() {
            members[s].push(i);
        }
        let shard_paths: Vec<PathBuf> = (0..n_shards)
            .map(|s| path.with_file_name(shard_file_name(path, s)))
            .collect();
        // one detached pool batch, one task per shard file; errors land in
        // per-shard slots (the closure is shared across workers)
        let errs: Vec<Mutex<Option<anyhow::Error>>> =
            (0..n_shards).map(|_| Mutex::new(None)).collect();
        {
            let tensors = &self.tensors;
            let task = |s: usize| {
                if let Err(e) = write_shard_file(&shard_paths[s], s, &members[s], tensors) {
                    *errs[s].lock().unwrap_or_else(|p| p.into_inner()) = Some(e);
                }
            };
            // SAFETY: the handle is waited on immediately, inside the
            // borrows' scope, and cannot leak.
            unsafe { parallel::submit(n_shards, task) }.wait();
        }
        for e in errs {
            if let Some(e) = e.into_inner().unwrap_or_else(|p| p.into_inner()) {
                return Err(e);
            }
        }
        let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        write_u32(&mut w, MAGIC)?;
        // only the manifest version changes for adaptive captures; shard
        // files always use the v5 per-shard format
        write_u32(&mut w, if self.ctl.is_some() { VERSION_ADAPTIVE } else { VERSION_SHARDED })?;
        write_u64(&mut w, self.step)?;
        for st in self.rng_state {
            write_u64(&mut w, st)?;
        }
        if let Some(ctl) = &self.ctl {
            write_u32(&mut w, 1)?; // layout 1: sharded manifest
            write_ctl(&mut w, ctl)?;
        }
        write_u64(&mut w, n_shards as u64)?;
        for s in 0..n_shards {
            write_str(&mut w, &shard_file_name(path, s))?;
            write_u64(&mut w, members[s].len() as u64)?;
        }
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let f = File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = BufReader::new(f);
        if read_u32(&mut r)? != MAGIC {
            return Err(anyhow!("bad checkpoint magic"));
        }
        let version = read_u32(&mut r)?;
        if !(MIN_VERSION..=VERSION_ADAPTIVE).contains(&version) {
            return Err(anyhow!("unsupported checkpoint version {version}"));
        }
        let step = read_u64(&mut r)?;
        let mut rng_state = [0u64; 4];
        for st in rng_state.iter_mut() {
            *st = read_u64(&mut r)?;
        }
        // v2–v5 encode the layout in the version number; v6 carries an
        // explicit discriminator plus the controller window
        let (sharded, ctl) = if version == VERSION_ADAPTIVE {
            let layout = read_u32(&mut r)?;
            ensure!(layout <= 1, "checkpoint layout {layout} unknown (0/1)");
            (layout == 1, Some(read_ctl(&mut r)?))
        } else {
            (version == VERSION_SHARDED, None)
        };
        if sharded {
            // sharded manifest: shard file names + expected tensor counts;
            // the tensors themselves live in the per-shard files next to it
            let dir = path.as_ref().parent().map(Path::to_path_buf).unwrap_or_default();
            let n_shards = read_u64(&mut r)? as usize;
            let mut tensors = Vec::new();
            for s in 0..n_shards {
                let fname = read_str(&mut r)?;
                let expect = read_u64(&mut r)? as usize;
                let spath = dir.join(&fname);
                let sf = File::open(&spath).with_context(|| {
                    format!("opening shard file {} (manifest names {fname:?})", spath.display())
                })?;
                let mut sr = BufReader::new(sf);
                ensure!(read_u32(&mut sr)? == MAGIC, "shard file {fname:?}: bad magic");
                let sv = read_u32(&mut sr)?;
                ensure!(
                    sv == VERSION_SHARDED,
                    "shard file {fname:?}: version {sv}, expected {VERSION_SHARDED}"
                );
                let recorded = read_u64(&mut sr)? as usize;
                ensure!(recorded == s, "shard file {fname:?}: shard index {recorded}, not {s}");
                let nt = read_u64(&mut sr)? as usize;
                ensure!(
                    nt == expect,
                    "shard file {fname:?}: {nt} tensors, manifest expects {expect}"
                );
                for _ in 0..nt {
                    tensors.push(read_tensor(&mut sr, VERSION_SHARDED)?);
                }
            }
            return Ok(Checkpoint { step, rng_state, tensors, ctl });
        }
        let nt = read_u64(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(nt);
        for _ in 0..nt {
            tensors.push(read_tensor(&mut r, version)?);
        }
        Ok(Checkpoint { step, rng_state, tensors, ctl })
    }

    /// Restore into a live [`ParamOptimizer`] + parameter set, matching
    /// tensors by name (requantizes 8-bit states losslessly).
    ///
    /// When both the checkpoint and the caller carry precision-controller
    /// state (format v6 + an adaptive run), each tensor is first moved to
    /// the width it was captured at — so a mid-run promotion or demotion
    /// survives the restore — and the controller's review window is
    /// restored afterwards. Otherwise `ctl` may be `None` and the stored
    /// widths stay informational, exactly as in v2–v5.
    pub fn restore(
        &self,
        params: &mut [Vec<f32>],
        popt: &mut ParamOptimizer,
        mut ctl: Option<&mut PrecisionController>,
    ) -> Result<()> {
        anyhow::ensure!(
            self.tensors.len() == popt.n_tensors(),
            "tensor count mismatch: checkpoint {} vs model {}",
            self.tensors.len(),
            popt.n_tensors()
        );
        anyhow::ensure!(params.len() == popt.n_tensors(), "params/optimizer tensor count");
        let adaptive = ctl.is_some() && self.ctl.is_some();
        let by_name: BTreeMap<&str, &TensorCheckpoint> =
            self.tensors.iter().map(|t| (t.name.as_str(), t)).collect();
        for i in 0..popt.n_tensors() {
            let name = popt.tensor_name(i).to_string();
            let t = by_name
                .get(name.as_str())
                .ok_or_else(|| anyhow!("checkpoint has no tensor {name:?}"))?;
            anyhow::ensure!(
                t.params.len() == params[i].len(),
                "tensor {name:?}: param len {} vs {}",
                t.params.len(),
                params[i].len()
            );
            params[i].copy_from_slice(&t.params);
            if adaptive && t.state_bits != 0 {
                // move the live tensor to its captured width *before*
                // loading states, so they requantize into the right
                // buffers (no-op when already there)
                popt.set_tensor_bits(i, t.state_bits);
            }
            let opt = popt.opt_mut(i);
            opt.set_t(self.step);
            let live_states = opt.states_mut();
            anyhow::ensure!(
                live_states.len() == t.states.len(),
                "tensor {name:?}: state count {} vs {}",
                t.states.len(),
                live_states.len()
            );
            for ((sname, vals), (live_name, live)) in t.states.iter().zip(live_states) {
                anyhow::ensure!(
                    sname == live_name,
                    "tensor {name:?}: state name {sname} vs {live_name}"
                );
                match live {
                    crate::optim::StateTensor::F32(v) => {
                        anyhow::ensure!(v.len() == vals.len(), "state len mismatch");
                        v.copy_from_slice(vals);
                    }
                    crate::optim::StateTensor::Quant { q, codebook } => {
                        anyhow::ensure!(q.len == vals.len(), "state len mismatch");
                        // quantize_into takes the width from q itself, so
                        // 8-bit and 4-bit states restore identically
                        let bq = crate::quant::BlockQuantizer::new(codebook.clone(), q.block);
                        bq.quantize_into(vals, q);
                    }
                }
            }
            if !t.gnorm.is_empty() {
                popt.opt_mut(i).restore_gnorm_history(&t.gnorm);
            }
        }
        if let (Some(c), Some(saved)) = (ctl.as_deref_mut(), self.ctl.as_ref()) {
            // name-keyed like the tensor payload; a tensor absent from the
            // saved window (layout drift) resumes with a fresh one
            let by: BTreeMap<&str, &TensorCtlState> =
                saved.tensors.iter().map(|(n, s)| (n.as_str(), s)).collect();
            let ordered: Vec<TensorCtlState> = (0..popt.n_tensors())
                .map(|i| by.get(popt.tensor_name(i)).map(|s| (*s).clone()).unwrap_or_default())
                .collect();
            c.restore(&ordered, saved.window_clips, saved.window_crash);
            c.note_state_bytes(popt.state_bytes());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{
        Bits, GroupOverride, OptimConfig, OptimSpec, ParamOptimizer, PrecisionPolicy, TensorInfo,
    };
    use crate::util::rng::Rng;

    fn tensors() -> Vec<TensorInfo> {
        [("embed.tok", 4096usize), ("block0.attn.wq", 2048), ("lm_head", 3000)]
            .into_iter()
            .map(|(name, size)| TensorInfo {
                name: name.to_string(),
                size,
                shape: None,
                padded: size.next_multiple_of(2048),
            })
            .collect()
    }

    /// Mixed 4/8/32-bit group layout (embeddings 32-bit via the emb32
    /// sugar, attention 4-bit) built over synthetic tensors.
    fn mixed_popt() -> ParamOptimizer {
        let spec = OptimSpec::with_groups(
            OptimConfig::adam(0.01, Bits::b8_dynamic()),
            vec![
                GroupOverride::emb32(),
                GroupOverride::parse("block0.attn.*:bits=4").unwrap(),
            ],
        );
        ParamOptimizer::build(spec, &tensors(), None).unwrap()
    }

    #[test]
    fn roundtrip_preserves_training_trajectory_mixed_groups() {
        // Train A for 10 steps, checkpoint at 5; restoring into B and
        // re-running steps 6..10 must give identical params (quantized
        // states included, thanks to idempotent requantization at every
        // code width; the 32-bit embedding group restores at full
        // precision).
        let mut rng = Rng::new(1);
        let shapes: Vec<usize> = tensors().iter().map(|t| t.size).collect();
        let targets: Vec<Vec<f32>> = shapes
            .iter()
            .map(|&n| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let grads = |params: &[Vec<f32>]| -> Vec<Vec<f32>> {
            params
                .iter()
                .zip(&targets)
                .map(|(p, t)| p.iter().zip(t).map(|(a, b)| a - b).collect())
                .collect()
        };

        let mut popt_a = mixed_popt();
        assert!(popt_a.tensor_cfg(0).bits == Bits::B32, "embed.tok in the 32-bit group");
        assert!(popt_a.tensor_cfg(1).bits == Bits::b4_dynamic(), "attn in the 4-bit group");
        assert!(popt_a.tensor_cfg(2).bits == Bits::b8_dynamic());
        let mut p_a: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0.0f32; n]).collect();
        for _ in 0..5 {
            let g = grads(&p_a);
            popt_a.step_native(&mut p_a, &g);
        }
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bin");
        Checkpoint::capture(5, &Rng::new(9), &p_a, &popt_a, None).save(&path).unwrap();
        for _ in 0..5 {
            let g = grads(&p_a);
            popt_a.step_native(&mut p_a, &g);
        }

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 5);
        assert_eq!(loaded.tensors.len(), 3);
        assert_eq!(loaded.tensors[0].name, "embed.tok");
        assert_eq!(loaded.tensors[0].group, 1, "embedding group recorded");
        assert_eq!(loaded.tensors[1].group, 2, "attention group recorded");
        assert_eq!(loaded.tensors[2].group, 0);
        // v3: per-tensor resolved precision travels with the file
        assert_eq!(loaded.tensors[0].state_bits, 32);
        assert_eq!(loaded.tensors[1].state_bits, 4);
        assert_eq!(loaded.tensors[2].state_bits, 8);

        let mut popt_b = mixed_popt();
        let mut p_b: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0.0f32; n]).collect();
        loaded.restore(&mut p_b, &mut popt_b, None).unwrap();
        assert_eq!(popt_b.opt(0).t(), 5);
        for _ in 0..5 {
            let g = grads(&p_b);
            popt_b.step_native(&mut p_b, &g);
        }
        assert_eq!(p_a, p_b, "trajectories diverged after restore");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gnorm_history_roundtrips_and_preserves_clip_decisions() {
        // With percentile clipping on, the clip threshold is a quantile of
        // the rolling gnorm window — losing the window across a restore
        // would change every post-resume clip decision. Train A with
        // clipping, checkpoint mid-history, continue through a gradient
        // spike; B restored from the checkpoint must reproduce A exactly.
        let spec = OptimSpec::new({
            let mut cfg = OptimConfig::adam(0.01, Bits::b8_dynamic());
            cfg.clip_percentile = 95.0;
            cfg.max_unorm = 0.5;
            cfg
        });
        let build = || ParamOptimizer::build(spec.clone(), &tensors(), None).unwrap();
        let shapes: Vec<usize> = tensors().iter().map(|t| t.size).collect();
        let mut rng = Rng::new(3);
        let targets: Vec<Vec<f32>> = shapes
            .iter()
            .map(|&n| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let grads = |params: &[Vec<f32>], scale: f32| -> Vec<Vec<f32>> {
            params
                .iter()
                .zip(&targets)
                .map(|(p, t)| p.iter().zip(t).map(|(a, b)| scale * (a - b)).collect())
                .collect()
        };

        let mut popt_a = build();
        let mut p_a: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0.0f32; n]).collect();
        for _ in 0..8 {
            let g = grads(&p_a, 1.0);
            popt_a.step_native(&mut p_a, &g);
        }
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt_v4_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bin");
        Checkpoint::capture(8, &Rng::new(9), &p_a, &popt_a, None).save(&path).unwrap();
        // post-checkpoint steps, including a spike the percentile phase
        // must clip against the *restored* window
        for s in 0..4 {
            let g = grads(&p_a, if s == 1 { 50.0 } else { 1.0 });
            popt_a.step_native(&mut p_a, &g);
        }

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.tensors[0].gnorm.len(), 8, "8 steps of history travel");
        let mut popt_b = build();
        let mut p_b: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0.0f32; n]).collect();
        loaded.restore(&mut p_b, &mut popt_b, None).unwrap();
        for s in 0..4 {
            let g = grads(&p_b, if s == 1 { 50.0 } else { 1.0 });
            popt_b.step_native(&mut p_b, &g);
        }
        assert_eq!(p_a, p_b, "clip decisions diverged after restore");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_rejects_mismatched_layout() {
        let popt = mixed_popt();
        let params: Vec<Vec<f32>> = tensors().iter().map(|t| vec![0.0; t.size]).collect();
        let mut ck = Checkpoint::capture(1, &Rng::new(2), &params, &popt, None);
        ck.tensors[1].name = "renamed".into();
        let mut popt_b = mixed_popt();
        let mut p_b = params.clone();
        let err = ck.restore(&mut p_b, &mut popt_b, None).unwrap_err();
        assert!(format!("{err:#}").contains("block0.attn.wq"), "{err:#}");
    }

    #[test]
    fn loads_v2_files_without_precision_field() {
        // hand-write a minimal v2-layout file (no per-tensor state_bits)
        // and check it still loads, reporting 0 for the missing field
        use std::io::Write as _;
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt_v2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v2.bin");
        {
            let f = File::create(&path).unwrap();
            let mut w = BufWriter::new(f);
            write_u32(&mut w, MAGIC).unwrap();
            write_u32(&mut w, 2).unwrap(); // the pre-width format version
            write_u64(&mut w, 7).unwrap(); // step
            for st in [1u64, 2, 3, 4] {
                write_u64(&mut w, st).unwrap();
            }
            write_u64(&mut w, 1).unwrap(); // one tensor
            write_str(&mut w, "embed.tok").unwrap();
            write_u64(&mut w, 0).unwrap(); // group
            write_f32_slice(&mut w, &[1.0, 2.0]).unwrap();
            write_u64(&mut w, 1).unwrap(); // one state
            write_str(&mut w, "m").unwrap();
            write_f32_slice(&mut w, &[0.5, -0.5]).unwrap();
            w.flush().unwrap();
        }
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.step, 7);
        assert_eq!(ck.tensors.len(), 1);
        assert_eq!(ck.tensors[0].state_bits, 0, "v2 has no precision field");
        assert_eq!(ck.tensors[0].params, vec![1.0, 2.0]);
        assert_eq!(ck.tensors[0].states[0].1, vec![0.5, -0.5]);
        // an unknown future version is still rejected
        {
            let f = File::create(&path).unwrap();
            let mut w = BufWriter::new(f);
            write_u32(&mut w, MAGIC).unwrap();
            write_u32(&mut w, VERSION_ADAPTIVE + 1).unwrap();
            w.flush().unwrap();
        }
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loads_v3_files_without_gnorm_history() {
        // v3 layout: has the per-tensor precision field but predates the
        // gnorm-history slice — loads with an empty history
        use std::io::Write as _;
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt_v3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v3.bin");
        {
            let f = File::create(&path).unwrap();
            let mut w = BufWriter::new(f);
            write_u32(&mut w, MAGIC).unwrap();
            write_u32(&mut w, 3).unwrap();
            write_u64(&mut w, 4).unwrap(); // step
            for st in [1u64, 2, 3, 4] {
                write_u64(&mut w, st).unwrap();
            }
            write_u64(&mut w, 1).unwrap(); // one tensor
            write_str(&mut w, "embed.tok").unwrap();
            write_u64(&mut w, 0).unwrap(); // group
            write_u32(&mut w, 8).unwrap(); // state_bits (v3 field)
            write_f32_slice(&mut w, &[1.0, 2.0]).unwrap();
            write_u64(&mut w, 1).unwrap(); // one state
            write_str(&mut w, "m").unwrap();
            write_f32_slice(&mut w, &[0.5, -0.5]).unwrap();
            w.flush().unwrap();
        }
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.tensors[0].state_bits, 8);
        assert!(ck.tensors[0].gnorm.is_empty(), "v3 has no gnorm history");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adaptive_v6_roundtrips_controller_and_widths() {
        // A controller-bearing capture writes v6: promoted widths and the
        // review window must both survive the roundtrip, in both the
        // monolithic and sharded layouts.
        let mut popt = mixed_popt();
        let mut ctl = PrecisionController::new(PrecisionPolicy::default(), &popt);
        // warm the per-tensor histories, then promote via the detector
        // trigger (a crash observed since the last review)
        for s in 0..6 {
            ctl.observe_step(&[1.0 + s as f64, 2.0, 3.0], 0, 0, false);
        }
        ctl.observe_step(&[1.0, 2.0, 3.0], 0, 0, true);
        let moved = ctl.review(25, &mut popt);
        assert!(!moved.is_empty(), "detector review promotes");
        assert_eq!(popt.tensor_cfg(1).bits.bit_count(), 8, "attn promoted 4 -> 8");
        assert_eq!(popt.tensor_cfg(2).bits.bit_count(), 32, "lm_head promoted 8 -> 32");
        ctl.observe_step(&[4.0, 5.0, 6.0], 2, 0, false); // pending window state

        let params: Vec<Vec<f32>> =
            tensors().iter().map(|t| (0..t.size).map(|i| i as f32 * 0.25).collect()).collect();
        let ck = Checkpoint::capture(25, &Rng::new(4), &params, &popt, Some(&ctl));
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt_v6_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bin");
        ck.save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 25);
        let saved = loaded.ctl.as_ref().expect("v6 carries the controller window");
        assert_eq!(saved.window_clips, 2);
        assert!(!saved.window_crash, "crash flag was consumed by the review");
        assert_eq!(saved.tensors.len(), 3);
        assert_eq!(loaded.tensors[1].state_bits, 8, "promoted width travels");

        // restore into a freshly built (4/8/32) layout with a fresh
        // controller: tensors move to the captured widths and the review
        // window matches the live controller's exactly
        let mut popt_b = mixed_popt();
        let mut ctl_b = PrecisionController::new(PrecisionPolicy::default(), &popt_b);
        let mut p_b: Vec<Vec<f32>> = tensors().iter().map(|t| vec![0.0; t.size]).collect();
        loaded.restore(&mut p_b, &mut popt_b, Some(&mut ctl_b)).unwrap();
        assert_eq!(popt_b.tensor_cfg(1).bits.bit_count(), 8);
        assert_eq!(popt_b.tensor_cfg(2).bits.bit_count(), 32);
        assert_eq!(ctl_b.snapshot(), ctl.snapshot(), "review window restored");
        assert_eq!(p_b, params);

        // without a controller the same file restores statically: states
        // land at the built widths, as in v2-v5
        let mut popt_c = mixed_popt();
        let mut p_c: Vec<Vec<f32>> = tensors().iter().map(|t| vec![0.0; t.size]).collect();
        loaded.restore(&mut p_c, &mut popt_c, None).unwrap();
        assert_eq!(popt_c.tensor_cfg(1).bits.bit_count(), 4, "static restore keeps built width");

        // sharded adaptive manifest: same controller payload, resharded
        ck.save_sharded(&path, &[1, 0, 1], 2).unwrap();
        let sl = Checkpoint::load(&path).unwrap();
        let sctl = sl.ctl.as_ref().expect("sharded v6 manifest carries the window");
        assert_eq!(sctl.window_clips, 2);
        assert_eq!(sctl.tensors.len(), 3);
        let mut popt_d = mixed_popt();
        let mut ctl_d = PrecisionController::new(PrecisionPolicy::default(), &popt_d);
        let mut p_d: Vec<Vec<f32>> = tensors().iter().map(|t| vec![0.0; t.size]).collect();
        sl.restore(&mut p_d, &mut popt_d, Some(&mut ctl_d)).unwrap();
        assert_eq!(popt_d.tensor_cfg(1).bits.bit_count(), 8);
        assert_eq!(ctl_d.snapshot(), ctl.snapshot());
        assert_eq!(p_d, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_save_roundtrips_and_reshards() {
        // Save under a 4-shard assignment, check the manifest + per-shard
        // files land on disk, and load back a checkpoint equal to the
        // monolithic one (restore is name-keyed, so shard order of the
        // tensor list is immaterial).
        let popt = mixed_popt();
        let params: Vec<Vec<f32>> =
            tensors().iter().map(|t| (0..t.size).map(|i| i as f32 * 0.5).collect()).collect();
        let ck = Checkpoint::capture(3, &Rng::new(2), &params, &popt, None);
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt_v5_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bin");
        // 3 tensors over 4 shards: one shard stays empty — still valid
        ck.save_sharded(&path, &[2, 0, 1], 4).unwrap();
        for s in 0..4 {
            assert!(dir.join(format!("c.bin.shard{s:02}")).exists(), "shard {s} file");
        }
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 3);
        assert_eq!(loaded.rng_state, ck.rng_state);
        assert_eq!(loaded.tensors.len(), 3);
        // shard-major order: shard 0 holds tensor 1, shard 1 tensor 2, ...
        assert_eq!(loaded.tensors[0].name, "block0.attn.wq");
        for t in &ck.tensors {
            let l = loaded.tensors.iter().find(|l| l.name == t.name).unwrap();
            assert_eq!(l.params, t.params);
            assert_eq!(l.states, t.states);
            assert_eq!(l.state_bits, t.state_bits);
            assert_eq!(l.group, t.group);
        }
        // restoring into a live optimizer works regardless of the saved
        // shard count (resharding is the integration tests' job; here we
        // pin the name-keyed mechanics)
        let mut popt_b = mixed_popt();
        let mut p_b: Vec<Vec<f32>> = tensors().iter().map(|t| vec![0.0; t.size]).collect();
        loaded.restore(&mut p_b, &mut popt_b, None).unwrap();
        assert_eq!(p_b, params);

        // invalid assignments are rejected up front
        assert!(ck.save_sharded(&path, &[0, 1], 2).is_err(), "short assignment");
        assert!(ck.save_sharded(&path, &[0, 1, 5], 2).is_err(), "shard out of range");

        // a manifest whose shard file vanished is a load error
        ck.save_sharded(&path, &[0, 1, 1], 2).unwrap();
        std::fs::remove_file(dir.join("c.bin.shard01")).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("shard"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
