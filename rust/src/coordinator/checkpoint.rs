//! Checkpointing: parameters + optimizer state + step + RNG.
//!
//! Quantized states are stored *dequantized* (f32). This is lossless:
//! quantization is idempotent (`q(dq(q(x))) == q(x)`, pinned by the quant
//! property tests), and the per-block absmax of a dequantized block equals
//! the stored absmax exactly, so re-quantizing on load reproduces the
//! codes bit-for-bit.

use std::fs::File;
use std::io::{BufReader, BufWriter};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::optim::Optimizer;
use crate::util::io::*;
use crate::util::rng::Rng;

const MAGIC: u32 = 0xB1707_8_0;
const VERSION: u32 = 1;

pub struct Checkpoint {
    pub step: u64,
    pub rng_state: [u64; 4],
    pub params: Vec<Vec<f32>>,
    /// per tensor: named dequantized states
    pub states: Vec<Vec<(String, Vec<f32>)>>,
}

impl Checkpoint {
    pub fn capture(
        step: u64,
        rng: &Rng,
        params: &[Vec<f32>],
        opts: &[Box<dyn Optimizer>],
    ) -> Checkpoint {
        let states = opts
            .iter()
            .map(|o| {
                o.states()
                    .into_iter()
                    .map(|(n, s)| (n.to_string(), s.to_f32()))
                    .collect()
            })
            .collect();
        Checkpoint { step, rng_state: rng.state(), params: params.to_vec(), states }
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let f = File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut w = BufWriter::new(f);
        write_u32(&mut w, MAGIC)?;
        write_u32(&mut w, VERSION)?;
        write_u64(&mut w, self.step)?;
        for s in self.rng_state {
            write_u64(&mut w, s)?;
        }
        write_u64(&mut w, self.params.len() as u64)?;
        for p in &self.params {
            write_f32_slice(&mut w, p)?;
        }
        write_u64(&mut w, self.states.len() as u64)?;
        for per_tensor in &self.states {
            write_u64(&mut w, per_tensor.len() as u64)?;
            for (name, vals) in per_tensor {
                write_str(&mut w, name)?;
                write_f32_slice(&mut w, vals)?;
            }
        }
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let f = File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = BufReader::new(f);
        if read_u32(&mut r)? != MAGIC {
            return Err(anyhow!("bad checkpoint magic"));
        }
        if read_u32(&mut r)? != VERSION {
            return Err(anyhow!("unsupported checkpoint version"));
        }
        let step = read_u64(&mut r)?;
        let mut rng_state = [0u64; 4];
        for s in rng_state.iter_mut() {
            *s = read_u64(&mut r)?;
        }
        let np = read_u64(&mut r)? as usize;
        let mut params = Vec::with_capacity(np);
        for _ in 0..np {
            params.push(read_f32_slice(&mut r)?);
        }
        let nt = read_u64(&mut r)? as usize;
        let mut states = Vec::with_capacity(nt);
        for _ in 0..nt {
            let k = read_u64(&mut r)? as usize;
            let mut per = Vec::with_capacity(k);
            for _ in 0..k {
                let name = read_str(&mut r)?;
                per.push((name, read_f32_slice(&mut r)?));
            }
            states.push(per);
        }
        Ok(Checkpoint { step, rng_state, params, states })
    }

    /// Restore into live optimizers (requantizes 8-bit states losslessly).
    pub fn restore(
        &self,
        params: &mut Vec<Vec<f32>>,
        opts: &mut [Box<dyn Optimizer>],
    ) -> Result<()> {
        anyhow::ensure!(self.params.len() == params.len(), "tensor count mismatch");
        *params = self.params.clone();
        for (per_tensor, opt) in self.states.iter().zip(opts.iter_mut()) {
            opt.set_t(self.step);
            for ((name, vals), (live_name, live)) in
                per_tensor.iter().zip(opt.states_mut().into_iter())
            {
                anyhow::ensure!(name == live_name, "state name mismatch {name} vs {live_name}");
                match live {
                    crate::optim::StateTensor::F32(v) => {
                        anyhow::ensure!(v.len() == vals.len(), "state len mismatch");
                        v.copy_from_slice(vals);
                    }
                    crate::optim::StateTensor::Q8 { q, codebook } => {
                        anyhow::ensure!(q.len == vals.len(), "state len mismatch");
                        let bq = crate::quant::BlockQuantizer::new(codebook.clone(), q.block);
                        bq.quantize_into(vals, q);
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build, Bits, OptimConfig};
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_preserves_training_trajectory() {
        // Train A for 10 steps, checkpoint at 5; restoring into B and
        // re-running steps 6..10 must give identical params (8-bit states
        // included, thanks to idempotent requantization).
        let n = 4096;
        let cfg = OptimConfig::adam(0.01, Bits::b8_dynamic());
        let mut rng = Rng::new(1);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

        let grads = |p: &[f32]| -> Vec<f32> {
            p.iter().zip(&target).map(|(a, b)| a - b).collect()
        };

        let mut p_a = vec![0.0f32; n];
        let mut opt_a = vec![build(&cfg, n, None)];
        for _ in 0..5 {
            let g = grads(&p_a);
            opt_a[0].step(&mut p_a, &g);
        }
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.bin");
        Checkpoint::capture(5, &Rng::new(9), &[p_a.clone()], &opt_a)
            .save(&path)
            .unwrap();
        for _ in 0..5 {
            let g = grads(&p_a);
            opt_a[0].step(&mut p_a, &g);
        }

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 5);
        let mut p_b = vec![vec![0.0f32; n]];
        let mut opt_b = vec![build(&cfg, n, None)];
        loaded.restore(&mut p_b, &mut opt_b).unwrap();
        for _ in 0..5 {
            let g = grads(&p_b[0]);
            opt_b[0].step(&mut p_b[0], &g);
        }
        assert_eq!(p_a, p_b[0], "trajectories diverged after restore");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("bitopt8_ckpt2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
