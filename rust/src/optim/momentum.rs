//! SGD with Momentum (Eq. 1) — single signed state, 32-bit or 8-bit.
//!
//! Follows the paper's formulation (PyTorch-style, no dampening):
//! `m_t = β1 m_{t-1} + g_t`, `w_t = w_{t-1} − α m_t`, with `m_0 = g_0`
//! (the first step uses the raw gradient).

use super::stability;
use super::state::{block_steps_vec, BlockView, LaneView, StateTensor, StepPlan};
use super::{make_state, Bits, OptimConfig, Optimizer};
use crate::util::lanes::LANES;

pub struct Momentum {
    cfg: OptimConfig,
    m: StateTensor,
    stab: stability::Stab,
    t: u64,
}

impl Momentum {
    pub fn new(cfg: OptimConfig, n: usize) -> Momentum {
        Momentum { cfg, m: make_state(&cfg.bits, n, true), stab: stability::Stab::default(), t: 0 }
    }
}

impl Optimizer for Momentum {
    // Fully block-local: one phase, no combine.
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let first = self.t == 1;
        let cfg = self.cfg;
        let block = cfg.bits.state_block(params.len());
        if cfg.stability_on() {
            let direct_rule =
                move |p: &mut f32, g_raw: f32, m: &mut f32, _s2: Option<&mut f32>, gs: f32| {
                    if cfg.skip_zeros && g_raw == 0.0 {
                        return;
                    }
                    let mut g = g_raw * gs;
                    if cfg.weight_decay != 0.0 {
                        g += cfg.weight_decay * *p;
                    }
                    *m = if first { g } else { cfg.beta1 * *m + g };
                    *p -= cfg.lr * *m;
                };
            let u_rule = move |u: &mut f32,
                               g_raw: f32,
                               m: &mut f32,
                               _s2: Option<&mut f32>,
                               w: f32,
                               gs: f32| {
                if cfg.skip_zeros && g_raw == 0.0 {
                    *u = 0.0;
                    return;
                }
                let mut g = g_raw * gs;
                if cfg.weight_decay != 0.0 {
                    g += cfg.weight_decay * w;
                }
                *m = if first { g } else { cfg.beta1 * *m + g };
                *u = *m;
            };
            return stability::stabilized_plan(
                &mut self.stab,
                &cfg,
                params,
                grads,
                &mut self.m,
                None,
                block,
                direct_rule,
                u_rule,
            );
        }
        StepPlan::single(block_steps_vec(
            params,
            grads,
            &mut self.m,
            None,
            block,
            move |v: LaneView| {
                let LaneView { params, grads, s1: m, .. } = v;
                for l in 0..LANES {
                    let mut g = grads[l];
                    if cfg.weight_decay != 0.0 {
                        g += cfg.weight_decay * params[l];
                    }
                    m[l] = if first { g } else { cfg.beta1 * m[l] + g };
                    params[l] -= cfg.lr * m[l];
                }
            },
            move |v: BlockView| {
                let BlockView { params, grads, s1: m, .. } = v;
                for i in 0..params.len() {
                    let mut g = grads[i];
                    if cfg.weight_decay != 0.0 {
                        g += cfg.weight_decay * params[i];
                    }
                    m[i] = if first { g } else { cfg.beta1 * m[i] + g };
                    params[i] -= cfg.lr * m[i];
                }
            },
        ))
    }

    fn state_bytes(&self) -> usize {
        self.m.bytes()
    }

    fn name(&self) -> String {
        format!("{} momentum", self.cfg.bits.describe())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }

    fn gnorm_history(&self) -> Option<Vec<f32>> {
        (self.cfg.clip_percentile > 0.0).then(|| self.stab.history.snapshot())
    }

    fn restore_gnorm_history(&mut self, hist: &[f32]) {
        self.stab.history.restore(hist);
    }

    fn set_bits(&mut self, bits: &Bits) -> bool {
        if !self.cfg.kind.supports_bits(bits) {
            return false;
        }
        super::requantize_state(&mut self.m, bits, true);
        self.cfg.bits = *bits;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::Bits;
    use crate::util::rng::Rng;

    #[test]
    fn first_step_initializes_state_with_gradient() {
        let mut opt = Momentum::new(OptimConfig::momentum(0.1, 0.9, Bits::B32), 4);
        let mut p = vec![0.0f32; 4];
        let g = vec![1.0f32, -2.0, 0.5, 0.0];
        opt.step(&mut p, &g);
        let m = opt.m.to_f32();
        assert_eq!(m, g);
        assert_eq!(p[0], -0.1);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Momentum::new(OptimConfig::momentum(0.0, 0.9, Bits::B32), 1);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0]);
        opt.step(&mut p, &[1.0]);
        let m = opt.m.to_f32();
        assert!((m[0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn momentum32_converges_on_quadratic() {
        let n = 1024;
        let mut rng = Rng::new(4);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Momentum::new(OptimConfig::momentum(0.02, 0.9, Bits::B32), n);
        for _ in 0..600 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-4, "mse {mse}");
    }

    #[test]
    fn momentum8_close_to_momentum32() {
        let n = 4096;
        let mut rng = Rng::new(5);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p32 = vec![0.0f32; n];
        let mut p8 = vec![0.0f32; n];
        let mut o32 = Momentum::new(OptimConfig::momentum(0.02, 0.9, Bits::B32), n);
        let mut o8 = Momentum::new(OptimConfig::momentum(0.02, 0.9, Bits::b8_dynamic()), n);
        for _ in 0..400 {
            let g32: Vec<f32> = p32.iter().zip(&target).map(|(a, b)| a - b).collect();
            o32.step(&mut p32, &g32);
            let g8: Vec<f32> = p8.iter().zip(&target).map(|(a, b)| a - b).collect();
            o8.step(&mut p8, &g8);
        }
        let mse8: f32 =
            p8.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse8 < 5e-3, "8-bit mse {mse8}");
    }

    #[test]
    fn percentile_clip_caps_spike_step() {
        // Momentum has no adaptive normalizer, so a spike hits the params
        // directly — exactly the case percentile clipping is for.
        let n = 128;
        let mut cfg = OptimConfig::momentum(0.1, 0.9, Bits::B32);
        cfg.clip_percentile = 95.0;
        let mut oc = Momentum::new(cfg, n);
        let mut ou = Momentum::new(OptimConfig::momentum(0.1, 0.9, Bits::B32), n);
        let mut pc = vec![0.0f32; n];
        let mut pu = vec![0.0f32; n];
        let g = vec![0.1f32; n];
        for _ in 0..10 {
            oc.step(&mut pc, &g);
            ou.step(&mut pu, &g);
        }
        let bc = pc[0];
        let bu = pu[0];
        let spike = vec![100.0f32; n];
        oc.step(&mut pc, &spike);
        ou.step(&mut pu, &spike);
        let dc = (pc[0] - bc).abs();
        let du = (pu[0] - bu).abs();
        assert!(dc < du / 100.0, "clipped step {dc} vs unclipped {du}");
    }

    #[test]
    fn max_unorm_matches_plain_momentum_when_inactive() {
        let n = 512;
        let mut cfg = OptimConfig::momentum(0.02, 0.9, Bits::B32);
        cfg.max_unorm = 1e30;
        let mut os = Momentum::new(cfg, n);
        let mut op = Momentum::new(OptimConfig::momentum(0.02, 0.9, Bits::B32), n);
        let mut ps = vec![1.0f32; n];
        let mut pp = vec![1.0f32; n];
        let mut rng = Rng::new(21);
        for _ in 0..30 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            os.step(&mut ps, &g);
            op.step(&mut pp, &g);
        }
        for (a, b) in ps.iter().zip(&pp) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn state_is_quarter_size_in_8bit() {
        let n = 1 << 18;
        let o32 = Momentum::new(OptimConfig::momentum(0.1, 0.9, Bits::B32), n);
        let o8 = Momentum::new(OptimConfig::momentum(0.1, 0.9, Bits::b8_dynamic()), n);
        let ratio = o32.state_bytes() as f64 / o8.state_bytes() as f64;
        assert!(ratio > 3.9, "{ratio}");
    }
}
