//! Placement — engine layer 5: ZeRO-style partitioning of optimizer state
//! across N simulated shards, with parameter groups as the unit of policy.
//!
//! The paper's premise is that optimizer state is the memory bottleneck;
//! block-wise quantization shrinks it ~4x, and *partitioning* that state
//! across workers (ZeRO-1) is the orthogonal axis. Here a shard is
//! simulated on the single host: every tensor of a group is assigned to
//! one of the group's `shards = N` shards by greedy bytes-balanced
//! placement ([`assign_greedy`]), and a training step runs each shard's
//! tensors as an independent phased batch on the existing worker pool —
//! shard s owns the full dequantize → update → requantize of its tensors,
//! and the step ends with a deterministic all-gather-style exchange
//! (shards drained in shard order; since all shards share this process's
//! memory the parameter copy is elided, but the published volume is
//! accounted by [`ShardLayout::exchange_bytes`]).
//!
//! Determinism: sharding inherits bit-identity for free from the layers
//! below. Tensors never share optimizer state, shard boundaries fall on
//! whole tensors (and quantization blocks are tensor-local, so block
//! boundaries are respected by construction), and each tensor walks its
//! phases in the canonical [`StepPlan`](super::state::StepPlan)
//! item/combine order with all reductions folded in fixed order — so *any*
//! partition of the tensor set across concurrent
//! [`StreamingStep`](super::StreamingStep)s produces the same bits as the
//! single-shard fused path, at every thread count and lane width
//! (`rust/tests/shard_parity.rs` pins shards {1,2,4,8} × threads × lanes ×
//! bits × optimizers).
//!
//! Checkpointing: shard-parallel I/O lives in `coordinator::checkpoint`
//! (format v5, one file per shard written off the worker pool via detached
//! batches). State is keyed by tensor+group — never by shard — so an
//! N-shard checkpoint restores into any M-shard layout (resharding).

use super::{Optimizer, StreamingStep};
use crate::optim::spec::OptimSpec;

/// Upper bound on `shards = N` (per group and spec-wide). Far above any
/// realistic simulated-host count; mostly a guard against typos.
pub const MAX_SHARDS: u32 = 64;

/// Greedy bytes-balanced assignment: items (tensors) are placed heaviest
/// first onto the currently-lightest shard. Returns one shard index per
/// item. Deterministic: ties in weight break toward the lower item index,
/// ties in load toward the lower shard index.
pub fn assign_greedy(bytes: &[usize], n_shards: usize) -> Vec<usize> {
    let n_shards = n_shards.max(1);
    let mut order: Vec<usize> = (0..bytes.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(bytes[i]), i));
    let mut load = vec![0usize; n_shards];
    let mut out = vec![0usize; bytes.len()];
    for i in order {
        let s = (0..n_shards).min_by_key(|&s| (load[s], s)).expect("n_shards >= 1");
        out[i] = s;
        load[s] += bytes[i];
    }
    out
}

/// The resolved tensor → shard map for one model, built once by
/// [`ParamOptimizer::build`](super::ParamOptimizer::build) from the spec's
/// placement policy. Each group is partitioned independently across its
/// own `shards = N` (group-local shard s is global shard s, so a group
/// with fewer shards simply concentrates on the low-numbered ones); the
/// global shard count is the maximum over groups.
#[derive(Clone, Debug)]
pub struct ShardLayout {
    /// Global shard count (1 = placement off, everything on shard 0).
    pub n_shards: usize,
    /// Shard index per model tensor.
    pub assignment: Vec<usize>,
    /// Optimizer-state bytes per shard — `max` is the number that actually
    /// bounds per-worker memory.
    pub shard_bytes: Vec<usize>,
    /// Parameter elements per shard (the all-gather publication volume).
    pub shard_params: Vec<usize>,
}

impl ShardLayout {
    /// Build the layout from the spec's per-group shard policy and each
    /// tensor's `(group, state_bytes, elements)`.
    pub fn build(spec: &OptimSpec, tensors: &[(usize, usize, usize)]) -> ShardLayout {
        let n_groups = spec.groups.len() + 1;
        let n_shards =
            (0..n_groups).map(|g| spec.shards_of(g) as usize).max().unwrap_or(1).max(1);
        let mut assignment = vec![0usize; tensors.len()];
        for g in 0..n_groups {
            let members: Vec<usize> =
                (0..tensors.len()).filter(|&i| tensors[i].0 == g).collect();
            if members.is_empty() {
                continue;
            }
            let bytes: Vec<usize> = members.iter().map(|&i| tensors[i].1).collect();
            let local = assign_greedy(&bytes, spec.shards_of(g) as usize);
            for (m, &i) in members.iter().enumerate() {
                assignment[i] = local[m];
            }
        }
        let mut shard_bytes = vec![0usize; n_shards];
        let mut shard_params = vec![0usize; n_shards];
        for (i, &(_, bytes, size)) in tensors.iter().enumerate() {
            shard_bytes[assignment[i]] += bytes;
            shard_params[assignment[i]] += size;
        }
        ShardLayout { n_shards, assignment, shard_bytes, shard_params }
    }

    /// A trivial single-shard layout over `n` tensors (placement off).
    pub fn single(n: usize) -> ShardLayout {
        ShardLayout {
            n_shards: 1,
            assignment: vec![0; n],
            shard_bytes: vec![0],
            shard_params: vec![0],
        }
    }

    /// The largest per-shard state footprint — with ZeRO-style placement
    /// this, not the total, is what bounds a worker's memory.
    pub fn max_shard_bytes(&self) -> usize {
        self.shard_bytes.iter().copied().max().unwrap_or(0)
    }

    /// Bytes a real N-shard all-gather would move per step: each shard
    /// broadcasts its owned updated parameters (f32) to the other N-1
    /// shards. Zero when unsharded.
    pub fn exchange_bytes(&self) -> usize {
        if self.n_shards <= 1 {
            return 0;
        }
        self.shard_params.iter().map(|&p| p * 4).sum::<usize>() * (self.n_shards - 1)
    }

    /// State bytes of one group, split per shard (indexed by global shard,
    /// truncated to the group's own shard count `n`).
    pub fn group_shard_bytes(
        &self,
        n: usize,
        tensors: impl Iterator<Item = (usize, usize)>,
    ) -> Vec<usize> {
        let mut out = vec![0usize; n.max(1)];
        for (i, bytes) in tensors {
            out[self.assignment[i]] += bytes;
        }
        out
    }
}

/// Run one sharded training step: each tensor is admitted to its shard's
/// own [`StreamingStep`] (shard-major admission order, tensor order within
/// a shard), all shards' phased batches overlap on the worker pool, and
/// the step ends with the deterministic all-gather-style exchange — shards
/// drained in shard order, so the step completes in the same canonical
/// sequence every run. Bit-identical to the single-shard fused path for
/// any assignment.
pub fn run_sharded<'a>(
    tensors: Vec<(usize, &'a mut dyn Optimizer, &'a mut [f32], &'a [f32])>,
    n_shards: usize,
) {
    let n_shards = n_shards.max(1);
    let mut slots: Vec<Option<_>> = tensors.into_iter().map(Some).collect();
    let mut shards: Vec<StreamingStep<'a>> =
        (0..n_shards).map(|_| StreamingStep::new()).collect();
    for s in 0..n_shards {
        for slot in slots.iter_mut() {
            if slot.as_ref().is_some_and(|t| t.0 == s) {
                let (_, opt, p, g) = slot.take().expect("checked is_some");
                shards[s].push(opt, p, g);
            }
        }
    }
    for slot in &slots {
        assert!(slot.is_none(), "tensor assigned to shard >= n_shards");
    }
    // the "all-gather": every shard's updates must be fully applied (and
    // thereby published to the shared parameter memory) before the step
    // ends; draining in shard order makes the exchange deterministic
    for st in shards {
        st.finish();
    }
}

/// Step every tensor through the sharded engine under an explicit
/// tensor → shard assignment. Bit-identical to
/// [`fused_update`](super::fused_update) /
/// [`streaming_update`](super::streaming_update) and to the serial
/// per-tensor loop; used by benches and the shard parity tests.
pub fn sharded_update(
    opts: &mut [Box<dyn Optimizer>],
    params: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    assignment: &[usize],
    n_shards: usize,
) {
    assert_eq!(opts.len(), params.len());
    assert_eq!(opts.len(), grads.len());
    assert_eq!(opts.len(), assignment.len());
    let tensors: Vec<(usize, &mut dyn Optimizer, &mut [f32], &[f32])> = opts
        .iter_mut()
        .zip(params.iter_mut())
        .zip(grads.iter())
        .enumerate()
        .map(|(i, ((opt, p), g))| (assignment[i], opt.as_mut(), p.as_mut_slice(), g.as_slice()))
        .collect();
    run_sharded(tensors, n_shards);
}

#[cfg(test)]
mod tests {
    use super::super::{build, Bits, GroupOverride, OptimConfig, OptimKind};
    use super::*;

    #[test]
    fn greedy_assignment_balances_bytes() {
        // heaviest-first onto lightest shard: 10,8,6,4 over 2 shards
        // -> 10|8, then 6 joins 8, 4 joins 10 => loads 14/14
        let a = assign_greedy(&[4, 10, 8, 6], 2);
        let mut load = [0usize; 2];
        for (i, &s) in a.iter().enumerate() {
            load[s] += [4, 10, 8, 6][i];
        }
        assert_eq!(load[0], load[1], "{a:?}");
        // deterministic: equal inputs always produce the same map
        assert_eq!(a, assign_greedy(&[4, 10, 8, 6], 2));
        // more shards than items: one item per shard, heaviest on shard 0
        let a = assign_greedy(&[1, 5], 4);
        assert_eq!(a[1], 0);
        assert_ne!(a[0], a[1]);
        // degenerate inputs
        assert_eq!(assign_greedy(&[], 4), Vec::<usize>::new());
        assert_eq!(assign_greedy(&[7, 7], 1), vec![0, 0]);
    }

    #[test]
    fn layout_partitions_groups_independently() {
        let base = OptimConfig::adam(1e-3, Bits::b8_dynamic());
        let mut spec = OptimSpec::with_groups(
            base,
            vec![GroupOverride::parse("big.*:shards=4").unwrap()],
        );
        spec.default_shards = 1;
        // (group, state_bytes, elements): default group stays on shard 0,
        // the 4-way group spreads
        let tensors = [
            (0usize, 100usize, 25usize),
            (1, 4000, 1000),
            (1, 3000, 750),
            (1, 2000, 500),
            (1, 1000, 250),
            (0, 50, 12),
        ];
        let layout = ShardLayout::build(&spec, &tensors);
        assert_eq!(layout.n_shards, 4);
        assert_eq!(layout.assignment[0], 0);
        assert_eq!(layout.assignment[5], 0);
        // the four group-1 tensors land on four distinct shards
        let mut seen: Vec<usize> = layout.assignment[1..5].to_vec();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert_eq!(layout.shard_bytes.iter().sum::<usize>(), 10150);
        assert_eq!(layout.max_shard_bytes(), 4000 + 100 + 50);
        // exchange volume: every shard broadcasts its params to 3 peers
        assert_eq!(layout.exchange_bytes(), 2537 * 4 * 3);
        let gsb = layout.group_shard_bytes(
            4,
            tensors
                .iter()
                .enumerate()
                .filter(|(_, t)| t.0 == 1)
                .map(|(i, t)| (i, t.1)),
        );
        assert_eq!(gsb.iter().sum::<usize>(), 10000);
        assert_eq!(gsb.iter().copied().max(), Some(4000));
    }

    #[test]
    fn sharded_update_matches_serial_stepping_bitwise() {
        let kinds = [
            (OptimKind::Adam, 3usize),
            (OptimKind::Adam, 2048),
            (OptimKind::Momentum, 5000),
            (OptimKind::Lamb, 1024),
            (OptimKind::Lamb, 20000),
            (OptimKind::Adam, 2049),
        ];
        let fleet = |bits: Bits| {
            let mut rng = crate::util::rng::Rng::new(77);
            let mut opts: Vec<Box<dyn Optimizer>> = Vec::new();
            let mut params = Vec::new();
            let mut grads = Vec::new();
            for &(kind, n) in &kinds {
                let mut cfg = OptimConfig::adam(0.01, bits);
                cfg.kind = kind;
                opts.push(build(&cfg, n, None));
                params.push((0..n).map(|_| rng.normal() as f32).collect::<Vec<f32>>());
                grads.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect::<Vec<f32>>());
            }
            (opts, params, grads)
        };
        for bits in [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()] {
            let (mut o_serial, mut p_serial, g) = fleet(bits);
            let (mut o_shard, mut p_shard, _) = fleet(bits);
            let bytes: Vec<usize> = o_shard.iter().map(|o| o.state_bytes()).collect();
            let assignment = assign_greedy(&bytes, 3);
            for _ in 0..3 {
                for i in 0..o_serial.len() {
                    o_serial[i].step(&mut p_serial[i], &g[i]);
                }
                sharded_update(&mut o_shard, &mut p_shard, &g, &assignment, 3);
            }
            assert_eq!(p_serial, p_shard, "params diverged ({})", bits.describe());
            for (a, b) in o_serial.iter().zip(&o_shard) {
                for ((na, sa), (nb, sb)) in a.states().iter().zip(b.states().iter()) {
                    assert_eq!(na, nb);
                    assert_eq!(sa.to_f32(), sb.to_f32(), "state {na} diverged");
                }
            }
        }
    }

    #[test]
    fn empty_sharded_step_is_a_no_op() {
        run_sharded(Vec::new(), 4);
        let mut none: Vec<Box<dyn Optimizer>> = Vec::new();
        sharded_update(&mut none, &mut [], &[], &[], 2);
    }
}
