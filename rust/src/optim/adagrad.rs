//! AdaGrad (Duchi et al. 2011) — Table 7 / Appendix H comparison.
//!
//! Accumulates squared gradients over the *whole* run, so the state spans a
//! much wider dynamic range than Adam's smoothed moments — the regime where
//! the paper observes 8-bit quantization to be hardest. The 8-bit variant
//! optionally uses stochastic rounding, which Appendix H suggests helps for
//! AdaGrad-style accumulators.

use super::stability;
use super::state::{block_steps_vec, BlockView, LaneView, StateTensor, StepPlan};
use super::{make_state, Bits, OptimConfig, Optimizer};
use crate::util::lanes::LANES;

pub struct Adagrad {
    cfg: OptimConfig,
    acc: StateTensor,
    stab: stability::Stab,
    t: u64,
}

impl Adagrad {
    pub fn new(cfg: OptimConfig, n: usize) -> Adagrad {
        Adagrad { cfg, acc: make_state(&cfg.bits, n, false), stab: stability::Stab::default(), t: 0 }
    }
}

impl Optimizer for Adagrad {
    // Fully block-local: one phase, no combine.
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let cfg = self.cfg;
        let block = cfg.bits.state_block(params.len());
        if cfg.stability_on() {
            let direct_rule =
                move |p: &mut f32, g_raw: f32, acc: &mut f32, _s2: Option<&mut f32>, gs: f32| {
                    if cfg.skip_zeros && g_raw == 0.0 {
                        return;
                    }
                    let mut g = g_raw * gs;
                    if cfg.weight_decay != 0.0 {
                        g += cfg.weight_decay * *p;
                    }
                    *acc += g * g;
                    *p -= cfg.lr * g / (acc.max(0.0).sqrt() + cfg.eps);
                };
            let u_rule = move |u: &mut f32,
                               g_raw: f32,
                               acc: &mut f32,
                               _s2: Option<&mut f32>,
                               w: f32,
                               gs: f32| {
                if cfg.skip_zeros && g_raw == 0.0 {
                    *u = 0.0;
                    return;
                }
                let mut g = g_raw * gs;
                if cfg.weight_decay != 0.0 {
                    g += cfg.weight_decay * w;
                }
                *acc += g * g;
                *u = g / (acc.max(0.0).sqrt() + cfg.eps);
            };
            return stability::stabilized_plan(
                &mut self.stab,
                &cfg,
                params,
                grads,
                &mut self.acc,
                None,
                block,
                direct_rule,
                u_rule,
            );
        }
        StepPlan::single(block_steps_vec(
            params,
            grads,
            &mut self.acc,
            None,
            block,
            move |v: LaneView| {
                let LaneView { params, grads, s1: acc, .. } = v;
                for l in 0..LANES {
                    let mut g = grads[l];
                    if cfg.weight_decay != 0.0 {
                        g += cfg.weight_decay * params[l];
                    }
                    acc[l] += g * g;
                    params[l] -= cfg.lr * g / (acc[l].max(0.0).sqrt() + cfg.eps);
                }
            },
            move |v: BlockView| {
                let BlockView { params, grads, s1: acc, .. } = v;
                for i in 0..params.len() {
                    let mut g = grads[i];
                    if cfg.weight_decay != 0.0 {
                        g += cfg.weight_decay * params[i];
                    }
                    acc[i] += g * g;
                    params[i] -= cfg.lr * g / (acc[i].max(0.0).sqrt() + cfg.eps);
                }
            },
        ))
    }

    fn state_bytes(&self) -> usize {
        self.acc.bytes()
    }

    fn name(&self) -> String {
        format!("{} adagrad", self.cfg.bits.describe())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("acc", &self.acc)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("acc", &mut self.acc)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }

    fn gnorm_history(&self) -> Option<Vec<f32>> {
        (self.cfg.clip_percentile > 0.0).then(|| self.stab.history.snapshot())
    }

    fn restore_gnorm_history(&mut self, hist: &[f32]) {
        self.stab.history.restore(hist);
    }

    fn set_bits(&mut self, bits: &Bits) -> bool {
        if !self.cfg.kind.supports_bits(bits) {
            return false;
        }
        super::requantize_state(&mut self.acc, bits, false);
        self.cfg.bits = *bits;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32, bits: Bits) -> OptimConfig {
        let mut cfg = OptimConfig::adam(lr, bits);
        cfg.kind = OptimKind::Adagrad;
        cfg.beta1 = 0.0;
        cfg.beta2 = 0.0;
        cfg.eps = 1e-10;
        cfg
    }

    #[test]
    fn accumulator_is_monotone_nondecreasing() {
        let n = 256;
        let mut opt = Adagrad::new(cfg(0.1, Bits::B32), n);
        let mut rng = Rng::new(6);
        let mut p = vec![0.0f32; n];
        let mut prev = vec![0.0f32; n];
        for _ in 0..20 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            opt.step(&mut p, &g);
            let acc = opt.acc.to_f32();
            for (a, b) in acc.iter().zip(&prev) {
                assert!(a >= b);
            }
            prev = acc;
        }
    }

    #[test]
    fn effective_lr_decays() {
        // With constant gradient 1.0 the step size shrinks ~1/sqrt(t).
        let mut opt = Adagrad::new(cfg(1.0, Bits::B32), 1);
        let mut p = vec![0.0f32];
        let mut steps = Vec::new();
        let mut last = 0.0f32;
        for _ in 0..10 {
            opt.step(&mut p, &[1.0]);
            steps.push(last - p[0]);
            last = p[0];
        }
        for w in steps.windows(2) {
            assert!(w[1] < w[0] + 1e-6);
        }
        assert!((steps[0] - 1.0).abs() < 1e-3); // first step = lr*g/sqrt(g^2)
    }

    #[test]
    fn adagrad32_converges_on_quadratic() {
        let n = 1024;
        let mut rng = Rng::new(7);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Adagrad::new(cfg(0.5, Bits::B32), n);
        for _ in 0..800 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-2, "mse {mse}");
    }

    #[test]
    fn skip_zeros_freezes_accumulator_for_zero_grads() {
        let n = 32;
        let mut c = cfg(0.1, Bits::B32);
        c.skip_zeros = true;
        let mut opt = Adagrad::new(c, n);
        let mut p = vec![1.0f32; n];
        let g: Vec<f32> = (0..n).map(|i| if i < 16 { 0.0 } else { 1.0 }).collect();
        for _ in 0..10 {
            opt.step(&mut p, &g);
        }
        let acc = opt.acc.to_f32();
        for i in 0..16 {
            assert_eq!(acc[i], 0.0);
            assert_eq!(p[i], 1.0);
        }
        for i in 16..n {
            assert!(acc[i] > 9.9, "{}", acc[i]);
        }
    }

    #[test]
    fn adagrad8_remains_finite_over_long_run() {
        // The hard case (Appendix H): accumulator spans a wide range.
        let n = 2048;
        let mut opt = Adagrad::new(cfg(0.1, Bits::b8_dynamic()), n);
        let mut rng = Rng::new(8);
        let mut p = vec![0.0f32; n];
        for _ in 0..300 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            opt.step(&mut p, &g);
        }
        assert!(p.iter().all(|v| v.is_finite()));
        assert!(opt.acc.to_f32().iter().all(|&v| v >= 0.0));
    }
}
