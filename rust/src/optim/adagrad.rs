//! AdaGrad (Duchi et al. 2011) — Table 7 / Appendix H comparison.
//!
//! Accumulates squared gradients over the *whole* run, so the state spans a
//! much wider dynamic range than Adam's smoothed moments — the regime where
//! the paper observes 8-bit quantization to be hardest. The 8-bit variant
//! optionally uses stochastic rounding, which Appendix H suggests helps for
//! AdaGrad-style accumulators.

use super::state::{block_steps_vec, BlockView, LaneView, StateTensor, StepPlan};
use super::{make_state, OptimConfig, Optimizer};
use crate::util::lanes::LANES;

pub struct Adagrad {
    cfg: OptimConfig,
    acc: StateTensor,
    t: u64,
}

impl Adagrad {
    pub fn new(cfg: OptimConfig, n: usize) -> Adagrad {
        Adagrad { cfg, acc: make_state(&cfg.bits, n, false), t: 0 }
    }
}

impl Optimizer for Adagrad {
    // Fully block-local: one phase, no combine.
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let cfg = self.cfg;
        let block = cfg.bits.state_block(params.len());
        StepPlan::single(block_steps_vec(
            params,
            grads,
            &mut self.acc,
            None,
            block,
            move |v: LaneView| {
                let LaneView { params, grads, s1: acc, .. } = v;
                for l in 0..LANES {
                    let mut g = grads[l];
                    if cfg.weight_decay != 0.0 {
                        g += cfg.weight_decay * params[l];
                    }
                    acc[l] += g * g;
                    params[l] -= cfg.lr * g / (acc[l].max(0.0).sqrt() + cfg.eps);
                }
            },
            move |v: BlockView| {
                let BlockView { params, grads, s1: acc, .. } = v;
                for i in 0..params.len() {
                    let mut g = grads[i];
                    if cfg.weight_decay != 0.0 {
                        g += cfg.weight_decay * params[i];
                    }
                    acc[i] += g * g;
                    params[i] -= cfg.lr * g / (acc[i].max(0.0).sqrt() + cfg.eps);
                }
            },
        ))
    }

    fn state_bytes(&self) -> usize {
        self.acc.bytes()
    }

    fn name(&self) -> String {
        format!("{} adagrad", self.cfg.bits.describe())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("acc", &self.acc)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("acc", &mut self.acc)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32, bits: Bits) -> OptimConfig {
        OptimConfig {
            kind: OptimKind::Adagrad,
            lr,
            beta1: 0.0,
            beta2: 0.0,
            eps: 1e-10,
            weight_decay: 0.0,
            bits,
        }
    }

    #[test]
    fn accumulator_is_monotone_nondecreasing() {
        let n = 256;
        let mut opt = Adagrad::new(cfg(0.1, Bits::B32), n);
        let mut rng = Rng::new(6);
        let mut p = vec![0.0f32; n];
        let mut prev = vec![0.0f32; n];
        for _ in 0..20 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            opt.step(&mut p, &g);
            let acc = opt.acc.to_f32();
            for (a, b) in acc.iter().zip(&prev) {
                assert!(a >= b);
            }
            prev = acc;
        }
    }

    #[test]
    fn effective_lr_decays() {
        // With constant gradient 1.0 the step size shrinks ~1/sqrt(t).
        let mut opt = Adagrad::new(cfg(1.0, Bits::B32), 1);
        let mut p = vec![0.0f32];
        let mut steps = Vec::new();
        let mut last = 0.0f32;
        for _ in 0..10 {
            opt.step(&mut p, &[1.0]);
            steps.push(last - p[0]);
            last = p[0];
        }
        for w in steps.windows(2) {
            assert!(w[1] < w[0] + 1e-6);
        }
        assert!((steps[0] - 1.0).abs() < 1e-3); // first step = lr*g/sqrt(g^2)
    }

    #[test]
    fn adagrad32_converges_on_quadratic() {
        let n = 1024;
        let mut rng = Rng::new(7);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Adagrad::new(cfg(0.5, Bits::B32), n);
        for _ in 0..800 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-2, "mse {mse}");
    }

    #[test]
    fn adagrad8_remains_finite_over_long_run() {
        // The hard case (Appendix H): accumulator spans a wide range.
        let n = 2048;
        let mut opt = Adagrad::new(cfg(0.1, Bits::b8_dynamic()), n);
        let mut rng = Rng::new(8);
        let mut p = vec![0.0f32; n];
        for _ in 0..300 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            opt.step(&mut p, &g);
        }
        assert!(p.iter().all(|v| v.is_finite()));
        assert!(opt.acc.to_f32().iter().all(|&v| v >= 0.0));
    }
}
