//! Optimizer substrate: every optimizer the paper touches, each available
//! with 32-bit, 8-bit, or 4-bit block-wise quantized state (code width is
//! a parameter of the quant substrate — see [`Bits`] and
//! [`crate::quant::CodeWidth`]).
//!
//! | optimizer | states | paper use |
//! |-----------|--------|-----------|
//! | Adam / AdamW | m (signed), r (unsigned) | Tables 1,3,4,5; Figs 3,4,5 |
//! | Momentum     | m (signed)               | Tables 1,5 |
//! | LAMB / LARS  | Adam-/momentum-like + trust ratio | Table 5 |
//! | Adafactor    | m + factored r (32-bit only) | Tables 1,4 |
//! | AdaGrad      | accumulator (unsigned)   | Table 7 / Appendix H |
//! | SM3          | row/col accumulators     | related-work comparison |
//!
//! The 8-bit variants follow §2 of the paper exactly: state blocks are
//! dequantized to 32-bit scratch, updated, and requantized — one block at a
//! time, in parallel, with no cross-block synchronization.
//!
//! Execution goes through the unified block-kernel engine (see
//! `rust/src/optim/README.md`): every optimizer decomposes its update into
//! a phased [`state::StepPlan`] — parallel block items, deterministic
//! combines between phase barriers — built on [`state::block_steps`],
//! which owns the load/update/store dance; the coordinator merges every
//! tensor's phase-aligned items into one pool batch per phase per training
//! step via [`engine::FusedStep`].
//!
//! Construction goes through the parameter-group surface: an
//! [`spec::OptimSpec`] (base [`OptimConfig`] + ordered
//! [`groups::GroupOverride`]s, first match wins) resolved per tensor by
//! [`groups::ParamOptimizer`], which owns every tensor's optimizer (and HLO
//! mirror) and drives the fused step and per-group LR scheduling. The §2.3
//! stable-embedding policy is simply a `bits = 32` override on the
//! embedding tensors ([`groups::GroupOverride::emb32`]).

pub mod adafactor;
pub mod adagrad;
pub mod adam;
pub mod engine;
pub mod groups;
pub mod lamb;
pub mod lars;
pub mod momentum;
pub mod precision;
pub mod shard;
pub mod sm3;
pub mod spec;
pub mod stability;
pub mod state;

pub use engine::{fused_update, streaming_update, FusedStep, StreamingStep};
pub use groups::{
    GroupOverride, GroupReport, HloDispatch, HloEnv, HloMirror, NativeStream, ParamOptimizer,
    Pattern, StreamSlot, TensorInfo,
};
pub use precision::{
    describe_policy, PrecisionController, PrecisionPolicy, TensorCtlState, Transition,
};
pub use shard::{assign_greedy, sharded_update, ShardLayout, MAX_SHARDS};
pub use spec::{validate_config, OptimSpec};
pub use stability::{take_clip_events, take_unorm_clips, GnormHistory};
pub use state::{
    block_steps, step_blocks, AccessSet, BlockSteps, BlockView, CombineAccess, Counter, Grid,
    Phase, Region, Span, StateTensor, StepPlan,
};

use crate::quant::{CodeWidth, Format, BLOCK};

/// State precision for an optimizer instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Bits {
    /// Full-precision 32-bit states (the replication baselines).
    B32,
    /// 8-bit quantized states (the paper's contribution).
    B8 {
        /// Quantization data type (Table 3 ablates Dynamic vs Linear).
        format: Format,
        /// Block-wise (true, §2.1) or tensor-wide normalization (false —
        /// the "no block-wise" ablation rows of Table 3).
        blockwise: bool,
    },
    /// 4-bit quantized states (Li et al. 2023): 16-level codebooks, two
    /// codes per stored byte.
    B4 {
        format: Format,
        blockwise: bool,
    },
}

impl Bits {
    pub fn b8_dynamic() -> Bits {
        Bits::B8 { format: Format::Dynamic, blockwise: true }
    }

    pub fn b4_dynamic() -> Bits {
        Bits::B4 { format: Format::Dynamic, blockwise: true }
    }

    pub fn describe(&self) -> String {
        match self.quantized() {
            None => "32-bit".into(),
            Some((format, blockwise, width)) => format!(
                "{}-bit[{}{}]",
                width.bits(),
                format.name(),
                if blockwise { ",blockwise" } else { ",tensorwise" }
            ),
        }
    }

    /// Bits per stored state element (32, 8, or 4).
    pub fn bit_count(&self) -> u32 {
        match self.quantized() {
            None => 32,
            Some((_, _, width)) => width.bits(),
        }
    }

    /// `(format, blockwise, code width)` for quantized precisions, `None`
    /// for 32-bit — the one place the enum unfolds, so everything else
    /// stays width-generic.
    pub fn quantized(&self) -> Option<(Format, bool, CodeWidth)> {
        match *self {
            Bits::B32 => None,
            Bits::B8 { format, blockwise } => Some((format, blockwise, CodeWidth::U8)),
            Bits::B4 { format, blockwise } => Some((format, blockwise, CodeWidth::U4)),
        }
    }

    /// Block size to use for quantized state storage.
    pub fn state_block(&self, n: usize) -> usize {
        match self.quantized() {
            Some((_, false, _)) => n.max(1),
            _ => BLOCK.min(n.max(1)),
        }
    }
}

/// Which optimizer algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimKind {
    Adam,
    AdamW,
    Momentum,
    Lamb,
    Lars,
    Adafactor,
    Adagrad,
    Sm3,
}

impl OptimKind {
    pub fn parse(s: &str) -> Option<OptimKind> {
        match s.to_ascii_lowercase().as_str() {
            "adam" => Some(OptimKind::Adam),
            "adamw" => Some(OptimKind::AdamW),
            "momentum" | "sgdm" => Some(OptimKind::Momentum),
            "lamb" => Some(OptimKind::Lamb),
            "lars" => Some(OptimKind::Lars),
            "adafactor" => Some(OptimKind::Adafactor),
            "adagrad" => Some(OptimKind::Adagrad),
            "sm3" => Some(OptimKind::Sm3),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimKind::Adam => "adam",
            OptimKind::AdamW => "adamw",
            OptimKind::Momentum => "momentum",
            OptimKind::Lamb => "lamb",
            OptimKind::Lars => "lars",
            OptimKind::Adafactor => "adafactor",
            OptimKind::Adagrad => "adagrad",
            OptimKind::Sm3 => "sm3",
        }
    }

    // ---- capability registry (drives parse-time validation and the HLO
    // artifact selection in `groups::ParamOptimizer`) ----------------------

    /// Whether this optimizer honors `bits = 8`. Adafactor and SM3 keep
    /// their (factored) statistics in 32-bit by construction — asking for
    /// 8-bit state is a config error, not a silent fallback
    /// (`spec::validate_config`).
    pub fn supports_8bit(&self) -> bool {
        !matches!(self, OptimKind::Adafactor | OptimKind::Sm3)
    }

    /// Whether this optimizer honors `bits = 4`. Same set as 8-bit: every
    /// elementwise-state optimizer runs the identical dequantize → update →
    /// requantize pipeline at 16 levels (Li et al. 2023 quantize exactly
    /// these moment tensors); the factored optimizers stay 32-bit.
    pub fn supports_4bit(&self) -> bool {
        self.supports_8bit()
    }

    /// Width-dispatching capability check for a precision setting.
    pub fn supports_bits(&self, bits: &Bits) -> bool {
        match bits.quantized() {
            None => true,
            Some((_, _, CodeWidth::U8)) => self.supports_8bit(),
            Some((_, _, CodeWidth::U4)) => self.supports_4bit(),
        }
    }

    /// Whether this optimizer implements the bnb stability toolkit
    /// (percentile clipping, `max_unorm`, `skip_zeros`) as fused phases.
    /// The elementwise-state optimizers do; the reduction-bearing ones
    /// (LAMB/LARS/Adafactor/SM3) already own multi-phase plans with their
    /// own norm semantics, so asking for stability overrides there is a
    /// config error, not a silent no-op (`spec::validate_config`).
    pub fn supports_stability(&self) -> bool {
        matches!(
            self,
            OptimKind::Adam | OptimKind::AdamW | OptimKind::Momentum | OptimKind::Adagrad
        )
    }

    /// Whether a parameter group running this optimizer may be partitioned
    /// across shards (`shards = N` placement). Sharding assigns whole
    /// tensors to shards by state-byte load, so it needs state whose bytes
    /// are proportional to the tensor's elements and an update that runs as
    /// a self-contained phased plan per tensor — true for every elementwise
    /// and norm-based optimizer. The factored optimizers (Adafactor, SM3)
    /// keep row/column statistics whose footprint is *not*
    /// element-proportional, which would make bytes-balanced placement
    /// accounting meaningless; asking for `shards > 1` there is a config
    /// error, not a silent fallback (`spec::validate_config`).
    pub fn supports_sharding(&self) -> bool {
        !matches!(self, OptimKind::Adafactor | OptimKind::Sm3)
    }

    /// AOT update-artifact key for the HLO engine, plus whether the
    /// artifact carries a single state tensor. Only quantized Adam/AdamW
    /// and Momentum have compiled Pallas kernels.
    pub fn hlo_kind_key(&self) -> Option<(&'static str, bool)> {
        match self {
            OptimKind::Adam | OptimKind::AdamW => Some(("adam8", false)),
            OptimKind::Momentum => Some(("momentum8", true)),
            _ => None,
        }
    }
}

/// Hyperparameters + precision for one optimizer instance. Defaults mirror
/// the paper's baselines (we never tune per-precision, per §3 setup).
#[derive(Clone, Copy, Debug)]
pub struct OptimConfig {
    pub kind: OptimKind,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub bits: Bits,
    /// Percentile clipping (bnb `percentile_clipping`): clip the gradient
    /// to the `clip_percentile`-th percentile of a rolling per-tensor
    /// gradient-norm history. `0.0` disables (the default); active values
    /// lie in `(0, 100]`.
    pub clip_percentile: f32,
    /// Update-norm clip (bnb `max_unorm`): scale the applied update down
    /// when `‖u‖ > max_unorm · ‖w‖`. `0.0` disables.
    pub max_unorm: f32,
    /// bnb `skip_zeros`: elements with an exactly-zero gradient leave
    /// their moments and parameter untouched (sparse-gradient semantics).
    pub skip_zeros: bool,
}

impl OptimConfig {
    pub fn adam(lr: f32, bits: Bits) -> OptimConfig {
        OptimConfig {
            kind: OptimKind::Adam,
            lr,
            beta1: 0.9,
            beta2: 0.995,
            eps: 1e-7,
            weight_decay: 0.0,
            bits,
            clip_percentile: 0.0,
            max_unorm: 0.0,
            skip_zeros: false,
        }
    }

    pub fn momentum(lr: f32, beta: f32, bits: Bits) -> OptimConfig {
        OptimConfig {
            kind: OptimKind::Momentum,
            lr,
            beta1: beta,
            beta2: 0.0,
            eps: 0.0,
            weight_decay: 0.0,
            bits,
            clip_percentile: 0.0,
            max_unorm: 0.0,
            skip_zeros: false,
        }
    }

    /// Whether any of the bnb stability mechanisms is active — the switch
    /// between an optimizer's legacy plan and its stabilized phased plan.
    pub fn stability_on(&self) -> bool {
        self.clip_percentile > 0.0 || self.max_unorm > 0.0 || self.skip_zeros
    }

    pub fn describe(&self) -> String {
        format!("{} {}", self.bits.describe(), self.kind.name())
    }
}

/// A per-tensor optimizer. Elementwise optimizers could share instances
/// across tensors, but norm-based ones (LAMB/LARS) and factored ones
/// (Adafactor/SM3) need the tensor boundary, so the coordinator builds one
/// instance per parameter tensor.
pub trait Optimizer: Send {
    /// Decompose one update into a phased plan of pool-schedulable block
    /// tasks. Runs the cheap per-step prologue here (advance `t`, bias
    /// corrections); everything heavier — including tensor-wide reductions,
    /// expressed as per-block partials + an ordered combine — lives inside
    /// the plan's phases, so the fused engine can batch it with every other
    /// tensor's work.
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a>;
    /// Apply one update. `params` and `grads` are the flattened tensor.
    /// The provided implementation runs the plan in its canonical phase
    /// order, which is what makes per-tensor stepping bit-identical to the
    /// fused multi-tensor engine.
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        self.plan(params, grads).execute();
    }
    /// Optimizer-state footprint in bytes (Table 1 "Mem saved" accounting).
    fn state_bytes(&self) -> usize;
    fn name(&self) -> String;
    /// Update count so far.
    fn t(&self) -> u64;
    /// Named state tensors (analysis & checkpointing).
    fn states(&self) -> Vec<(&'static str, &StateTensor)>;
    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)>;
    /// Restore the step counter (checkpoint load).
    fn set_t(&mut self, t: u64);
    /// Set the learning rate (LR schedules are driven by the coordinator).
    fn set_lr(&mut self, lr: f32);
    /// Current learning rate.
    fn lr(&self) -> f32;
    /// Rolling gradient-norm history backing percentile clipping
    /// (chronological, oldest first), for checkpointing; `None` when this
    /// optimizer carries none (clipping off or unsupported).
    fn gnorm_history(&self) -> Option<Vec<f32>> {
        None
    }
    /// Restore a history captured by [`Optimizer::gnorm_history`]
    /// (checkpoint load); a no-op for optimizers without one.
    fn restore_gnorm_history(&mut self, _hist: &[f32]) {}
    /// Runtime width transition: re-resolve every state tensor's storage
    /// precision to `bits`, requantizing from the 32-bit working values
    /// (the checkpoint-restore mechanism, so the swap is lossless from the
    /// dequantized values and `q(dq(q(x))) == q(x)` pins same-width swaps
    /// bit-identically). Returns `false` when this optimizer cannot change
    /// width (the factored 32-bit-only kinds); the default refuses.
    fn set_bits(&mut self, _bits: &Bits) -> bool {
        false
    }
}

/// Build an optimizer for a tensor of `n` elements; `shape` (rows, cols)
/// enables factored second moments for Adafactor/SM3 on 2-D tensors.
pub fn build(cfg: &OptimConfig, n: usize, shape: Option<(usize, usize)>) -> Box<dyn Optimizer> {
    match cfg.kind {
        OptimKind::Adam | OptimKind::AdamW => Box::new(adam::Adam::new(*cfg, n)),
        OptimKind::Momentum => Box::new(momentum::Momentum::new(*cfg, n)),
        OptimKind::Lamb => Box::new(lamb::Lamb::new(*cfg, n)),
        OptimKind::Lars => Box::new(lars::Lars::new(*cfg, n)),
        OptimKind::Adafactor => Box::new(adafactor::Adafactor::new(*cfg, n, shape)),
        OptimKind::Adagrad => Box::new(adagrad::Adagrad::new(*cfg, n)),
        OptimKind::Sm3 => Box::new(sm3::Sm3::new(*cfg, n, shape)),
    }
}

/// Swap one state tensor to a new storage precision: dequantize to 32-bit
/// working values, allocate fresh storage (a new `CodeBuf` at the new
/// `CodeWidth` for quantized targets), and requantize. Signedness is the
/// optimizer's static per-state knowledge (Adam's m is signed, its r is
/// not), exactly as at construction time.
pub(crate) fn requantize_state(state: &mut StateTensor, bits: &Bits, signed: bool) {
    let vals = state.to_f32();
    let mut fresh = make_state(bits, vals.len(), signed);
    fresh.load_f32(&vals);
    *state = fresh;
}

/// Make the signed/unsigned state tensors for a given precision config.
pub(crate) fn make_state(bits: &Bits, n: usize, signed: bool) -> StateTensor {
    match bits.quantized() {
        None => StateTensor::new_f32(n),
        Some((format, _, width)) => {
            let cb = format.codebook(width, signed);
            StateTensor::new_quant(n, cb, bits.state_block(n), width)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            OptimKind::Adam,
            OptimKind::AdamW,
            OptimKind::Momentum,
            OptimKind::Lamb,
            OptimKind::Lars,
            OptimKind::Adafactor,
            OptimKind::Adagrad,
            OptimKind::Sm3,
        ] {
            assert_eq!(OptimKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn build_all_kinds() {
        for k in [
            OptimKind::Adam,
            OptimKind::AdamW,
            OptimKind::Momentum,
            OptimKind::Lamb,
            OptimKind::Lars,
            OptimKind::Adafactor,
            OptimKind::Adagrad,
            OptimKind::Sm3,
        ] {
            for bits in [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()] {
                let mut cfg = OptimConfig::adam(1e-3, bits);
                cfg.kind = k;
                let mut opt = build(&cfg, 100, Some((10, 10)));
                let mut p = vec![1.0f32; 100];
                let g = vec![0.1f32; 100];
                opt.step(&mut p, &g);
                assert!(p.iter().all(|v| v.is_finite()));
                assert!(opt.state_bytes() > 0 || matches!(k, OptimKind::Sm3));
                assert_eq!(opt.t(), 1);
            }
        }
    }

    #[test]
    fn eight_bit_adam_uses_quarter_memory() {
        let n = 1 << 20;
        let o32 = build(&OptimConfig::adam(1e-3, Bits::B32), n, None);
        let o8 = build(&OptimConfig::adam(1e-3, Bits::b8_dynamic()), n, None);
        let ratio = o32.state_bytes() as f64 / o8.state_bytes() as f64;
        assert!(ratio > 3.9 && ratio < 4.1, "ratio {ratio}");
    }

    #[test]
    fn four_bit_adam_uses_eighth_memory() {
        let n = 1 << 20;
        let o32 = build(&OptimConfig::adam(1e-3, Bits::B32), n, None);
        let o4 = build(&OptimConfig::adam(1e-3, Bits::b4_dynamic()), n, None);
        let ratio = o32.state_bytes() as f64 / o4.state_bytes() as f64;
        assert!(ratio > 7.8 && ratio < 8.1, "ratio {ratio}");
    }

    #[test]
    fn bits_introspection() {
        assert_eq!(Bits::B32.bit_count(), 32);
        assert_eq!(Bits::b8_dynamic().bit_count(), 8);
        assert_eq!(Bits::b4_dynamic().bit_count(), 4);
        assert_eq!(Bits::b4_dynamic().describe(), "4-bit[dynamic,blockwise]");
        assert_eq!(
            Bits::B4 { format: crate::quant::Format::Linear, blockwise: false }.describe(),
            "4-bit[linear,tensorwise]"
        );
        assert!(OptimKind::Adam.supports_bits(&Bits::b4_dynamic()));
        assert!(!OptimKind::Adafactor.supports_bits(&Bits::b4_dynamic()));
        assert!(OptimKind::Adafactor.supports_bits(&Bits::B32));
    }

    #[test]
    fn tensorwise_ablation_has_single_block() {
        let bits = Bits::B8 { format: crate::quant::Format::Dynamic, blockwise: false };
        assert_eq!(bits.state_block(1 << 20), 1 << 20);
    }
}
