//! Parameter groups: glob-style per-tensor config overrides and the
//! model-level [`ParamOptimizer`] that owns every tensor's optimizer.
//!
//! The paper's headline usability claim is a drop-in replacement that only
//! needs a two-line change — `GlobalOptimManager.override_config` in
//! bitsandbytes — whose essential power is *per-parameter policy*: keep the
//! stable embedding layer (§2.3) in 32-bit state while everything else runs
//! 8-bit. This module is that surface:
//!
//! * [`Pattern`] — glob-style tensor-name pattern (`*`, `?`, and `|`
//!   alternation).
//! * [`GroupOverride`] — a pattern plus optional `bits` / `format` /
//!   `blockwise` / `lr` / `weight_decay` / `beta1` / `beta2` / `eps` /
//!   `clip_percentile` / `max_unorm` / `skip_zeros` / `shards` /
//!   `bits_min` / `bits_max` overrides, parseable from
//!   `"pattern:key=val,key=val"` (the CLI `--override` syntax) or a
//!   `[[optimizer.group]]` TOML table. `shards` is the *placement* axis
//!   (engine layer 5, `optim::shard`): how many simulated shards this
//!   group's optimizer state is partitioned across. `bits_min`/`bits_max`
//!   bound the runtime precision controller (engine layer 6,
//!   `optim::precision`) — the floor/ceiling of adaptive width
//!   transitions, never the starting width.
//! * [`ParamOptimizer`] — built from an [`OptimSpec`](super::OptimSpec)
//!   (base config + ordered overrides, first match wins) and the model's
//!   tensor list; owns the per-tensor `Box<dyn Optimizer>`s and their HLO
//!   mirrors, resolves each tensor's effective config at build time,
//!   drives the fused phased step ([`ParamOptimizer::step_native`]) or the
//!   streaming split ([`ParamOptimizer::stream_native`]: a [`NativeStream`]
//!   with group-aware admission order plus the [`HloDispatch`] units the
//!   coordinator runs serially through PJRT while the pool crunches), and
//!   per-group LR scheduling / `state_bytes` reporting.
//!
//! The historical `emb32` trainer flag is sugar: [`GroupOverride::emb32`]
//! is the equivalent `embed.tok|embed.pos: bits=32` override (exact names
//! rather than `embed.*`, because the stable-embedding graph also has
//! `embed.ln.*` LayerNorm tensors that the historical flag left 8-bit —
//! the sugar is pinned bit-identical to the flag by
//! `rust/tests/param_groups.rs`).

use std::collections::BTreeMap;

use anyhow::{anyhow, ensure, Result};

use super::shard::ShardLayout;
use super::spec::OptimSpec;
use super::{Bits, FusedStep, OptimConfig, Optimizer, StreamingStep};
use crate::config::toml::TomlValue;
use crate::quant::{CodeWidth, Format};

// ------------------------------------------------------------------ Pattern

/// Glob-style tensor-name pattern: `*` matches any (possibly empty) run,
/// `?` matches one character, `|` separates alternatives (any may match).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pattern(String);

impl Pattern {
    pub fn new(s: &str) -> Result<Pattern> {
        ensure!(!s.trim().is_empty(), "empty tensor-name pattern");
        ensure!(
            s.split('|').all(|alt| !alt.trim().is_empty()),
            "pattern {s:?} has an empty alternative"
        );
        Ok(Pattern(s.trim().to_string()))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }

    pub fn matches(&self, name: &str) -> bool {
        self.0.split('|').any(|alt| glob_match(alt.trim().as_bytes(), name.as_bytes()))
    }
}

/// Iterative glob matcher with single-`*` backtracking (linear time).
fn glob_match(pat: &[u8], text: &[u8]) -> bool {
    let (mut p, mut t) = (0usize, 0usize);
    let (mut star, mut mark) = (usize::MAX, 0usize);
    while t < text.len() {
        if p < pat.len() && (pat[p] == b'?' || pat[p] == text[t]) {
            p += 1;
            t += 1;
        } else if p < pat.len() && pat[p] == b'*' {
            star = p;
            mark = t;
            p += 1;
        } else if star != usize::MAX {
            p = star + 1;
            mark += 1;
            t = mark;
        } else {
            return false;
        }
    }
    while p < pat.len() && pat[p] == b'*' {
        p += 1;
    }
    p == pat.len()
}

// ------------------------------------------------------------ GroupOverride

/// One parameter group: a name pattern carrying optional config overrides.
/// Unset fields inherit from the spec's base config. Overrides are applied
/// first-match-wins in declaration order.
#[derive(Clone, Debug, Default)]
pub struct GroupOverride {
    pub pattern: Option<Pattern>,
    /// State precision: 4, 8, or 32 (validated at parse time).
    pub bits: Option<u32>,
    pub format: Option<Format>,
    pub blockwise: Option<bool>,
    pub lr: Option<f32>,
    pub weight_decay: Option<f32>,
    pub beta1: Option<f32>,
    pub beta2: Option<f32>,
    pub eps: Option<f32>,
    /// Percentile gradient clipping (0 = off; active in (0, 100]).
    pub clip_percentile: Option<f32>,
    /// Update-norm clipping threshold (0 = off).
    pub max_unorm: Option<f32>,
    /// Leave moments and params untouched where the gradient is exactly 0.
    pub skip_zeros: Option<bool>,
    /// Placement: partition this group's optimizer state across N simulated
    /// shards (1 = unsharded, the default; validated in `1..=MAX_SHARDS`).
    /// Unlike the other keys this never changes the resolved
    /// [`OptimConfig`] — placement is *where* state lives, not *what* the
    /// update computes, and the N-shard path is pinned bit-identical to
    /// the single-shard path.
    pub shards: Option<u32>,
    /// Adaptive-precision floor: the runtime precision controller
    /// (`optim::precision`) never demotes this group's tensors below this
    /// width (4, 8, or 32). Like `shards` this never changes the resolved
    /// [`OptimConfig`] — the starting width is still `bits`; the bound
    /// only constrains runtime transitions. Defaults to the resolved
    /// starting width.
    pub bits_min: Option<u32>,
    /// Adaptive-precision ceiling: the controller never promotes this
    /// group's tensors above this width (4, 8, or 32). Defaults to 32.
    pub bits_max: Option<u32>,
}

impl GroupOverride {
    pub fn new(pattern: Pattern) -> GroupOverride {
        GroupOverride { pattern: Some(pattern), ..GroupOverride::default() }
    }

    /// The §2.3 stable-embedding policy (the historical `emb32` flag) as a
    /// group override. Exact embedding names, not `embed.*`: the stable
    /// graph also has `embed.ln.{scale,bias}` tensors which the historical
    /// flag kept 8-bit, and the sugar is pinned bit-identical to the flag.
    pub fn emb32() -> GroupOverride {
        GroupOverride::parse("embed.tok|embed.pos:bits=32").expect("static emb32 sugar")
    }

    /// Parse the CLI form `"pattern:key=val[,key=val...]"`, e.g.
    /// `"embed.*:bits=32"` or `"block?.attn.*:lr=1e-4,weight_decay=0.1"`.
    pub fn parse(text: &str) -> Result<GroupOverride> {
        let (pat, rest) = text
            .split_once(':')
            .ok_or_else(|| anyhow!("override {text:?}: expected \"pattern:key=val[,key=val]\""))?;
        let mut ov = GroupOverride::new(Pattern::new(pat)?);
        for kv in rest.split(',') {
            let kv = kv.trim();
            if kv.is_empty() {
                continue;
            }
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow!("override {text:?}: bad pair {kv:?} (want key=val)"))?;
            ov.set(k.trim(), v.trim())?;
        }
        ensure!(ov.has_effect(), "override {text:?} sets nothing");
        Ok(ov)
    }

    /// Parse a `[[optimizer.group]]` TOML table (`pattern = "..."` plus any
    /// override keys).
    pub fn from_table(table: &BTreeMap<String, TomlValue>) -> Result<GroupOverride> {
        let pat = table
            .get("pattern")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("[[optimizer.group]] needs a string `pattern`"))?;
        let mut ov = GroupOverride::new(Pattern::new(pat)?);
        for (k, v) in table {
            if k == "pattern" {
                continue;
            }
            let text = match v {
                TomlValue::Str(s) => s.clone(),
                TomlValue::Int(i) => i.to_string(),
                TomlValue::Float(f) => format!("{f}"),
                TomlValue::Bool(b) => b.to_string(),
            };
            ov.set(k, &text)?;
        }
        ensure!(ov.has_effect(), "[[optimizer.group]] {pat:?} sets nothing");
        Ok(ov)
    }

    /// Set one override key from its string form (shared TOML/CLI parser).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let f32_of = |k: &str, v: &str| -> Result<f32> {
            v.parse::<f32>().map_err(|_| anyhow!("override key {k}: bad number {v:?}"))
        };
        match key {
            "bits" => {
                let b: u32 =
                    val.parse().map_err(|_| anyhow!("override key bits: bad value {val:?}"))?;
                ensure!(b == 4 || b == 8 || b == 32, "bits must be 4, 8 or 32, got {b}");
                self.bits = Some(b);
            }
            "format" => {
                self.format =
                    Some(Format::parse(val).ok_or_else(|| anyhow!("unknown format {val:?}"))?);
            }
            "blockwise" => {
                self.blockwise = Some(
                    val.parse::<bool>()
                        .map_err(|_| anyhow!("blockwise must be true or false, got {val:?}"))?,
                );
            }
            "lr" => self.lr = Some(f32_of("lr", val)?),
            "weight_decay" | "wd" => self.weight_decay = Some(f32_of("weight_decay", val)?),
            "beta1" | "beta" => self.beta1 = Some(f32_of("beta1", val)?),
            "beta2" => self.beta2 = Some(f32_of("beta2", val)?),
            "eps" => self.eps = Some(f32_of("eps", val)?),
            "clip_percentile" => {
                let p = f32_of("clip_percentile", val)?;
                ensure!(
                    p == 0.0 || (p > 0.0 && p <= 100.0),
                    "clip_percentile must be 0 (off) or in (0, 100], got {p}"
                );
                self.clip_percentile = Some(p);
            }
            "max_unorm" => {
                let m = f32_of("max_unorm", val)?;
                ensure!(m.is_finite() && m >= 0.0, "max_unorm must be finite and >= 0, got {m}");
                self.max_unorm = Some(m);
            }
            "skip_zeros" => {
                self.skip_zeros = Some(
                    val.parse::<bool>()
                        .map_err(|_| anyhow!("skip_zeros must be true or false, got {val:?}"))?,
                );
            }
            "shards" => {
                let s: u32 = val
                    .parse()
                    .map_err(|_| anyhow!("override key shards: bad value {val:?}"))?;
                ensure!(
                    (1..=super::shard::MAX_SHARDS).contains(&s),
                    "shards must be in 1..={}, got {s}",
                    super::shard::MAX_SHARDS
                );
                self.shards = Some(s);
            }
            "bits_min" | "bits_max" => {
                let b: u32 = val
                    .parse()
                    .map_err(|_| anyhow!("override key {key}: bad value {val:?}"))?;
                ensure!(b == 4 || b == 8 || b == 32, "{key} must be 4, 8 or 32, got {b}");
                if key == "bits_min" {
                    self.bits_min = Some(b);
                } else {
                    self.bits_max = Some(b);
                }
            }
            other => {
                return Err(anyhow!(
                    "unknown override key {other:?} (known: bits, format, blockwise, lr, \
                     weight_decay, beta1, beta2, eps, clip_percentile, max_unorm, skip_zeros, \
                     shards, bits_min, bits_max)"
                ))
            }
        }
        Ok(())
    }

    pub fn has_effect(&self) -> bool {
        self.bits.is_some()
            || self.format.is_some()
            || self.blockwise.is_some()
            || self.lr.is_some()
            || self.weight_decay.is_some()
            || self.beta1.is_some()
            || self.beta2.is_some()
            || self.eps.is_some()
            || self.clip_percentile.is_some()
            || self.max_unorm.is_some()
            || self.skip_zeros.is_some()
            || self.shards.is_some()
            || self.bits_min.is_some()
            || self.bits_max.is_some()
    }

    pub fn pattern(&self) -> &Pattern {
        self.pattern.as_ref().expect("GroupOverride built without a pattern")
    }

    /// Resolve: the base config with this group's overrides applied.
    pub fn apply(&self, base: &OptimConfig) -> OptimConfig {
        let mut cfg = *base;
        if self.bits.is_some() || self.format.is_some() || self.blockwise.is_some() {
            let (b0, f0, bw0) = match cfg.bits.quantized() {
                None => (32, Format::Dynamic, true),
                Some((format, blockwise, width)) => (width.bits(), format, blockwise),
            };
            let format = self.format.unwrap_or(f0);
            let blockwise = self.blockwise.unwrap_or(bw0);
            cfg.bits = match self.bits.unwrap_or(b0) {
                32 => Bits::B32,
                4 => Bits::B4 { format, blockwise },
                _ => Bits::B8 { format, blockwise },
            };
        }
        if let Some(v) = self.lr {
            cfg.lr = v;
        }
        if let Some(v) = self.weight_decay {
            cfg.weight_decay = v;
        }
        if let Some(v) = self.beta1 {
            cfg.beta1 = v;
        }
        if let Some(v) = self.beta2 {
            cfg.beta2 = v;
        }
        if let Some(v) = self.eps {
            cfg.eps = v;
        }
        if let Some(v) = self.clip_percentile {
            cfg.clip_percentile = v;
        }
        if let Some(v) = self.max_unorm {
            cfg.max_unorm = v;
        }
        if let Some(v) = self.skip_zeros {
            cfg.skip_zeros = v;
        }
        cfg
    }

    /// Sanity of this override *against a base config* (parse-time errors
    /// instead of silent fallbacks; see also `spec::validate_config`).
    pub fn check_against(&self, base: &OptimConfig) -> Result<()> {
        let resolved_bits = self.bits.unwrap_or(base.bits.bit_count());
        if resolved_bits == 32 && (self.format.is_some() || self.blockwise.is_some()) {
            return Err(anyhow!(
                "group {:?} sets format/blockwise but resolves to 32-bit state \
                 (add bits = 8 or drop the quantization keys)",
                self.pattern().as_str()
            ));
        }
        if let Some(s) = self.shards {
            ensure!(
                (1..=super::shard::MAX_SHARDS).contains(&s),
                "group {:?}: shards must be in 1..={}, got {s}",
                self.pattern().as_str(),
                super::shard::MAX_SHARDS
            );
            // groups cannot override the optimizer kind, so the resolved
            // kind is the base kind
            if s > 1 && !base.kind.supports_sharding() {
                return Err(anyhow!(
                    "group {:?} requests shards = {s}, but {} has no shardable fused \
                     plan (its factored statistics are not element-proportional); \
                     use shards = 1",
                    self.pattern().as_str(),
                    base.kind.name()
                ));
            }
        }
        if self.bits_min.is_some() || self.bits_max.is_some() {
            let floor = self.bits_min.unwrap_or(4);
            let ceil = self.bits_max.unwrap_or(32);
            ensure!(
                floor <= ceil,
                "group {:?}: bits_min ({floor}) above bits_max ({ceil})",
                self.pattern().as_str()
            );
            ensure!(
                (floor..=ceil).contains(&resolved_bits),
                "group {:?}: starting bits ({resolved_bits}) outside \
                 [bits_min, bits_max] = [{floor}, {ceil}]",
                self.pattern().as_str()
            );
            // groups cannot override the optimizer kind
            if floor < 32 && !base.kind.supports_8bit() {
                return Err(anyhow!(
                    "group {:?} sets bits_min = {floor}, but {} keeps 32-bit state by \
                     construction and cannot requantize at runtime",
                    self.pattern().as_str(),
                    base.kind.name()
                ));
            }
        }
        Ok(())
    }

    /// Canonical `pattern:key=val,...` form (round-trips through `parse`).
    pub fn describe(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some(b) = self.bits {
            parts.push(format!("bits={b}"));
        }
        if let Some(f) = self.format {
            parts.push(format!("format={}", f.name()));
        }
        if let Some(b) = self.blockwise {
            parts.push(format!("blockwise={b}"));
        }
        if let Some(v) = self.lr {
            parts.push(format!("lr={v}"));
        }
        if let Some(v) = self.weight_decay {
            parts.push(format!("weight_decay={v}"));
        }
        if let Some(v) = self.beta1 {
            parts.push(format!("beta1={v}"));
        }
        if let Some(v) = self.beta2 {
            parts.push(format!("beta2={v}"));
        }
        if let Some(v) = self.eps {
            parts.push(format!("eps={v}"));
        }
        if let Some(v) = self.clip_percentile {
            parts.push(format!("clip_percentile={v}"));
        }
        if let Some(v) = self.max_unorm {
            parts.push(format!("max_unorm={v}"));
        }
        if let Some(v) = self.skip_zeros {
            parts.push(format!("skip_zeros={v}"));
        }
        if let Some(v) = self.shards {
            parts.push(format!("shards={v}"));
        }
        if let Some(v) = self.bits_min {
            parts.push(format!("bits_min={v}"));
        }
        if let Some(v) = self.bits_max {
            parts.push(format!("bits_max={v}"));
        }
        format!("{}:{}", self.pattern().as_str(), parts.join(","))
    }
}

// ----------------------------------------------------------- ParamOptimizer

/// What [`ParamOptimizer::build`] needs to know about one model tensor.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub name: String,
    /// Element count.
    pub size: usize,
    /// (rows, cols) for 2-D tensors — enables factored second moments.
    pub shape: Option<(usize, usize)>,
    /// Size rounded up to a quantization-block multiple (HLO state layout);
    /// unused when no HLO environment is supplied.
    pub padded: usize,
}

/// HLO-engine build environment: the artifact block size plus a lookup from
/// (optimizer kind key, tensor size) to the compiled artifact file.
pub struct HloEnv<'a> {
    pub block: usize,
    pub artifact_for: &'a dyn Fn(&str, usize) -> Option<String>,
}

/// 8-bit optimizer state mirrored for the HLO engine (padded layout).
pub struct HloMirror {
    pub artifact: String,
    pub codes1: Vec<u8>,
    pub absmax1: Vec<f32>,
    pub codes2: Vec<u8>,
    pub absmax2: Vec<f32>,
    /// momentum artifacts carry a single state
    pub single_state: bool,
}

/// Per-group summary for reporting (`state_bytes`, CLI/metrics output).
#[derive(Clone, Debug)]
pub struct GroupReport {
    /// "default" for the base config, else the group's pattern.
    pub label: String,
    /// Resolved config description (e.g. "8-bit[dynamic,blockwise] adam").
    pub config: String,
    /// Resolved state precision of this group (32, 8, or 4) — makes mixed
    /// 4/8/32 runs distinguishable in the JSONL `groups` record.
    pub bits: u32,
    /// Resolved stability knobs (0/0/false = all off) — recorded in the
    /// JSONL `groups` record so a run's clip policy is auditable.
    pub clip_percentile: f32,
    pub max_unorm: f32,
    pub skip_zeros: bool,
    pub tensors: usize,
    pub params: usize,
    pub state_bytes: usize,
    /// Placement: how many shards this group's state is partitioned across
    /// (1 = unsharded).
    pub shards: u32,
    /// State bytes per shard of this group (`shards` entries; all zeros
    /// for an unmatched group). Sums to `state_bytes`.
    pub shard_state_bytes: Vec<usize>,
}

impl GroupReport {
    /// Optimizer-state bytes per parameter (0.0 for an unmatched group) —
    /// the Table 1-style footprint this group actually pays.
    pub fn bytes_per_param(&self) -> f64 {
        if self.params == 0 {
            0.0
        } else {
            self.state_bytes as f64 / self.params as f64
        }
    }

    /// The group's largest per-shard footprint — what one worker actually
    /// holds for this group (equals `state_bytes` when unsharded).
    pub fn max_shard_bytes(&self) -> usize {
        self.shard_state_bytes.iter().copied().max().unwrap_or(self.state_bytes)
    }
}

/// One native tensor queued for streaming admission. The pub metadata
/// drives (and lets tests inspect) the group-aware admission policy; the
/// borrows feed [`StreamingStep::push`] when the tensor is admitted.
pub struct StreamSlot<'a> {
    /// Model tensor index.
    pub index: usize,
    /// Group index (0 = default).
    pub group: usize,
    /// Element count.
    pub size: usize,
    /// Resolved to 32-bit state — the bandwidth hogs, admitted first.
    pub bits32: bool,
    opt: &'a mut dyn Optimizer,
    params: &'a mut [f32],
    grads: &'a [f32],
}

/// One HLO-engine tensor's dispatch unit: everything the coordinator needs
/// to drive the PJRT update artifact on the calling thread while the
/// native stream crunches on the worker pool.
pub struct HloDispatch<'a> {
    /// Model tensor index.
    pub index: usize,
    /// The tensor's *resolved* group config (hyperparameter vector).
    pub cfg: OptimConfig,
    pub opt: &'a mut dyn Optimizer,
    pub mirror: &'a mut HloMirror,
    pub params: &'a mut Vec<f32>,
    pub grads: &'a [f32],
}

/// The trainer-facing streaming path over a model's native tensors,
/// produced by [`ParamOptimizer::stream_native`]. Admission follows the
/// group-aware policy (32-bit groups first, then descending size, then
/// tensor index) unless the caller picks tensors explicitly with
/// [`NativeStream::admit_index`]; either way results are bit-identical to
/// the fused step — admission order is a scheduling choice, never a
/// semantic one.
pub struct NativeStream<'a> {
    stream: StreamingStep<'a>,
    /// Not-yet-admitted tensors in *reverse* policy order
    /// ([`NativeStream::admit_next`] pops the back).
    queue: Vec<StreamSlot<'a>>,
}

impl<'a> NativeStream<'a> {
    /// Remaining admission order (model tensor indices, policy order).
    pub fn admission_order(&self) -> Vec<usize> {
        self.queue.iter().rev().map(|s| s.index).collect()
    }

    /// Tensors not yet admitted.
    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    /// Admit the next queued tensor in policy order: its phase-0 block
    /// items start on the pool and the call returns. `false` once
    /// everything is admitted.
    pub fn admit_next(&mut self) -> bool {
        match self.queue.pop() {
            Some(s) => {
                self.stream.push(s.opt, s.params, s.grads);
                true
            }
            None => false,
        }
    }

    /// Admit a specific tensor out of policy order (parity tests pin that
    /// admission order cannot change results). `false` if the tensor is
    /// not queued (already admitted, or an HLO tensor).
    pub fn admit_index(&mut self, tensor: usize) -> bool {
        match self.queue.iter().position(|s| s.index == tensor) {
            Some(pos) => {
                let s = self.queue.remove(pos);
                self.stream.push(s.opt, s.params, s.grads);
                true
            }
            None => false,
        }
    }

    /// Admit every remaining tensor, in policy order (non-blocking — the
    /// pool keeps crunching while the caller moves on).
    pub fn admit_all(&mut self) {
        while self.admit_next() {}
    }

    /// Non-blocking progress on admitted tensors (see
    /// [`StreamingStep::poll`]); call between PJRT round-trips so
    /// multi-phase plans keep moving.
    pub fn poll(&mut self) {
        self.stream.poll();
    }

    /// Admit anything still queued and drain the stream; after this every
    /// native tensor's update is fully applied.
    pub fn finish(mut self) {
        self.admit_all();
        self.stream.finish();
    }
}

struct TensorSlot {
    name: String,
    /// 0 = default group (base config); g+1 = spec.groups[g].
    group: usize,
    /// Live resolved config. `cfg.bits` tracks runtime width transitions
    /// (see [`ParamOptimizer::set_tensor_bits`]), so reports and
    /// checkpoint capture always reflect the tensor's current precision.
    cfg: OptimConfig,
    /// Build-time resolved precision — the quantization format/blockwise
    /// template runtime transitions re-resolve against, and the default
    /// adaptive floor.
    built_bits: Bits,
    size: usize,
    opt: Box<dyn Optimizer>,
    hlo: Option<HloMirror>,
}

/// The model-level optimizer: every tensor's `Box<dyn Optimizer>` (plus its
/// HLO mirror when the HLO engine is active), with each tensor's effective
/// config resolved from an [`OptimSpec`] at build time. Replaces the
/// trainer's parallel `opts`/`hlo` vectors and the hard-coded `emb32`
/// special case.
pub struct ParamOptimizer {
    spec: OptimSpec,
    slots: Vec<TensorSlot>,
    /// Resolved tensor → shard placement (engine layer 5; trivial
    /// single-shard layout when placement is off).
    layout: ShardLayout,
}

impl ParamOptimizer {
    /// Resolve every tensor's config (first matching group wins), validate
    /// it, and build the per-tensor optimizers. With an [`HloEnv`], tensors
    /// whose *resolved* config has a compiled update artifact additionally
    /// get an [`HloMirror`] — the artifact is derived from the per-tensor
    /// resolved kind and precision, not from any global config.
    pub fn build(
        spec: OptimSpec,
        tensors: &[TensorInfo],
        hlo: Option<HloEnv<'_>>,
    ) -> Result<ParamOptimizer> {
        spec.validate()?;
        let sharded = (0..=spec.groups.len()).any(|g| spec.shards_of(g) > 1);
        ensure!(
            !(sharded && hlo.is_some()),
            "sharded placement (shards > 1) is not supported with the HLO engine: \
             shard ownership of the dequantize→update→requantize pipeline requires \
             the native fused plans"
        );
        let mut slots = Vec::with_capacity(tensors.len());
        for t in tensors {
            let (cfg, group) = spec.resolve(&t.name);
            let opt = super::build(&cfg, t.size, t.shape);
            let mirror = hlo.as_ref().and_then(|env| Self::make_hlo_mirror(&cfg, t, env));
            slots.push(TensorSlot {
                name: t.name.clone(),
                group,
                cfg,
                built_bits: cfg.bits,
                size: t.size,
                opt,
                hlo: mirror,
            });
        }
        let layout = ShardLayout::build(
            &spec,
            &slots
                .iter()
                .map(|s| (s.group, s.opt.state_bytes(), s.size))
                .collect::<Vec<_>>(),
        );
        Ok(ParamOptimizer { spec, slots, layout })
    }

    /// HLO mirror for one tensor, from its *resolved* config. Artifacts
    /// exist only for quantized Adam/AdamW/Momentum in the paper's dynamic
    /// block-wise layout; everything else (including 32-bit-policy groups)
    /// stays on the native engine.
    fn make_hlo_mirror(cfg: &OptimConfig, t: &TensorInfo, env: &HloEnv<'_>) -> Option<HloMirror> {
        if !matches!(cfg.bits, Bits::B8 { format: Format::Dynamic, blockwise: true }) {
            return None;
        }
        let (kind_key, single) = cfg.kind.hlo_kind_key()?;
        let artifact = (env.artifact_for)(kind_key, t.size)?;
        let zero = Format::Dynamic.signed_codebook().encode(0.0);
        let zero_u = Format::Dynamic.unsigned_codebook().encode(0.0);
        let nb = t.padded / env.block;
        Some(HloMirror {
            artifact,
            codes1: vec![zero; t.padded],
            absmax1: vec![0.0; nb],
            codes2: if single { Vec::new() } else { vec![zero_u; t.padded] },
            absmax2: if single { Vec::new() } else { vec![0.0; nb] },
            single_state: single,
        })
    }

    pub fn spec(&self) -> &OptimSpec {
        &self.spec
    }

    pub fn n_tensors(&self) -> usize {
        self.slots.len()
    }

    pub fn tensor_name(&self, i: usize) -> &str {
        &self.slots[i].name
    }

    /// Resolved effective config of tensor `i`.
    pub fn tensor_cfg(&self, i: usize) -> &OptimConfig {
        &self.slots[i].cfg
    }

    /// Group index of tensor `i` (0 = default, g+1 = spec.groups[g]).
    pub fn group_of(&self, i: usize) -> usize {
        self.slots[i].group
    }

    pub fn find(&self, name: &str) -> Option<usize> {
        self.slots.iter().position(|s| s.name == name)
    }

    pub fn opt(&self, i: usize) -> &dyn Optimizer {
        self.slots[i].opt.as_ref()
    }

    pub fn opt_mut(&mut self, i: usize) -> &mut dyn Optimizer {
        self.slots[i].opt.as_mut()
    }

    /// Total optimizer-state footprint (Table 1 "Mem saved" accounting).
    pub fn state_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.opt.state_bytes()).sum()
    }

    /// Tensors updated through the HLO engine.
    pub fn n_hlo(&self) -> usize {
        self.slots.iter().filter(|s| s.hlo.is_some()).count()
    }

    /// The resolved tensor → shard placement (trivial when placement is
    /// off; see `optim::shard`).
    pub fn shard_layout(&self) -> &ShardLayout {
        &self.layout
    }

    /// Largest per-shard optimizer-state footprint — with ZeRO-style
    /// placement this, not [`ParamOptimizer::state_bytes`], bounds one
    /// worker's memory. Equals the total when unsharded.
    pub fn max_shard_state_bytes(&self) -> usize {
        if self.layout.n_shards <= 1 {
            self.state_bytes()
        } else {
            self.layout.max_shard_bytes()
        }
    }

    /// Split the model into its two execution engines for one training
    /// step: a [`NativeStream`] over every native tensor (queued in the
    /// group-aware admission order) and the list of [`HloDispatch`] units
    /// the caller drives serially through PJRT while the stream crunches
    /// on the worker pool. Tensors are disjoint between (and within) the
    /// two, so the caller may interleave them freely; results are
    /// bit-identical to [`ParamOptimizer::step_native`] + serial HLO
    /// dispatch in any order.
    pub fn stream_native<'a>(
        &'a mut self,
        params: &'a mut [Vec<f32>],
        grads: &'a [Vec<f32>],
    ) -> (NativeStream<'a>, Vec<HloDispatch<'a>>) {
        assert_eq!(self.slots.len(), params.len());
        assert_eq!(self.slots.len(), grads.len());
        let mut queue: Vec<StreamSlot<'a>> = Vec::new();
        let mut dispatches: Vec<HloDispatch<'a>> = Vec::new();
        let tensors = self.slots.iter_mut().zip(params.iter_mut().zip(grads.iter()));
        for (i, (slot, (p, g))) in tensors.enumerate() {
            let TensorSlot { group, cfg, size, opt, hlo, .. } = slot;
            match hlo.as_mut() {
                None => queue.push(StreamSlot {
                    index: i,
                    group: *group,
                    size: *size,
                    bits32: matches!(cfg.bits, Bits::B32),
                    opt: opt.as_mut(),
                    params: p.as_mut_slice(),
                    grads: g.as_slice(),
                }),
                Some(mirror) => dispatches.push(HloDispatch {
                    index: i,
                    cfg: *cfg,
                    opt: opt.as_mut(),
                    mirror,
                    params: p,
                    grads: g.as_slice(),
                }),
            }
        }
        // Admission policy (a *group* property, not an accident of tensor
        // index): 32-bit groups first — the stable-embedding §2.3 tensors
        // carry 4x the state bandwidth — then descending size so the big
        // tensors keep the pool busy longest, then tensor index for
        // determinism. Stored reversed: `admit_next` pops the back.
        queue.sort_by_key(|s| (std::cmp::Reverse(s.bits32), std::cmp::Reverse(s.size), s.index));
        queue.reverse();
        (NativeStream { stream: StreamingStep::new(), queue }, dispatches)
    }

    /// Per-group LR scheduling: set each tensor's learning rate from its
    /// *group's* base LR through the caller's schedule.
    pub fn schedule_lr(&mut self, lr_at: impl Fn(f32) -> f32) {
        for slot in self.slots.iter_mut() {
            let lr = lr_at(slot.cfg.lr);
            slot.opt.set_lr(lr);
        }
    }

    /// One native training step over every tensor that is not on the HLO
    /// engine. Unsharded (`n_shards == 1`): all tensors' phased plans
    /// merged phase-aligned into one pool batch per phase (see
    /// `optim::engine`). Sharded: each shard runs its tensors as an
    /// independent phased batch, drained in shard order at step end (see
    /// `optim::shard`). Both are bit-identical to stepping the tensors
    /// serially — placement is a scheduling choice, never a semantic one.
    pub fn step_native(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        assert_eq!(self.slots.len(), params.len());
        assert_eq!(self.slots.len(), grads.len());
        if self.layout.n_shards > 1 {
            let assignment = &self.layout.assignment;
            let tensors: Vec<(usize, &mut dyn Optimizer, &mut [f32], &[f32])> = self
                .slots
                .iter_mut()
                .zip(params.iter_mut())
                .zip(grads.iter())
                .enumerate()
                .map(|(i, ((slot, p), g))| {
                    (assignment[i], slot.opt.as_mut(), p.as_mut_slice(), g.as_slice())
                })
                .collect();
            super::shard::run_sharded(tensors, self.layout.n_shards);
            return;
        }
        let mut fused = FusedStep::new();
        for ((slot, p), g) in self.slots.iter_mut().zip(params.iter_mut()).zip(grads.iter()) {
            if slot.hlo.is_none() {
                fused.push(slot.opt.as_mut(), p.as_mut_slice(), g.as_slice());
            }
        }
        fused.run();
    }

    /// Per-group breakdown (every group reported, matched or not, plus the
    /// default group first).
    pub fn group_reports(&self) -> Vec<GroupReport> {
        let n_groups = self.spec.groups.len() + 1;
        let mut reports: Vec<GroupReport> = (0..n_groups)
            .map(|g| {
                // Groups with no matching tensor still show their would-be
                // resolved config and precision.
                let cfg = if g == 0 {
                    self.spec.base
                } else {
                    self.spec.groups[g - 1].apply(&self.spec.base)
                };
                let shards = self.spec.shards_of(g);
                GroupReport {
                    label: self.spec.group_label(g),
                    config: cfg.describe(),
                    bits: cfg.bits.bit_count(),
                    clip_percentile: cfg.clip_percentile,
                    max_unorm: cfg.max_unorm,
                    skip_zeros: cfg.skip_zeros,
                    tensors: 0,
                    params: 0,
                    state_bytes: 0,
                    shards,
                    shard_state_bytes: vec![0; shards as usize],
                }
            })
            .collect();
        for (i, slot) in self.slots.iter().enumerate() {
            let r = &mut reports[slot.group];
            let bytes = slot.opt.state_bytes();
            r.tensors += 1;
            r.params += slot.size;
            r.state_bytes += bytes;
            r.shard_state_bytes[self.layout.assignment[i]] += bytes;
        }
        reports
    }

    /// Multi-line human description of the group layout.
    pub fn describe(&self) -> String {
        self.group_reports()
            .iter()
            .map(|r| {
                let mut line = format!(
                    "group {:<24} {:<28} {:>3} tensors {:>10} params {:>10.2} KB state \
                     ({:.3} B/param)",
                    r.label,
                    r.config,
                    r.tensors,
                    r.params,
                    r.state_bytes as f64 / 1e3,
                    r.bytes_per_param()
                );
                if r.shards > 1 {
                    line.push_str(&format!(
                        " | {} shards, max {:.2} KB/shard",
                        r.shards,
                        r.max_shard_bytes() as f64 / 1e3
                    ));
                }
                line
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// The tensor → shard assignment table (placement inspection without
    /// running a step — the `--dry-run` output). `None` when placement is
    /// off (single shard).
    pub fn describe_placement(&self) -> Option<String> {
        let layout = &self.layout;
        if layout.n_shards <= 1 {
            return None;
        }
        let mut lines = vec![format!(
            "placement: {} shards | total state {:.2} KB | max shard {:.2} KB | \
             all-gather {:.2} KB/step",
            layout.n_shards,
            self.state_bytes() as f64 / 1e3,
            layout.max_shard_bytes() as f64 / 1e3,
            layout.exchange_bytes() as f64 / 1e3
        )];
        for s in 0..layout.n_shards {
            let tensors = layout.assignment.iter().filter(|&&a| a == s).count();
            lines.push(format!(
                "  shard {s}: {:>3} tensors {:>10} params {:>10.2} KB state",
                tensors,
                layout.shard_params[s],
                layout.shard_bytes[s] as f64 / 1e3
            ));
        }
        for (i, slot) in self.slots.iter().enumerate() {
            lines.push(format!(
                "  {:<24} (group {:<24}) -> shard {}",
                slot.name,
                self.spec.group_label(slot.group),
                layout.assignment[i]
            ));
        }
        Some(lines.join("\n"))
    }

    /// Runtime width transition for tensor `i` — the precision
    /// controller's mechanism (`optim::precision`). Requantizes the
    /// tensor's states at `bits` (4, 8, or 32) from their 32-bit working
    /// values and updates the slot's live config, so byte accounting,
    /// group reports, and checkpoint capture stay truthful. The
    /// quantization format/blockwise template is the tensor's build-time
    /// resolution (dynamic blockwise for groups that started 32-bit).
    /// Returns `false` (no change) when the width is already current, the
    /// optimizer kind cannot requantize, or the tensor runs on the HLO
    /// engine (mirrors bake the width into the compiled artifact). Shard
    /// placement is untouched: assignment is fixed at build time, only
    /// the per-shard byte accounting shifts.
    pub fn set_tensor_bits(&mut self, i: usize, bits: u32) -> bool {
        debug_assert!(bits == 4 || bits == 8 || bits == 32, "bits {bits}");
        let (format, blockwise) = self.quant_template(i);
        let slot = &mut self.slots[i];
        if slot.hlo.is_some() || slot.cfg.bits.bit_count() == bits {
            return false;
        }
        let new_bits = match bits {
            32 => Bits::B32,
            4 => Bits::B4 { format, blockwise },
            _ => Bits::B8 { format, blockwise },
        };
        if !slot.cfg.kind.supports_bits(&new_bits) || !slot.opt.set_bits(&new_bits) {
            return false;
        }
        slot.cfg.bits = new_bits;
        true
    }

    /// The quantization format / blockwise template runtime width
    /// transitions use for tensor `i`: the live config's when currently
    /// quantized, else the build-time resolution (so a tensor promoted to
    /// 32-bit remembers its group's format on the way back down), else
    /// dynamic blockwise for groups that started 32-bit.
    pub fn quant_template(&self, i: usize) -> (Format, bool) {
        let slot = &self.slots[i];
        slot.cfg
            .bits
            .quantized()
            .or_else(|| slot.built_bits.quantized())
            .map(|(f, bw, _)| (f, bw))
            .unwrap_or((Format::Dynamic, true))
    }

    /// Resolved adaptive-precision bounds for tensor `i`: the group's
    /// (`bits_min`, `bits_max`) when set, else the build-time width as the
    /// floor and 32 as the ceiling. Tensors that cannot transition (HLO
    /// mirrors, factored 32-bit-only kinds) are pinned at their built
    /// width.
    pub fn bits_bounds(&self, i: usize) -> (u32, u32) {
        let slot = &self.slots[i];
        let built = slot.built_bits.bit_count();
        if slot.hlo.is_some() || !slot.cfg.kind.supports_8bit() {
            return (built, built);
        }
        let ov = if slot.group > 0 { Some(&self.spec.groups[slot.group - 1]) } else { None };
        let floor = ov.and_then(|o| o.bits_min).unwrap_or(built);
        let ceil = ov.and_then(|o| o.bits_max).unwrap_or(32);
        (floor.min(ceil), ceil.max(floor))
    }

    /// Exact storage bytes of an `n`-element state tensor at a given width
    /// (mirrors `Quantized::bytes`: packed codes + one f32 absmax per
    /// block).
    fn state_bytes_at(n: usize, bits: u32, blockwise: bool) -> usize {
        match bits {
            32 => n * 4,
            w => {
                let width = if w == 4 { CodeWidth::U4 } else { CodeWidth::U8 };
                let block = if blockwise { crate::quant::BLOCK.min(n.max(1)) } else { n.max(1) };
                width.bytes_for(n) + 4 * n.div_ceil(block).max(1)
            }
        }
    }

    /// Projected total optimizer-state footprint with every adaptive
    /// tensor at its precision floor / ceiling — the best/worst-case bytes
    /// a run under the precision policy can reach (`--dry-run` output).
    /// Exact: only state-tensor storage changes with width, so each
    /// state's live bytes are adjusted in place; per-optimizer scratch
    /// (e.g. LAMB's update buffer) is carried through unchanged.
    pub fn projected_state_bytes(&self) -> (usize, usize) {
        let (mut at_floor, mut at_ceil) = (0usize, 0usize);
        for (i, slot) in self.slots.iter().enumerate() {
            let live = slot.opt.state_bytes();
            let (floor, ceil) = self.bits_bounds(i);
            let (_, blockwise) = self.quant_template(i);
            let (mut lo, mut hi) = (live as i64, live as i64);
            for (_, st) in slot.opt.states() {
                let cur = st.bytes() as i64;
                lo += Self::state_bytes_at(st.len(), floor, blockwise) as i64 - cur;
                hi += Self::state_bytes_at(st.len(), ceil, blockwise) as i64 - cur;
            }
            at_floor += lo.max(0) as usize;
            at_ceil += hi.max(0) as usize;
        }
        (at_floor, at_ceil)
    }

    /// Dequantized snapshots of every optimizer state, keyed
    /// `tensor::state` (Figure 4 capture; checkpointing uses
    /// [`ParamOptimizer::opt`]/[`ParamOptimizer::opt_mut`] directly).
    pub fn state_snapshot(&self) -> Vec<(String, Vec<f32>)> {
        let mut out = Vec::new();
        for slot in &self.slots {
            for (name, st) in slot.opt.states() {
                out.push((format!("{}::{}", slot.name, name), st.to_f32()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::OptimKind;
    use super::*;

    #[test]
    fn glob_patterns() {
        let p = Pattern::new("embed.*").unwrap();
        assert!(p.matches("embed.tok"));
        assert!(p.matches("embed.ln.bias"));
        assert!(!p.matches("block0.embed"));
        let p = Pattern::new("block?.attn.*").unwrap();
        assert!(p.matches("block0.attn.wq"));
        assert!(!p.matches("block12.attn.wq"));
        let p = Pattern::new("*.bias").unwrap();
        assert!(p.matches("block0.mlp.b1.bias"));
        assert!(!p.matches("bias_less"));
        let p = Pattern::new("embed.tok|embed.pos").unwrap();
        assert!(p.matches("embed.tok") && p.matches("embed.pos"));
        assert!(!p.matches("embed.ln.bias"));
        let p = Pattern::new("*").unwrap();
        assert!(p.matches("anything.at.all") && p.matches(""));
        assert!(Pattern::new("").is_err());
        assert!(Pattern::new("a||b").is_err());
    }

    #[test]
    fn override_parse_roundtrip() {
        let ov = GroupOverride::parse("embed.*:bits=32").unwrap();
        assert_eq!(ov.bits, Some(32));
        assert_eq!(ov.describe(), "embed.*:bits=32");
        let ov =
            GroupOverride::parse("head:bits=8,format=linear,blockwise=false,lr=0.01,wd=0.1")
                .unwrap();
        assert_eq!(ov.format, Some(Format::Linear));
        assert_eq!(ov.blockwise, Some(false));
        assert_eq!(ov.weight_decay, Some(0.1));
        let re = GroupOverride::parse(&ov.describe()).unwrap();
        assert_eq!(re.lr, ov.lr);
        assert_eq!(re.format, ov.format);

        let ov = GroupOverride::parse("block?.attn.*:bits=4").unwrap();
        assert_eq!(ov.bits, Some(4));
        assert_eq!(ov.describe(), "block?.attn.*:bits=4");

        assert!(GroupOverride::parse("no-colon").is_err());
        assert!(GroupOverride::parse("p:bits=16").is_err());
        assert!(GroupOverride::parse("p:bogus=1").is_err());
        assert!(GroupOverride::parse("p:").is_err(), "no-op override");
        assert!(GroupOverride::parse("p:lr=abc").is_err());
    }

    #[test]
    fn stability_override_keys() {
        let ov =
            GroupOverride::parse("block*:clip_percentile=95,max_unorm=0.02,skip_zeros=true")
                .unwrap();
        assert_eq!(ov.clip_percentile, Some(95.0));
        assert_eq!(ov.max_unorm, Some(0.02));
        assert_eq!(ov.skip_zeros, Some(true));
        let re = GroupOverride::parse(&ov.describe()).unwrap();
        assert_eq!(re.clip_percentile, ov.clip_percentile);
        assert_eq!(re.max_unorm, ov.max_unorm);
        assert_eq!(re.skip_zeros, ov.skip_zeros);
        // applied on top of a base with everything off
        let base = OptimConfig::adam(1e-3, Bits::b8_dynamic());
        let cfg = ov.apply(&base);
        assert_eq!(cfg.clip_percentile, 95.0);
        assert_eq!(cfg.max_unorm, 0.02);
        assert!(cfg.skip_zeros);
        assert!(cfg.stability_on());
        // range validation happens at parse time
        assert!(GroupOverride::parse("p:clip_percentile=101").is_err());
        assert!(GroupOverride::parse("p:clip_percentile=-5").is_err());
        assert!(GroupOverride::parse("p:max_unorm=-1").is_err());
        assert!(GroupOverride::parse("p:skip_zeros=maybe").is_err());
    }

    fn lm_tensors() -> Vec<TensorInfo> {
        [
            ("embed.tok", 512 * 64, Some((512, 64))),
            ("embed.pos", 64 * 64, Some((64, 64))),
            ("embed.ln.bias", 64, None),
            ("block0.attn.wq", 64 * 64, Some((64, 64))),
            ("block0.mlp.w1", 64 * 256, Some((64, 256))),
            ("lm_head", 64 * 512, Some((64, 512))),
        ]
        .into_iter()
        .map(|(name, size, shape)| TensorInfo {
            name: name.to_string(),
            size,
            shape,
            padded: size.next_multiple_of(2048),
        })
        .collect()
    }

    #[test]
    fn first_match_wins_resolution() {
        let base = OptimConfig::adam(1e-3, Bits::b8_dynamic());
        let spec = OptimSpec::with_groups(
            base,
            vec![
                GroupOverride::parse("embed.tok:lr=0.5").unwrap(),
                GroupOverride::parse("embed.*:bits=32").unwrap(),
                GroupOverride::parse("embed.tok:lr=0.9").unwrap(), // shadowed
            ],
        );
        let popt = ParamOptimizer::build(spec, &lm_tensors(), None).unwrap();
        let tok = popt.find("embed.tok").unwrap();
        // first group wins: lr override only, still 8-bit
        assert_eq!(popt.group_of(tok), 1);
        assert_eq!(popt.tensor_cfg(tok).lr, 0.5);
        assert_eq!(popt.tensor_cfg(tok).bits, Bits::b8_dynamic());
        // embed.pos + embed.ln.bias fall to the second group
        let pos = popt.find("embed.pos").unwrap();
        assert_eq!(popt.group_of(pos), 2);
        assert_eq!(popt.tensor_cfg(pos).bits, Bits::B32);
        // non-embedding tensors keep the base
        let wq = popt.find("block0.attn.wq").unwrap();
        assert_eq!(popt.group_of(wq), 0);
        assert_eq!(popt.tensor_cfg(wq).bits, Bits::b8_dynamic());
    }

    #[test]
    fn group_reports_cover_all_tensors_and_bytes() {
        let base = OptimConfig::adam(1e-3, Bits::b8_dynamic());
        let spec = OptimSpec::with_groups(base, vec![GroupOverride::emb32()]);
        let popt = ParamOptimizer::build(spec, &lm_tensors(), None).unwrap();
        let reports = popt.group_reports();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].label, "default");
        assert_eq!(reports.iter().map(|r| r.tensors).sum::<usize>(), popt.n_tensors());
        assert_eq!(reports.iter().map(|r| r.state_bytes).sum::<usize>(), popt.state_bytes());
        // the 32-bit embedding group costs ~4x more bytes per param
        let emb = &reports[1];
        assert_eq!(emb.tensors, 2);
        assert!(emb.config.contains("32-bit"));
        let per_param_emb = emb.state_bytes as f64 / emb.params as f64;
        let per_param_def = reports[0].state_bytes as f64 / reports[0].params as f64;
        assert!(per_param_emb > 3.0 * per_param_def, "{per_param_emb} vs {per_param_def}");
    }

    #[test]
    fn bits4_group_resolution_and_reporting() {
        // a mixed 32/8/4 layout: embeddings at 32-bit, attention at 4-bit,
        // everything else at the 8-bit base
        let base = OptimConfig::adam(1e-3, Bits::b8_dynamic());
        let spec = OptimSpec::with_groups(
            base,
            vec![
                GroupOverride::emb32(),
                GroupOverride::parse("block?.attn.*:bits=4").unwrap(),
            ],
        );
        let popt = ParamOptimizer::build(spec, &lm_tensors(), None).unwrap();
        let wq = popt.find("block0.attn.wq").unwrap();
        assert_eq!(popt.tensor_cfg(wq).bits, Bits::b4_dynamic());
        assert_eq!(popt.group_of(wq), 2);
        let reports = popt.group_reports();
        assert_eq!(reports[0].bits, 8);
        assert_eq!(reports[1].bits, 32);
        assert_eq!(reports[2].bits, 4);
        // the 4-bit group pays about half a byte per param per state
        // (Adam: two states => ~1.0 B/param + absmax overhead)
        let q4 = &reports[2];
        assert!(q4.tensors > 0);
        assert!(
            q4.bytes_per_param() > 0.9 && q4.bytes_per_param() < 1.1,
            "{}",
            q4.bytes_per_param()
        );
        let q8 = &reports[0];
        assert!(q8.bytes_per_param() > 1.9 && q8.bytes_per_param() < 2.2);
        assert!(reports[1].bytes_per_param() > 7.9);
    }

    #[test]
    fn per_group_lr_scheduling() {
        let base = OptimConfig::adam(1e-3, Bits::B32);
        let spec = OptimSpec::with_groups(
            base,
            vec![GroupOverride::parse("lm_head:lr=0.01").unwrap()],
        );
        let mut popt = ParamOptimizer::build(spec, &lm_tensors(), None).unwrap();
        popt.schedule_lr(|b| b * 0.5);
        let head = popt.find("lm_head").unwrap();
        assert!((popt.opt(head).lr() - 0.005).abs() < 1e-9);
        let other = popt.find("embed.tok").unwrap();
        assert!((popt.opt(other).lr() - 0.0005).abs() < 1e-9);
    }

    #[test]
    fn step_native_matches_serial_per_tensor_stepping() {
        use crate::util::rng::Rng;
        let base = {
            let mut c = OptimConfig::adam(0.01, Bits::b8_dynamic());
            c.kind = OptimKind::AdamW;
            c.weight_decay = 0.01;
            c
        };
        let groups = vec![GroupOverride::emb32()];
        let tensors = lm_tensors();
        let mk_data = || {
            let mut rng = Rng::new(99);
            let params: Vec<Vec<f32>> = tensors
                .iter()
                .map(|t| (0..t.size).map(|_| rng.normal() as f32).collect())
                .collect();
            let grads: Vec<Vec<f32>> = tensors
                .iter()
                .map(|t| (0..t.size).map(|_| rng.normal() as f32 * 0.1).collect())
                .collect();
            (params, grads)
        };

        let spec = OptimSpec::with_groups(base, groups.clone());
        let mut popt = ParamOptimizer::build(spec, &tensors, None).unwrap();
        let (mut p_fused, grads) = mk_data();
        for _ in 0..3 {
            popt.step_native(&mut p_fused, &grads);
        }

        // serial reference: same resolution, tensor-by-tensor stepping
        let spec = OptimSpec::with_groups(base, groups);
        let (mut p_serial, _) = mk_data();
        let mut opts: Vec<Box<dyn Optimizer>> = tensors
            .iter()
            .map(|t| {
                let (cfg, _) = spec.resolve(&t.name);
                super::super::build(&cfg, t.size, t.shape)
            })
            .collect();
        for _ in 0..3 {
            for (i, opt) in opts.iter_mut().enumerate() {
                opt.step(&mut p_serial[i], &grads[i]);
            }
        }
        assert_eq!(p_fused, p_serial);
        for (i, opt) in opts.iter().enumerate() {
            for ((na, sa), (nb, sb)) in opt.states().iter().zip(popt.opt(i).states()) {
                assert_eq!(*na, nb);
                assert_eq!(sa.to_f32(), sb.to_f32());
            }
        }
    }
}
