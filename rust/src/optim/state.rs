//! Optimizer-state storage and the shared block-kernel engine.
//!
//! The paper's update (§2, Figure 1): dequantize the 8-bit state block to
//! 32-bit *in registers*, perform the update, requantize for storage. Here
//! a "register block" is a per-thread scratch `Vec<f32>` of one
//! quantization block; blocks are processed independently and in parallel,
//! mirroring the per-core independence that makes block-wise quantization
//! fast.
//!
//! The engine owns the whole dequantize → update → requantize dance: an
//! optimizer only supplies a [`BlockView`] kernel (its elementwise update
//! rule) to [`block_steps`]/[`step_blocks`]. The returned [`BlockSteps`]
//! decomposes one tensor's update into independent block tasks;
//! [`StepPlan`] strings such task sets into *phases* with deterministic
//! combines between barriers, which is how tensor-wide reductions (LAMB
//! trust ratios, Adafactor statistics, SM3 maxes) stay block-local. Plans
//! have three executors, all following the same canonical item/combine
//! order: immediately on the worker pool ([`StepPlan::execute`]), merged
//! phase-aligned with every other tensor's plan into one batch per phase
//! (`optim::engine::FusedStep`), or streamed — phase 0 starts the moment
//! the tensor's gradient exists, phases advance as their batches drain
//! (`optim::engine::StreamingStep`). Scratch buffers are thread-local and
//! shared by every optimizer and tensor, so the hot loop allocates
//! nothing.

use std::cell::RefCell;
use std::sync::Arc;

use crate::quant::blockwise::{dequantize_block_codes, quantize_block_codes};
use crate::quant::{CodeWidth, Codebook, Quantized};
use crate::util::lanes::{self, LANES};
use crate::util::parallel::{self, SendPtr};

/// How a state tensor is stored.
#[derive(Clone)]
pub enum StateTensor {
    /// Full-precision baseline (the 32-bit optimizers of Table 1).
    F32(Vec<f32>),
    /// Block-wise quantized (packed codes + per-block absmax); the code
    /// width (8-bit byte-per-code or 4-bit two-per-byte) travels with the
    /// buffer.
    Quant { q: Quantized, codebook: Arc<Codebook> },
}

impl StateTensor {
    pub fn new_f32(n: usize) -> StateTensor {
        StateTensor::F32(vec![0.0; n])
    }

    /// Byte-per-code quantized state (the paper's 8-bit layout).
    pub fn new_q8(n: usize, codebook: Arc<Codebook>, block: usize) -> StateTensor {
        Self::new_quant(n, codebook, block, CodeWidth::U8)
    }

    /// Width-generic quantized state.
    pub fn new_quant(
        n: usize,
        codebook: Arc<Codebook>,
        block: usize,
        width: CodeWidth,
    ) -> StateTensor {
        assert!(
            codebook.len() <= width.max_levels(),
            "codebook {} does not fit {:?} codes",
            codebook.name(),
            width
        );
        let zero = codebook.encode(0.0);
        StateTensor::Quant {
            q: Quantized::zeros(n, block.min(n.max(1)), zero, width),
            codebook,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            StateTensor::F32(v) => v.len(),
            StateTensor::Quant { q, .. } => q.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage footprint in bytes — the quantity Table 1/2 account for.
    pub fn bytes(&self) -> usize {
        match self {
            StateTensor::F32(v) => v.len() * 4,
            StateTensor::Quant { q, .. } => q.bytes(),
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, StateTensor::Quant { .. })
    }

    /// Code width of the stored state (32-bit states have none).
    pub fn code_width(&self) -> Option<CodeWidth> {
        match self {
            StateTensor::F32(_) => None,
            StateTensor::Quant { q, .. } => Some(q.width()),
        }
    }

    /// Overwrite the stored values from 32-bit working values: F32 states
    /// copy in place; quantized states requantize block by block through
    /// the public quantizer API. This is the checkpoint-restore mechanism,
    /// also reused for runtime width transitions — when `vals` came from
    /// [`StateTensor::to_f32`] of a same-width tensor the stored codes are
    /// bit-identical (the `idempotent_roundtrip` contract).
    pub fn load_f32(&mut self, vals: &[f32]) {
        match self {
            StateTensor::F32(v) => {
                assert_eq!(v.len(), vals.len(), "state length mismatch");
                v.copy_from_slice(vals);
            }
            StateTensor::Quant { q, codebook } => {
                assert_eq!(q.len, vals.len(), "state length mismatch");
                let bq = crate::quant::BlockQuantizer::with_width(
                    codebook.clone(),
                    q.block,
                    q.width(),
                );
                bq.quantize_into(vals, q);
            }
        }
    }

    /// Dequantize the whole tensor (for checkpoints / analysis).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            StateTensor::F32(v) => v.clone(),
            StateTensor::Quant { q, codebook } => {
                let mut out = vec![0.0f32; q.len];
                let width = q.width();
                let bytes = q.codes.as_bytes();
                for b in 0..q.n_blocks() {
                    let (lo, hi) = q.block_range(b);
                    let (blo, bhi) = q.code_byte_range(b);
                    dequantize_block_codes(
                        codebook,
                        width,
                        &bytes[blo..bhi],
                        q.absmax[b],
                        &mut out[lo..hi],
                    );
                }
                out
            }
        }
    }
}

/// One block's worth of optimizer-step inputs, with states already
/// dequantized to f32 working slices. For F32 states the slice *is* the
/// storage (updated in place); for quantized states (any code width) it is
/// thread-local scratch that the engine requantizes after the kernel
/// returns.
pub struct BlockView<'a> {
    /// Global element offset of this block.
    pub start: usize,
    pub params: &'a mut [f32],
    pub grads: &'a [f32],
    pub s1: &'a mut [f32],
    /// Second state (None for single-state optimizers like Momentum).
    pub s2: Option<&'a mut [f32]>,
}

/// One [`LANES`]-wide chunk of a block — the lane-chunked kernel entry
/// point (see `crate::util::lanes`). Fixed-size array references give the
/// optimizer's elementwise rule a fixed trip count the autovectorizer
/// lowers to SIMD; the rule's arithmetic must be the identical per-element
/// IEEE expression as its scalar [`BlockView`] kernel so both paths stay
/// bit-identical (the engine never reassociates and Rust never contracts
/// to FMA).
pub struct LaneView<'a> {
    /// Global element offset of this lane chunk.
    pub start: usize,
    pub params: &'a mut [f32; LANES],
    pub grads: &'a [f32; LANES],
    pub s1: &'a mut [f32; LANES],
    /// Second state (None for single-state optimizers like Momentum).
    pub s2: Option<&'a mut [f32; LANES]>,
}

/// Split one block into [`LANES`]-wide chunks for `lane` plus a scalar tail
/// for `scalar` (the whole block when `lanes::scalar_forced()` — the
/// oracle path). The scalar kernel receives a [`BlockView`] whose `start`
/// is offset past the lane main, so rules that use global indices keep
/// working.
pub fn run_lanes<L, S>(v: BlockView<'_>, lane: &L, scalar: &S)
where
    L: Fn(LaneView),
    S: Fn(BlockView),
{
    let BlockView { start, params, grads, s1, s2 } = v;
    let n = params.len();
    let main = if lanes::scalar_forced() { 0 } else { n - n % LANES };
    let (p_main, p_tail) = params.split_at_mut(main);
    let (g_main, g_tail) = grads.split_at(main);
    let (s1_main, s1_tail) = s1.split_at_mut(main);
    let (mut s2_main, mut s2_tail): (Option<&mut [f32]>, Option<&mut [f32]>) = (None, None);
    if let Some(s2) = s2 {
        let (a, b) = s2.split_at_mut(main);
        s2_main = Some(a);
        s2_tail = Some(b);
    }
    for c in 0..main / LANES {
        let off = c * LANES;
        lane(LaneView {
            start: start + off,
            params: <&mut [f32; LANES]>::try_from(&mut p_main[off..off + LANES]).unwrap(),
            grads: <&[f32; LANES]>::try_from(&g_main[off..off + LANES]).unwrap(),
            s1: <&mut [f32; LANES]>::try_from(&mut s1_main[off..off + LANES]).unwrap(),
            s2: s2_main
                .as_deref_mut()
                .map(|s| <&mut [f32; LANES]>::try_from(&mut s[off..off + LANES]).unwrap()),
        });
    }
    if !p_tail.is_empty() {
        scalar(BlockView {
            start: start + main,
            params: p_tail,
            grads: g_tail,
            s1: s1_tail,
            s2: s2_tail,
        });
    }
}

thread_local! {
    /// Per-thread dequantization scratch (one block per state), reused by
    /// every optimizer and tensor (§Perf: a Vec allocation per block
    /// dominated the fused loop before this).
    static SCRATCH: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Type-erased per-state storage pointers for the block runner. Safety
/// contract: block index `b` only touches its own elements' packed bytes
/// and `absmax[b]`, so distinct blocks are disjoint (4-bit packing keeps
/// this true because blocks start on byte boundaries — `Quantized`
/// enforces an even block size for multi-block `U4` tensors).
#[derive(Clone, Copy)]
enum StateParts<'a> {
    F32(SendPtr<f32>),
    Quant {
        bytes: SendPtr<u8>,
        width: CodeWidth,
        absmax: SendPtr<f32>,
        codebook: &'a Codebook,
    },
}

fn state_parts(s: &mut StateTensor, block: usize, n: usize) -> StateParts<'_> {
    match s {
        StateTensor::F32(v) => {
            assert_eq!(v.len(), n, "state length mismatch");
            StateParts::F32(SendPtr(v.as_mut_ptr()))
        }
        StateTensor::Quant { q, codebook } => {
            assert_eq!(q.block, block, "state block sizes must agree");
            assert_eq!(q.len, n, "state length mismatch");
            let width = q.width();
            // Re-check the packing invariant the parallel store relies on
            // (`Quantized::zeros` enforces it, but the fields are public):
            // multi-block U4 tensors need byte-aligned block starts.
            assert!(
                width == CodeWidth::U8 || block % 2 == 0 || n <= block,
                "4-bit packed state needs an even block size (got {block} for {n} elements)"
            );
            StateParts::Quant {
                bytes: SendPtr(q.codes.as_mut_bytes().as_mut_ptr()),
                width,
                absmax: SendPtr(q.absmax.as_mut_ptr()),
                codebook: &**codebook,
            }
        }
    }
}

/// A named storage region a phase item or combine may touch — the
/// vocabulary of [`AccessSet`] declarations. `Params`/`Grads`/`State1`/
/// `State2` are the tensors handed to `Optimizer::plan`; `Slot` names a
/// persistent scratch buffer by a stable id (e.g. `"stab.partials"`), so
/// the linter can track cross-phase data flow through reduction scratch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Region {
    Params,
    /// Gradients are read-only by contract; any declared write is rejected.
    Grads,
    State1,
    State2,
    /// A named shared scratch slot (stable id, unique per optimizer).
    Slot(&'static str),
}

/// A process-global telemetry counter a phase may increment. Rule (c) of
/// the plan linter demands every incremented counter have a registered
/// drain point (the trainer's JSONL step records), so a plan can't leak
/// counts silently into a later step's record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Counter {
    /// `quant::blockwise` non-finite-block sanitizer hits — bumped by any
    /// quantized-state store.
    NonfiniteBlocks,
    /// `optim::stability::CLIP_EVENTS` — percentile-clip activations.
    ClipEvents,
    /// `optim::stability::UNORM_CLIPS` — max_unorm activations.
    UnormClips,
}

/// Which element range of a [`Region`] each item of a phase touches — the
/// per-item footprint the linter intersects to prove disjointness.
#[derive(Clone, Copy, Debug)]
pub enum Span {
    /// Item `i` owns `[base + i*block, base + min((i+1)*block, n))`; items
    /// past `n` touch nothing. The shape of every block-partitioned
    /// footprint (quantization blocks, reduction chunks, partial slots).
    Blocked { base: usize, block: usize, n: usize },
    /// Every item touches the whole `[lo, hi)` — broadcast reads (a scale
    /// produced by an earlier combine) or a combine's whole fold input.
    All { lo: usize, hi: usize },
    /// Row items of `grid` own `[base + r0*stride, base + r1*stride)`
    /// (their row range scaled by `stride`); column items touch nothing.
    GridRows { grid: Grid, stride: usize, base: usize },
    /// Column items of `grid` own `[base + c0*stride, base + c1*stride)`;
    /// row items touch nothing.
    GridCols { grid: Grid, stride: usize, base: usize },
}

impl Span {
    /// Element interval item `i` touches, `None` if it touches nothing.
    pub fn item_range(&self, i: usize) -> Option<(usize, usize)> {
        match *self {
            Span::Blocked { base, block, n } => {
                if block == 0 {
                    return None;
                }
                let lo = i.checked_mul(block)?;
                if lo >= n {
                    return None;
                }
                Some((base + lo, base + (lo + block).min(n)))
            }
            Span::All { lo, hi } => (lo < hi).then_some((lo, hi)),
            Span::GridRows { grid, stride, base } => {
                let (r0, r1) = grid.row_range(i)?;
                Some((base + r0 * stride, base + r1 * stride))
            }
            Span::GridCols { grid, stride, base } => {
                if grid.row_range(i).is_some() {
                    return None;
                }
                let (c0, c1) = grid.col_range(i);
                (c0 < c1).then_some((base + c0 * stride, base + c1 * stride))
            }
        }
    }

    /// Whether this span partitions work over a factored [`Grid`] — the
    /// shape signature the capability linter cross-checks against
    /// `supports_sharding` (factored statistics are not
    /// element-proportional, hence unshardable).
    pub fn is_grid(&self) -> bool {
        matches!(self, Span::GridRows { .. } | Span::GridCols { .. })
    }
}

/// What a phase's combine (the post-barrier fold) touches. Combines run
/// exactly once, single-threaded, between phase barriers, so their
/// reads/writes need no disjointness — the linter instead checks that they
/// declare order-determinism (rule d): the fold must visit its per-item
/// partials in fixed index order (`util::reduce` primitives), never in
/// completion order.
#[derive(Clone, Debug, Default)]
pub struct CombineAccess {
    pub reads: Vec<(Region, Span)>,
    pub writes: Vec<(Region, Span)>,
    pub counters: Vec<Counter>,
    pub deterministic: bool,
}

impl CombineAccess {
    /// A combine that folds in fixed index order (the only kind the linter
    /// accepts).
    pub fn deterministic() -> CombineAccess {
        CombineAccess { deterministic: true, ..CombineAccess::default() }
    }

    pub fn read(mut self, region: Region, span: Span) -> Self {
        self.reads.push((region, span));
        self
    }

    pub fn write(mut self, region: Region, span: Span) -> Self {
        self.writes.push((region, span));
        self
    }

    pub fn counter(mut self, c: Counter) -> Self {
        self.counters.push(c);
        self
    }
}

/// Declared footprint of one phase: what its parallel items read and
/// write, which global counters they bump, what its combine touches, and
/// which regions hold state that is already initialized when the plan
/// starts (`presets` — persistent moments, rolling histories, scratch
/// carried across steps). [`block_steps`] derives the declaration
/// automatically for plain block-partitioned phases; hand-built phases
/// declare theirs via [`Phase::with_access`] / [`Phase::map_access`].
/// `analysis::plan_lint` statically verifies the declared sets.
#[derive(Clone, Debug, Default)]
pub struct AccessSet {
    pub reads: Vec<(Region, Span)>,
    pub writes: Vec<(Region, Span)>,
    pub counters: Vec<Counter>,
    pub combine: Option<CombineAccess>,
    pub presets: Vec<Region>,
}

impl AccessSet {
    pub fn new() -> AccessSet {
        AccessSet::default()
    }

    pub fn read(mut self, region: Region, span: Span) -> Self {
        self.reads.push((region, span));
        self
    }

    pub fn write(mut self, region: Region, span: Span) -> Self {
        self.writes.push((region, span));
        self
    }

    /// Read-modify-write: the item reads and writes the same range.
    pub fn rmw(self, region: Region, span: Span) -> Self {
        self.read(region, span).write(region, span)
    }

    pub fn counter(mut self, c: Counter) -> Self {
        self.counters.push(c);
        self
    }

    /// Declare `region` initialized before the plan runs (persistent
    /// optimizer state carried across steps).
    pub fn preset(mut self, region: Region) -> Self {
        self.presets.push(region);
        self
    }

    pub fn combine(mut self, c: CombineAccess) -> Self {
        self.combine = Some(c);
        self
    }

    /// Re-label a region: [`block_steps`] describes its slots positionally
    /// (params/grads/state), but optimizers sometimes lend those slots to
    /// other buffers (LAMB runs its update vector through the params
    /// slot); the declaration then renames the slot to the buffer it
    /// really is.
    pub fn relabel(mut self, from: Region, to: Region) -> Self {
        for (r, _) in self.reads.iter_mut().chain(self.writes.iter_mut()) {
            if *r == from {
                *r = to;
            }
        }
        self
    }

    /// Rule (a): some two distinct items of this phase write overlapping
    /// elements of the same region. Returns the first offending region.
    pub fn item_write_conflict(&self, n_items: usize) -> Option<Region> {
        for region in regions_of(&self.writes) {
            let writes = spans_for(&self.writes, region);
            if sweep_overlap(&writes, &[], n_items) {
                return Some(region);
            }
        }
        None
    }

    /// Rule (b), same-phase half: an item reads elements another item of
    /// the same phase writes — a race, because items of one phase are
    /// unordered. Same-item read+write (RMW) is legal.
    pub fn item_read_write_race(&self, n_items: usize) -> Option<Region> {
        for region in regions_of(&self.writes) {
            let reads = spans_for(&self.reads, region);
            if reads.is_empty() {
                continue;
            }
            let writes = spans_for(&self.writes, region);
            if sweep_overlap(&writes, &reads, n_items) {
                return Some(region);
            }
        }
        None
    }

    /// Any declared write (items or combine) to the read-only gradients.
    pub fn writes_grads(&self) -> bool {
        self.writes.iter().any(|(r, _)| *r == Region::Grads)
            || self
                .combine
                .as_ref()
                .is_some_and(|c| c.writes.iter().any(|(r, _)| *r == Region::Grads))
    }

    /// Every counter this phase increments (items plus combine).
    pub fn all_counters(&self) -> Vec<Counter> {
        let mut out = self.counters.clone();
        if let Some(c) = &self.combine {
            out.extend(c.counters.iter().copied());
        }
        out
    }
}

/// Distinct regions named by an access list, in first-seen order.
fn regions_of(list: &[(Region, Span)]) -> Vec<Region> {
    let mut out: Vec<Region> = Vec::new();
    for (r, _) in list {
        if !out.contains(r) {
            out.push(*r);
        }
    }
    out
}

fn spans_for(list: &[(Region, Span)], region: Region) -> Vec<Span> {
    list.iter().filter(|(r, _)| *r == region).map(|(_, s)| *s).collect()
}

/// Furthest-open-interval tracker for the overlap sweeps: remembers the
/// two largest interval ends seen so far that belong to *distinct* items —
/// enough to answer "is any interval of an item other than `it` still open
/// at position `s`" during a start-sorted scan.
#[derive(Default)]
struct TopTwo {
    /// `(end, item)` with the furthest end overall.
    top: Option<(usize, usize)>,
    /// Furthest end among items different from `top`'s item.
    second: Option<(usize, usize)>,
}

impl TopTwo {
    fn other_end(&self, it: usize) -> Option<usize> {
        match self.top {
            Some((end, item)) if item != it => Some(end),
            _ => self.second.map(|(end, _)| end),
        }
    }

    fn add(&mut self, e: usize, it: usize) {
        match self.top {
            None => self.top = Some((e, it)),
            Some((end, item)) if item == it => {
                if e > end {
                    self.top = Some((e, it));
                }
            }
            Some((end, _)) if e > end => {
                self.second = self.top.take();
                self.top = Some((e, it));
            }
            _ => match self.second {
                Some((e2, _)) if e <= e2 => {}
                _ => self.second = Some((e, it)),
            },
        }
    }
}

/// Whether any write interval of one item overlaps a write (or, when
/// `reads` is non-empty, a read) interval of a *different* item. A
/// start-sorted sweep over the materialized per-item intervals; same-item
/// overlap (RMW, repeated declarations) never counts.
fn sweep_overlap(writes: &[Span], reads: &[Span], n_items: usize) -> bool {
    // (start, end, item, is_write)
    let mut events: Vec<(usize, usize, usize, bool)> = Vec::new();
    for i in 0..n_items {
        for s in writes {
            if let Some((lo, hi)) = s.item_range(i) {
                events.push((lo, hi, i, true));
            }
        }
        for s in reads {
            if let Some((lo, hi)) = s.item_range(i) {
                events.push((lo, hi, i, false));
            }
        }
    }
    events.sort_unstable();
    let check_writes_vs_writes = reads.is_empty();
    let mut open_w = TopTwo::default();
    let mut open_r = TopTwo::default();
    for (s, e, it, is_write) in events {
        if is_write {
            if check_writes_vs_writes && open_w.other_end(it).is_some_and(|end| s < end) {
                return true;
            }
            if open_r.other_end(it).is_some_and(|end| s < end) {
                return true;
            }
            open_w.add(e, it);
        } else {
            if open_w.other_end(it).is_some_and(|end| s < end) {
                return true;
            }
            open_r.add(e, it);
        }
    }
    false
}

/// One tensor's decomposed update: `n_blocks` independent block tasks that
/// the pool — or the fused multi-tensor engine — may run in any order, on
/// any thread, each exactly once per step. Results are bit-identical at
/// every schedule because blocks share no mutable state and in-block
/// element order is fixed.
pub struct BlockSteps<'a> {
    n_blocks: usize,
    run: Box<dyn Fn(usize) + Sync + Send + 'a>,
    access: Option<AccessSet>,
}

impl<'a> BlockSteps<'a> {
    /// Wrap an arbitrary set of `n` independent, disjoint work items as
    /// block tasks — for phase items that are not quantization blocks
    /// (reduction partials, row/column statistic chunks).
    pub fn from_fn<F>(n: usize, f: F) -> BlockSteps<'a>
    where
        F: Fn(usize) + Sync + Send + 'a,
    {
        BlockSteps { n_blocks: n, run: Box::new(f), access: None }
    }

    /// Attach (or replace) the declared access set.
    pub fn with_access(mut self, access: AccessSet) -> Self {
        self.access = Some(access);
        self
    }

    pub fn access(&self) -> Option<&AccessSet> {
        self.access.as_ref()
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Run one block. Callable concurrently for distinct `b`; calling the
    /// same `b` twice within one step is a logic error (it would re-apply
    /// the update).
    pub fn run_block(&self, b: usize) {
        debug_assert!(b < self.n_blocks);
        (self.run)(b)
    }

    /// Run every block of this tensor on the worker pool (the single-tensor
    /// step path).
    pub fn execute(self) {
        parallel::run_indexed(self.n_blocks, |b| self.run_block(b));
    }
}

/// One phase of a [`StepPlan`]: a set of independent parallel items plus an
/// optional `combine` that runs *after every item of this phase across all
/// fused tensors* has completed (the engine's barrier) and before any item
/// of the next phase starts. The combine folds per-item partials in fixed
/// order, so reductions stay deterministic at every thread count.
pub struct Phase<'a> {
    items: BlockSteps<'a>,
    combine: Option<Box<dyn FnOnce() + Send + Sync + 'a>>,
    access: Option<AccessSet>,
}

impl<'a> Phase<'a> {
    pub fn new(mut items: BlockSteps<'a>) -> Phase<'a> {
        let access = items.access.take();
        Phase { items, combine: None, access }
    }

    pub fn with_combine<F>(mut items: BlockSteps<'a>, combine: F) -> Phase<'a>
    where
        F: FnOnce() + Send + Sync + 'a,
    {
        let access = items.access.take();
        Phase { items, combine: Some(Box::new(combine)), access }
    }

    /// Replace the declared access set wholesale.
    pub fn with_access(mut self, access: AccessSet) -> Self {
        self.access = Some(access);
        self
    }

    /// Refine the inherited declaration — e.g. add the broadcast read of a
    /// combine-produced scale to a phase whose base declaration was
    /// auto-derived by [`block_steps`].
    pub fn map_access<F>(mut self, f: F) -> Self
    where
        F: FnOnce(AccessSet) -> AccessSet,
    {
        self.access = Some(f(self.access.take().unwrap_or_default()));
        self
    }

    pub fn access(&self) -> Option<&AccessSet> {
        self.access.as_ref()
    }

    pub fn has_combine(&self) -> bool {
        self.combine.is_some()
    }

    pub fn n_items(&self) -> usize {
        self.items.n_blocks()
    }
}

/// One tensor's full update as a sequence of phases — the decomposed form
/// every optimizer hands to the engine. Single-pass optimizers (Adam,
/// Momentum, AdaGrad, 1-D SM3) have one phase and no combine; the
/// reduction-bearing optimizers (LARS, LAMB, Adafactor, factored SM3) put
/// per-block partials in early phases, fold them in combines, and finish
/// with the block-local apply.
///
/// Execution contract: within a phase, items may run in any order on any
/// thread (they are disjoint); phases are separated by a barrier; combines
/// run exactly once between the barriers. Both the serial path
/// ([`StepPlan::execute`]) and the fused multi-tensor engine
/// (`optim::engine::FusedStep`) follow this same canonical order, which is
/// why they are bit-identical.
#[derive(Default)]
pub struct StepPlan<'a> {
    phases: Vec<Phase<'a>>,
}

impl<'a> StepPlan<'a> {
    pub fn new() -> StepPlan<'a> {
        StepPlan { phases: Vec::new() }
    }

    /// The common single-phase plan (block-local optimizers).
    pub fn single(items: BlockSteps<'a>) -> StepPlan<'a> {
        let mut plan = StepPlan::new();
        plan.push(Phase::new(items));
        plan
    }

    /// Append a phase, `debug_assert!`-validating its declared access set
    /// at construction time: rule (a) item-write disjointness, the
    /// read-only gradient contract, and combine-declaration consistency.
    /// Phases without a declaration pass through (the strict check — every
    /// phase must declare — lives in `analysis::plan_lint`). Use
    /// [`StepPlan::push_unchecked`] to build deliberately malformed plans
    /// for linter tests.
    pub fn push(&mut self, phase: Phase<'a>) {
        if cfg!(debug_assertions) {
            if let Some(access) = phase.access() {
                let n = phase.n_items();
                debug_assert!(
                    access.item_write_conflict(n).is_none(),
                    "phase declares overlapping item writes to {:?}",
                    access.item_write_conflict(n)
                );
                debug_assert!(!access.writes_grads(), "phase declares a write to Grads");
                debug_assert_eq!(
                    access.combine.is_some(),
                    phase.has_combine(),
                    "combine closure and combine access declaration must agree"
                );
            }
        }
        self.phases.push(phase);
    }

    /// [`StepPlan::push`] without construction-time validation — for
    /// negative linter tests that need a malformed plan to exist.
    pub fn push_unchecked(&mut self, phase: Phase<'a>) {
        self.phases.push(phase);
    }

    pub fn n_phases(&self) -> usize {
        self.phases.len()
    }

    /// Declared access set of phase `k` — the plan linter's input.
    pub fn phase_access(&self, k: usize) -> Option<&AccessSet> {
        self.phases.get(k).and_then(|p| p.access())
    }

    /// Whether phase `k` carries a (not yet taken) combine closure.
    pub fn phase_has_combine(&self, k: usize) -> bool {
        self.phases.get(k).is_some_and(|p| p.combine.is_some())
    }

    /// Item count of phase `k` (0 past the last phase, so the fused engine
    /// can iterate to the max phase count over all tensors).
    pub fn phase_items(&self, k: usize) -> usize {
        self.phases.get(k).map_or(0, |p| p.n_items())
    }

    /// Total work items across all phases.
    pub fn n_items(&self) -> usize {
        self.phases.iter().map(|p| p.n_items()).sum()
    }

    /// Run one item of phase `k`. Callable concurrently for distinct `i`;
    /// the caller must respect the phase barrier and run each item exactly
    /// once.
    pub fn run_item(&self, k: usize, i: usize) {
        self.phases[k].items.run_block(i);
    }

    /// Take phase `k`'s combine (the engine runs it after the phase-`k`
    /// barrier). `None` if the phase has no combine or it was taken.
    pub fn take_combine(&mut self, k: usize) -> Option<Box<dyn FnOnce() + Send + Sync + 'a>> {
        self.phases.get_mut(k).and_then(|p| p.combine.take())
    }

    /// Execute the whole plan on the worker pool, phase by phase — the
    /// single-tensor `Optimizer::step` path. Canonical order: phase items
    /// (parallel), then the phase's combine, then the next phase.
    pub fn execute(self) {
        for phase in self.phases {
            phase.items.execute();
            if let Some(combine) = phase.combine {
                combine();
            }
        }
    }
}

/// Tiling of a (rows × cols) tensor into single-writer phase items for the
/// factored optimizers (Adafactor, SM3): `n_row_items` items each owning a
/// contiguous range of whole rows, then `n_col_items` items each owning a
/// range of whole columns — so every row/col statistic slot has exactly
/// one writer and no cross-item scratch is needed. Items are sized to
/// ~one reduction chunk of elements each.
#[derive(Clone, Copy, Debug)]
pub struct Grid {
    rows: usize,
    cols: usize,
    rpi: usize,
    cpi: usize,
    n_row_items: usize,
}

impl Grid {
    pub fn new(rows: usize, cols: usize) -> Grid {
        let rpi = (crate::util::reduce::CHUNK / cols).max(1);
        let cpi = (crate::util::reduce::CHUNK / rows).max(1);
        Grid { rows, cols, rpi, cpi, n_row_items: rows.div_ceil(rpi) }
    }

    pub fn n_items(&self) -> usize {
        self.n_row_items + self.cols.div_ceil(self.cpi)
    }

    /// `Some((r0, r1))` when item `it` is a row item, else `None` (use
    /// [`Grid::col_range`]).
    pub fn row_range(&self, it: usize) -> Option<(usize, usize)> {
        if it < self.n_row_items {
            let r0 = it * self.rpi;
            Some((r0, (r0 + self.rpi).min(self.rows)))
        } else {
            None
        }
    }

    /// Column range of a non-row item.
    pub fn col_range(&self, it: usize) -> (usize, usize) {
        let c0 = (it - self.n_row_items) * self.cpi;
        (c0, (c0 + self.cpi).min(self.cols))
    }
}

/// Decompose one optimizer update into block tasks. The engine owns block
/// partitioning (taken from the quantized state's block size, or
/// `fallback_block` if all states are F32), state dequantization into
/// thread-local scratch, the kernel call, and requantization.
pub fn block_steps<'a, F>(
    params: &'a mut [f32],
    grads: &'a [f32],
    s1: &'a mut StateTensor,
    s2: Option<&'a mut StateTensor>,
    fallback_block: usize,
    kernel: F,
) -> BlockSteps<'a>
where
    F: Fn(BlockView) + Sync + Send + 'a,
{
    let n = params.len();
    assert_eq!(grads.len(), n);
    assert_eq!(s1.len(), n);
    if let Some(ref s) = s2 {
        assert_eq!(s.len(), n);
    }
    let block = match (&*s1, s2.as_deref()) {
        (StateTensor::Quant { q, .. }, _) => q.block,
        (_, Some(StateTensor::Quant { q, .. })) => q.block,
        _ => fallback_block.min(n.max(1)),
    };
    let n_blocks = n.div_ceil(block);
    let quantized = s1.is_quantized() || s2.as_deref().is_some_and(StateTensor::is_quantized);
    let two_state = s2.is_some();
    let p1 = state_parts(s1, block, n);
    let p2 = s2.map(|s| state_parts(s, block, n));
    let params_ptr = SendPtr(params.as_mut_ptr());

    let run = move |b: usize| {
        let lo = b * block;
        let hi = (lo + block).min(n);
        let len = hi - lo;
        // SAFETY: distinct blocks cover disjoint ranges of every tensor,
        // and the scheduler runs each block exactly once per step while
        // the borrows captured by this closure are alive.
        let params_b = unsafe { std::slice::from_raw_parts_mut(params_ptr.0.add(lo), len) };
        let grads_b = &grads[lo..hi];
        SCRATCH.with(|cell| {
            let scratch = &mut *cell.borrow_mut();
            let (scratch1, scratch2) = (&mut scratch.0, &mut scratch.1);
            // Load: F32 state hands out its storage (in-place update);
            // quantized states dequantize their packed bytes into this
            // thread's scratch. `width.bytes_for` maps element offsets to
            // byte offsets — exact because blocks start at even elements.
            let s1_work: &mut [f32] = match p1 {
                StateParts::F32(ptr) => unsafe {
                    std::slice::from_raw_parts_mut(ptr.0.add(lo), len)
                },
                StateParts::Quant { bytes, width, absmax, codebook } => {
                    let bytes_b = unsafe {
                        std::slice::from_raw_parts(
                            bytes.0.add(width.bytes_for(lo)),
                            width.bytes_for(len),
                        )
                    };
                    let am = unsafe { *absmax.0.add(b) };
                    scratch1.resize(len, 0.0);
                    dequantize_block_codes(codebook, width, bytes_b, am, scratch1);
                    scratch1
                }
            };
            let s2_work: Option<&mut [f32]> = match p2 {
                None => None,
                Some(StateParts::F32(ptr)) => {
                    Some(unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo), len) })
                }
                Some(StateParts::Quant { bytes, width, absmax, codebook }) => {
                    let bytes_b = unsafe {
                        std::slice::from_raw_parts(
                            bytes.0.add(width.bytes_for(lo)),
                            width.bytes_for(len),
                        )
                    };
                    let am = unsafe { *absmax.0.add(b) };
                    scratch2.resize(len, 0.0);
                    dequantize_block_codes(codebook, width, bytes_b, am, scratch2);
                    Some(scratch2)
                }
            };

            kernel(BlockView {
                start: lo,
                params: params_b,
                grads: grads_b,
                s1: s1_work,
                s2: s2_work,
            });

            // Store: requantize quantized states from scratch (Figure 1 —
            // the update itself ran on the in-register values); F32 states
            // were updated in place.
            if let StateParts::Quant { bytes, width, absmax, codebook } = p1 {
                let bytes_b = unsafe {
                    std::slice::from_raw_parts_mut(
                        bytes.0.add(width.bytes_for(lo)),
                        width.bytes_for(len),
                    )
                };
                let am = unsafe { &mut *absmax.0.add(b) };
                *am = quantize_block_codes(codebook, width, &scratch1[..len], bytes_b);
            }
            if let Some(StateParts::Quant { bytes, width, absmax, codebook }) = p2 {
                let bytes_b = unsafe {
                    std::slice::from_raw_parts_mut(
                        bytes.0.add(width.bytes_for(lo)),
                        width.bytes_for(len),
                    )
                };
                let am = unsafe { &mut *absmax.0.add(b) };
                *am = quantize_block_codes(codebook, width, &scratch2[..len], bytes_b);
            }
        });
    };

    // Auto-derived access declaration: block `b` owns element range
    // `[b*block, min((b+1)*block, n))` of every slot it touches, and any
    // quantized store may bump the non-finite-block sanitizer counter.
    let span = Span::Blocked { base: 0, block, n };
    let mut access = AccessSet::new()
        .rmw(Region::Params, span)
        .read(Region::Grads, span)
        .rmw(Region::State1, span);
    if two_state {
        access = access.rmw(Region::State2, span);
    }
    if quantized {
        access = access.counter(Counter::NonfiniteBlocks);
    }

    BlockSteps { n_blocks, run: Box::new(run), access: Some(access) }
}

/// Lane-chunked variant of [`block_steps`]: the optimizer supplies its
/// elementwise rule twice — a [`LaneView`] kernel (fixed-width chunks the
/// autovectorizer lowers) and the scalar [`BlockView`] kernel that remains
/// the tail-and-oracle path. Both must compute the identical per-element
/// update; `rust/tests/simd_parity.rs` and the `pool_parity`
/// scalar-vs-lane fleets enforce the resulting bit-identity.
///
/// To vectorize a new optimizer: keep its scalar closure as-is, add a lane
/// closure that applies the same rule with `for l in 0..LANES` over the
/// array views, and switch its `plan()` from `block_steps` to this.
pub fn block_steps_vec<'a, L, S>(
    params: &'a mut [f32],
    grads: &'a [f32],
    s1: &'a mut StateTensor,
    s2: Option<&'a mut StateTensor>,
    fallback_block: usize,
    lane: L,
    scalar: S,
) -> BlockSteps<'a>
where
    L: Fn(LaneView) + Sync + Send + 'a,
    S: Fn(BlockView) + Sync + Send + 'a,
{
    block_steps(params, grads, s1, s2, fallback_block, move |v: BlockView| {
        run_lanes(v, &lane, &scalar)
    })
}

/// Run a block kernel over (params, grads, state1[, state2]) immediately,
/// in parallel on the pool — the single-tensor convenience over
/// [`block_steps`].
pub fn step_blocks<'a, F>(
    params: &'a mut [f32],
    grads: &'a [f32],
    s1: &'a mut StateTensor,
    s2: Option<&'a mut StateTensor>,
    fallback_block: usize,
    kernel: F,
) where
    F: Fn(BlockView) + Sync + Send + 'a,
{
    block_steps(params, grads, s1, s2, fallback_block, kernel).execute()
}

#[cfg(test)]
mod tests {
    use std::cell::RefCell;

    use super::*;
    use crate::quant::dynamic_tree::dynamic_signed;
    use crate::util::rng::Rng;

    #[test]
    fn f32_state_update_is_in_place() {
        let mut s = StateTensor::new_f32(10);
        if let StateTensor::F32(v) = &mut s {
            v[3] = 5.0;
        }
        let mut params = vec![0.0f32; 10];
        let grads = vec![0.0f32; 10];
        step_blocks(&mut params, &grads, &mut s, None, 4, |v| {
            for x in v.s1.iter_mut() {
                *x += 1.0;
            }
        });
        assert_eq!(s.to_f32()[3], 6.0);
        assert_eq!(s.to_f32()[0], 1.0);
    }

    #[test]
    fn q8_state_roundtrips_through_block_update() {
        let cb = Arc::new(dynamic_signed());
        let n = 5000;
        let mut s = StateTensor::new_q8(n, cb, 512);
        let mut params = vec![0.0f32; n];
        let grads: Vec<f32> = {
            let mut rng = Rng::new(5);
            (0..n).map(|_| rng.normal() as f32 * 0.01).collect()
        };
        // write grads into state through the block engine (the engine
        // requantizes the worked slice after the kernel returns)
        step_blocks(&mut params, &grads, &mut s, None, 512, |v| {
            v.s1.copy_from_slice(v.grads);
        });
        let back = s.to_f32();
        // round-trip error bounded by dynamic-tree precision: worst-case
        // relative error at a decade's bottom edge is ~0.45/(0.1*2^f) ≈ 30%
        for (a, b) in grads.iter().zip(&back) {
            assert!((a - b).abs() <= 0.35 * a.abs() + 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn bytes_accounting() {
        let cb = Arc::new(dynamic_signed());
        let s32 = StateTensor::new_f32(2048 * 4);
        let s8 = StateTensor::new_q8(2048 * 4, cb, 2048);
        assert_eq!(s32.bytes(), 2048 * 4 * 4);
        assert_eq!(s8.bytes(), 2048 * 4 + 4 * 4);
        let cb4 = Arc::new(crate::quant::dynamic_tree::dynamic_signed4());
        let s4 = StateTensor::new_quant(2048 * 4, cb4, 2048, CodeWidth::U4);
        assert_eq!(s4.bytes(), 2048 * 2 + 4 * 4);
        assert_eq!(s4.code_width(), Some(CodeWidth::U4));
        assert_eq!(s32.code_width(), None);
    }

    #[test]
    fn q4_state_roundtrips_match_quantizer_reference() {
        // the engine's packed store path must agree bit-for-bit with the
        // public quantizer API (including the ragged odd tail block)
        use crate::quant::BlockQuantizer;
        let cb = Arc::new(crate::quant::dynamic_tree::dynamic_signed4());
        let n = 5 * 512 + 301; // ragged, odd-length tail
        let mut s = StateTensor::new_quant(n, cb.clone(), 512, CodeWidth::U4);
        let mut params = vec![0.0f32; n];
        let grads: Vec<f32> = {
            let mut rng = Rng::new(11);
            (0..n).map(|_| rng.normal() as f32 * 0.01).collect()
        };
        step_blocks(&mut params, &grads, &mut s, None, 512, |v| {
            v.s1.copy_from_slice(v.grads);
        });
        let bq = BlockQuantizer::with_width(cb, 512, CodeWidth::U4);
        let reference = bq.dequantize(&bq.quantize(&grads));
        assert_eq!(s.to_f32(), reference);
    }

    #[test]
    fn mixed_width_states_in_one_tensor() {
        // a 4-bit first state alongside an 8-bit second state: widths are
        // per-buffer, only block sizes must agree
        let cb4 = Arc::new(crate::quant::dynamic_tree::dynamic_signed4());
        let cb8 = Arc::new(dynamic_signed());
        let n = 700;
        let mut s1 = StateTensor::new_quant(n, cb4, 256, CodeWidth::U4);
        let mut s2 = StateTensor::new_q8(n, cb8, 256);
        let mut params = vec![0.0f32; n];
        let grads: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
        step_blocks(&mut params, &grads, &mut s1, Some(&mut s2), 256, |v| {
            let s2 = v.s2.expect("two states");
            for i in 0..v.params.len() {
                v.s1[i] = v.grads[i];
                s2[i] = -v.grads[i];
            }
        });
        let a = s1.to_f32();
        let b = s2.to_f32();
        for i in 0..n {
            let g = grads[i];
            // 4-bit is coarse (16 levels) but must keep the sign and rough
            // magnitude; 8-bit stays at its usual tolerance
            assert!((a[i] - g).abs() <= 0.6 * g.abs() + 2e-3, "s1[{i}] {} vs {g}", a[i]);
            assert!((b[i] + g).abs() <= 0.35 * g.abs() + 1e-3, "s2[{i}] {} vs {}", b[i], -g);
        }
    }

    #[test]
    fn run_lanes_partitions_block_into_chunks_and_tail() {
        // every element visited exactly once, lane chunks LANES-aligned,
        // the scalar tail shorter than LANES with the right start offset
        for n in [1usize, 7, 8, 9, 16, 23, 300] {
            let mut params = vec![0.0f32; n];
            let grads = vec![0.0f32; n];
            let mut s1 = vec![0.0f32; n];
            let mut s2 = vec![0.0f32; n];
            let seen = RefCell::new(vec![0u32; n]);
            run_lanes(
                BlockView {
                    start: 0,
                    params: &mut params,
                    grads: &grads,
                    s1: &mut s1,
                    s2: Some(&mut s2),
                },
                &|v: LaneView| {
                    assert_eq!(v.start % LANES, 0);
                    assert!(v.s2.is_some());
                    let mut guard = seen.borrow_mut();
                    for l in 0..LANES {
                        guard[v.start + l] += 1;
                    }
                },
                &|v: BlockView| {
                    assert!(v.params.len() < LANES, "tail must be shorter than LANES");
                    assert_eq!(v.start, n - n % LANES);
                    let mut guard = seen.borrow_mut();
                    for i in 0..v.params.len() {
                        guard[v.start + i] += 1;
                    }
                },
            );
            assert!(seen.into_inner().iter().all(|&c| c == 1), "n={n}");
        }
    }

    #[test]
    fn run_lanes_forced_scalar_routes_whole_block_to_scalar() {
        let n = 64;
        let mut params = vec![0.0f32; n];
        let grads = vec![0.0f32; n];
        let mut s1 = vec![0.0f32; n];
        let hits = RefCell::new((0usize, 0usize));
        crate::util::lanes::with_forced_scalar(|| {
            run_lanes(
                BlockView { start: 0, params: &mut params, grads: &grads, s1: &mut s1, s2: None },
                &|_: LaneView| hits.borrow_mut().0 += 1,
                &|v: BlockView| {
                    assert_eq!(v.params.len(), n);
                    hits.borrow_mut().1 += 1;
                },
            );
        });
        assert_eq!(hits.into_inner(), (0, 1));
    }

    #[test]
    fn block_steps_vec_matches_block_steps_bitwise() {
        // a lane rule that repeats the scalar arithmetic must give a
        // bit-identical trajectory through the quantized engine
        let n = 5 * 256 + 37;
        let cb = Arc::new(dynamic_signed());
        let grads: Vec<f32> = {
            let mut rng = Rng::new(21);
            (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
        };
        let rule = |p: &mut f32, g: f32, m: &mut f32| {
            *m = 0.9 * *m + g;
            *p -= 0.1 * *m;
        };
        let run_vec = || -> (Vec<f32>, Vec<f32>) {
            let mut s = StateTensor::new_q8(n, cb.clone(), 256);
            let mut params = vec![1.0f32; n];
            for _ in 0..3 {
                block_steps_vec(
                    &mut params,
                    &grads,
                    &mut s,
                    None,
                    256,
                    move |v: LaneView| {
                        for l in 0..LANES {
                            rule(&mut v.params[l], v.grads[l], &mut v.s1[l]);
                        }
                    },
                    move |v: BlockView| {
                        for i in 0..v.params.len() {
                            rule(&mut v.params[i], v.grads[i], &mut v.s1[i]);
                        }
                    },
                )
                .execute();
            }
            (params, s.to_f32())
        };
        let run_scalar = || -> (Vec<f32>, Vec<f32>) {
            let mut s = StateTensor::new_q8(n, cb.clone(), 256);
            let mut params = vec![1.0f32; n];
            for _ in 0..3 {
                block_steps(&mut params, &grads, &mut s, None, 256, move |v: BlockView| {
                    for i in 0..v.params.len() {
                        rule(&mut v.params[i], v.grads[i], &mut v.s1[i]);
                    }
                })
                .execute();
            }
            (params, s.to_f32())
        };
        let (p_vec, s_vec) = run_vec();
        let (p_scalar, s_scalar) =
            crate::util::lanes::with_forced_scalar(run_scalar);
        assert_eq!(p_vec, p_scalar);
        assert_eq!(s_vec, s_scalar);
    }

    #[test]
    fn block_starts_cover_tensor() {
        let mut s = StateTensor::new_f32(1000);
        let mut params = vec![0.0f32; 1000];
        let grads = vec![0.0f32; 1000];
        let seen = std::sync::Mutex::new(vec![false; 1000]);
        step_blocks(&mut params, &grads, &mut s, None, 300, |v| {
            let mut guard = seen.lock().unwrap();
            for i in 0..v.params.len() {
                assert!(!guard[v.start + i]);
                guard[v.start + i] = true;
            }
        });
        assert!(seen.into_inner().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn deferred_block_steps_run_out_of_order() {
        // The fused engine may interleave blocks arbitrarily; running them
        // manually in reverse must produce the same result as execute().
        let n = 1024;
        let cb = Arc::new(dynamic_signed());
        let grads: Vec<f32> = {
            let mut rng = Rng::new(9);
            (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
        };
        let run = |reverse: bool| -> (Vec<f32>, Vec<f32>) {
            let mut s = StateTensor::new_q8(n, cb.clone(), 256);
            let mut params = vec![1.0f32; n];
            let steps = block_steps(&mut params, &grads, &mut s, None, 256, |v| {
                for i in 0..v.params.len() {
                    v.s1[i] = 0.9 * v.s1[i] + v.grads[i];
                    v.params[i] -= 0.1 * v.s1[i];
                }
            });
            assert_eq!(steps.n_blocks(), 4);
            if reverse {
                for b in (0..steps.n_blocks()).rev() {
                    steps.run_block(b);
                }
                drop(steps); // release the borrows before reading results
            } else {
                steps.execute();
            }
            (params, s.to_f32())
        };
        let (p_fwd, s_fwd) = run(false);
        let (p_rev, s_rev) = run(true);
        assert_eq!(p_fwd, p_rev);
        assert_eq!(s_fwd, s_rev);
    }

    #[test]
    fn block_steps_derives_its_access_set() {
        let cb = Arc::new(dynamic_signed());
        let n = 700;
        let mut s = StateTensor::new_q8(n, cb, 256);
        let mut params = vec![0.0f32; n];
        let grads = vec![0.0f32; n];
        let steps = block_steps(&mut params, &grads, &mut s, None, 256, |_| {});
        let access = steps.access().expect("block_steps declares its access");
        assert!(access.counters.contains(&Counter::NonfiniteBlocks));
        assert!(!access.writes_grads());
        assert!(access.item_write_conflict(steps.n_blocks()).is_none());
        assert!(access.item_read_write_race(steps.n_blocks()).is_none());
        drop(steps);
        // an F32 state derives the same spans but no quantizer counter
        let mut s32 = StateTensor::new_f32(n);
        let steps = block_steps(&mut params, &grads, &mut s32, None, 256, |_| {});
        assert!(steps.access().expect("declared").all_counters().is_empty());
    }

    #[test]
    fn span_item_ranges_partition_blocked_and_grid() {
        let span = Span::Blocked { base: 10, block: 256, n: 700 };
        assert_eq!(span.item_range(0), Some((10, 266)));
        assert_eq!(span.item_range(2), Some((522, 710)));
        assert_eq!(span.item_range(3), None);
        let grid = Grid::new(8, 8);
        let rows = Span::GridRows { grid, stride: 8, base: 0 };
        let cols = Span::GridCols { grid, stride: 1, base: 0 };
        // a 8x8 grid fits one row item and one col item at CHUNK = 2048
        assert_eq!(grid.n_items(), 2);
        assert_eq!(rows.item_range(0), Some((0, 64)));
        assert_eq!(rows.item_range(1), None);
        assert_eq!(cols.item_range(0), None);
        assert_eq!(cols.item_range(1), Some((0, 8)));
        assert!(rows.is_grid() && cols.is_grid());
    }

    #[test]
    fn access_sweeps_flag_overlap_and_races() {
        // two items both writing [0, 4): rule (a)
        let bad = AccessSet::new().write(Region::Slot("x"), Span::All { lo: 0, hi: 4 });
        assert_eq!(bad.item_write_conflict(2), Some(Region::Slot("x")));
        assert!(bad.item_write_conflict(1).is_none(), "single item may write anything");
        // blocked writes are disjoint
        let ok = AccessSet::new()
            .write(Region::Slot("x"), Span::Blocked { base: 0, block: 2, n: 4 });
        assert!(ok.item_write_conflict(2).is_none());
        // cross-item read/write: every item reads what item 0 writes
        let race = AccessSet::new()
            .read(Region::Slot("x"), Span::All { lo: 0, hi: 4 })
            .write(Region::Slot("x"), Span::Blocked { base: 0, block: 2, n: 4 });
        assert_eq!(race.item_read_write_race(2), Some(Region::Slot("x")));
        // item-local RMW is legal
        let rmw = AccessSet::new()
            .rmw(Region::Slot("x"), Span::Blocked { base: 0, block: 2, n: 4 });
        assert!(rmw.item_read_write_race(2).is_none());
    }

    #[test]
    fn two_state_q8_blocks_share_scratch_correctly() {
        let cb = Arc::new(dynamic_signed());
        let n = 700;
        let mut s1 = StateTensor::new_q8(n, cb.clone(), 256);
        let mut s2 = StateTensor::new_q8(n, cb, 256);
        let mut params = vec![0.0f32; n];
        let grads: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
        step_blocks(&mut params, &grads, &mut s1, Some(&mut s2), 256, |v| {
            let s2 = v.s2.expect("two states");
            for i in 0..v.params.len() {
                v.s1[i] = v.grads[i];
                s2[i] = -v.grads[i];
            }
        });
        let a = s1.to_f32();
        let b = s2.to_f32();
        for i in 0..n {
            let g = grads[i];
            let tol = 0.35 * g.abs() + 1e-3;
            // if the two states had collided in scratch, b would hold +g
            assert!((a[i] - g).abs() <= tol, "s1[{i}] {} vs {g}", a[i]);
            assert!((b[i] + g).abs() <= tol, "s2[{i}] {} vs {}", b[i], -g);
        }
    }
}
