//! Optimizer-state storage: 32-bit or 8-bit block-wise quantized.
//!
//! The paper's update (§2, Figure 1): dequantize the 8-bit state block to
//! 32-bit *in registers*, perform the update, requantize for storage. Here
//! a "register block" is a scratch `Vec<f32>` of one quantization block;
//! blocks are processed independently and in parallel, mirroring the
//! per-core independence that makes block-wise quantization fast.

use std::sync::Arc;

use crate::quant::blockwise::{dequantize_block, quantize_block};
use crate::quant::{Codebook, Quantized};
use crate::util::parallel;

/// How a state tensor is stored.
#[derive(Clone)]
pub enum StateTensor {
    /// Full-precision baseline (the 32-bit optimizers of Table 1).
    F32(Vec<f32>),
    /// 8-bit block-wise quantized (codes + per-block absmax).
    Q8 { q: Quantized, codebook: Arc<Codebook> },
}

impl StateTensor {
    pub fn new_f32(n: usize) -> StateTensor {
        StateTensor::F32(vec![0.0; n])
    }

    pub fn new_q8(n: usize, codebook: Arc<Codebook>, block: usize) -> StateTensor {
        let zero = codebook.encode(0.0);
        StateTensor::Q8 { q: Quantized::zeros(n, block.min(n.max(1)), zero), codebook }
    }

    pub fn len(&self) -> usize {
        match self {
            StateTensor::F32(v) => v.len(),
            StateTensor::Q8 { q, .. } => q.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage footprint in bytes — the quantity Table 1/2 account for.
    pub fn bytes(&self) -> usize {
        match self {
            StateTensor::F32(v) => v.len() * 4,
            StateTensor::Q8 { q, .. } => q.bytes(),
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, StateTensor::Q8 { .. })
    }

    /// Dequantize the whole tensor (for checkpoints / analysis).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            StateTensor::F32(v) => v.clone(),
            StateTensor::Q8 { q, codebook } => {
                let mut out = vec![0.0f32; q.len];
                for b in 0..q.n_blocks() {
                    let lo = b * q.block;
                    let hi = (lo + q.block).min(q.len);
                    dequantize_block(codebook, &q.codes[lo..hi], q.absmax[b], &mut out[lo..hi]);
                }
                out
            }
        }
    }
}

/// A mutable view of one block of a state tensor.
pub enum StateBlockMut<'a> {
    F32(&'a mut [f32]),
    Q8 { codes: &'a mut [u8], absmax: &'a mut f32, codebook: &'a Codebook },
}

impl<'a> StateBlockMut<'a> {
    /// Dequantize into `scratch` and return the working slice. For F32
    /// state this is the storage itself (no copy).
    pub fn load<'s>(&'s mut self, scratch: &'s mut Vec<f32>) -> &'s mut [f32]
    where
        'a: 's,
    {
        match self {
            StateBlockMut::F32(v) => v,
            StateBlockMut::Q8 { codes, absmax, codebook } => {
                scratch.resize(codes.len(), 0.0);
                dequantize_block(codebook, codes, **absmax, scratch);
                scratch
            }
        }
    }

    /// Requantize the worked-on slice back into storage (no-op for F32,
    /// where `load` handed out the storage directly).
    pub fn store(&mut self, worked: &[f32]) {
        if let StateBlockMut::Q8 { codes, absmax, codebook } = self {
            **absmax = quantize_block(codebook, worked, codes);
        }
    }
}

/// One block's worth of optimizer-step inputs.
pub struct BlockCtx<'a> {
    /// Global element offset of this block.
    pub start: usize,
    pub params: &'a mut [f32],
    pub grads: &'a [f32],
    pub s1: StateBlockMut<'a>,
    /// Second state (None for single-state optimizers like Momentum).
    pub s2: Option<StateBlockMut<'a>>,
}

/// Iterate `f` over the blocks of (params, grads, state1[, state2]) in
/// parallel. All tensors share the same block partition, taken from the
/// quantized state's block size (or `fallback_block` if all states are F32).
pub fn for_each_block<F>(
    params: &mut [f32],
    grads: &[f32],
    s1: &mut StateTensor,
    s2: Option<&mut StateTensor>,
    fallback_block: usize,
    f: F,
) where
    F: Fn(&mut BlockCtx) + Sync + Send,
{
    let n = params.len();
    assert_eq!(grads.len(), n);
    assert_eq!(s1.len(), n);
    if let Some(ref s) = s2 {
        assert_eq!(s.len(), n);
    }
    let block = match (&*s1, s2.as_deref()) {
        (StateTensor::Q8 { q, .. }, _) => q.block,
        (_, Some(StateTensor::Q8 { q, .. })) => q.block,
        _ => fallback_block.min(n.max(1)),
    };

    // Build per-block views by zipping chunk iterators over every tensor.
    enum Parts<'a> {
        F32(std::slice::ChunksMut<'a, f32>),
        Q8 {
            codes: std::slice::ChunksMut<'a, u8>,
            absmax: std::slice::IterMut<'a, f32>,
            codebook: &'a Codebook,
        },
    }
    impl<'a> Parts<'a> {
        fn next_block(&mut self) -> StateBlockMut<'a> {
            match self {
                Parts::F32(it) => StateBlockMut::F32(it.next().expect("block count")),
                Parts::Q8 { codes, absmax, codebook } => StateBlockMut::Q8 {
                    codes: codes.next().expect("block count"),
                    absmax: absmax.next().expect("block count"),
                    codebook,
                },
            }
        }
    }
    fn parts(s: &mut StateTensor, block: usize) -> Parts<'_> {
        match s {
            StateTensor::F32(v) => Parts::F32(v.chunks_mut(block)),
            StateTensor::Q8 { q, codebook } => {
                assert_eq!(q.block, block, "state block sizes must agree");
                Parts::Q8 {
                    codes: q.codes.chunks_mut(block),
                    absmax: q.absmax.iter_mut(),
                    codebook,
                }
            }
        }
    }

    let n_blocks = n.div_ceil(block).max(1);
    let mut p1 = parts(s1, block);
    let mut p2 = s2.map(|s| parts(s, block));
    let mut ctxs: Vec<BlockCtx> = Vec::with_capacity(n_blocks);
    for (b, p_chunk) in params.chunks_mut(block).enumerate() {
        let start = b * block;
        ctxs.push(BlockCtx {
            start,
            grads: &grads[start..start + p_chunk.len()],
            params: p_chunk,
            s1: p1.next_block(),
            s2: p2.as_mut().map(|p| p.next_block()),
        });
    }

    // Distribute blocks across threads.
    let threads = parallel::num_threads().min(ctxs.len().max(1));
    if threads <= 1 || ctxs.len() <= 1 {
        for mut ctx in ctxs {
            f(&mut ctx);
        }
        return;
    }
    let per = ctxs.len().div_ceil(threads);
    let mut groups: Vec<Vec<BlockCtx>> = Vec::new();
    let mut it = ctxs.into_iter();
    loop {
        let g: Vec<_> = it.by_ref().take(per).collect();
        if g.is_empty() {
            break;
        }
        groups.push(g);
    }
    let fref = &f;
    std::thread::scope(|s| {
        for group in groups {
            s.spawn(move || {
                for mut ctx in group {
                    fref(&mut ctx);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::dynamic_tree::dynamic_signed;
    use crate::util::rng::Rng;

    #[test]
    fn f32_state_load_is_in_place() {
        let mut s = StateTensor::new_f32(10);
        if let StateTensor::F32(v) = &mut s {
            v[3] = 5.0;
        }
        let mut params = vec![0.0f32; 10];
        let grads = vec![0.0f32; 10];
        for_each_block(&mut params, &grads, &mut s, None, 4, |ctx| {
            let mut scratch = Vec::new();
            {
                let v = ctx.s1.load(&mut scratch);
                for x in v.iter_mut() {
                    *x += 1.0;
                }
            }
            // canonical pattern: store(&scratch) — no-op for F32 (mutated in
            // place), requantize for Q8 (worked data lives in scratch).
            ctx.s1.store(&scratch);
        });
        assert_eq!(s.to_f32()[3], 6.0);
        assert_eq!(s.to_f32()[0], 1.0);
    }

    #[test]
    fn q8_state_roundtrips_through_block_update() {
        let cb = Arc::new(dynamic_signed());
        let n = 5000;
        let mut s = StateTensor::new_q8(n, cb, 512);
        let mut params = vec![0.0f32; n];
        let grads: Vec<f32> = {
            let mut rng = Rng::new(5);
            (0..n).map(|_| rng.normal() as f32 * 0.01).collect()
        };
        // write grads into state through the block API
        for_each_block(&mut params, &grads, &mut s, None, 512, |ctx| {
            let mut scratch = Vec::new();
            {
                let v = ctx.s1.load(&mut scratch);
                v.copy_from_slice(ctx.grads);
            }
            ctx.s1.store(&scratch);
        });
        let back = s.to_f32();
        // round-trip error bounded by dynamic-tree precision: worst-case
        // relative error at a decade's bottom edge is ~0.45/(0.1*2^f) ≈ 30%
        for (a, b) in grads.iter().zip(&back) {
            assert!((a - b).abs() <= 0.35 * a.abs() + 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn bytes_accounting() {
        let cb = Arc::new(dynamic_signed());
        let s32 = StateTensor::new_f32(2048 * 4);
        let s8 = StateTensor::new_q8(2048 * 4, cb, 2048);
        assert_eq!(s32.bytes(), 2048 * 4 * 4);
        assert_eq!(s8.bytes(), 2048 * 4 + 4 * 4);
    }

    #[test]
    fn block_starts_cover_tensor() {
        let mut s = StateTensor::new_f32(1000);
        let mut params = vec![0.0f32; 1000];
        let grads = vec![0.0f32; 1000];
        let seen = std::sync::Mutex::new(vec![false; 1000]);
        for_each_block(&mut params, &grads, &mut s, None, 300, |ctx| {
            let mut guard = seen.lock().unwrap();
            for i in 0..ctx.params.len() {
                assert!(!guard[ctx.start + i]);
                guard[ctx.start + i] = true;
            }
        });
        assert!(seen.into_inner().unwrap().iter().all(|&b| b));
    }
}
