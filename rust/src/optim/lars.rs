//! LARS (You et al. 2017) — layer-wise adaptive rate scaling on top of
//! momentum. Appears in the paper's Table 5 runtime comparison; the 8-bit
//! variant quantizes the momentum state exactly like 8-bit Momentum.
//!
//! trust λ = η·‖w‖ / (‖g‖ + wd·‖w‖ + ε); m = β·m + lr·λ·(g + wd·w);
//! w −= m. One tensor = one "layer" (the coordinator builds per-tensor
//! optimizers).
//!
//! Two-phase plan: phase A computes per-chunk ‖w‖²/‖g‖² partials (the
//! canonical `util::reduce` reduction), the combine folds them in fixed
//! chunk order into the trust ratio, and phase B is the block-local
//! momentum update — so the whole step, norms included, runs inside the
//! fused engine's pool batches.

use super::state::{
    block_steps_vec, AccessSet, BlockSteps, BlockView, CombineAccess, LaneView, Phase, Region,
    Span, StateTensor, StepPlan,
};
use super::{make_state, Bits, OptimConfig, Optimizer};
use crate::util::lanes::LANES;
use crate::util::parallel::Shared;
use crate::util::reduce;

/// Default trust coefficient η from the LARS paper.
pub const TRUST_COEFF: f32 = 0.001;

pub struct Lars {
    cfg: OptimConfig,
    m: StateTensor,
    /// Phase-A norm partials: `[w chunks | g chunks]` (not optimizer state).
    partials: Vec<f64>,
    /// lr·trust, written by the combine, read by phase B.
    scaled_lr: f32,
    t: u64,
}

impl Lars {
    pub fn new(cfg: OptimConfig, n: usize) -> Lars {
        Lars {
            cfg,
            m: make_state(&cfg.bits, n, true),
            partials: vec![0.0; 2 * reduce::n_chunks(n)],
            scaled_lr: 0.0,
            t: 0,
        }
    }
}

impl Optimizer for Lars {
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let cfg = self.cfg;
        let n = params.len();
        let nc = reduce::n_chunks(n);
        self.partials.resize(2 * nc, 0.0);
        // SAFETY (all `Shared` uses below): phase-A items write disjoint
        // partial slots and only read params; the combine runs after the
        // phase-A barrier and alone; phase-B items write disjoint param
        // chunks and read `scaled_lr` after the barrier. `plan`'s `&'a mut
        // self` borrow keeps every target alive for the plan's lifetime.
        let partials = Shared::new(&mut self.partials);
        let scaled_lr = Shared::new(std::slice::from_mut(&mut self.scaled_lr));
        let params_sh = Shared::new(params);

        // Phase A: per-chunk norm partials of the *pre-update* values.
        let phase_a = BlockSteps::from_fn(nc, move |c| {
            let (lo, hi) = reduce::chunk_bounds(n, c);
            let w = unsafe { params_sh.range(lo, hi) };
            unsafe {
                partials.write(c, reduce::sum_sq(w));
                partials.write(nc + c, reduce::sum_sq(&grads[lo..hi]));
            }
        });
        // Combine: fold partials in fixed chunk order -> trust ratio.
        let combine = move || {
            let p = unsafe { partials.range(0, 2 * nc) };
            let w_norm = reduce::fold(&p[..nc]).sqrt() as f32;
            let g_norm = reduce::fold(&p[nc..]).sqrt() as f32;
            let trust = if w_norm > 0.0 && g_norm > 0.0 {
                TRUST_COEFF * w_norm / (g_norm + cfg.weight_decay * w_norm + 1e-9)
            } else {
                1.0
            };
            unsafe { scaled_lr.write(0, cfg.lr * trust) };
        };

        // Phase B: block-local momentum update, lane-chunked with the
        // scalar closure as the tail-and-oracle path.
        let block = cfg.bits.state_block(n);
        let params_b: &'a mut [f32] = unsafe { params_sh.range_mut(0, n) };
        let phase_b = block_steps_vec(
            params_b,
            grads,
            &mut self.m,
            None,
            block,
            move |v: LaneView| {
                let LaneView { params, grads, s1: m, .. } = v;
                let scaled_lr = unsafe { scaled_lr.read(0) };
                for l in 0..LANES {
                    let g = grads[l] + cfg.weight_decay * params[l];
                    m[l] = cfg.beta1 * m[l] + scaled_lr * g;
                    params[l] -= m[l];
                }
            },
            move |v: BlockView| {
                let BlockView { params, grads, s1: m, .. } = v;
                let scaled_lr = unsafe { scaled_lr.read(0) };
                for i in 0..params.len() {
                    let g = grads[i] + cfg.weight_decay * params[i];
                    m[i] = cfg.beta1 * m[i] + scaled_lr * g;
                    params[i] -= m[i];
                }
            },
        );

        let chunk = Span::Blocked { base: 0, block: reduce::CHUNK, n };
        let mut plan = StepPlan::new();
        plan.push(
            Phase::with_combine(phase_a, combine).with_access(
                AccessSet::new()
                    .read(Region::Params, chunk)
                    .read(Region::Grads, chunk)
                    .write(
                        Region::Slot("lars.partials"),
                        Span::Blocked { base: 0, block: 1, n: nc },
                    )
                    .write(
                        Region::Slot("lars.partials"),
                        Span::Blocked { base: nc, block: 1, n: nc },
                    )
                    .combine(
                        CombineAccess::deterministic()
                            .read(Region::Slot("lars.partials"), Span::All { lo: 0, hi: 2 * nc })
                            .write(Region::Slot("lars.scaled_lr"), Span::All { lo: 0, hi: 1 }),
                    ),
            ),
        );
        plan.push(Phase::new(phase_b).map_access(|a| {
            a.read(Region::Slot("lars.scaled_lr"), Span::All { lo: 0, hi: 1 })
        }));
        plan
    }

    fn state_bytes(&self) -> usize {
        self.m.bytes()
    }

    fn name(&self) -> String {
        format!("{} lars", self.cfg.bits.describe())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }

    fn set_bits(&mut self, bits: &Bits) -> bool {
        if !self.cfg.kind.supports_bits(bits) {
            return false;
        }
        super::requantize_state(&mut self.m, bits, true);
        self.cfg.bits = *bits;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32, bits: Bits) -> OptimConfig {
        let mut cfg = OptimConfig::adam(lr, bits);
        cfg.kind = OptimKind::Lars;
        cfg.beta2 = 0.0;
        cfg.eps = 0.0;
        cfg
    }

    #[test]
    fn trust_ratio_scales_update_with_weight_norm() {
        // Bigger weights => bigger trust => bigger step, same gradient.
        let g = vec![0.1f32; 64];
        let mut p_small = vec![0.1f32; 64];
        let mut p_big = vec![10.0f32; 64];
        let mut o1 = Lars::new(cfg(1.0, Bits::B32), 64);
        let mut o2 = Lars::new(cfg(1.0, Bits::B32), 64);
        let s0 = p_small[0];
        let b0 = p_big[0];
        o1.step(&mut p_small, &g);
        o2.step(&mut p_big, &g);
        let step_small = (s0 - p_small[0]).abs();
        let step_big = (b0 - p_big[0]).abs();
        assert!(step_big > step_small * 10.0, "{step_big} vs {step_small}");
    }

    #[test]
    fn lars32_converges_on_quadratic() {
        let n = 512;
        let mut rng = Rng::new(10);
        let target: Vec<f32> = (0..n).map(|_| 1.0 + rng.normal() as f32 * 0.1).collect();
        let mut p = vec![2.0f32; n];
        let mut opt = Lars::new(cfg(20.0, Bits::B32), n);
        for _ in 0..2000 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-2, "mse {mse}");
    }

    #[test]
    fn lars8_finite_and_close() {
        let n = 4096;
        let mut rng = Rng::new(11);
        let mut p = vec![1.0f32; n];
        let mut opt = Lars::new(cfg(1.0, Bits::b8_dynamic()), n);
        for _ in 0..100 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();
            opt.step(&mut p, &g);
        }
        assert!(p.iter().all(|v| v.is_finite()));
    }
}
