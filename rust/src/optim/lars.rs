//! LARS (You et al. 2017) — layer-wise adaptive rate scaling on top of
//! momentum. Appears in the paper's Table 5 runtime comparison; the 8-bit
//! variant quantizes the momentum state exactly like 8-bit Momentum.
//!
//! trust λ = η·‖w‖ / (‖g‖ + wd·‖w‖ + ε); m = β·m + lr·λ·(g + wd·w);
//! w −= m. One tensor = one "layer" (the coordinator builds per-tensor
//! optimizers).

use super::state::{block_steps, BlockSteps, BlockView, StateTensor};
use super::{make_state, OptimConfig, Optimizer};
use crate::util::parallel;

/// Default trust coefficient η from the LARS paper.
pub const TRUST_COEFF: f32 = 0.001;

pub struct Lars {
    cfg: OptimConfig,
    m: StateTensor,
    t: u64,
}

impl Lars {
    pub fn new(cfg: OptimConfig, n: usize) -> Lars {
        Lars { cfg, m: make_state(&cfg.bits, n, true), t: 0 }
    }
}

/// ‖x‖₂ computed in parallel chunks with f64 accumulation.
pub(crate) fn l2_norm(x: &[f32]) -> f64 {
    let chunks = x.len().div_ceil(1 << 16).max(1);
    let partial = parallel::par_map(chunks, |c| {
        let lo = c * (1 << 16);
        let hi = (lo + (1 << 16)).min(x.len());
        x[lo..hi].iter().map(|&v| v as f64 * v as f64).sum::<f64>()
    });
    partial.into_iter().sum::<f64>().sqrt()
}

impl Optimizer for Lars {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        self.begin_step(params, grads).expect("lars is block-local").execute();
    }

    fn is_block_local(&self) -> bool {
        true
    }

    fn begin_step<'a>(
        &'a mut self,
        params: &'a mut [f32],
        grads: &'a [f32],
    ) -> Option<BlockSteps<'a>> {
        self.t += 1;
        let cfg = self.cfg;
        // Per-tensor prologue: the trust ratio needs whole-tensor norms of
        // the *pre-update* values, so it runs here; the block tasks are
        // then independent.
        let w_norm = l2_norm(params) as f32;
        let g_norm = l2_norm(grads) as f32;
        let trust = if w_norm > 0.0 && g_norm > 0.0 {
            TRUST_COEFF * w_norm / (g_norm + cfg.weight_decay * w_norm + 1e-9)
        } else {
            1.0
        };
        let scaled_lr = cfg.lr * trust;
        let block = cfg.bits.state_block(params.len());
        Some(block_steps(params, grads, &mut self.m, None, block, move |v: BlockView| {
            let BlockView { params, grads, s1: m, .. } = v;
            for i in 0..params.len() {
                let g = grads[i] + cfg.weight_decay * params[i];
                m[i] = cfg.beta1 * m[i] + scaled_lr * g;
                params[i] -= m[i];
            }
        }))
    }

    fn state_bytes(&self) -> usize {
        self.m.bytes()
    }

    fn name(&self) -> String {
        format!("{} lars", self.cfg.bits.describe())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32, bits: Bits) -> OptimConfig {
        OptimConfig {
            kind: OptimKind::Lars,
            lr,
            beta1: 0.9,
            beta2: 0.0,
            eps: 0.0,
            weight_decay: 0.0,
            bits,
        }
    }

    #[test]
    fn l2_norm_matches_naive() {
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..200_000).map(|_| rng.normal() as f32).collect();
        let naive: f64 = x.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
        assert!((l2_norm(&x) - naive).abs() < 1e-6 * naive);
    }

    #[test]
    fn trust_ratio_scales_update_with_weight_norm() {
        // Bigger weights => bigger trust => bigger step, same gradient.
        let g = vec![0.1f32; 64];
        let mut p_small = vec![0.1f32; 64];
        let mut p_big = vec![10.0f32; 64];
        let mut o1 = Lars::new(cfg(1.0, Bits::B32), 64);
        let mut o2 = Lars::new(cfg(1.0, Bits::B32), 64);
        let s0 = p_small[0];
        let b0 = p_big[0];
        o1.step(&mut p_small, &g);
        o2.step(&mut p_big, &g);
        let step_small = (s0 - p_small[0]).abs();
        let step_big = (b0 - p_big[0]).abs();
        assert!(step_big > step_small * 10.0, "{step_big} vs {step_small}");
    }

    #[test]
    fn lars32_converges_on_quadratic() {
        let n = 512;
        let mut rng = Rng::new(10);
        let target: Vec<f32> = (0..n).map(|_| 1.0 + rng.normal() as f32 * 0.1).collect();
        let mut p = vec![2.0f32; n];
        let mut opt = Lars::new(cfg(20.0, Bits::B32), n);
        for _ in 0..2000 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-2, "mse {mse}");
    }

    #[test]
    fn lars8_finite_and_close() {
        let n = 4096;
        let mut rng = Rng::new(11);
        let mut p = vec![1.0f32; n];
        let mut opt = Lars::new(cfg(1.0, Bits::b8_dynamic()), n);
        for _ in 0..100 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.01).collect();
            opt.step(&mut p, &g);
        }
        assert!(p.iter().all(|v| v.is_finite()));
    }
}
