//! `OptimSpec`: the declarative optimizer construction surface — one base
//! [`OptimConfig`] plus an ordered list of [`GroupOverride`]s (first match
//! wins). The spec is what configs (TOML `[[optimizer.group]]` tables, the
//! CLI `--override` flag) parse into and what
//! [`ParamOptimizer::build`](super::ParamOptimizer::build) consumes; it
//! also centralizes *parse-time validation* of unsupported combinations,
//! which previously fell through to silently-constructed fallbacks (e.g.
//! `adafactor` with `bits = 8` built full 32-bit states without a word).

use anyhow::{anyhow, Context, Result};

use super::groups::GroupOverride;
use super::OptimConfig;
use crate::quant::Format;

/// Base optimizer config + ordered group overrides. Resolution is
/// first-match-wins on the tensor name; tensors matching no group use the
/// base config (group index 0).
#[derive(Clone, Debug)]
pub struct OptimSpec {
    pub base: OptimConfig,
    pub groups: Vec<GroupOverride>,
    /// Placement default: shard count for the default group and for any
    /// group that does not set its own `shards` key (1 = unsharded). Set
    /// from `[placement] shards = N` / `--shards N`; validated in
    /// `1..=MAX_SHARDS` like the per-group key.
    pub default_shards: u32,
}

impl OptimSpec {
    pub fn new(base: OptimConfig) -> OptimSpec {
        OptimSpec { base, groups: Vec::new(), default_shards: 1 }
    }

    pub fn with_groups(base: OptimConfig, groups: Vec<GroupOverride>) -> OptimSpec {
        OptimSpec { base, groups, default_shards: 1 }
    }

    /// Effective config for a tensor name, plus its group index
    /// (0 = default/base, g+1 = `groups[g]`).
    pub fn resolve(&self, name: &str) -> (OptimConfig, usize) {
        for (g, ov) in self.groups.iter().enumerate() {
            if ov.pattern().matches(name) {
                return (ov.apply(&self.base), g + 1);
            }
        }
        (self.base, 0)
    }

    /// Label for a group index as returned by [`OptimSpec::resolve`].
    pub fn group_label(&self, group: usize) -> String {
        if group == 0 {
            "default".to_string()
        } else {
            self.groups[group - 1].pattern().as_str().to_string()
        }
    }

    /// Shard count of a group index (0 = default group): the group's own
    /// `shards` key, else the spec-level placement default.
    pub fn shards_of(&self, group: usize) -> u32 {
        if group == 0 {
            self.default_shards
        } else {
            self.groups[group - 1].shards.unwrap_or(self.default_shards)
        }
    }

    /// Validate the base config and every group's resolved config — real
    /// errors at parse/build time instead of silent fallbacks.
    pub fn validate(&self) -> Result<()> {
        validate_config(&self.base).context("base optimizer config")?;
        if !(1..=super::shard::MAX_SHARDS).contains(&self.default_shards) {
            return Err(anyhow!(
                "placement shards must be in 1..={}, got {}",
                super::shard::MAX_SHARDS,
                self.default_shards
            ));
        }
        if self.default_shards > 1 && !self.base.kind.supports_sharding() {
            return Err(anyhow!(
                "placement shards = {} requires a shardable optimizer, but {} has no \
                 shardable fused plan (its factored statistics are not \
                 element-proportional); use shards = 1",
                self.default_shards,
                self.base.kind.name()
            ));
        }
        for (g, ov) in self.groups.iter().enumerate() {
            let label = ov.pattern().as_str().to_string();
            ov.check_against(&self.base)
                .with_context(|| format!("optimizer group {} ({label:?})", g + 1))?;
            validate_config(&ov.apply(&self.base))
                .with_context(|| format!("optimizer group {} ({label:?})", g + 1))?;
        }
        Ok(())
    }

    /// Compact one-line form: base config plus each override (and the
    /// placement default when sharding is on).
    pub fn describe(&self) -> String {
        let mut out = if self.groups.is_empty() {
            self.base.describe()
        } else {
            let ovs: Vec<String> = self.groups.iter().map(|g| g.describe()).collect();
            format!("{} [{}]", self.base.describe(), ovs.join(" "))
        };
        if self.default_shards > 1 {
            out.push_str(&format!(" shards={}", self.default_shards));
        }
        out
    }
}

/// Reject optimizer configs that the substrate cannot honor, instead of
/// letting `optim::build` silently construct a fallback:
///
/// * `adafactor` / `sm3` with `bits = 8` or `bits = 4` — their factored
///   row/column statistics are inherently 32-bit; the old path built
///   full-precision states while claiming quantization.
/// * `quantile` format without block-wise normalization — the quantile
///   codebook is calibrated on unit-normalized *block* statistics (Appendix
///   F.2 evaluates it block-wise only); a single tensor-wide block voids
///   the calibration. The same argument applies at every code width.
/// * Out-of-range hyperparameters (non-finite or non-positive `lr`, betas
///   outside `[0, 1)`, negative `eps`/`weight_decay`).
pub fn validate_config(cfg: &OptimConfig) -> Result<()> {
    if let Some((format, blockwise, _)) = cfg.bits.quantized() {
        if !cfg.kind.supports_bits(&cfg.bits) {
            return Err(anyhow!(
                "{} has no {}-bit state implementation (its factored statistics are \
                 inherently 32-bit); use bits = 32",
                cfg.kind.name(),
                cfg.bits.bit_count()
            ));
        }
        if format == Format::Quantile && !blockwise {
            return Err(anyhow!(
                "quantile format requires blockwise = true (the codebook is calibrated \
                 on unit-normalized block statistics)"
            ));
        }
    }
    if !cfg.lr.is_finite() || cfg.lr <= 0.0 {
        return Err(anyhow!("lr must be finite and > 0, got {}", cfg.lr));
    }
    for (name, v) in [("beta1", cfg.beta1), ("beta2", cfg.beta2)] {
        if !(0.0..1.0).contains(&v) {
            return Err(anyhow!("{name} must be in [0, 1), got {v}"));
        }
    }
    if cfg.eps.is_nan() || cfg.eps < 0.0 {
        return Err(anyhow!("eps must be >= 0, got {}", cfg.eps));
    }
    if cfg.weight_decay.is_nan() || cfg.weight_decay < 0.0 {
        return Err(anyhow!("weight_decay must be >= 0, got {}", cfg.weight_decay));
    }
    if !cfg.clip_percentile.is_finite()
        || cfg.clip_percentile < 0.0
        || cfg.clip_percentile > 100.0
    {
        return Err(anyhow!(
            "clip_percentile must be 0 (off) or in (0, 100], got {}",
            cfg.clip_percentile
        ));
    }
    if !cfg.max_unorm.is_finite() || cfg.max_unorm < 0.0 {
        return Err(anyhow!("max_unorm must be finite and >= 0, got {}", cfg.max_unorm));
    }
    if cfg.stability_on() && !cfg.kind.supports_stability() {
        return Err(anyhow!(
            "{} has no stabilized step path; clip_percentile/max_unorm/skip_zeros \
             require adam, adamw, momentum, or adagrad",
            cfg.kind.name()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{Bits, OptimKind};
    use super::*;

    fn base8() -> OptimConfig {
        OptimConfig::adam(1e-3, Bits::b8_dynamic())
    }

    #[test]
    fn resolve_falls_back_to_base() {
        let spec = OptimSpec::new(base8());
        let (cfg, g) = spec.resolve("block0.attn.wq");
        assert_eq!(g, 0);
        assert_eq!(cfg.bits, Bits::b8_dynamic());
        assert_eq!(spec.group_label(0), "default");
    }

    #[test]
    fn emb32_sugar_resolves_embeddings_to_32bit() {
        let spec = OptimSpec::with_groups(base8(), vec![GroupOverride::emb32()]);
        for name in ["embed.tok", "embed.pos"] {
            let (cfg, g) = spec.resolve(name);
            assert_eq!(g, 1, "{name}");
            assert_eq!(cfg.bits, Bits::B32, "{name}");
        }
        // exactly the historical flag's tensor set: embed.ln.* stays 8-bit
        for name in ["embed.ln.bias", "embed.ln.scale", "lm_head"] {
            let (cfg, g) = spec.resolve(name);
            assert_eq!(g, 0, "{name}");
            assert_eq!(cfg.bits, Bits::b8_dynamic(), "{name}");
        }
    }

    #[test]
    fn validation_rejects_unsupported_combos() {
        // adafactor/sm3 + quantized state: previously a silent 32-bit
        // fallback — rejected at every code width
        for kind in [OptimKind::Adafactor, OptimKind::Sm3] {
            for bits in [Bits::b8_dynamic(), Bits::b4_dynamic()] {
                let mut cfg = base8();
                cfg.kind = kind;
                cfg.bits = bits;
                assert!(validate_config(&cfg).is_err(), "{kind:?} {bits:?}");
            }
            let mut cfg = base8();
            cfg.kind = kind;
            cfg.bits = Bits::B32;
            assert!(validate_config(&cfg).is_ok(), "{kind:?} 32-bit");
        }
        // the quantile-needs-blockwise rule holds at 4-bit too
        let mut cfg = OptimConfig::adam(
            1e-3,
            Bits::B4 { format: Format::Quantile, blockwise: false },
        );
        assert!(validate_config(&cfg).is_err());
        cfg.bits = Bits::B4 { format: Format::Quantile, blockwise: true };
        assert!(validate_config(&cfg).is_ok());
        // quantile requires blockwise
        let mut cfg = OptimConfig::adam(
            1e-3,
            Bits::B8 { format: Format::Quantile, blockwise: false },
        );
        assert!(validate_config(&cfg).is_err());
        cfg.bits = Bits::B8 { format: Format::Quantile, blockwise: true };
        assert!(validate_config(&cfg).is_ok());
        // linear tensorwise stays legal (Table 3 ablation row)
        cfg.bits = Bits::B8 { format: Format::Linear, blockwise: false };
        assert!(validate_config(&cfg).is_ok());
        // hyperparameter ranges
        let mut cfg = base8();
        cfg.lr = 0.0;
        assert!(validate_config(&cfg).is_err());
        let mut cfg = base8();
        cfg.beta2 = 1.0;
        assert!(validate_config(&cfg).is_err());
    }

    #[test]
    fn spec_validation_covers_groups() {
        // a group flipping an 8-bit base to adafactor-incompatible settings
        let mut base = base8();
        base.kind = OptimKind::Adafactor;
        base.bits = Bits::B32;
        let spec = OptimSpec::with_groups(
            base,
            vec![GroupOverride::parse("embed.*:bits=8").unwrap()],
        );
        let err = spec.validate().unwrap_err();
        assert!(format!("{err:#}").contains("adafactor"), "{err:#}");

        // quantization keys on a group that resolves to 32-bit state
        let spec = OptimSpec::with_groups(
            OptimConfig::adam(1e-3, Bits::B32),
            vec![GroupOverride::parse("embed.*:format=linear").unwrap()],
        );
        assert!(spec.validate().is_err());

        // a healthy mixed-precision spec
        let spec = OptimSpec::with_groups(base8(), vec![GroupOverride::emb32()]);
        spec.validate().unwrap();
        assert!(spec.describe().contains("embed.tok|embed.pos:bits=32"));
    }

    #[test]
    fn validation_gates_stability_knobs_on_capability() {
        // stability on a supported kind: fine
        let mut cfg = base8();
        cfg.clip_percentile = 95.0;
        cfg.max_unorm = 0.02;
        cfg.skip_zeros = true;
        validate_config(&cfg).unwrap();
        // LAMB/LARS own their norm phases; SM3/Adafactor have no stabilized
        // path — all four reject the knobs instead of silently ignoring them
        for kind in [OptimKind::Lamb, OptimKind::Lars, OptimKind::Sm3, OptimKind::Adafactor] {
            let mut cfg = base8();
            cfg.kind = kind;
            cfg.bits = Bits::B32;
            cfg.clip_percentile = 95.0;
            let err = validate_config(&cfg).unwrap_err();
            assert!(format!("{err:#}").contains("stabilized"), "{kind:?}: {err:#}");
        }
        // range checks
        let mut cfg = base8();
        cfg.clip_percentile = 101.0;
        assert!(validate_config(&cfg).is_err());
        let mut cfg = base8();
        cfg.clip_percentile = f32::NAN;
        assert!(validate_config(&cfg).is_err());
        let mut cfg = base8();
        cfg.max_unorm = f32::INFINITY;
        assert!(validate_config(&cfg).is_err());
        // a group turning clipping on for a subset of tensors validates
        let spec = OptimSpec::with_groups(
            base8(),
            vec![GroupOverride::parse("block*:clip_percentile=95").unwrap()],
        );
        spec.validate().unwrap();
    }
}
