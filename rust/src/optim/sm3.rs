//! SM3 (Anil et al. 2019) — the memory-efficient AdaGrad variant the paper
//! cites as potentially *more* efficient than 8-bit Adam (Related Work).
//! Included as a comparison point for the memory-model and ablation benches.
//!
//! For a 2-D tensor with row accumulators R and column accumulators C:
//!   ν_ij = min(R_i, C_j) + g²_ij
//!   w −= lr · g / √ν;  R_i = max_j ν_ij;  C_j = max_i ν_ij
//! 1-D tensors use a single full accumulator (equivalent to AdaGrad).

use super::state::{
    block_steps, AccessSet, BlockSteps, BlockView, CombineAccess, Grid, Phase, Region, Span,
    StateTensor, StepPlan,
};
use super::{OptimConfig, Optimizer};
use crate::util::parallel::Shared;

pub struct Sm3 {
    cfg: OptimConfig,
    row: Vec<f32>,
    col: Vec<f32>,
    /// Next-step accumulators, staged during the parallel phase and
    /// installed by the combine (each slot has exactly one writer).
    new_row: Vec<f32>,
    new_col: Vec<f32>,
    /// 1-D fallback accumulator (empty when factored).
    acc: StateTensor,
    shape: Option<(usize, usize)>,
    t: u64,
}

impl Sm3 {
    pub fn new(cfg: OptimConfig, n: usize, shape: Option<(usize, usize)>) -> Sm3 {
        let factored = matches!(shape, Some((r, c)) if r > 1 && c > 1 && r * c == n);
        let shape = if factored { shape } else { None };
        let (rows, cols) = shape.unwrap_or((0, 0));
        Sm3 {
            cfg,
            row: vec![0.0; rows],
            col: vec![0.0; cols],
            new_row: vec![0.0; rows],
            new_col: vec![0.0; cols],
            acc: StateTensor::new_f32(if factored { 0 } else { n }),
            shape,
            t: 0,
        }
    }

    pub fn is_factored(&self) -> bool {
        self.shape.is_some()
    }
}

impl Optimizer for Sm3 {
    /// Factored tensors: one parallel phase + a combine. Row items own
    /// whole rows (param update + staged R_i = max_j ν); col items own
    /// whole columns (staged C_j = max_i ν, recomputing ν from the *old*
    /// accumulators — a couple of flops per element buys single-writer
    /// slots and no cross-item scratch). The combine installs the staged
    /// accumulators. 1-D tensors run the block-local AdaGrad-style plan.
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let cfg = self.cfg;
        let Some((rows, cols)) = self.shape else {
            let block = crate::quant::BLOCK.min(params.len().max(1));
            return StepPlan::single(block_steps(
                params,
                grads,
                &mut self.acc,
                None,
                block,
                move |v: BlockView| {
                    let BlockView { params, grads, s1: acc, .. } = v;
                    for i in 0..params.len() {
                        let g = grads[i];
                        acc[i] += g * g;
                        params[i] -= cfg.lr * g / (acc[i].sqrt() + cfg.eps.max(1e-12));
                    }
                },
            ));
        };
        // SAFETY (all `Shared` uses below): within the phase, row items
        // write disjoint param rows and staged-row slots, col items write
        // disjoint staged-col slots, and `row`/`col` are only read; the
        // combine runs alone after the barrier. `plan`'s `&'a mut self`
        // borrow keeps every target alive for the plan's lifetime.
        let row_sh = Shared::new(&mut self.row);
        let col_sh = Shared::new(&mut self.col);
        let new_row_sh = Shared::new(&mut self.new_row);
        let new_col_sh = Shared::new(&mut self.new_col);
        let params_sh = Shared::new(params);
        let grid = Grid::new(rows, cols);
        let items = BlockSteps::from_fn(grid.n_items(), move |it| {
            let row = unsafe { row_sh.range(0, rows) };
            let col = unsafe { col_sh.range(0, cols) };
            if let Some((r0, r1)) = grid.row_range(it) {
                let nr = unsafe { new_row_sh.range_mut(r0, r1) };
                let p = unsafe { params_sh.range_mut(r0 * cols, r1 * cols) };
                for i in r0..r1 {
                    let mut mx = 0.0f32;
                    for j in 0..cols {
                        let idx = i * cols + j;
                        let g = grads[idx];
                        let nu = row[i].min(col[j]) + g * g;
                        p[idx - r0 * cols] -= cfg.lr * g / (nu.sqrt() + cfg.eps.max(1e-12));
                        if nu > mx {
                            mx = nu;
                        }
                    }
                    nr[i - r0] = mx;
                }
            } else {
                let (c0, c1) = grid.col_range(it);
                let nc_slots = unsafe { new_col_sh.range_mut(c0, c1) };
                for j in c0..c1 {
                    let mut mx = 0.0f32;
                    for i in 0..rows {
                        let g = grads[i * cols + j];
                        let nu = row[i].min(col[j]) + g * g;
                        if nu > mx {
                            mx = nu;
                        }
                    }
                    nc_slots[j - c0] = mx;
                }
            }
        });
        // Combine: install the staged accumulators.
        let combine = move || unsafe {
            row_sh.range_mut(0, rows).copy_from_slice(new_row_sh.range(0, rows));
            col_sh.range_mut(0, cols).copy_from_slice(new_col_sh.range(0, cols));
        };
        let mut plan = StepPlan::new();
        plan.push(
            Phase::with_combine(items, combine).with_access(
                AccessSet::new()
                    .read(Region::Grads, Span::All { lo: 0, hi: rows * cols })
                    .read(Region::Slot("sm3.row"), Span::All { lo: 0, hi: rows })
                    .read(Region::Slot("sm3.col"), Span::All { lo: 0, hi: cols })
                    .preset(Region::Slot("sm3.row"))
                    .preset(Region::Slot("sm3.col"))
                    .rmw(Region::Params, Span::GridRows { grid, stride: cols, base: 0 })
                    .write(
                        Region::Slot("sm3.new_row"),
                        Span::GridRows { grid, stride: 1, base: 0 },
                    )
                    .write(
                        Region::Slot("sm3.new_col"),
                        Span::GridCols { grid, stride: 1, base: 0 },
                    )
                    .combine(
                        CombineAccess::deterministic()
                            .read(Region::Slot("sm3.new_row"), Span::All { lo: 0, hi: rows })
                            .read(Region::Slot("sm3.new_col"), Span::All { lo: 0, hi: cols })
                            .write(Region::Slot("sm3.row"), Span::All { lo: 0, hi: rows })
                            .write(Region::Slot("sm3.col"), Span::All { lo: 0, hi: cols }),
                    ),
            ),
        );
        plan
    }

    fn state_bytes(&self) -> usize {
        (self.row.len() + self.col.len()) * 4 + self.acc.bytes()
    }

    fn name(&self) -> String {
        "32-bit sm3".into()
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("acc", &self.acc)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("acc", &mut self.acc)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32) -> OptimConfig {
        let mut cfg = OptimConfig::adam(lr, Bits::B32);
        cfg.kind = OptimKind::Sm3;
        cfg.beta1 = 0.0;
        cfg.beta2 = 0.0;
        cfg.eps = 1e-8;
        cfg
    }

    #[test]
    fn sublinear_memory_for_2d() {
        let sm3 = Sm3::new(cfg(0.1), 1024 * 1024, Some((1024, 1024)));
        assert!(sm3.is_factored());
        assert_eq!(sm3.state_bytes(), 2 * 1024 * 4); // rows + cols only
    }

    #[test]
    fn accumulators_upper_bound_adagrad() {
        // SM3 invariant: min(R_i, C_j) ≥ Σ g² for every coordinate, so the
        // effective lr is never larger than AdaGrad's... check ν grows.
        let (rows, cols) = (4, 4);
        let mut opt = Sm3::new(cfg(0.1), 16, Some((rows, cols)));
        let mut rng = Rng::new(16);
        let mut p = vec![0.0f32; 16];
        let mut sum_sq = vec![0.0f32; 16];
        for _ in 0..50 {
            let g: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            for (s, &gi) in sum_sq.iter_mut().zip(&g) {
                *s += gi * gi;
            }
            opt.step(&mut p, &g);
        }
        for i in 0..rows {
            for j in 0..cols {
                let bound = opt.row[i].min(opt.col[j]);
                assert!(
                    bound + 1e-4 >= sum_sq[i * cols + j],
                    "ν bound {bound} < Σg² {}",
                    sum_sq[i * cols + j]
                );
            }
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let n = 256;
        let mut rng = Rng::new(17);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Sm3::new(cfg(0.5), n, Some((16, 16)));
        for _ in 0..800 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 5e-2, "mse {mse}");
    }

    #[test]
    fn one_d_fallback_matches_adagrad_memory() {
        let sm3 = Sm3::new(cfg(0.1), 1000, None);
        assert!(!sm3.is_factored());
        assert_eq!(sm3.state_bytes(), 4000);
    }
}
