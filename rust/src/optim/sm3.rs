//! SM3 (Anil et al. 2019) — the memory-efficient AdaGrad variant the paper
//! cites as potentially *more* efficient than 8-bit Adam (Related Work).
//! Included as a comparison point for the memory-model and ablation benches.
//!
//! For a 2-D tensor with row accumulators R and column accumulators C:
//!   ν_ij = min(R_i, C_j) + g²_ij
//!   w −= lr · g / √ν;  R_i = max_j ν_ij;  C_j = max_i ν_ij
//! 1-D tensors use a single full accumulator (equivalent to AdaGrad).

use super::state::{block_steps, BlockSteps, BlockView, StateTensor};
use super::{OptimConfig, Optimizer};

pub struct Sm3 {
    cfg: OptimConfig,
    row: Vec<f32>,
    col: Vec<f32>,
    /// 1-D fallback accumulator (empty when factored).
    acc: StateTensor,
    shape: Option<(usize, usize)>,
    t: u64,
}

impl Sm3 {
    pub fn new(cfg: OptimConfig, n: usize, shape: Option<(usize, usize)>) -> Sm3 {
        let factored = matches!(shape, Some((r, c)) if r > 1 && c > 1 && r * c == n);
        let shape = if factored { shape } else { None };
        let (rows, cols) = shape.unwrap_or((0, 0));
        Sm3 {
            cfg,
            row: vec![0.0; rows],
            col: vec![0.0; cols],
            acc: StateTensor::new_f32(if factored { 0 } else { n }),
            shape,
            t: 0,
        }
    }

    pub fn is_factored(&self) -> bool {
        self.shape.is_some()
    }
}

impl Optimizer for Sm3 {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        if self.shape.is_none() {
            // 1-D fallback (≡ AdaGrad) is block-local and runs through the
            // shared engine.
            self.begin_step(params, grads).expect("1-D sm3 is block-local").execute();
            return;
        }
        self.t += 1;
        let cfg = self.cfg;
        let (rows, cols) = self.shape.expect("factored");
        let mut new_row = vec![0.0f32; rows];
        let mut new_col = vec![0.0f32; cols];
        for i in 0..rows {
            for j in 0..cols {
                let idx = i * cols + j;
                let g = grads[idx];
                let nu = self.row[i].min(self.col[j]) + g * g;
                params[idx] -= cfg.lr * g / (nu.sqrt() + cfg.eps.max(1e-12));
                if nu > new_row[i] {
                    new_row[i] = nu;
                }
                if nu > new_col[j] {
                    new_col[j] = nu;
                }
            }
        }
        self.row = new_row;
        self.col = new_col;
    }

    fn is_block_local(&self) -> bool {
        // The factored update couples every element of a row/column through
        // the shared accumulators; only the 1-D fallback is block-local.
        self.shape.is_none()
    }

    fn begin_step<'a>(
        &'a mut self,
        params: &'a mut [f32],
        grads: &'a [f32],
    ) -> Option<BlockSteps<'a>> {
        if self.shape.is_some() {
            return None;
        }
        self.t += 1;
        let cfg = self.cfg;
        let block = crate::quant::BLOCK.min(params.len().max(1));
        Some(block_steps(params, grads, &mut self.acc, None, block, move |v: BlockView| {
            let BlockView { params, grads, s1: acc, .. } = v;
            for i in 0..params.len() {
                let g = grads[i];
                acc[i] += g * g;
                params[i] -= cfg.lr * g / (acc[i].sqrt() + cfg.eps.max(1e-12));
            }
        }))
    }

    fn state_bytes(&self) -> usize {
        (self.row.len() + self.col.len()) * 4 + self.acc.bytes()
    }

    fn name(&self) -> String {
        "32-bit sm3".into()
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("acc", &self.acc)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("acc", &mut self.acc)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32) -> OptimConfig {
        OptimConfig {
            kind: OptimKind::Sm3,
            lr,
            beta1: 0.0,
            beta2: 0.0,
            eps: 1e-8,
            weight_decay: 0.0,
            bits: Bits::B32,
        }
    }

    #[test]
    fn sublinear_memory_for_2d() {
        let sm3 = Sm3::new(cfg(0.1), 1024 * 1024, Some((1024, 1024)));
        assert!(sm3.is_factored());
        assert_eq!(sm3.state_bytes(), 2 * 1024 * 4); // rows + cols only
    }

    #[test]
    fn accumulators_upper_bound_adagrad() {
        // SM3 invariant: min(R_i, C_j) ≥ Σ g² for every coordinate, so the
        // effective lr is never larger than AdaGrad's... check ν grows.
        let (rows, cols) = (4, 4);
        let mut opt = Sm3::new(cfg(0.1), 16, Some((rows, cols)));
        let mut rng = Rng::new(16);
        let mut p = vec![0.0f32; 16];
        let mut sum_sq = vec![0.0f32; 16];
        for _ in 0..50 {
            let g: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            for (s, &gi) in sum_sq.iter_mut().zip(&g) {
                *s += gi * gi;
            }
            opt.step(&mut p, &g);
        }
        for i in 0..rows {
            for j in 0..cols {
                let bound = opt.row[i].min(opt.col[j]);
                assert!(
                    bound + 1e-4 >= sum_sq[i * cols + j],
                    "ν bound {bound} < Σg² {}",
                    sum_sq[i * cols + j]
                );
            }
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let n = 256;
        let mut rng = Rng::new(17);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Sm3::new(cfg(0.5), n, Some((16, 16)));
        for _ in 0..800 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 5e-2, "mse {mse}");
    }

    #[test]
    fn one_d_fallback_matches_adagrad_memory() {
        let sm3 = Sm3::new(cfg(0.1), 1000, None);
        assert!(!sm3.is_factored());
        assert_eq!(sm3.state_bytes(), 4000);
    }
}
