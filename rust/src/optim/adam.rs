//! Adam / AdamW with 32-bit or 8-bit block-wise quantized states (Eq. 2).
//!
//! The 8-bit step is the paper's Figure 1 pipeline: per quantization block,
//! dequantize m and r to 32-bit scratch, apply the exact 32-bit Adam rule,
//! requantize. m uses the signed codebook, r (strictly positive) the
//! unsigned one (§2.2).

use super::stability;
use super::state::{block_steps_vec, BlockView, LaneView, StateTensor, StepPlan};
use super::{make_state, Bits, OptimConfig, OptimKind, Optimizer};
use crate::util::lanes::LANES;

pub struct Adam {
    cfg: OptimConfig,
    m: StateTensor,
    r: StateTensor,
    stab: stability::Stab,
    t: u64,
}

impl Adam {
    pub fn new(cfg: OptimConfig, n: usize) -> Adam {
        debug_assert!(matches!(cfg.kind, OptimKind::Adam | OptimKind::AdamW));
        Adam {
            cfg,
            m: make_state(&cfg.bits, n, true),
            r: make_state(&cfg.bits, n, false),
            stab: stability::Stab::default(),
            t: 0,
        }
    }

    /// The elementwise 32-bit update rule, shared by every precision path
    /// (and mirrored by the Pallas kernel `kernels/adam8bit.py`).
    #[inline(always)]
    #[allow(clippy::too_many_arguments)]
    pub fn update_rule(
        p: &mut f32,
        g: f32,
        m: &mut f32,
        r: &mut f32,
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        decoupled_wd: bool,
        bias_c1: f32,
        bias_c2: f32,
    ) {
        let g = if !decoupled_wd && weight_decay != 0.0 { g + weight_decay * *p } else { g };
        *m = beta1 * *m + (1.0 - beta1) * g;
        *r = beta2 * *r + (1.0 - beta2) * g * g;
        let m_hat = *m / bias_c1;
        let r_hat = *r / bias_c2;
        let mut step = lr * m_hat / (r_hat.sqrt() + eps);
        if decoupled_wd && weight_decay != 0.0 {
            step += lr * weight_decay * *p;
        }
        *p -= step;
    }
}

impl Optimizer for Adam {
    // Fully block-local: one phase, no combine. Lane-chunked: both closures
    // apply the identical `update_rule`, so the vectorized path is
    // bit-identical to the scalar tail-and-oracle path.
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let cfg = self.cfg;
        let bias_c1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bias_c2 = 1.0 - cfg.beta2.powi(self.t as i32);
        let decoupled = cfg.kind == OptimKind::AdamW;
        let block = cfg.bits.state_block(params.len());
        if cfg.stability_on() {
            // Stabilized phased plan (clip_percentile / max_unorm /
            // skip_zeros). Same moment arithmetic as the legacy path; the
            // max_unorm branch factors the step into direction ± wd term
            // times the (possibly clipped) lr.
            let direct_rule =
                move |p: &mut f32, g_raw: f32, m: &mut f32, r: Option<&mut f32>, gs: f32| {
                    if cfg.skip_zeros && g_raw == 0.0 {
                        return;
                    }
                    let r = r.expect("adam has two states");
                    Self::update_rule(
                        p,
                        g_raw * gs,
                        m,
                        r,
                        cfg.lr,
                        cfg.beta1,
                        cfg.beta2,
                        cfg.eps,
                        cfg.weight_decay,
                        decoupled,
                        bias_c1,
                        bias_c2,
                    );
                };
            let u_rule = move |u: &mut f32,
                               g_raw: f32,
                               m: &mut f32,
                               r: Option<&mut f32>,
                               w: f32,
                               gs: f32| {
                if cfg.skip_zeros && g_raw == 0.0 {
                    *u = 0.0;
                    return;
                }
                let r = r.expect("adam has two states");
                let mut g = g_raw * gs;
                if !decoupled && cfg.weight_decay != 0.0 {
                    g += cfg.weight_decay * w;
                }
                *m = cfg.beta1 * *m + (1.0 - cfg.beta1) * g;
                *r = cfg.beta2 * *r + (1.0 - cfg.beta2) * g * g;
                let m_hat = *m / bias_c1;
                let r_hat = *r / bias_c2;
                let mut dir = m_hat / (r_hat.sqrt() + cfg.eps);
                if decoupled && cfg.weight_decay != 0.0 {
                    dir += cfg.weight_decay * w;
                }
                *u = dir;
            };
            return stability::stabilized_plan(
                &mut self.stab,
                &cfg,
                params,
                grads,
                &mut self.m,
                Some(&mut self.r),
                block,
                direct_rule,
                u_rule,
            );
        }
        StepPlan::single(block_steps_vec(
            params,
            grads,
            &mut self.m,
            Some(&mut self.r),
            block,
            move |v: LaneView| {
                let LaneView { params, grads, s1: m, s2, .. } = v;
                let r = s2.expect("adam has two states");
                for l in 0..LANES {
                    Self::update_rule(
                        &mut params[l],
                        grads[l],
                        &mut m[l],
                        &mut r[l],
                        cfg.lr,
                        cfg.beta1,
                        cfg.beta2,
                        cfg.eps,
                        cfg.weight_decay,
                        decoupled,
                        bias_c1,
                        bias_c2,
                    );
                }
            },
            move |v: BlockView| {
                let BlockView { params, grads, s1: m, s2, .. } = v;
                let r = s2.expect("adam has two states");
                for i in 0..params.len() {
                    Self::update_rule(
                        &mut params[i],
                        grads[i],
                        &mut m[i],
                        &mut r[i],
                        cfg.lr,
                        cfg.beta1,
                        cfg.beta2,
                        cfg.eps,
                        cfg.weight_decay,
                        decoupled,
                        bias_c1,
                        bias_c2,
                    );
                }
            },
        ))
    }

    fn state_bytes(&self) -> usize {
        self.m.bytes() + self.r.bytes()
    }

    fn name(&self) -> String {
        format!("{} {}", self.cfg.bits.describe(), self.cfg.kind.name())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m), ("r", &self.r)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m), ("r", &mut self.r)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }

    fn gnorm_history(&self) -> Option<Vec<f32>> {
        (self.cfg.clip_percentile > 0.0).then(|| self.stab.history.snapshot())
    }

    fn restore_gnorm_history(&mut self, hist: &[f32]) {
        self.stab.history.restore(hist);
    }

    fn set_bits(&mut self, bits: &Bits) -> bool {
        if !self.cfg.kind.supports_bits(bits) {
            return false;
        }
        super::requantize_state(&mut self.m, bits, true);
        super::requantize_state(&mut self.r, bits, false);
        self.cfg.bits = *bits;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::Bits;
    use crate::util::rng::Rng;

    fn quadratic_grads(p: &[f32], target: &[f32]) -> Vec<f32> {
        // loss = 0.5 * ||p - target||^2  ->  grad = p - target
        p.iter().zip(target).map(|(a, b)| a - b).collect()
    }

    #[test]
    fn adam32_converges_on_quadratic() {
        let n = 4096;
        let mut rng = Rng::new(1);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Adam::new(OptimConfig::adam(0.05, Bits::B32), n);
        for _ in 0..500 {
            let g = quadratic_grads(&p, &target);
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-3, "mse {mse}");
    }

    #[test]
    fn adam8_tracks_adam32_closely() {
        // The paper's core claim at micro scale: the 8-bit trajectory stays
        // close to the 32-bit one on a well-conditioned problem.
        let n = 8192;
        let mut rng = Rng::new(2);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p32 = vec![0.0f32; n];
        let mut p8 = vec![0.0f32; n];
        let mut o32 = Adam::new(OptimConfig::adam(0.05, Bits::B32), n);
        let mut o8 = Adam::new(OptimConfig::adam(0.05, Bits::b8_dynamic()), n);
        for _ in 0..300 {
            let g32 = quadratic_grads(&p32, &target);
            o32.step(&mut p32, &g32);
            let g8 = quadratic_grads(&p8, &target);
            o8.step(&mut p8, &g8);
        }
        let mse32: f32 =
            p32.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        let mse8: f32 =
            p8.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse32 < 1e-3);
        assert!(mse8 < 5e-3, "8-bit mse {mse8} vs 32-bit {mse32}");
    }

    #[test]
    fn adamw_decoupled_weight_decay_shrinks_params() {
        let n = 128;
        let mut cfg = OptimConfig::adam(0.0, Bits::B32); // lr used by wd term
        cfg.kind = OptimKind::AdamW;
        cfg.lr = 0.1;
        cfg.weight_decay = 0.1;
        let mut opt = Adam::new(cfg, n);
        let mut p = vec![1.0f32; n];
        let g = vec![0.0f32; n];
        opt.step(&mut p, &g);
        // zero grad: p shrinks by exactly lr*wd*p
        for &v in &p {
            assert!((v - (1.0 - 0.1 * 0.1)).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn adam_coupled_weight_decay_enters_moments() {
        let n = 16;
        let mut cfg = OptimConfig::adam(0.01, Bits::B32);
        cfg.weight_decay = 0.5;
        let mut opt = Adam::new(cfg, n);
        let mut p = vec![2.0f32; n];
        let g = vec![0.0f32; n];
        opt.step(&mut p, &g);
        // grad becomes wd*p = 1.0, so m > 0 after one step
        let m = opt.m.to_f32();
        assert!(m.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn bias_correction_first_step_matches_closed_form() {
        // After one step from zero state: m_hat = g, r_hat = g^2, so
        // p -= lr * g/(|g| + eps) = lr * sign(g) (approximately).
        let mut opt = Adam::new(OptimConfig::adam(0.1, Bits::B32), 4);
        let mut p = vec![0.0f32; 4];
        let g = vec![0.5f32, -0.5, 2.0, -2.0];
        opt.step(&mut p, &g);
        for (v, gi) in p.iter().zip(&g) {
            let expect = -0.1 * gi.signum();
            assert!((v - expect).abs() < 1e-3, "{v} vs {expect}");
        }
    }

    #[test]
    fn second_state_stays_nonnegative_in_8bit() {
        let n = 4096;
        let mut opt = Adam::new(OptimConfig::adam(0.01, Bits::b8_dynamic()), n);
        let mut rng = Rng::new(3);
        let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        for _ in 0..20 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            opt.step(&mut p, &g);
        }
        assert!(opt.r.to_f32().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn state_bytes_8bit_close_to_2_bytes_per_param() {
        let n = 1 << 16;
        let opt = Adam::new(OptimConfig::adam(0.01, Bits::b8_dynamic()), n);
        let per = opt.state_bytes() as f64 / n as f64;
        assert!(per < 2.02, "{per}");
    }

    #[test]
    fn set_bits_swaps_width_and_pins_values_through_32() {
        let n = 4096;
        let mut opt = Adam::new(OptimConfig::adam(0.01, Bits::b8_dynamic()), n);
        let mut rng = Rng::new(7);
        let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        for _ in 0..10 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            opt.step(&mut p, &g);
        }
        let bytes8 = opt.state_bytes();
        let m0 = opt.m.to_f32();
        let r0 = opt.r.to_f32();
        // Promote 8 -> 32: the dequantized working values carry over exactly.
        assert!(opt.set_bits(&Bits::B32));
        assert!(opt.state_bytes() > bytes8);
        assert_eq!(opt.m.to_f32(), m0);
        assert_eq!(opt.r.to_f32(), r0);
        // Demote 32 -> 8: requantizing those same working values is the
        // idempotent-roundtrip contract, so every code lands where it was.
        assert!(opt.set_bits(&Bits::b8_dynamic()));
        assert_eq!(opt.state_bytes(), bytes8);
        assert_eq!(opt.m.to_f32(), m0);
        assert_eq!(opt.r.to_f32(), r0);
        // Demotion to 4-bit shrinks storage and leaves the states usable.
        assert!(opt.set_bits(&Bits::b4_dynamic()));
        assert!(opt.state_bytes() < bytes8);
        let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
        opt.step(&mut p, &g);
        assert!(p.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn skip_zeros_leaves_zero_grad_elements_untouched() {
        // Coupled wd would otherwise move even zero-grad elements (g_eff =
        // wd*p). With skip_zeros, params AND moments stay bit-identical.
        let n = 64;
        let mut cfg = OptimConfig::adam(0.05, Bits::B32);
        cfg.weight_decay = 0.5;
        cfg.skip_zeros = true;
        let mut opt = Adam::new(cfg, n);
        let mut p: Vec<f32> = (0..n).map(|i| 1.0 + i as f32 * 0.01).collect();
        let p0 = p.clone();
        let g: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 0.0 } else { 0.3 }).collect();
        for _ in 0..5 {
            opt.step(&mut p, &g);
        }
        let m = opt.m.to_f32();
        let r = opt.r.to_f32();
        for i in 0..n {
            if i % 2 == 0 {
                assert_eq!(p[i], p0[i], "param {i} moved");
                assert_eq!(m[i], 0.0, "m {i} moved");
                assert_eq!(r[i], 0.0, "r {i} moved");
            } else {
                assert_ne!(p[i], p0[i], "param {i} should move");
            }
        }
    }

    #[test]
    fn percentile_clip_damps_gradient_spike() {
        // Steady gradients build the norm history; a 1000x spike is then
        // clipped back to the recorded percentile, so the clipped run's
        // post-spike step is far smaller than the unclipped run's.
        let n = 256;
        let mut clipped_cfg = OptimConfig::adam(0.01, Bits::B32);
        clipped_cfg.clip_percentile = 95.0;
        let mut oc = Adam::new(clipped_cfg, n);
        let mut ou = Adam::new(OptimConfig::adam(0.01, Bits::B32), n);
        let mut pc = vec![1.0f32; n];
        let mut pu = vec![1.0f32; n];
        let g = vec![0.01f32; n];
        for _ in 0..10 {
            oc.step(&mut pc, &g);
            ou.step(&mut pu, &g);
        }
        let spike = vec![10.0f32; n];
        oc.step(&mut pc, &spike);
        ou.step(&mut pu, &spike);
        // Adam's sqrt(r) normalization keeps the raw step bounded either
        // way; the damage a spike does is to the *moments* (poisoned m and
        // r distort every following step) — so that's what we assert on.
        let mc = oc.m.to_f32()[0];
        let mu = ou.m.to_f32()[0];
        assert!(
            mc < mu / 10.0,
            "clipped first moment {mc} should be far below unclipped {mu}"
        );
        let rc = oc.r.to_f32()[0];
        let ru = ou.r.to_f32()[0];
        assert!(rc < ru / 10.0, "clipped second moment {rc} vs unclipped {ru}");
    }

    #[test]
    fn max_unorm_bounds_applied_update() {
        let n = 512;
        let mut cfg = OptimConfig::adam(0.5, Bits::B32); // huge lr
        cfg.max_unorm = 0.1;
        let mut opt = Adam::new(cfg, n);
        let mut rng = Rng::new(42);
        let mut p: Vec<f32> = (0..n).map(|_| 1.0 + rng.normal() as f32 * 0.1).collect();
        for _ in 0..5 {
            let before = p.clone();
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            opt.step(&mut p, &g);
            let w_norm =
                before.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            let step_norm = p
                .iter()
                .zip(&before)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            // ‖Δp‖ = lr·factor·‖u‖ ≤ lr·max_unorm·‖w‖
            let bound = 0.5 * 0.1 * w_norm * 1.0001;
            assert!(step_norm <= bound, "step {step_norm} > bound {bound}");
        }
    }

    #[test]
    fn unorm_path_matches_direct_path_when_no_clip_triggers() {
        // With max_unorm huge the clip factor stays 1.0, so the u-path
        // trajectory must match the direct stabilized path to float
        // round-off (different expression order, same math).
        let n = 1024;
        let mut direct_cfg = OptimConfig::adam(0.01, Bits::B32);
        direct_cfg.skip_zeros = true; // force stabilized direct path
        let mut unorm_cfg = direct_cfg;
        unorm_cfg.max_unorm = 1e30;
        let mut od = Adam::new(direct_cfg, n);
        let mut ou = Adam::new(unorm_cfg, n);
        let mut pd = vec![1.0f32; n];
        let mut pu = vec![1.0f32; n];
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            od.step(&mut pd, &g);
            ou.step(&mut pu, &g);
        }
        for (a, b) in pd.iter().zip(&pu) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
