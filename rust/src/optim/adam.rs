//! Adam / AdamW with 32-bit or 8-bit block-wise quantized states (Eq. 2).
//!
//! The 8-bit step is the paper's Figure 1 pipeline: per quantization block,
//! dequantize m and r to 32-bit scratch, apply the exact 32-bit Adam rule,
//! requantize. m uses the signed codebook, r (strictly positive) the
//! unsigned one (§2.2).

use super::state::{block_steps_vec, BlockView, LaneView, StateTensor, StepPlan};
use super::{make_state, OptimConfig, OptimKind, Optimizer};
use crate::util::lanes::LANES;

pub struct Adam {
    cfg: OptimConfig,
    m: StateTensor,
    r: StateTensor,
    t: u64,
}

impl Adam {
    pub fn new(cfg: OptimConfig, n: usize) -> Adam {
        debug_assert!(matches!(cfg.kind, OptimKind::Adam | OptimKind::AdamW));
        Adam {
            cfg,
            m: make_state(&cfg.bits, n, true),
            r: make_state(&cfg.bits, n, false),
            t: 0,
        }
    }

    /// The elementwise 32-bit update rule, shared by every precision path
    /// (and mirrored by the Pallas kernel `kernels/adam8bit.py`).
    #[inline(always)]
    #[allow(clippy::too_many_arguments)]
    pub fn update_rule(
        p: &mut f32,
        g: f32,
        m: &mut f32,
        r: &mut f32,
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        decoupled_wd: bool,
        bias_c1: f32,
        bias_c2: f32,
    ) {
        let g = if !decoupled_wd && weight_decay != 0.0 { g + weight_decay * *p } else { g };
        *m = beta1 * *m + (1.0 - beta1) * g;
        *r = beta2 * *r + (1.0 - beta2) * g * g;
        let m_hat = *m / bias_c1;
        let r_hat = *r / bias_c2;
        let mut step = lr * m_hat / (r_hat.sqrt() + eps);
        if decoupled_wd && weight_decay != 0.0 {
            step += lr * weight_decay * *p;
        }
        *p -= step;
    }
}

impl Optimizer for Adam {
    // Fully block-local: one phase, no combine. Lane-chunked: both closures
    // apply the identical `update_rule`, so the vectorized path is
    // bit-identical to the scalar tail-and-oracle path.
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let cfg = self.cfg;
        let bias_c1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bias_c2 = 1.0 - cfg.beta2.powi(self.t as i32);
        let decoupled = cfg.kind == OptimKind::AdamW;
        let block = cfg.bits.state_block(params.len());
        StepPlan::single(block_steps_vec(
            params,
            grads,
            &mut self.m,
            Some(&mut self.r),
            block,
            move |v: LaneView| {
                let LaneView { params, grads, s1: m, s2, .. } = v;
                let r = s2.expect("adam has two states");
                for l in 0..LANES {
                    Self::update_rule(
                        &mut params[l],
                        grads[l],
                        &mut m[l],
                        &mut r[l],
                        cfg.lr,
                        cfg.beta1,
                        cfg.beta2,
                        cfg.eps,
                        cfg.weight_decay,
                        decoupled,
                        bias_c1,
                        bias_c2,
                    );
                }
            },
            move |v: BlockView| {
                let BlockView { params, grads, s1: m, s2, .. } = v;
                let r = s2.expect("adam has two states");
                for i in 0..params.len() {
                    Self::update_rule(
                        &mut params[i],
                        grads[i],
                        &mut m[i],
                        &mut r[i],
                        cfg.lr,
                        cfg.beta1,
                        cfg.beta2,
                        cfg.eps,
                        cfg.weight_decay,
                        decoupled,
                        bias_c1,
                        bias_c2,
                    );
                }
            },
        ))
    }

    fn state_bytes(&self) -> usize {
        self.m.bytes() + self.r.bytes()
    }

    fn name(&self) -> String {
        format!("{} {}", self.cfg.bits.describe(), self.cfg.kind.name())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m), ("r", &self.r)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m), ("r", &mut self.r)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::Bits;
    use crate::util::rng::Rng;

    fn quadratic_grads(p: &[f32], target: &[f32]) -> Vec<f32> {
        // loss = 0.5 * ||p - target||^2  ->  grad = p - target
        p.iter().zip(target).map(|(a, b)| a - b).collect()
    }

    #[test]
    fn adam32_converges_on_quadratic() {
        let n = 4096;
        let mut rng = Rng::new(1);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Adam::new(OptimConfig::adam(0.05, Bits::B32), n);
        for _ in 0..500 {
            let g = quadratic_grads(&p, &target);
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-3, "mse {mse}");
    }

    #[test]
    fn adam8_tracks_adam32_closely() {
        // The paper's core claim at micro scale: the 8-bit trajectory stays
        // close to the 32-bit one on a well-conditioned problem.
        let n = 8192;
        let mut rng = Rng::new(2);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p32 = vec![0.0f32; n];
        let mut p8 = vec![0.0f32; n];
        let mut o32 = Adam::new(OptimConfig::adam(0.05, Bits::B32), n);
        let mut o8 = Adam::new(OptimConfig::adam(0.05, Bits::b8_dynamic()), n);
        for _ in 0..300 {
            let g32 = quadratic_grads(&p32, &target);
            o32.step(&mut p32, &g32);
            let g8 = quadratic_grads(&p8, &target);
            o8.step(&mut p8, &g8);
        }
        let mse32: f32 =
            p32.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        let mse8: f32 =
            p8.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse32 < 1e-3);
        assert!(mse8 < 5e-3, "8-bit mse {mse8} vs 32-bit {mse32}");
    }

    #[test]
    fn adamw_decoupled_weight_decay_shrinks_params() {
        let n = 128;
        let mut cfg = OptimConfig::adam(0.0, Bits::B32); // lr used by wd term
        cfg.kind = OptimKind::AdamW;
        cfg.lr = 0.1;
        cfg.weight_decay = 0.1;
        let mut opt = Adam::new(cfg, n);
        let mut p = vec![1.0f32; n];
        let g = vec![0.0f32; n];
        opt.step(&mut p, &g);
        // zero grad: p shrinks by exactly lr*wd*p
        for &v in &p {
            assert!((v - (1.0 - 0.1 * 0.1)).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn adam_coupled_weight_decay_enters_moments() {
        let n = 16;
        let mut cfg = OptimConfig::adam(0.01, Bits::B32);
        cfg.weight_decay = 0.5;
        let mut opt = Adam::new(cfg, n);
        let mut p = vec![2.0f32; n];
        let g = vec![0.0f32; n];
        opt.step(&mut p, &g);
        // grad becomes wd*p = 1.0, so m > 0 after one step
        let m = opt.m.to_f32();
        assert!(m.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn bias_correction_first_step_matches_closed_form() {
        // After one step from zero state: m_hat = g, r_hat = g^2, so
        // p -= lr * g/(|g| + eps) = lr * sign(g) (approximately).
        let mut opt = Adam::new(OptimConfig::adam(0.1, Bits::B32), 4);
        let mut p = vec![0.0f32; 4];
        let g = vec![0.5f32, -0.5, 2.0, -2.0];
        opt.step(&mut p, &g);
        for (v, gi) in p.iter().zip(&g) {
            let expect = -0.1 * gi.signum();
            assert!((v - expect).abs() < 1e-3, "{v} vs {expect}");
        }
    }

    #[test]
    fn second_state_stays_nonnegative_in_8bit() {
        let n = 4096;
        let mut opt = Adam::new(OptimConfig::adam(0.01, Bits::b8_dynamic()), n);
        let mut rng = Rng::new(3);
        let mut p: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        for _ in 0..20 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            opt.step(&mut p, &g);
        }
        assert!(opt.r.to_f32().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn state_bytes_8bit_close_to_2_bytes_per_param() {
        let n = 1 << 16;
        let opt = Adam::new(OptimConfig::adam(0.01, Bits::b8_dynamic()), n);
        let per = opt.state_bytes() as f64 / n as f64;
        assert!(per < 2.02, "{per}");
    }
}
