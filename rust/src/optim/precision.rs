//! Layer 6: the adaptive precision controller — a runtime bit-width
//! policy over [`super::groups::ParamOptimizer`].
//!
//! The paper's block-wise 8-bit states hold a *static* precision chosen
//! at build time. This module re-resolves each tensor's width while the
//! run is live: on a configurable cadence it reviews deterministic
//! per-tensor signals and walks tensors one rung up or down the
//! 4 ↔ 8 ↔ 32 ladder, clamped to the group's `bits_min`/`bits_max`
//! bounds ([`ParamOptimizer::bits_bounds`]).
//!
//! Promotion triggers, in precedence order (first match wins):
//!
//! | trigger       | signal                                                        |
//! |---------------|---------------------------------------------------------------|
//! | `detector`    | a gradient crash, percentile-clip or update-norm-clip event   |
//! |               | landed since the last review (instability is global: every    |
//! |               | promotable tensor goes up a rung)                             |
//! | `gnorm_spike` | the tensor's max gradient norm since the last review exceeds  |
//! |               | `spike_factor` × its rolling median ([`GnormHistory`], ≥ 5    |
//! |               | observations)                                                 |
//! | `quant_error` | the measured resolution error of the tensor's stored state    |
//! |               | ([`resolution_error`] score, worst state) exceeds             |
//! |               | `promote_error`                                               |
//!
//! Demotion (`quiet` trigger): after `hysteresis` consecutive reviews in
//! which *no* promotion trigger fired, a tensor above its floor steps one
//! rung down — guarded by [`roundtrip_error`]: the state must survive
//! re-quantization at the narrower width with mean relative error below
//! `demote_error`, or the demotion is deferred to a later review.
//!
//! Transitions are **bit-lossless** by the same mechanism checkpoint
//! restore relies on: [`ParamOptimizer::set_tensor_bits`] requantizes
//! from the 32-bit working values, and the blockwise round trip is
//! idempotent (`q(dq(q(x))) == q(x)`), so promoting and later demoting a
//! healthy tensor reproduces its exact stored codes.
//!
//! Everything the controller consumes is deterministic and
//! thread-count-independent: per-tensor gradient norms are accumulated
//! in fixed element order by the trainer, clip/crash events are exact
//! drained counters, and the probes stream states sequentially — so the
//! transition sequence is pinned across threads × lanes × shards (the
//! `precision_parity` integration suite).

use super::groups::ParamOptimizer;
use super::stability::GnormHistory;
use super::StateTensor;
use crate::analysis::probe::{resolution_error, roundtrip_error};
use crate::quant::CodeWidth;
use anyhow::{anyhow, ensure, Result};

/// Tunables of the runtime bit-width policy (`[precision]` TOML table /
/// `--precision-policy` CLI).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionPolicy {
    /// Review every `cadence` steps.
    pub cadence: usize,
    /// Promote when a state's [`resolution_error`] score exceeds this.
    pub promote_error: f64,
    /// Demote only when the [`roundtrip_error`] at the narrower width
    /// stays strictly below this (0 disables demotion entirely).
    pub demote_error: f64,
    /// Promote when the window-max gradient norm exceeds this multiple of
    /// the tensor's rolling median norm.
    pub spike_factor: f64,
    /// Consecutive quiet reviews required before a demotion.
    pub hysteresis: u32,
}

impl Default for PrecisionPolicy {
    fn default() -> PrecisionPolicy {
        PrecisionPolicy {
            cadence: 25,
            promote_error: 0.6,
            demote_error: 0.1,
            spike_factor: 4.0,
            hysteresis: 2,
        }
    }
}

impl PrecisionPolicy {
    /// Set one policy key from its string form (shared TOML/CLI parser,
    /// the [`GroupOverride::set`](super::GroupOverride::set) pattern).
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        macro_rules! num {
            () => {
                val.parse().map_err(|_| anyhow!("[precision] key {key}: bad number {val:?}"))?
            };
        }
        match key {
            "cadence" => self.cadence = num!(),
            "promote_error" => self.promote_error = num!(),
            "demote_error" => self.demote_error = num!(),
            "spike_factor" => self.spike_factor = num!(),
            "hysteresis" => self.hysteresis = num!(),
            _ => {
                return Err(anyhow!(
                    "unknown [precision] key {key:?} (expected cadence, promote_error, \
                     demote_error, spike_factor, hysteresis)"
                ))
            }
        }
        Ok(())
    }

    /// Parse the CLI form `"key=val[,key=val...]"` over the defaults,
    /// e.g. `--precision-policy "cadence=50,spike_factor=8"`. An empty
    /// string yields the default policy.
    pub fn parse(text: &str) -> Result<PrecisionPolicy> {
        let mut p = PrecisionPolicy::default();
        for kv in text.split(',') {
            let kv = kv.trim();
            if kv.is_empty() {
                continue;
            }
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow!("--precision-policy: bad pair {kv:?} (want key=val)"))?;
            p.set(k.trim(), v.trim())?;
        }
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.cadence >= 1, "[precision] cadence must be >= 1");
        ensure!(
            self.promote_error.is_finite() && self.promote_error > 0.0,
            "[precision] promote_error must be finite and > 0"
        );
        ensure!(
            self.demote_error.is_finite() && self.demote_error >= 0.0,
            "[precision] demote_error must be finite and >= 0"
        );
        ensure!(
            self.spike_factor.is_finite() && self.spike_factor >= 1.0,
            "[precision] spike_factor must be finite and >= 1"
        );
        ensure!(self.hysteresis >= 1, "[precision] hysteresis must be >= 1");
        Ok(())
    }

    /// One-line summary for `--dry-run` / logs.
    pub fn describe(&self) -> String {
        format!(
            "cadence {} | promote_error {} | demote_error {} | spike x{} | hysteresis {}",
            self.cadence, self.promote_error, self.demote_error, self.spike_factor, self.hysteresis
        )
    }
}

/// One recorded width transition (JSONL `groups` stream / `RunResult`).
#[derive(Clone, Debug, PartialEq)]
pub struct Transition {
    pub step: usize,
    pub tensor: String,
    pub from_bits: u32,
    pub to_bits: u32,
    /// `"detector"`, `"gnorm_spike"`, `"quant_error"`, or `"quiet"`.
    pub trigger: &'static str,
}

/// Checkpointable per-tensor controller state (format v6). Histories are
/// serialized at full f64 precision: the spike trigger compares exact
/// medians, and a restored run must replay the same decisions bit for
/// bit.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TensorCtlState {
    /// Chronological gradient-norm history ([`GnormHistory::snapshot_f64`]).
    pub hist: Vec<f64>,
    /// Consecutive quiet reviews so far.
    pub quiet: u32,
    /// Max gradient norm observed since the last review.
    pub max_since_review: f64,
}

/// Live per-tensor tracking.
struct TensorCtl {
    floor: u32,
    ceil: u32,
    history: GnormHistory,
    quiet: u32,
    max_since_review: f64,
}

/// The runtime bit-width controller. The trainer feeds it one
/// [`PrecisionController::observe_step`] per optimizer step and calls
/// [`PrecisionController::review`] on the policy cadence; the controller
/// mutates tensor widths through [`ParamOptimizer::set_tensor_bits`] and
/// records every transition.
pub struct PrecisionController {
    policy: PrecisionPolicy,
    tensors: Vec<TensorCtl>,
    /// Clip + update-norm-clip events drained since the last review.
    window_clips: u64,
    /// A gradient crash landed since the last review.
    window_crash: bool,
    transitions: Vec<Transition>,
    peak_state_bytes: usize,
}

fn rung_up(bits: u32) -> u32 {
    match bits {
        4 => 8,
        _ => 32,
    }
}

fn rung_down(bits: u32) -> u32 {
    match bits {
        32 => 8,
        _ => 4,
    }
}

/// Per-state signedness for the demote-guard codebook: quantized states
/// carry it in their codebook (values sorted ascending, so a negative
/// first level means signed); 32-bit states are scanned.
fn state_is_signed(st: &StateTensor) -> bool {
    match st {
        StateTensor::Quant { codebook, .. } => {
            codebook.values().first().is_some_and(|&v| v < 0.0)
        }
        StateTensor::F32(v) => v.iter().any(|&x| x < 0.0),
    }
}

/// Would demoting tensor `i` to `to` bits stay under the loss budget?
fn demote_ok(popt: &ParamOptimizer, i: usize, to: u32, demote_error: f64) -> bool {
    if to == 32 {
        return true;
    }
    let width = if to == 4 { CodeWidth::U4 } else { CodeWidth::U8 };
    let (format, _) = popt.quant_template(i);
    popt.opt(i).states().iter().all(|(_, st)| {
        let cb = format.codebook(width, state_is_signed(st));
        roundtrip_error(st, &cb, width).mean_rel < demote_error
    })
}

impl PrecisionController {
    pub fn new(policy: PrecisionPolicy, popt: &ParamOptimizer) -> PrecisionController {
        let tensors = (0..popt.n_tensors())
            .map(|i| {
                let (floor, ceil) = popt.bits_bounds(i);
                TensorCtl {
                    floor,
                    ceil,
                    history: GnormHistory::new(),
                    quiet: 0,
                    max_since_review: 0.0,
                }
            })
            .collect();
        PrecisionController {
            policy,
            tensors,
            window_clips: 0,
            window_crash: false,
            transitions: Vec::new(),
            peak_state_bytes: popt.state_bytes(),
        }
    }

    pub fn policy(&self) -> &PrecisionPolicy {
        &self.policy
    }

    /// Is `step` (1-based, the trainer's post-increment count) a review
    /// step?
    pub fn due(&self, step: usize) -> bool {
        step > 0 && step % self.policy.cadence == 0
    }

    /// Record one optimizer step's signals: per-tensor squared gradient
    /// norms (fixed-order accumulation from the trainer's `grad_stats`)
    /// plus the clip / update-norm-clip / crash events it drained.
    pub fn observe_step(
        &mut self,
        tensor_sq_norms: &[f64],
        clip_events: u64,
        unorm_clips: u64,
        grad_crash: bool,
    ) {
        debug_assert_eq!(tensor_sq_norms.len(), self.tensors.len(), "tensor count mismatch");
        for (t, &sq) in self.tensors.iter_mut().zip(tensor_sq_norms) {
            let gnorm = sq.sqrt();
            t.history.push(gnorm);
            if gnorm.is_finite() && gnorm > t.max_since_review {
                t.max_since_review = gnorm;
            }
        }
        self.window_clips += clip_events + unorm_clips;
        self.window_crash |= grad_crash;
    }

    /// Run one review: resolve each tensor's triggers against the signals
    /// gathered since the last review, apply at most one rung of width
    /// change per tensor, reset the window, and return (and record) the
    /// transitions.
    pub fn review(&mut self, step: usize, popt: &mut ParamOptimizer) -> Vec<Transition> {
        let pol = self.policy;
        let global_unstable = self.window_crash || self.window_clips > 0;
        let mut out = Vec::new();
        for i in 0..self.tensors.len() {
            let (floor, ceil) = (self.tensors[i].floor, self.tensors[i].ceil);
            let max_gnorm = self.tensors[i].max_since_review;
            self.tensors[i].max_since_review = 0.0;
            if floor == ceil {
                continue; // pinned (HLO mirror, factored kind, or bounds)
            }
            let cur = popt.tensor_cfg(i).bits.bit_count();
            let spike = match self.tensors[i].history.clip_value(50.0) {
                Some(median) => max_gnorm > pol.spike_factor * median,
                None => false, // too little history to call a spike
            };
            let trigger = if global_unstable {
                Some("detector")
            } else if spike {
                Some("gnorm_spike")
            } else {
                let err_score = if cur < 32 {
                    popt.opt(i)
                        .states()
                        .iter()
                        .filter_map(|(_, st)| resolution_error(st))
                        .map(|s| s.score())
                        .fold(0.0, f64::max)
                } else {
                    0.0
                };
                (err_score > pol.promote_error).then_some("quant_error")
            };
            if let Some(trig) = trigger {
                self.tensors[i].quiet = 0;
                if cur < ceil {
                    let to = rung_up(cur).min(ceil);
                    if popt.set_tensor_bits(i, to) {
                        out.push(Transition {
                            step,
                            tensor: popt.tensor_name(i).to_string(),
                            from_bits: cur,
                            to_bits: to,
                            trigger: trig,
                        });
                    }
                }
            } else {
                self.tensors[i].quiet = self.tensors[i].quiet.saturating_add(1);
                if cur > floor && self.tensors[i].quiet >= pol.hysteresis {
                    let to = rung_down(cur).max(floor);
                    if demote_ok(popt, i, to, pol.demote_error)
                        && popt.set_tensor_bits(i, to)
                    {
                        // A fresh quiet window is required before the
                        // next rung down.
                        self.tensors[i].quiet = 0;
                        out.push(Transition {
                            step,
                            tensor: popt.tensor_name(i).to_string(),
                            from_bits: cur,
                            to_bits: to,
                            trigger: "quiet",
                        });
                    }
                }
            }
        }
        self.window_clips = 0;
        self.window_crash = false;
        self.peak_state_bytes = self.peak_state_bytes.max(popt.state_bytes());
        self.transitions.extend(out.iter().cloned());
        out
    }

    /// All transitions applied over the controller's lifetime.
    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    /// Largest total optimizer-state footprint seen at any review (plus
    /// the build-time footprint).
    pub fn peak_state_bytes(&self) -> usize {
        self.peak_state_bytes
    }

    /// Lets the trainer fold post-restore / post-step footprints into the
    /// peak without a review.
    pub fn note_state_bytes(&mut self, bytes: usize) {
        self.peak_state_bytes = self.peak_state_bytes.max(bytes);
    }

    /// Checkpoint capture (format v6): per-tensor state plus the global
    /// review window.
    pub fn snapshot(&self) -> (Vec<TensorCtlState>, u64, bool) {
        let tensors = self
            .tensors
            .iter()
            .map(|t| TensorCtlState {
                hist: t.history.snapshot_f64(),
                quiet: t.quiet,
                max_since_review: t.max_since_review,
            })
            .collect();
        (tensors, self.window_clips, self.window_crash)
    }

    /// Checkpoint restore: rebuild the review window exactly. Tensor
    /// bounds and the transition log are not part of the snapshot — the
    /// bounds are re-derived from the spec at build time, and the log
    /// counts transitions of *this* run.
    pub fn restore(&mut self, tensors: &[TensorCtlState], window_clips: u64, window_crash: bool) {
        for (t, s) in self.tensors.iter_mut().zip(tensors) {
            t.history.restore_f64(&s.hist);
            t.quiet = s.quiet;
            t.max_since_review = s.max_since_review;
        }
        self.window_clips = window_clips;
        self.window_crash = window_crash;
    }
}

/// `--dry-run` report: the resolved policy, each group's adaptive range,
/// and the best/worst-case projected state footprint
/// ([`ParamOptimizer::projected_state_bytes`]).
pub fn describe_policy(policy: &PrecisionPolicy, popt: &ParamOptimizer) -> String {
    let spec = popt.spec();
    let mut lines = vec![format!("precision policy: {}", policy.describe())];
    for g in 0..=spec.groups.len() {
        let cfg = if g == 0 { spec.base } else { spec.groups[g - 1].apply(&spec.base) };
        let start = cfg.bits.bit_count();
        let ov = if g == 0 { None } else { Some(&spec.groups[g - 1]) };
        let (floor, ceil) = if cfg.kind.supports_8bit() {
            let f = ov.and_then(|o| o.bits_min).unwrap_or(start);
            let c = ov.and_then(|o| o.bits_max).unwrap_or(32);
            (f.min(c), c.max(f))
        } else {
            (start, start) // factored kinds cannot requantize
        };
        lines.push(format!(
            "  group {:<24} start {:>2}-bit  floor {:>2}-bit  ceiling {:>2}-bit",
            spec.group_label(g),
            start,
            floor,
            ceil
        ));
    }
    let (lo, hi) = popt.projected_state_bytes();
    lines.push(format!("  projected state bytes: {lo} (all at floor) .. {hi} (all at ceiling)"));
    lines.join("\n")
}

#[cfg(test)]
mod tests {
    use super::super::{Bits, GroupOverride, OptimConfig, TensorInfo};
    use super::*;
    use crate::optim::{OptimSpec, ParamOptimizer};

    fn infos(names: &[(&str, usize)]) -> Vec<TensorInfo> {
        names
            .iter()
            .map(|&(name, size)| TensorInfo {
                name: name.to_string(),
                size,
                shape: None,
                padded: size.next_multiple_of(2048),
            })
            .collect()
    }

    fn build(bits: Bits, groups: Vec<GroupOverride>) -> ParamOptimizer {
        let spec = OptimSpec::with_groups(OptimConfig::adam(1e-3, bits), groups);
        ParamOptimizer::build(spec, &infos(&[("w.a", 256), ("w.b", 512)]), None).unwrap()
    }

    #[test]
    fn policy_parse_set_and_validate() {
        let p = PrecisionPolicy::parse("cadence=50, spike_factor=8").unwrap();
        assert_eq!(p.cadence, 50);
        assert_eq!(p.spike_factor, 8.0);
        assert_eq!(p.hysteresis, PrecisionPolicy::default().hysteresis);
        assert!(PrecisionPolicy::parse("").is_ok());
        assert!(PrecisionPolicy::parse("cadence=0").is_err());
        assert!(PrecisionPolicy::parse("nope=1").is_err());
        assert!(PrecisionPolicy::parse("cadence").is_err());
        let mut p = PrecisionPolicy::default();
        p.set("demote_error", "0").unwrap();
        assert!(p.validate().is_ok());
    }

    #[test]
    fn detector_promotes_one_rung_per_review_up_to_ceiling() {
        let mut popt = build(Bits::b4_dynamic(), vec![]);
        let start_bytes = popt.state_bytes();
        let mut ctl = PrecisionController::new(PrecisionPolicy::default(), &popt);
        assert!(ctl.due(25) && !ctl.due(26) && !ctl.due(0));

        ctl.observe_step(&[1.0, 1.0], 0, 0, true);
        let tr = ctl.review(25, &mut popt);
        assert_eq!(tr.len(), 2);
        for t in &tr {
            assert_eq!((t.from_bits, t.to_bits, t.trigger), (4, 8, "detector"));
        }
        assert_eq!(popt.tensor_cfg(0).bits.bit_count(), 8);

        // Clip events alone (no crash) also count as instability.
        ctl.observe_step(&[1.0, 1.0], 2, 1, false);
        let tr = ctl.review(50, &mut popt);
        assert_eq!(tr.len(), 2);
        assert_eq!((tr[0].from_bits, tr[0].to_bits), (8, 32));

        // At the ceiling: instability no longer transitions anything.
        ctl.observe_step(&[1.0, 1.0], 0, 0, true);
        assert!(ctl.review(75, &mut popt).is_empty());
        assert_eq!(popt.tensor_cfg(1).bits.bit_count(), 32);
        assert_eq!(ctl.transitions().len(), 4);
        assert!(ctl.peak_state_bytes() > start_bytes);
    }

    #[test]
    fn quiet_reviews_demote_after_hysteresis() {
        let mut popt = build(Bits::b4_dynamic(), vec![]);
        let policy = PrecisionPolicy { demote_error: 0.9, ..PrecisionPolicy::default() };
        let mut ctl = PrecisionController::new(policy, &popt);

        ctl.observe_step(&[1.0, 1.0], 0, 0, true);
        assert_eq!(ctl.review(25, &mut popt).len(), 2); // 4 -> 8

        ctl.observe_step(&[0.01, 0.01], 0, 0, false);
        assert!(ctl.review(50, &mut popt).is_empty()); // quiet 1 of 2
        ctl.observe_step(&[0.01, 0.01], 0, 0, false);
        let tr = ctl.review(75, &mut popt); // quiet 2 of 2
        assert_eq!(tr.len(), 2);
        for t in &tr {
            assert_eq!((t.from_bits, t.to_bits, t.trigger), (8, 4, "quiet"));
        }
        // Never below the floor (= the build-time width, 4).
        ctl.observe_step(&[0.01, 0.01], 0, 0, false);
        ctl.observe_step(&[0.01, 0.01], 0, 0, false);
        assert!(ctl.review(100, &mut popt).is_empty());
        assert_eq!(popt.tensor_cfg(0).bits.bit_count(), 4);
    }

    #[test]
    fn frozen_policy_never_transitions() {
        let mut popt = build(Bits::b8_dynamic(), vec![]);
        let policy =
            PrecisionPolicy::parse("promote_error=2, spike_factor=1e9, demote_error=0").unwrap();
        let mut ctl = PrecisionController::new(policy, &popt);
        for s in 1..=100usize {
            let g = if s % 10 == 0 { 1e6 } else { 1.0 };
            ctl.observe_step(&[g, g], 0, 0, false);
            if ctl.due(s) {
                assert!(ctl.review(s, &mut popt).is_empty(), "step {s}");
            }
        }
        assert_eq!(popt.tensor_cfg(0).bits.bit_count(), 8);
        assert!(ctl.transitions().is_empty());
    }

    #[test]
    fn gnorm_spike_trigger_and_snapshot_restore_agree() {
        let policy = PrecisionPolicy { spike_factor: 2.0, ..PrecisionPolicy::default() };
        let mut popt_a = build(Bits::b4_dynamic(), vec![]);
        let mut popt_b = build(Bits::b4_dynamic(), vec![]);
        let mut a = PrecisionController::new(policy, &popt_a);

        // Warm the history past GNORM_MIN_HISTORY, then checkpoint.
        for _ in 0..6 {
            a.observe_step(&[1.0, 1.0], 0, 0, false);
        }
        let (ts, clips, crash) = a.snapshot();
        let mut b = PrecisionController::new(policy, &popt_b);
        b.restore(&ts, clips, crash);

        // Identical continuation: tensor 0 spikes, tensor 1 stays calm.
        for ctl in [&mut a, &mut b] {
            ctl.observe_step(&[1e4, 1.0], 0, 0, false);
        }
        let tr_a = a.review(25, &mut popt_a);
        let tr_b = b.review(25, &mut popt_b);
        assert_eq!(tr_a, tr_b);
        assert_eq!(tr_a.len(), 1);
        assert_eq!(tr_a[0].tensor, "w.a");
        assert_eq!((tr_a[0].from_bits, tr_a[0].to_bits, tr_a[0].trigger), (4, 8, "gnorm_spike"));
        assert_eq!(popt_a.tensor_cfg(0).bits.bit_count(), 8);
        assert_eq!(popt_a.tensor_cfg(1).bits.bit_count(), 4);
    }

    #[test]
    fn bounds_respect_group_overrides_in_describe_and_review() {
        let ov = GroupOverride::parse("w.a:bits_max=8").unwrap();
        let mut popt = build(Bits::b4_dynamic(), vec![ov]);
        assert_eq!(popt.bits_bounds(0), (4, 8));
        assert_eq!(popt.bits_bounds(1), (4, 32));
        let policy = PrecisionPolicy::default();
        let text = describe_policy(&policy, &popt);
        assert!(text.contains("ceiling  8-bit"), "{text}");
        assert!(text.contains("projected state bytes"), "{text}");

        let mut ctl = PrecisionController::new(policy, &popt);
        ctl.observe_step(&[1.0, 1.0], 0, 0, true);
        assert_eq!(ctl.review(25, &mut popt).len(), 2); // both 4 -> 8
        ctl.observe_step(&[1.0, 1.0], 0, 0, true);
        let tr = ctl.review(50, &mut popt);
        // w.a is capped at 8; only w.b promotes to 32.
        assert_eq!(tr.len(), 1);
        assert_eq!(tr[0].tensor, "w.b");
        assert_eq!(popt.tensor_cfg(0).bits.bit_count(), 8);
        assert_eq!(popt.tensor_cfg(1).bits.bit_count(), 32);
    }
}
