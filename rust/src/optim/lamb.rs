//! LAMB (You et al. 2019) — Adam with a per-tensor trust ratio. Appears in
//! Table 5's runtime comparison. 8-bit variant quantizes the two Adam
//! moments exactly like 8-bit Adam; the trust-ratio norms are computed on
//! the dequantized update in the same fused pass.
//!
//! u = m̂/(√r̂ + ε) + wd·w;  trust = ‖w‖/‖u‖ (1 if either is 0);
//! w −= lr · trust · u.
//!
//! Two-phase plan: phase A updates the quantized moments block by block,
//! materializes u, and emits per-chunk ‖w‖²/‖u‖² partials (the canonical
//! `util::reduce` reduction); the combine folds them in fixed chunk order
//! into the trust ratio; phase B applies `w −= lr·trust·u` block-locally.
//! No whole-tensor pass remains — every item runs inside the fused
//! engine's pool batches.

use super::state::{
    block_steps, AccessSet, BlockSteps, BlockView, CombineAccess, Phase, Region, Span, StateTensor,
    StepPlan,
};
use super::{make_state, Bits, OptimConfig, Optimizer};
use crate::util::lanes::{self, LANES};
use crate::util::parallel::Shared;
use crate::util::reduce;

pub struct Lamb {
    cfg: OptimConfig,
    m: StateTensor,
    r: StateTensor,
    /// Per-step update direction (reused buffer; not optimizer state).
    u: Vec<f32>,
    /// Phase-A norm partials: `[w chunks | u chunks]`.
    partials: Vec<f64>,
    /// lr·trust, written by the combine, read by phase B.
    scale: f32,
    t: u64,
}

impl Lamb {
    pub fn new(cfg: OptimConfig, n: usize) -> Lamb {
        Lamb {
            cfg,
            m: make_state(&cfg.bits, n, true),
            r: make_state(&cfg.bits, n, false),
            u: vec![0.0; n],
            partials: vec![0.0; 2 * reduce::n_chunks(n)],
            scale: 0.0,
            t: 0,
        }
    }
}

impl Optimizer for Lamb {
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let cfg = self.cfg;
        let bias_c1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bias_c2 = 1.0 - cfg.beta2.powi(self.t as i32);
        let n = params.len();
        assert_eq!(self.u.len(), n);
        let nc = reduce::n_chunks(n);
        self.partials.resize(2 * nc, 0.0);
        // SAFETY (all `Shared` uses below): phase-A items write disjoint
        // chunks of u and disjoint partial slots, and only read params; the
        // combine runs alone after the phase-A barrier; phase-B items write
        // disjoint param chunks and read u/scale after the barrier. `plan`'s
        // `&'a mut self` borrow keeps every target alive for the plan.
        let partials = Shared::new(&mut self.partials);
        let scale = Shared::new(std::slice::from_mut(&mut self.scale));
        let params_sh = Shared::new(params);
        let u_sh = Shared::new(&mut self.u);

        // Phase A: moment update + u, via the block engine with u in the
        // "params" slot (real params are only read, for the wd term and the
        // ‖w‖ partial). State blocks are either one reduce-chunk or the
        // whole tensor, so chunks never straddle items.
        let block = cfg.bits.state_block(n);
        // Single-writer contract for the partial slots: every phase-A item
        // must cover whole reduce-chunks, i.e. state blocks are CHUNK-
        // aligned or the tensor is one item.
        debug_assert!(
            block % reduce::CHUNK == 0 || block >= n,
            "phase-A partials need chunk-aligned state blocks (block {block}, n {n})"
        );
        let u_slot: &'a mut [f32] = unsafe { u_sh.range_mut(0, n) };
        let phase_a = block_steps(
            u_slot,
            grads,
            &mut self.m,
            Some(&mut self.r),
            block,
            move |v: BlockView| {
                let BlockView { params: u_b, grads, s1: m, s2, start } = v;
                let r = s2.expect("lamb has two states");
                let w = unsafe { params_sh.range(start, start + u_b.len()) };
                // Elementwise moment update + u, lane-chunked by hand: this
                // kernel reads `w` through `params_sh` and runs a partials
                // pass below, so it can't ride `block_steps_vec`. Same
                // per-element arithmetic in both paths => bit-identical.
                #[inline(always)]
                fn rule(
                    u: &mut f32,
                    g: f32,
                    m: &mut f32,
                    r: &mut f32,
                    w: f32,
                    cfg: &OptimConfig,
                    bias_c1: f32,
                    bias_c2: f32,
                ) {
                    *m = cfg.beta1 * *m + (1.0 - cfg.beta1) * g;
                    *r = cfg.beta2 * *r + (1.0 - cfg.beta2) * g * g;
                    let m_hat = *m / bias_c1;
                    let r_hat = *r / bias_c2;
                    *u = m_hat / (r_hat.sqrt() + cfg.eps) + cfg.weight_decay * w;
                }
                let len = u_b.len();
                let main = if lanes::scalar_forced() { 0 } else { len - len % LANES };
                for c in 0..main / LANES {
                    let off = c * LANES;
                    let u_l = <&mut [f32; LANES]>::try_from(&mut u_b[off..off + LANES]).unwrap();
                    let g_l = <&[f32; LANES]>::try_from(&grads[off..off + LANES]).unwrap();
                    let m_l = <&mut [f32; LANES]>::try_from(&mut m[off..off + LANES]).unwrap();
                    let r_l = <&mut [f32; LANES]>::try_from(&mut r[off..off + LANES]).unwrap();
                    let w_l = <&[f32; LANES]>::try_from(&w[off..off + LANES]).unwrap();
                    for l in 0..LANES {
                        rule(&mut u_l[l], g_l[l], &mut m_l[l], &mut r_l[l], w_l[l], &cfg, bias_c1, bias_c2);
                    }
                }
                for i in main..len {
                    rule(&mut u_b[i], grads[i], &mut m[i], &mut r[i], w[i], &cfg, bias_c1, bias_c2);
                }
                // Per-chunk norm partials for the chunks this item covers.
                let mut lo = 0usize;
                while lo < u_b.len() {
                    let c = (start + lo) / reduce::CHUNK;
                    let hi = (lo + reduce::CHUNK).min(u_b.len());
                    unsafe {
                        partials.write(c, reduce::sum_sq(&w[lo..hi]));
                        partials.write(nc + c, reduce::sum_sq(&u_b[lo..hi]));
                    }
                    lo = hi;
                }
            },
        );
        // Combine: fold partials in fixed chunk order -> trust ratio.
        let combine = move || {
            let p = unsafe { partials.range(0, 2 * nc) };
            let w_norm = reduce::fold(&p[..nc]).sqrt() as f32;
            let u_norm = reduce::fold(&p[nc..]).sqrt() as f32;
            let trust = if w_norm > 0.0 && u_norm > 0.0 { w_norm / u_norm } else { 1.0 };
            unsafe { scale.write(0, cfg.lr * trust) };
        };

        // Phase B: apply, block-locally.
        let phase_b = BlockSteps::from_fn(nc, move |c| {
            let (lo, hi) = reduce::chunk_bounds(n, c);
            let p = unsafe { params_sh.range_mut(lo, hi) };
            let u = unsafe { u_sh.range(lo, hi) };
            let step = unsafe { scale.read(0) };
            for i in 0..p.len() {
                p[i] -= step * u[i];
            }
        });

        // Chunks covered by one phase-A item (state blocks are CHUNK-
        // aligned, or the tensor is a single item).
        let cpb = if block >= n { nc } else { block / reduce::CHUNK };
        let chunk = Span::Blocked { base: 0, block: reduce::CHUNK, n };
        let mut plan = StepPlan::new();
        plan.push(
            Phase::with_combine(phase_a, combine).map_access(move |a| {
                // The "params" slot of phase A carries u; real parameters
                // are only read (weight decay + the ‖w‖ partial).
                a.relabel(Region::Params, Region::Slot("lamb.u"))
                    .preset(Region::Slot("lamb.u"))
                    .read(Region::Params, Span::Blocked { base: 0, block, n })
                    .write(
                        Region::Slot("lamb.partials"),
                        Span::Blocked { base: 0, block: cpb, n: nc },
                    )
                    .write(
                        Region::Slot("lamb.partials"),
                        Span::Blocked { base: nc, block: cpb, n: nc },
                    )
                    .combine(
                        CombineAccess::deterministic()
                            .read(Region::Slot("lamb.partials"), Span::All { lo: 0, hi: 2 * nc })
                            .write(Region::Slot("lamb.scale"), Span::All { lo: 0, hi: 1 }),
                    )
            }),
        );
        plan.push(Phase::new(phase_b).with_access(
            AccessSet::new()
                .rmw(Region::Params, chunk)
                .read(Region::Slot("lamb.u"), chunk)
                .read(Region::Slot("lamb.scale"), Span::All { lo: 0, hi: 1 }),
        ));
        plan
    }

    fn state_bytes(&self) -> usize {
        // u is transient scratch, not persistent optimizer state, but we
        // still report it: it exists for the lifetime of the optimizer.
        self.m.bytes() + self.r.bytes() + self.u.len() * 4
    }

    fn name(&self) -> String {
        format!("{} lamb", self.cfg.bits.describe())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m), ("r", &self.r)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m), ("r", &mut self.r)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }

    fn set_bits(&mut self, bits: &Bits) -> bool {
        if !self.cfg.kind.supports_bits(bits) {
            return false;
        }
        super::requantize_state(&mut self.m, bits, true);
        super::requantize_state(&mut self.r, bits, false);
        self.cfg.bits = *bits;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32, bits: Bits) -> OptimConfig {
        let mut cfg = OptimConfig::adam(lr, bits);
        cfg.kind = OptimKind::Lamb;
        cfg.beta2 = 0.999;
        cfg.eps = 1e-6;
        cfg
    }

    #[test]
    fn lamb32_converges_on_quadratic() {
        let n = 1024;
        let mut rng = Rng::new(12);
        let target: Vec<f32> = (0..n).map(|_| 1.0 + rng.normal() as f32 * 0.3).collect();
        let mut p = vec![3.0f32; n];
        let mut opt = Lamb::new(cfg(0.05, Bits::B32), n);
        for _ in 0..800 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-2, "mse {mse}");
    }

    #[test]
    fn trust_ratio_normalizes_step_scale() {
        // LAMB's step magnitude is set by ||w||, not by gradient scale:
        // scaling the gradient by 1000x should barely change the step.
        let make = || Lamb::new(cfg(0.1, Bits::B32), 64);
        let mut p1 = vec![1.0f32; 64];
        let mut p2 = vec![1.0f32; 64];
        let g1 = vec![0.001f32; 64];
        let g2 = vec![1.0f32; 64];
        let mut o1 = make();
        let mut o2 = make();
        o1.step(&mut p1, &g1);
        o2.step(&mut p2, &g2);
        let s1 = (1.0 - p1[0]).abs();
        let s2 = (1.0 - p2[0]).abs();
        assert!((s1 - s2).abs() < s2 * 0.1, "{s1} vs {s2}");
    }

    #[test]
    fn lamb8_finite_and_converging() {
        let n = 4096;
        let mut rng = Rng::new(13);
        let target: Vec<f32> = (0..n).map(|_| 1.0 + rng.normal() as f32 * 0.3).collect();
        let mut p = vec![3.0f32; n];
        let mut opt = Lamb::new(cfg(0.05, Bits::b8_dynamic()), n);
        let mse0: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        for _ in 0..400 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(p.iter().all(|v| v.is_finite()));
        assert!(mse < mse0 * 0.05, "mse {mse} (from {mse0})");
    }
}
