//! LAMB (You et al. 2019) — Adam with a per-tensor trust ratio. Appears in
//! Table 5's runtime comparison. 8-bit variant quantizes the two Adam
//! moments exactly like 8-bit Adam; the trust-ratio norms are computed on
//! the dequantized update in the same fused pass.
//!
//! u = m̂/(√r̂ + ε) + wd·w;  trust = ‖w‖/‖u‖ (1 if either is 0);
//! w −= lr · trust · u.

use super::lars::l2_norm;
use super::state::{step_blocks, BlockView, StateTensor};
use super::{make_state, OptimConfig, Optimizer};

pub struct Lamb {
    cfg: OptimConfig,
    m: StateTensor,
    r: StateTensor,
    /// Per-step update direction (reused buffer; not optimizer state).
    u: Vec<f32>,
    t: u64,
}

impl Lamb {
    pub fn new(cfg: OptimConfig, n: usize) -> Lamb {
        Lamb {
            cfg,
            m: make_state(&cfg.bits, n, true),
            r: make_state(&cfg.bits, n, false),
            u: vec![0.0; n],
            t: 0,
        }
    }
}

impl Optimizer for Lamb {
    // Not block-local: the trust ratio is a whole-tensor reduction *between*
    // the moment update and the apply, so the fused engine schedules LAMB
    // tensors as whole-tensor items (inter-tensor parallelism still holds).
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        self.t += 1;
        let cfg = self.cfg;
        let bias_c1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bias_c2 = 1.0 - cfg.beta2.powi(self.t as i32);

        // Pass 1: update moments, materialize the un-trust-scaled update u.
        {
            let u = &mut self.u;
            // params are only read in pass 1 (wd term); split borrow by
            // using the block engine on u in the "params" slot.
            let block = cfg.bits.state_block(u.len());
            let p_ro: &[f32] = params;
            step_blocks(u, grads, &mut self.m, Some(&mut self.r), block, |v: BlockView| {
                let BlockView { params: u_b, grads, s1: m, s2, start } = v;
                let r = s2.expect("lamb has two states");
                for i in 0..u_b.len() {
                    let g = grads[i];
                    m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * g;
                    r[i] = cfg.beta2 * r[i] + (1.0 - cfg.beta2) * g * g;
                    let m_hat = m[i] / bias_c1;
                    let r_hat = r[i] / bias_c2;
                    u_b[i] = m_hat / (r_hat.sqrt() + cfg.eps)
                        + cfg.weight_decay * p_ro[start + i];
                }
            });
        }

        // Trust ratio from whole-tensor norms.
        let w_norm = l2_norm(params) as f32;
        let u_norm = l2_norm(&self.u) as f32;
        let trust = if w_norm > 0.0 && u_norm > 0.0 { w_norm / u_norm } else { 1.0 };
        let step = cfg.lr * trust;

        // Pass 2: apply.
        for (p, &u) in params.iter_mut().zip(self.u.iter()) {
            *p -= step * u;
        }
    }

    fn state_bytes(&self) -> usize {
        // u is transient scratch, not persistent optimizer state, but we
        // still report it: it exists for the lifetime of the optimizer.
        self.m.bytes() + self.r.bytes() + self.u.len() * 4
    }

    fn name(&self) -> String {
        format!("{} lamb", self.cfg.bits.describe())
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m), ("r", &self.r)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m), ("r", &mut self.r)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32, bits: Bits) -> OptimConfig {
        OptimConfig {
            kind: OptimKind::Lamb,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            weight_decay: 0.0,
            bits,
        }
    }

    #[test]
    fn lamb32_converges_on_quadratic() {
        let n = 1024;
        let mut rng = Rng::new(12);
        let target: Vec<f32> = (0..n).map(|_| 1.0 + rng.normal() as f32 * 0.3).collect();
        let mut p = vec![3.0f32; n];
        let mut opt = Lamb::new(cfg(0.05, Bits::B32), n);
        for _ in 0..800 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 1e-2, "mse {mse}");
    }

    #[test]
    fn trust_ratio_normalizes_step_scale() {
        // LAMB's step magnitude is set by ||w||, not by gradient scale:
        // scaling the gradient by 1000x should barely change the step.
        let make = || Lamb::new(cfg(0.1, Bits::B32), 64);
        let mut p1 = vec![1.0f32; 64];
        let mut p2 = vec![1.0f32; 64];
        let g1 = vec![0.001f32; 64];
        let g2 = vec![1.0f32; 64];
        let mut o1 = make();
        let mut o2 = make();
        o1.step(&mut p1, &g1);
        o2.step(&mut p2, &g2);
        let s1 = (1.0 - p1[0]).abs();
        let s2 = (1.0 - p2[0]).abs();
        assert!((s1 - s2).abs() < s2 * 0.1, "{s1} vs {s2}");
    }

    #[test]
    fn lamb8_finite_and_converging() {
        let n = 4096;
        let mut rng = Rng::new(13);
        let target: Vec<f32> = (0..n).map(|_| 1.0 + rng.normal() as f32 * 0.3).collect();
        let mut p = vec![3.0f32; n];
        let mut opt = Lamb::new(cfg(0.05, Bits::b8_dynamic()), n);
        let mse0: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        for _ in 0..400 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(p.iter().all(|v| v.is_finite()));
        assert!(mse < mse0 * 0.05, "mse {mse} (from {mse0})");
    }
}
