//! The bnb stability toolkit as fused per-group phases: percentile
//! clipping (`clip_percentile`), update-norm clipping (`max_unorm`), and
//! sparse-gradient semantics (`skip_zeros`) — the paper's §3 stability
//! tools as they actually ship in bitsandbytes, executed *inside* the
//! fused/streaming batch instead of as serial pre-passes.
//!
//! Mechanisms (per tensor, resolved per parameter group):
//!
//! * **Percentile clipping** keeps a rolling window of the tensor's last
//!   [`GNORM_WINDOW`] gradient norms ([`GnormHistory`]). Each step the
//!   gradient norm is computed as the canonical two-phase reduction
//!   (per-chunk squared-norm partials, deterministic ordered fold —
//!   `util::reduce`); when it exceeds the `clip_percentile`-th percentile
//!   of the history, the gradient is scaled down to that percentile before
//!   it enters the moments. The raw (unclipped) norm is recorded, so a
//!   sustained shift in gradient scale re-adapts within one window.
//! * **`max_unorm`** materializes the raw update direction `u`, reduces
//!   `‖w‖` and `‖u‖` the same two-phase way, and scales the applied step
//!   down when `‖u‖ > max_unorm · ‖w‖`.
//! * **`skip_zeros`** leaves elements with an exactly-zero gradient
//!   untouched: moments and parameter keep their working values (for
//!   quantized state the block still requantizes, so a neighbour's update
//!   may move the block absmax — storage round-trip, not an update).
//!
//! Everything runs through [`stabilized_plan`], the shared phased-plan
//! builder used by Adam/AdamW, Momentum, and AdaGrad: an optional
//! gnorm-partials phase + clip combine, then either the direct elementwise
//! phase (lane-chunked via `block_steps_vec`, scalar tail-and-oracle) or —
//! when `max_unorm` is active — the LAMB-shaped trio of moment/u phase
//! with norm partials, unorm combine, and block-local apply. All phases
//! compose with `StepPlan`/`FusedStep`/`StreamingStep` and stay
//! bit-identical at every thread count and admission order.
//!
//! Clip activity is exported through process-global counters drained by
//! the trainer into the JSONL step records ([`take_clip_events`],
//! [`take_unorm_clips`] — the `NONFINITE_BLOCKS` telemetry pattern).

use std::sync::atomic::{AtomicU64, Ordering};

use super::state::{
    block_steps, block_steps_vec, AccessSet, BlockSteps, BlockView, CombineAccess, Counter,
    LaneView, Phase, Region, Span, StateTensor, StepPlan,
};
use super::OptimConfig;
use crate::util::lanes::{self, LANES};
use crate::util::parallel::Shared;
use crate::util::{reduce, stats};

/// Rolling gradient-norm window length (bnb's `gnorm_vec` is 100 steps).
pub const GNORM_WINDOW: usize = 100;

/// Minimum recorded norms before the percentile clip engages — clipping
/// against one or two observations would be noise, not statistics.
pub const GNORM_MIN_HISTORY: usize = 5;

/// Rolling per-tensor gradient-norm history feeding the percentile clip.
/// Non-finite norms are never recorded (a broken gradient must not poison
/// the statistics the *next* steps clip against).
#[derive(Clone, Debug, Default)]
pub struct GnormHistory {
    vals: Vec<f64>,
    /// Next write position once the window is full.
    pos: usize,
}

impl GnormHistory {
    pub fn new() -> GnormHistory {
        GnormHistory::default()
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Record one observed gradient norm (ignored when non-finite).
    pub fn push(&mut self, gnorm: f64) {
        if !gnorm.is_finite() {
            return;
        }
        if self.vals.len() < GNORM_WINDOW {
            self.vals.push(gnorm);
        } else {
            self.vals[self.pos] = gnorm;
        }
        self.pos = (self.pos + 1) % GNORM_WINDOW;
    }

    /// Clip threshold: the `percentile`-th percentile of the recorded
    /// norms, once at least [`GNORM_MIN_HISTORY`] exist. `None` while the
    /// history is too short (no clipping) or the quantile is degenerate.
    pub fn clip_value(&self, percentile: f32) -> Option<f64> {
        if self.vals.len() < GNORM_MIN_HISTORY {
            return None;
        }
        let v = stats::percentile(&self.vals, percentile as f64);
        (v.is_finite() && v > 0.0).then_some(v)
    }

    /// Chronological snapshot (oldest first) for checkpointing.
    pub fn snapshot(&self) -> Vec<f32> {
        if self.vals.len() < GNORM_WINDOW {
            self.vals.iter().map(|&v| v as f32).collect()
        } else {
            (0..GNORM_WINDOW)
                .map(|i| self.vals[(self.pos + i) % GNORM_WINDOW] as f32)
                .collect()
        }
    }

    /// Rebuild from a [`GnormHistory::snapshot`] (checkpoint restore).
    pub fn restore(&mut self, snap: &[f32]) {
        self.vals.clear();
        self.pos = 0;
        let skip = snap.len().saturating_sub(GNORM_WINDOW);
        for &v in &snap[skip..] {
            self.push(v as f64);
        }
    }

    /// Full-precision chronological snapshot (oldest first). The precision
    /// controller's spike trigger compares exact f64 medians, so its
    /// checkpointed histories must not round through f32 — a restored run
    /// has to reproduce the same promote/demote decisions bit for bit.
    pub fn snapshot_f64(&self) -> Vec<f64> {
        if self.vals.len() < GNORM_WINDOW {
            self.vals.clone()
        } else {
            (0..GNORM_WINDOW).map(|i| self.vals[(self.pos + i) % GNORM_WINDOW]).collect()
        }
    }

    /// Rebuild from a [`GnormHistory::snapshot_f64`] without precision loss.
    pub fn restore_f64(&mut self, snap: &[f64]) {
        self.vals.clear();
        self.pos = 0;
        let skip = snap.len().saturating_sub(GNORM_WINDOW);
        for &v in &snap[skip..] {
            self.push(v);
        }
    }
}

// ---- clip telemetry (the NONFINITE_BLOCKS pattern: process-global
// counters, drained by the trainer into the JSONL step records) ----------

static CLIP_EVENTS: AtomicU64 = AtomicU64::new(0);
static UNORM_CLIPS: AtomicU64 = AtomicU64::new(0);

/// Drain the percentile-clip event counter (tensors clipped since the
/// last call).
pub fn take_clip_events() -> u64 {
    CLIP_EVENTS.swap(0, Ordering::Relaxed)
}

/// Drain the update-norm clip counter.
pub fn take_unorm_clips() -> u64 {
    UNORM_CLIPS.swap(0, Ordering::Relaxed)
}

/// Test-only: bump both clip counters, so drain-path regression tests can
/// verify a crashed step's counts never leak into the next step's record.
#[cfg(test)]
pub(crate) fn bump_counters_for_test(clips: u64, unorms: u64) {
    CLIP_EVENTS.fetch_add(clips, Ordering::Relaxed);
    UNORM_CLIPS.fetch_add(unorms, Ordering::Relaxed);
}

/// Per-optimizer stability scratch: the gnorm history plus the reduction
/// partials / update buffer / cross-phase scales the stabilized plan
/// routes through `Shared`. Empty (a few dozen bytes) until the first
/// stabilized step.
#[derive(Default)]
pub(crate) struct Stab {
    pub(crate) history: GnormHistory,
    /// Raw update direction (allocated only when `max_unorm` is active).
    u: Vec<f32>,
    /// Reduction partials: `[gnorm chunks | ‖w‖ chunks | ‖u‖ chunks]`.
    partials: Vec<f64>,
    /// `[0]` = gradient scale (clip combine), `[1]` = lr · unorm factor
    /// (unorm combine) — written between barriers, read by later phases.
    scales: [f32; 2],
}

impl Stab {
    fn ensure(&mut self, n: usize, need_u: bool) {
        self.partials.resize(3 * reduce::n_chunks(n), 0.0);
        if need_u {
            self.u.resize(n, 0.0);
        }
    }
}

/// Gradient-norm phase: per-chunk squared-norm partials over the raw
/// gradient, then a combine that folds them in chunk order, consults the
/// history's percentile, and writes the gradient scale for the next phase.
/// A non-finite norm leaves the scale at 1.0 and is not recorded — broken
/// gradients are the trainer's `grad_stats`/detector problem, not the
/// clip's.
fn gnorm_clip_phase<'a>(
    grads: &'a [f32],
    partials: Shared<f64>,
    history: Shared<GnormHistory>,
    scales: Shared<f32>,
    clip_percentile: f32,
) -> Phase<'a> {
    let n = grads.len();
    let nc = reduce::n_chunks(n);
    let items = BlockSteps::from_fn(nc, move |c| {
        let (lo, hi) = reduce::chunk_bounds(n, c);
        // SAFETY: partial slot c is written only by item c of this phase.
        unsafe { partials.write(c, reduce::sum_sq(&grads[lo..hi])) };
    });
    let combine = move || {
        // SAFETY: combines run alone between the phase barriers.
        let p = unsafe { partials.range(0, nc) };
        let gnorm = reduce::fold(p).sqrt();
        let h = unsafe { &mut history.range_mut(0, 1)[0] };
        let mut scale = 1.0f32;
        if gnorm.is_finite() {
            if let Some(clip) = h.clip_value(clip_percentile) {
                if gnorm > clip {
                    scale = (clip / gnorm) as f32;
                    CLIP_EVENTS.fetch_add(1, Ordering::Relaxed);
                }
            }
            h.push(gnorm);
        }
        unsafe { scales.write(0, scale) };
    };
    Phase::with_combine(items, combine).with_access(
        AccessSet::new()
            .read(Region::Grads, Span::Blocked { base: 0, block: reduce::CHUNK, n })
            .write(Region::Slot("stab.partials"), Span::Blocked { base: 0, block: 1, n: nc })
            .preset(Region::Slot("stab.history"))
            .preset(Region::Slot("stab.scales"))
            .combine(
                CombineAccess::deterministic()
                    .read(Region::Slot("stab.partials"), Span::All { lo: 0, hi: nc })
                    .read(Region::Slot("stab.history"), Span::All { lo: 0, hi: 1 })
                    .write(Region::Slot("stab.history"), Span::All { lo: 0, hi: 1 })
                    .write(Region::Slot("stab.scales"), Span::All { lo: 0, hi: 1 })
                    .counter(Counter::ClipEvents),
            ),
    )
}

/// Update-norm combine: fold the `‖w‖²`/`‖u‖²` partials the moment/u phase
/// wrote, and derive the applied step scale `lr · min(1, max_unorm·‖w‖ /
/// ‖u‖)`. Zero-norm params never clip (a fresh tensor must be able to
/// leave the origin).
fn unorm_combine(
    partials: Shared<f64>,
    nc: usize,
    scales: Shared<f32>,
    lr: f32,
    max_unorm: f32,
) -> impl FnOnce() + Send + Sync {
    move || {
        // SAFETY: combines run alone between the phase barriers.
        let p = unsafe { partials.range(nc, 3 * nc) };
        let w_norm = reduce::fold(&p[..nc]).sqrt();
        let u_norm = reduce::fold(&p[nc..]).sqrt();
        let limit = max_unorm as f64 * w_norm;
        let mut factor = 1.0f64;
        if w_norm > 0.0 && u_norm > limit {
            factor = limit / u_norm;
            UNORM_CLIPS.fetch_add(1, Ordering::Relaxed);
        }
        unsafe { scales.write(1, lr * factor as f32) };
    }
}

/// Final phase of the `max_unorm` path: `w -= (lr·factor) · u`,
/// block-local over reduction chunks.
fn apply_phase<'a>(
    n: usize,
    params_sh: Shared<f32>,
    u_sh: Shared<f32>,
    scales: Shared<f32>,
) -> Phase<'a> {
    let chunk = Span::Blocked { base: 0, block: reduce::CHUNK, n };
    Phase::new(BlockSteps::from_fn(reduce::n_chunks(n), move |c| {
        let (lo, hi) = reduce::chunk_bounds(n, c);
        // SAFETY: item c owns param chunk c; u and the scale were written
        // in earlier phases (barrier-sequenced reads).
        let p = unsafe { params_sh.range_mut(lo, hi) };
        let u = unsafe { u_sh.range(lo, hi) };
        let step = unsafe { scales.read(1) };
        for i in 0..p.len() {
            p[i] -= step * u[i];
        }
    }))
    .with_access(
        AccessSet::new()
            .rmw(Region::Params, chunk)
            .read(Region::Slot("stab.u"), chunk)
            .read(Region::Slot("stab.scales"), Span::All { lo: 1, hi: 2 })
            .preset(Region::Slot("stab.scales")),
    )
}

/// The shared stabilized phased plan for the elementwise-state optimizers.
///
/// `direct_rule(p, g_raw, s1, s2, gscale)` applies one full element update
/// (moments **and** parameter) from the raw gradient and the clip scale —
/// used when `max_unorm` is off, so the plan stays a single elementwise
/// phase (plus the optional gnorm phase). `u_rule(u, g_raw, s1, s2, w,
/// gscale)` updates the moments and writes the raw update direction
/// *without* touching the parameter — used on the `max_unorm` path, where
/// the step is applied as `w -= lr·factor·u` after the norm combine. Both
/// rules own the `skip_zeros` check (skip ⇒ leave everything / write `u =
/// 0`), and both must be the identical per-element IEEE expression in the
/// lane and scalar paths (the builder dispatches each rule from both).
#[allow(clippy::too_many_arguments)]
pub(crate) fn stabilized_plan<'a, D, U>(
    stab: &'a mut Stab,
    cfg: &OptimConfig,
    params: &'a mut [f32],
    grads: &'a [f32],
    s1: &'a mut StateTensor,
    s2: Option<&'a mut StateTensor>,
    fallback_block: usize,
    direct_rule: D,
    u_rule: U,
) -> StepPlan<'a>
where
    D: Fn(&mut f32, f32, &mut f32, Option<&mut f32>, f32) + Copy + Send + Sync + 'a,
    U: Fn(&mut f32, f32, &mut f32, Option<&mut f32>, f32, f32) + Copy + Send + Sync + 'a,
{
    let n = params.len();
    let nc = reduce::n_chunks(n);
    let need_u = cfg.max_unorm > 0.0;
    stab.ensure(n, need_u);
    // Preset the neutral scales; combines of active features overwrite.
    stab.scales = [1.0, cfg.lr];
    // SAFETY (all `Shared` uses below): within each phase distinct items
    // touch disjoint chunks; values written by a combine are read only in
    // later phases (the engine's barrier provides the happens-before
    // edge); `stab`'s `&'a mut` borrow keeps every target alive for the
    // plan's lifetime.
    let partials = Shared::new(&mut stab.partials);
    let scales = Shared::new(&mut stab.scales);
    let history = Shared::new(std::slice::from_mut(&mut stab.history));

    let mut plan = StepPlan::new();
    if cfg.clip_percentile > 0.0 {
        plan.push(gnorm_clip_phase(grads, partials, history, scales, cfg.clip_percentile));
    }

    if !need_u {
        // Direct path: one lane-chunked elementwise phase; the clip scale
        // is read per block (written by the phase-0 combine, or preset).
        let direct = Phase::new(block_steps_vec(
            params,
            grads,
            s1,
            s2,
            fallback_block,
            move |v: LaneView| {
                let gs = unsafe { scales.read(0) };
                let LaneView { params, grads, s1, s2, .. } = v;
                match s2 {
                    Some(s2) => {
                        for l in 0..LANES {
                            direct_rule(&mut params[l], grads[l], &mut s1[l], Some(&mut s2[l]), gs);
                        }
                    }
                    None => {
                        for l in 0..LANES {
                            direct_rule(&mut params[l], grads[l], &mut s1[l], None, gs);
                        }
                    }
                }
            },
            move |v: BlockView| {
                let gs = unsafe { scales.read(0) };
                let BlockView { params, grads, s1, s2, .. } = v;
                match s2 {
                    Some(s2) => {
                        for i in 0..params.len() {
                            direct_rule(&mut params[i], grads[i], &mut s1[i], Some(&mut s2[i]), gs);
                        }
                    }
                    None => {
                        for i in 0..params.len() {
                            direct_rule(&mut params[i], grads[i], &mut s1[i], None, gs);
                        }
                    }
                }
            },
        ));
        plan.push(direct.map_access(|a| {
            a.read(Region::Slot("stab.scales"), Span::All { lo: 0, hi: 1 })
                .preset(Region::Slot("stab.scales"))
        }));
        return plan;
    }

    // max_unorm path (the LAMB shape): moment update + u materialized via
    // the block engine with u in the "params" slot (real params are only
    // read — for weight decay and the ‖w‖ partial), norm partials per
    // covered chunk, then the unorm combine, then the block-local apply.
    let params_sh = Shared::new(params);
    let u_sh = Shared::new(&mut stab.u);
    // Single-writer contract for the partial slots: every moment-phase
    // item must cover whole reduce-chunks (state blocks are CHUNK-aligned
    // or the tensor is one item).
    debug_assert!(
        fallback_block % reduce::CHUNK == 0 || fallback_block >= n,
        "unorm partials need chunk-aligned state blocks (block {fallback_block}, n {n})"
    );
    // Effective block size `block_steps` will pick (quantized state block,
    // else the fallback) — needed to declare which partial chunks each
    // moment-phase item covers.
    let eff_block = match (&*s1, s2.as_deref()) {
        (StateTensor::Quant { q, .. }, _) => q.block,
        (_, Some(StateTensor::Quant { q, .. })) => q.block,
        _ => fallback_block.min(n.max(1)),
    };
    let cpb = if eff_block >= n { nc } else { eff_block / reduce::CHUNK };
    let u_slot: &'a mut [f32] = unsafe { u_sh.range_mut(0, n) };
    let phase_m = block_steps(u_slot, grads, s1, s2, fallback_block, move |v: BlockView| {
        let BlockView { params: u_b, grads, s1: s1_b, s2: mut s2_b, start } = v;
        let w = unsafe { params_sh.range(start, start + u_b.len()) };
        let gs = unsafe { scales.read(0) };
        // Hand lane-chunked (this kernel reads `w` through `params_sh` and
        // runs a partials pass below, so it can't ride `block_steps_vec`);
        // same per-element arithmetic in both paths => bit-identical.
        let len = u_b.len();
        let main = if lanes::scalar_forced() { 0 } else { len - len % LANES };
        for c in 0..main / LANES {
            let off = c * LANES;
            let u_l = <&mut [f32; LANES]>::try_from(&mut u_b[off..off + LANES]).unwrap();
            let g_l = <&[f32; LANES]>::try_from(&grads[off..off + LANES]).unwrap();
            let s1_l = <&mut [f32; LANES]>::try_from(&mut s1_b[off..off + LANES]).unwrap();
            let w_l = <&[f32; LANES]>::try_from(&w[off..off + LANES]).unwrap();
            match s2_b.as_deref_mut() {
                Some(s2) => {
                    let s2_l = <&mut [f32; LANES]>::try_from(&mut s2[off..off + LANES]).unwrap();
                    for l in 0..LANES {
                        u_rule(&mut u_l[l], g_l[l], &mut s1_l[l], Some(&mut s2_l[l]), w_l[l], gs);
                    }
                }
                None => {
                    for l in 0..LANES {
                        u_rule(&mut u_l[l], g_l[l], &mut s1_l[l], None, w_l[l], gs);
                    }
                }
            }
        }
        for i in main..len {
            match s2_b.as_deref_mut() {
                Some(s2) => u_rule(&mut u_b[i], grads[i], &mut s1_b[i], Some(&mut s2[i]), w[i], gs),
                None => u_rule(&mut u_b[i], grads[i], &mut s1_b[i], None, w[i], gs),
            }
        }
        // Per-chunk ‖w‖²/‖u‖² partials for the chunks this item covers.
        let mut lo = 0usize;
        while lo < len {
            let c = (start + lo) / reduce::CHUNK;
            let hi = (lo + reduce::CHUNK).min(len);
            unsafe {
                partials.write(nc + c, reduce::sum_sq(&w[lo..hi]));
                partials.write(2 * nc + c, reduce::sum_sq(&u_b[lo..hi]));
            }
            lo = hi;
        }
    });
    plan.push(
        Phase::with_combine(phase_m, unorm_combine(partials, nc, scales, cfg.lr, cfg.max_unorm))
            .map_access(move |a| {
                // The "params" slot of this phase actually carries `u`; the
                // real parameters are only read (weight decay + ‖w‖).
                a.relabel(Region::Params, Region::Slot("stab.u"))
                    .preset(Region::Slot("stab.u"))
                    .preset(Region::Slot("stab.scales"))
                    .read(Region::Params, Span::Blocked { base: 0, block: eff_block, n })
                    .read(Region::Slot("stab.scales"), Span::All { lo: 0, hi: 1 })
                    .write(
                        Region::Slot("stab.partials"),
                        Span::Blocked { base: nc, block: cpb, n: nc },
                    )
                    .write(
                        Region::Slot("stab.partials"),
                        Span::Blocked { base: 2 * nc, block: cpb, n: nc },
                    )
                    .combine(
                        CombineAccess::deterministic()
                            .read(Region::Slot("stab.partials"), Span::All { lo: nc, hi: 3 * nc })
                            .write(Region::Slot("stab.scales"), Span::All { lo: 1, hi: 2 })
                            .counter(Counter::UnormClips),
                    )
            }),
    );
    plan.push(apply_phase(n, params_sh, u_sh, scales));
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_is_a_rolling_window() {
        let mut h = GnormHistory::new();
        for i in 0..(GNORM_WINDOW + 10) {
            h.push(i as f64);
        }
        assert_eq!(h.len(), GNORM_WINDOW);
        let snap = h.snapshot();
        // chronological: oldest surviving value first
        assert_eq!(snap[0], 10.0);
        assert_eq!(snap[GNORM_WINDOW - 1], (GNORM_WINDOW + 9) as f32);
    }

    #[test]
    fn non_finite_norms_are_never_recorded() {
        let mut h = GnormHistory::new();
        h.push(1.0);
        h.push(f64::NAN);
        h.push(f64::INFINITY);
        h.push(2.0);
        assert_eq!(h.len(), 2);
        assert_eq!(h.snapshot(), vec![1.0, 2.0]);
    }

    #[test]
    fn clip_engages_only_after_min_history() {
        let mut h = GnormHistory::new();
        for i in 0..GNORM_MIN_HISTORY - 1 {
            h.push(1.0 + i as f64 * 0.01);
            assert_eq!(h.clip_value(95.0), None, "after {} entries", i + 1);
        }
        h.push(1.0);
        let clip = h.clip_value(95.0).expect("enough history now");
        assert!(clip > 0.9 && clip < 1.1, "{clip}");
    }

    #[test]
    fn clip_value_tracks_percentile() {
        let mut h = GnormHistory::new();
        for i in 1..=100 {
            h.push(i as f64);
        }
        // 95th percentile of 1..=100 (linear interpolation over sorted)
        let clip = h.clip_value(95.0).unwrap();
        assert!((clip - 95.05).abs() < 1e-9, "{clip}");
        // the median is robust to a spike
        h.push(1e6);
        let med = h.clip_value(50.0).unwrap();
        assert!(med < 100.0, "{med}");
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut h = GnormHistory::new();
        for i in 0..137 {
            h.push(0.5 + (i % 17) as f64);
        }
        let snap = h.snapshot();
        let mut back = GnormHistory::new();
        back.restore(&snap);
        assert_eq!(back.snapshot(), snap);
        assert_eq!(back.clip_value(95.0).map(|v| v as f32), h.clip_value(95.0).map(|v| v as f32));
    }

    #[test]
    fn restore_keeps_only_the_last_window() {
        let long: Vec<f32> = (0..250).map(|i| i as f32).collect();
        let mut h = GnormHistory::new();
        h.restore(&long);
        assert_eq!(h.len(), GNORM_WINDOW);
        assert_eq!(h.snapshot()[0], 150.0);
    }
}
