//! Fused multi-tensor step executor — the top layer of the unified
//! block-kernel execution engine.
//!
//! Layering (see also `rust/src/optim/README.md`):
//!
//! 1. **Worker pool** (`util::parallel`) — persistent, lazily-initialized
//!    threads; one batch dispatch per call instead of per-call spawning.
//! 2. **Phased block plan** (`optim::state::StepPlan`) — one tensor's
//!    update decomposed into phases of independent (block) tasks with
//!    deterministic combines between them; the engine owns
//!    dequantize → update → requantize and per-thread scratch.
//! 3. **Fused step** (this module) — the phase-`k` items of *every* tensor
//!    merged into a single pool batch, then all phase-`k` combines in
//!    tensor order, then phase `k+1`. One pool batch per phase per
//!    training step — never one per tensor — and every optimizer,
//!    including the reduction-bearing ones (LARS, LAMB, Adafactor,
//!    factored SM3), executes fully inside the batch.
//!
//! Determinism: items never share mutable state, in-block order is fixed,
//! combines fold partials in fixed order between barriers — so the fused
//! step is bit-identical to stepping tensors one by one, at every thread
//! count.

use super::state::StepPlan;
use super::Optimizer;
use crate::util::parallel;

/// One training step's worth of optimizer work across many tensors: every
/// tensor's phased plan, executed phase-aligned — all tensors' phase-A
/// items as one pool batch, a barrier, the (tiny, ordered) combines, then
/// all phase-B items, and so on to the deepest plan.
#[derive(Default)]
pub struct FusedStep<'a> {
    plans: Vec<StepPlan<'a>>,
}

impl<'a> FusedStep<'a> {
    pub fn new() -> FusedStep<'a> {
        FusedStep { plans: Vec::new() }
    }

    /// Queue one tensor's update (the optimizer's cheap step prologue —
    /// `t` advance, bias corrections — runs here; all block work and all
    /// reductions run at [`FusedStep::run`]).
    pub fn push(&mut self, opt: &'a mut dyn Optimizer, params: &'a mut [f32], grads: &'a [f32]) {
        self.plans.push(opt.plan(params, grads));
    }

    /// Total number of queued work items across all phases.
    pub fn n_items(&self) -> usize {
        self.plans.iter().map(|p| p.n_items()).sum()
    }

    /// Execute everything queued. For each phase index `k` (up to the
    /// deepest plan): run every tensor's phase-`k` items as ONE pool batch,
    /// then run the phase-`k` combines serially in tensor order. Tensors
    /// with fewer phases simply contribute no items to later batches.
    pub fn run(mut self) {
        let n_phases = self.plans.iter().map(|p| p.n_phases()).max().unwrap_or(0);
        for k in 0..n_phases {
            // prefix offsets of each plan's phase-k items in the batch
            let mut offsets = Vec::with_capacity(self.plans.len());
            let mut total = 0usize;
            for p in &self.plans {
                offsets.push(total);
                total += p.phase_items(k);
            }
            if total > 0 {
                let plans = &self.plans;
                let offsets = &offsets;
                parallel::run_indexed(total, move |j| {
                    // last plan whose offset is <= j (plans with no items
                    // in this phase are skipped naturally: their range
                    // contains no j)
                    let p = offsets.partition_point(|&o| o <= j) - 1;
                    plans[p].run_item(k, j - offsets[p]);
                });
            }
            for plan in self.plans.iter_mut() {
                if let Some(combine) = plan.take_combine(k) {
                    combine();
                }
            }
        }
    }
}

/// Step every tensor through the fused engine — what the trainer's native
/// path does each training step. Bit-identical to the serial
/// `for i { opts[i].step(&mut params[i], &grads[i]) }` loop.
pub fn fused_update(
    opts: &mut [Box<dyn Optimizer>],
    params: &mut [Vec<f32>],
    grads: &[Vec<f32>],
) {
    assert_eq!(opts.len(), params.len());
    assert_eq!(opts.len(), grads.len());
    let mut fused = FusedStep::new();
    for ((opt, p), g) in opts.iter_mut().zip(params.iter_mut()).zip(grads.iter()) {
        fused.push(opt.as_mut(), p.as_mut_slice(), g.as_slice());
    }
    fused.run();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build, Bits, OptimConfig, OptimKind};
    use crate::util::rng::Rng;

    type Fleet = (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

    fn fleet(kinds: &[(OptimKind, usize)], bits: Bits) -> Fleet {
        let mut rng = Rng::new(77);
        let mut opts = Vec::new();
        let mut params = Vec::new();
        let mut grads = Vec::new();
        for &(kind, n) in kinds {
            let mut cfg = OptimConfig::adam(0.01, bits);
            cfg.kind = kind;
            opts.push(build(&cfg, n, None));
            params.push((0..n).map(|_| rng.normal() as f32).collect());
            grads.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect());
        }
        (opts, params, grads)
    }

    #[test]
    fn fused_matches_serial_stepping_bitwise() {
        // mixed workload: single-phase (adam, momentum) and multi-phase
        // (lamb) plans, sizes from sub-block to large multi-block
        let kinds = [
            (OptimKind::Adam, 3usize),
            (OptimKind::Adam, 2048),
            (OptimKind::Momentum, 5000),
            (OptimKind::Lamb, 1024),
            (OptimKind::Lamb, 20000), // many blocks, phased reductions
            (OptimKind::Adam, 2049),
        ];
        for bits in [Bits::B32, Bits::b8_dynamic()] {
            let (mut o_serial, mut p_serial, g) = fleet(&kinds, bits);
            let (mut o_fused, mut p_fused, _) = fleet(&kinds, bits);
            for _ in 0..3 {
                for i in 0..o_serial.len() {
                    o_serial[i].step(&mut p_serial[i], &g[i]);
                }
                fused_update(&mut o_fused, &mut p_fused, &g);
            }
            assert_eq!(p_serial, p_fused, "params diverged ({})", bits.describe());
            for (a, b) in o_serial.iter().zip(&o_fused) {
                for ((na, sa), (nb, sb)) in a.states().iter().zip(b.states().iter()) {
                    assert_eq!(na, nb);
                    assert_eq!(sa.to_f32(), sb.to_f32(), "state {na} diverged");
                }
            }
        }
    }

    #[test]
    fn empty_fused_step_is_a_no_op() {
        let fused = FusedStep::new();
        assert_eq!(fused.n_items(), 0);
        fused.run();
    }
}
