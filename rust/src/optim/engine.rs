//! Fused multi-tensor step executor — the top layer of the unified
//! block-kernel execution engine.
//!
//! Layering (see also `rust/src/optim/README.md`):
//!
//! 1. **Worker pool** (`util::parallel`) — persistent, lazily-initialized
//!    threads; one batch dispatch per call instead of per-call spawning.
//! 2. **Block kernel** (`optim::state::block_steps`) — one tensor's update
//!    decomposed into independent (block) tasks; the engine owns
//!    dequantize → update → requantize and per-thread scratch.
//! 3. **Fused step** (this module) — all (tensor, block) work items of one
//!    training step merged into a *single* pool batch, so inter-tensor
//!    parallelism covers the many small tensors of a real model and pool
//!    dispatch is paid once per step, not once per tensor.
//!
//! Determinism: items never share mutable state and in-block order is
//! fixed, so the fused step is bit-identical to stepping tensors one by
//! one, at every thread count.

use std::sync::Mutex;

use super::state::BlockSteps;
use super::Optimizer;
use crate::util::parallel;

/// Whole-tensor items larger than this run on the calling thread instead
/// of inside the pool batch: a pool worker executes nested parallel calls
/// inline, so folding a big LAMB/Adafactor tensor into the batch would
/// serialize its internal block loops and norms onto one core. Small
/// whole-tensor items lose nothing and gain inter-tensor parallelism.
const WHOLE_TENSOR_BATCH_MAX: usize = 8 * crate::quant::BLOCK;

/// One training step's worth of optimizer work across many tensors,
/// flattened into a single pool batch: every (tensor, block) item of every
/// block-local optimizer, plus one whole-tensor item per *small* optimizer
/// whose update needs tensor-wide reductions (LAMB, Adafactor, factored
/// SM3; LARS is block-local after its norm prologue). Large whole-tensor
/// items run on the calling thread, where their internal loops keep full
/// pool parallelism.
#[derive(Default)]
pub struct FusedStep<'a> {
    blocks: Vec<BlockSteps<'a>>,
    whole: Vec<Mutex<Option<Box<dyn FnOnce() + Send + 'a>>>>,
    caller: Vec<Box<dyn FnOnce() + Send + 'a>>,
}

impl<'a> FusedStep<'a> {
    pub fn new() -> FusedStep<'a> {
        FusedStep { blocks: Vec::new(), whole: Vec::new(), caller: Vec::new() }
    }

    /// Queue one tensor's update (the optimizer's step prologue — `t`
    /// advance, bias corrections, norms — runs here; the block work runs
    /// at [`FusedStep::run`]).
    pub fn push(&mut self, opt: &'a mut dyn Optimizer, params: &'a mut [f32], grads: &'a [f32]) {
        if opt.is_block_local() {
            let steps = opt.begin_step(params, grads).expect("block-local optimizer");
            self.blocks.push(steps);
        } else if params.len() > WHOLE_TENSOR_BATCH_MAX {
            self.caller.push(Box::new(move || opt.step(params, grads)));
        } else {
            let task = Box::new(move || opt.step(params, grads)) as Box<dyn FnOnce() + Send + 'a>;
            self.whole.push(Mutex::new(Some(task)));
        }
    }

    /// Total number of queued work items (pool batch items + caller-side
    /// whole-tensor items).
    pub fn n_items(&self) -> usize {
        self.blocks.iter().map(|b| b.n_blocks()).sum::<usize>()
            + self.whole.len()
            + self.caller.len()
    }

    /// Execute everything queued. Large whole-tensor items run first on
    /// this thread (each internally parallel across the pool); the rest —
    /// every block item plus small whole-tensor items — runs as one pool
    /// batch, small whole items scheduled ahead of the block backlog.
    pub fn run(self) {
        let FusedStep { blocks, whole, caller } = self;
        for task in caller {
            task();
        }
        let n_whole = whole.len();
        let total_blocks: usize = blocks.iter().map(|b| b.n_blocks()).sum();
        let n = n_whole + total_blocks;
        if n == 0 {
            return;
        }
        // prefix offsets of each tensor's blocks in the flattened index
        let mut offsets = Vec::with_capacity(blocks.len());
        let mut acc = 0usize;
        for b in &blocks {
            offsets.push(acc);
            acc += b.n_blocks();
        }
        let blocks_ref = &blocks;
        let whole_ref = &whole;
        parallel::run_indexed(n, move |i| {
            if i < n_whole {
                let task = whole_ref[i].lock().unwrap_or_else(|e| e.into_inner()).take();
                if let Some(task) = task {
                    task();
                }
            } else {
                let j = i - n_whole;
                // last tensor whose offset is <= j (empty tensors are
                // skipped naturally: their range contains no j)
                let k = offsets.partition_point(|&o| o <= j) - 1;
                blocks_ref[k].run_block(j - offsets[k]);
            }
        });
    }
}

/// Step every tensor through the fused engine — what the trainer's native
/// path does each training step. Bit-identical to the serial
/// `for i { opts[i].step(&mut params[i], &grads[i]) }` loop.
pub fn fused_update(
    opts: &mut [Box<dyn Optimizer>],
    params: &mut [Vec<f32>],
    grads: &[Vec<f32>],
) {
    assert_eq!(opts.len(), params.len());
    assert_eq!(opts.len(), grads.len());
    let mut fused = FusedStep::new();
    for ((opt, p), g) in opts.iter_mut().zip(params.iter_mut()).zip(grads.iter()) {
        fused.push(opt.as_mut(), p.as_mut_slice(), g.as_slice());
    }
    fused.run();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build, Bits, OptimConfig, OptimKind};
    use crate::util::rng::Rng;

    type Fleet = (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

    fn fleet(kinds: &[(OptimKind, usize)], bits: Bits) -> Fleet {
        let mut rng = Rng::new(77);
        let mut opts = Vec::new();
        let mut params = Vec::new();
        let mut grads = Vec::new();
        for &(kind, n) in kinds {
            let mut cfg = OptimConfig::adam(0.01, bits);
            cfg.kind = kind;
            opts.push(build(&cfg, n, None));
            params.push((0..n).map(|_| rng.normal() as f32).collect());
            grads.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect());
        }
        (opts, params, grads)
    }

    #[test]
    fn fused_matches_serial_stepping_bitwise() {
        // mixed workload: block-local (adam, momentum) and whole-tensor
        // (lamb) optimizers, sizes from sub-block to multi-block
        let kinds = [
            (OptimKind::Adam, 3usize),
            (OptimKind::Adam, 2048),
            (OptimKind::Momentum, 5000),
            (OptimKind::Lamb, 1024),  // small whole-tensor -> pool batch
            (OptimKind::Lamb, 20000), // large whole-tensor -> caller side
            (OptimKind::Adam, 2049),
        ];
        for bits in [Bits::B32, Bits::b8_dynamic()] {
            let (mut o_serial, mut p_serial, g) = fleet(&kinds, bits);
            let (mut o_fused, mut p_fused, _) = fleet(&kinds, bits);
            for _ in 0..3 {
                for i in 0..o_serial.len() {
                    o_serial[i].step(&mut p_serial[i], &g[i]);
                }
                fused_update(&mut o_fused, &mut p_fused, &g);
            }
            assert_eq!(p_serial, p_fused, "params diverged ({})", bits.describe());
            for (a, b) in o_serial.iter().zip(&o_fused) {
                for ((na, sa), (nb, sb)) in a.states().iter().zip(b.states().iter()) {
                    assert_eq!(na, nb);
                    assert_eq!(sa.to_f32(), sb.to_f32(), "state {na} diverged");
                }
            }
        }
    }

    #[test]
    fn empty_fused_step_is_a_no_op() {
        let fused = FusedStep::new();
        assert_eq!(fused.n_items(), 0);
        fused.run();
    }
}
