//! Fused and streaming multi-tensor step executors — the top layers of the
//! unified block-kernel execution engine.
//!
//! Layering (see also `rust/src/optim/README.md`):
//!
//! 1. **Worker pool** (`util::parallel`) — persistent, lazily-initialized
//!    threads; one batch dispatch per call instead of per-call spawning,
//!    plus detached batches (`submit`/`BatchHandle`) that run while the
//!    submitting thread keeps working.
//! 2. **Phased block plan** (`optim::state::StepPlan`) — one tensor's
//!    update decomposed into phases of independent (block) tasks with
//!    deterministic combines between them; the engine owns
//!    dequantize → update → requantize and per-thread scratch. Block
//!    kernels are lane-chunked (`util::lanes`, `state::block_steps_vec`):
//!    fixed-width `[f32; LANES]` chunks the autovectorizer lowers to SIMD,
//!    with the scalar closure kept as the tail-and-oracle path
//!    (bit-identical; `util::lanes::with_forced_scalar` pins it).
//! 3. **Fused step** ([`FusedStep`]) — the phase-`k` items of *every*
//!    tensor merged into a single pool batch, then all phase-`k` combines
//!    in tensor order, then phase `k+1`. One pool batch per phase per
//!    training step — never one per tensor — and every optimizer,
//!    including the reduction-bearing ones (LARS, LAMB, Adafactor,
//!    factored SM3), executes fully inside the batch.
//! 4. **Streaming step** ([`StreamingStep`]) — tensors admitted
//!    incrementally, each starting on the pool at `push` while the caller
//!    is still producing later tensors' gradients or driving the serial
//!    PJRT dispatches of the HLO engine. Trades the fused step's
//!    one-batch-per-phase dispatch for overlap with the producer.
//! 5. **Sharded placement** (`optim::shard`) — parameter groups
//!    partitioned across ZeRO-style shards, each shard stepping its
//!    tensors as an independent [`StreamingStep`] and the step ending in a
//!    deterministic shard-order drain (the all-gather). Placement moves
//!    state, never math: bit-identical to the unsharded step.
//!
//! Determinism: items never share mutable state, in-block order is fixed,
//! combines fold partials in fixed order between barriers — so the fused
//! step is bit-identical to stepping tensors one by one, at every thread
//! count. The streaming step additionally exploits that *tensors* never
//! share state: each tensor walks its own phases in the canonical
//! [`StepPlan::execute`] order, so any interleaving across tensors — any
//! admission order, any thread count — produces the same bits.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use super::state::StepPlan;
use super::Optimizer;
use crate::util::parallel::{self, BatchHandle, SendPtr};

/// One training step's worth of optimizer work across many tensors: every
/// tensor's phased plan, executed phase-aligned — all tensors' phase-A
/// items as one pool batch, a barrier, the (tiny, ordered) combines, then
/// all phase-B items, and so on to the deepest plan.
#[derive(Default)]
pub struct FusedStep<'a> {
    plans: Vec<StepPlan<'a>>,
}

impl<'a> FusedStep<'a> {
    pub fn new() -> FusedStep<'a> {
        FusedStep { plans: Vec::new() }
    }

    /// Queue one tensor's update (the optimizer's cheap step prologue —
    /// `t` advance, bias corrections — runs here; all block work and all
    /// reductions run at [`FusedStep::run`]).
    pub fn push(&mut self, opt: &'a mut dyn Optimizer, params: &'a mut [f32], grads: &'a [f32]) {
        self.plans.push(opt.plan(params, grads));
    }

    /// Total number of queued work items across all phases.
    pub fn n_items(&self) -> usize {
        self.plans.iter().map(|p| p.n_items()).sum()
    }

    /// Execute everything queued. For each phase index `k` (up to the
    /// deepest plan): run every tensor's phase-`k` items as ONE pool batch,
    /// then run the phase-`k` combines serially in tensor order. Tensors
    /// with fewer phases simply contribute no items to later batches.
    pub fn run(mut self) {
        let n_phases = self.plans.iter().map(|p| p.n_phases()).max().unwrap_or(0);
        for k in 0..n_phases {
            // prefix offsets of each plan's phase-k items in the batch
            let mut offsets = Vec::with_capacity(self.plans.len());
            let mut total = 0usize;
            for p in &self.plans {
                offsets.push(total);
                total += p.phase_items(k);
            }
            if total > 0 {
                let plans = &self.plans;
                let offsets = &offsets;
                parallel::run_indexed(total, move |j| {
                    // last plan whose offset is <= j (plans with no items
                    // in this phase are skipped naturally: their range
                    // contains no j)
                    let p = offsets.partition_point(|&o| o <= j) - 1;
                    plans[p].run_item(k, j - offsets[p]);
                });
            }
            for plan in self.plans.iter_mut() {
                if let Some(combine) = plan.take_combine(k) {
                    combine();
                }
            }
        }
    }
}

/// One tensor admitted to a [`StreamingStep`]: its phased plan, heap-pinned
/// behind a raw pointer, plus the detached batch handle of the phase
/// currently on the pool.
///
/// The plan is held as a `*mut` from `Box::into_raw` rather than as a
/// `Box`: pool tasks read the plan through a derived pointer, and moving a
/// `Box` (return-by-value from `new`, `Vec` growth in
/// [`StreamingStep::push`]) re-asserts its unique-ownership claim, which
/// would invalidate those derived pointers under the aliasing model. Raw
/// pointers carry no such claim, so moves of this struct are inert; the
/// allocation is reboxed and freed in `Drop`, after the in-flight batch
/// has been joined.
struct StreamTensor<'a> {
    /// In-flight batch for phase `phase`'s items; joined before the plan
    /// is mutated or freed.
    handle: Option<BatchHandle<'static>>,
    /// Heap `StepPlan`, owned by this struct (freed in `Drop`).
    plan: *mut StepPlan<'a>,
    /// The phase whose items are in flight; once every phase (and its
    /// combine) has run, `handle` is `None` and the tensor is done.
    phase: usize,
}

impl<'a> StreamTensor<'a> {
    fn new(plan: StepPlan<'a>) -> StreamTensor<'a> {
        let plan = Box::into_raw(Box::new(plan));
        let mut t = StreamTensor { handle: None, plan, phase: 0 };
        t.launch();
        t
    }

    /// Shared view of the plan — only used while no batch of this tensor
    /// is in flight (launch/advance sites) so no task aliases it.
    fn plan(&self) -> &StepPlan<'a> {
        // SAFETY: `plan` came from Box::into_raw in `new` and is freed
        // only in Drop, after the handle drained.
        unsafe { &*self.plan }
    }

    /// Submit the current phase's items to the pool (non-blocking).
    fn launch(&mut self) {
        if self.phase >= self.plan().n_phases() {
            return;
        }
        let k = self.phase;
        let n = self.plan().phase_items(k);
        let plan = SendPtr(self.plan as *mut StepPlan<'static>);
        // SAFETY (task body): items of one phase touch disjoint state, each
        // index runs exactly once, and the combine / next phase only run
        // after the handle drained — the same contract `FusedStep::run`
        // relies on. The 'static cast is lifetime erasure only.
        let task = move |i| unsafe { (*plan.0).run_item(k, i) };
        // SAFETY (submit contract): the handle cannot leak — it lives in
        // this private struct and is joined in `advance`/`Drop` before the
        // plan (and the `'a` data it borrows) can die.
        self.handle = Some(unsafe { parallel::submit(n, task) });
    }

    /// Whether any phase is still in flight or queued.
    fn pending(&self) -> bool {
        self.handle.is_some()
    }

    /// Join the in-flight phase (participating in its remaining work), run
    /// its combine, and start the next phase.
    fn advance(&mut self) {
        let Some(handle) = self.handle.take() else { return };
        handle.wait();
        // SAFETY: the batch drained — this thread is the plan's only
        // accessor until the next launch.
        let combine = unsafe { (*self.plan).take_combine(self.phase) };
        if let Some(combine) = combine {
            combine();
        }
        self.phase += 1;
        self.launch();
    }

    /// Advance only if the in-flight phase already drained (non-blocking).
    fn try_advance(&mut self) -> bool {
        let ready = self.handle.as_ref().is_some_and(|h| h.is_done());
        if ready {
            self.advance();
        }
        ready
    }
}

impl Drop for StreamTensor<'_> {
    fn drop(&mut self) {
        // Join any in-flight batch before freeing the plan it reads. The
        // handle re-throws a task panic on drop (when this thread is not
        // already unwinding); catch it so the plan is freed either way,
        // then re-throw.
        let handle = self.handle.take();
        let join = catch_unwind(AssertUnwindSafe(move || drop(handle)));
        // SAFETY: `plan` came from Box::into_raw in `new`, is freed only
        // here, and no task can reference it once the handle drained.
        unsafe { drop(Box::from_raw(self.plan)) };
        if let Err(p) = join {
            resume_unwind(p);
        }
    }
}

/// Streaming multi-tensor step executor — the engine's fourth layer.
///
/// Where [`FusedStep`] needs every tensor's plan before anything runs (the
/// phase-`k` items of all tensors form one barrier-aligned batch), a
/// `StreamingStep` accepts tensors incrementally: [`StreamingStep::push`]
/// puts the new tensor's phase-0 items on the worker pool and returns,
/// so the caller can keep producing later tensors' gradients — or drive
/// the HLO engine's serial PJRT dispatches — while the pool crunches.
/// Tensors advance through their phases independently:
/// [`StreamingStep::poll`] opportunistically joins drained phases (running
/// the combine and launching the next phase) and [`StreamingStep::finish`]
/// drains everything.
///
/// Determinism: tensors never share state, and each tensor's phases run in
/// the canonical [`StepPlan::execute`] item/combine order — so a streaming
/// step is bit-identical to [`FusedStep`] and to serial stepping, at every
/// thread count and for every admission order
/// (`rust/tests/streaming_parity.rs` pins this).
///
/// Dropping a `StreamingStep` without [`StreamingStep::finish`] (e.g. on
/// an error-unwind in the caller) is memory-safe — every in-flight batch
/// is joined — but leaves un-combined tensors mid-update; the step must be
/// considered unapplied. Do not `mem::forget` a `StreamingStep`: skipping
/// its drop would leak the in-flight batch handles that keep the pool's
/// borrows of `params`/`grads` sound.
#[derive(Default)]
pub struct StreamingStep<'a> {
    tensors: Vec<StreamTensor<'a>>,
}

impl<'a> StreamingStep<'a> {
    pub fn new() -> StreamingStep<'a> {
        StreamingStep { tensors: Vec::new() }
    }

    /// Admit one tensor: the optimizer's cheap step prologue (`t` advance,
    /// bias corrections) runs here, the plan's phase-0 items start on the
    /// pool, and the call returns without waiting. With 1 thread the items
    /// run inline instead — same results, no overlap.
    pub fn push(&mut self, opt: &'a mut dyn Optimizer, params: &'a mut [f32], grads: &'a [f32]) {
        self.tensors.push(StreamTensor::new(opt.plan(params, grads)));
        self.poll();
    }

    /// Number of admitted tensors.
    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Admitted tensors that still have phases in flight or queued.
    pub fn n_pending(&self) -> usize {
        self.tensors.iter().filter(|t| t.pending()).count()
    }

    /// Non-blocking progress: for every tensor whose in-flight phase has
    /// drained, run its combine and launch its next phase. Call this
    /// between bouts of other main-thread work (the trainer calls it
    /// between PJRT round-trips) so multi-phase plans keep moving.
    pub fn poll(&mut self) {
        for t in self.tensors.iter_mut() {
            while t.try_advance() {}
        }
    }

    /// Drain every admitted tensor through its remaining phases, with the
    /// calling thread participating in the pool work. After this, every
    /// admitted tensor's update is fully applied.
    pub fn finish(mut self) {
        for t in self.tensors.iter_mut() {
            while t.pending() {
                t.advance();
            }
        }
    }
}

/// Step every tensor through the streaming engine — push in index order,
/// then drain. Bit-identical to [`fused_update`] and to the serial
/// per-tensor loop; used by benches and parity tests.
pub fn streaming_update(
    opts: &mut [Box<dyn Optimizer>],
    params: &mut [Vec<f32>],
    grads: &[Vec<f32>],
) {
    assert_eq!(opts.len(), params.len());
    assert_eq!(opts.len(), grads.len());
    let mut stream = StreamingStep::new();
    for ((opt, p), g) in opts.iter_mut().zip(params.iter_mut()).zip(grads.iter()) {
        stream.push(opt.as_mut(), p.as_mut_slice(), g.as_slice());
    }
    stream.finish();
}

/// Step every tensor through the fused engine — what the trainer's native
/// path does each training step. Bit-identical to the serial
/// `for i { opts[i].step(&mut params[i], &grads[i]) }` loop.
pub fn fused_update(
    opts: &mut [Box<dyn Optimizer>],
    params: &mut [Vec<f32>],
    grads: &[Vec<f32>],
) {
    assert_eq!(opts.len(), params.len());
    assert_eq!(opts.len(), grads.len());
    let mut fused = FusedStep::new();
    for ((opt, p), g) in opts.iter_mut().zip(params.iter_mut()).zip(grads.iter()) {
        fused.push(opt.as_mut(), p.as_mut_slice(), g.as_slice());
    }
    fused.run();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build, Bits, OptimConfig, OptimKind};
    use crate::util::rng::Rng;

    type Fleet = (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

    fn fleet(kinds: &[(OptimKind, usize)], bits: Bits) -> Fleet {
        let mut rng = Rng::new(77);
        let mut opts = Vec::new();
        let mut params = Vec::new();
        let mut grads = Vec::new();
        for &(kind, n) in kinds {
            let mut cfg = OptimConfig::adam(0.01, bits);
            cfg.kind = kind;
            opts.push(build(&cfg, n, None));
            params.push((0..n).map(|_| rng.normal() as f32).collect());
            grads.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect());
        }
        (opts, params, grads)
    }

    #[test]
    fn fused_matches_serial_stepping_bitwise() {
        // mixed workload: single-phase (adam, momentum) and multi-phase
        // (lamb) plans, sizes from sub-block to large multi-block
        let kinds = [
            (OptimKind::Adam, 3usize),
            (OptimKind::Adam, 2048),
            (OptimKind::Momentum, 5000),
            (OptimKind::Lamb, 1024),
            (OptimKind::Lamb, 20000), // many blocks, phased reductions
            (OptimKind::Adam, 2049),
        ];
        for bits in [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()] {
            let (mut o_serial, mut p_serial, g) = fleet(&kinds, bits);
            let (mut o_fused, mut p_fused, _) = fleet(&kinds, bits);
            for _ in 0..3 {
                for i in 0..o_serial.len() {
                    o_serial[i].step(&mut p_serial[i], &g[i]);
                }
                fused_update(&mut o_fused, &mut p_fused, &g);
            }
            assert_eq!(p_serial, p_fused, "params diverged ({})", bits.describe());
            for (a, b) in o_serial.iter().zip(&o_fused) {
                for ((na, sa), (nb, sb)) in a.states().iter().zip(b.states().iter()) {
                    assert_eq!(na, nb);
                    assert_eq!(sa.to_f32(), sb.to_f32(), "state {na} diverged");
                }
            }
        }
    }

    #[test]
    fn empty_fused_step_is_a_no_op() {
        let fused = FusedStep::new();
        assert_eq!(fused.n_items(), 0);
        fused.run();
    }

    #[test]
    fn streaming_matches_serial_stepping_bitwise() {
        // same mixed workload as the fused test: single-phase and
        // multi-phase plans, sub-block to many-block sizes
        let kinds = [
            (OptimKind::Adam, 3usize),
            (OptimKind::Adam, 2048),
            (OptimKind::Momentum, 5000),
            (OptimKind::Lamb, 1024),
            (OptimKind::Lamb, 20000),
            (OptimKind::Adam, 2049),
        ];
        for bits in [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()] {
            let (mut o_serial, mut p_serial, g) = fleet(&kinds, bits);
            let (mut o_stream, mut p_stream, _) = fleet(&kinds, bits);
            for _ in 0..3 {
                for i in 0..o_serial.len() {
                    o_serial[i].step(&mut p_serial[i], &g[i]);
                }
                streaming_update(&mut o_stream, &mut p_stream, &g);
            }
            assert_eq!(p_serial, p_stream, "params diverged ({})", bits.describe());
            for (a, b) in o_serial.iter().zip(&o_stream) {
                for ((na, sa), (nb, sb)) in a.states().iter().zip(b.states().iter()) {
                    assert_eq!(na, nb);
                    assert_eq!(sa.to_f32(), sb.to_f32(), "state {na} diverged");
                }
            }
        }
    }

    #[test]
    fn streaming_push_overlaps_with_caller_work() {
        // Push each tensor, then do unrelated main-thread work before the
        // next push / the final finish — the stream must tolerate arbitrary
        // delays between admissions and still match serial stepping.
        let kinds = [(OptimKind::Lamb, 6000usize), (OptimKind::Adam, 4096), (OptimKind::Adam, 7)];
        let (mut o_serial, mut p_serial, g) = fleet(&kinds, Bits::b8_dynamic());
        let (mut o_stream, mut p_stream, _) = fleet(&kinds, Bits::b8_dynamic());
        for i in 0..o_serial.len() {
            o_serial[i].step(&mut p_serial[i], &g[i]);
        }
        let mut stream = StreamingStep::new();
        let mut busy = 0u64;
        for ((opt, p), g) in o_stream.iter_mut().zip(p_stream.iter_mut()).zip(g.iter()) {
            stream.push(opt.as_mut(), p.as_mut_slice(), g.as_slice());
            // stand-in for a serial PJRT round-trip on the caller thread
            for k in 0..20_000u64 {
                busy = busy.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            stream.poll();
        }
        assert!(busy != 1, "keep the busy loop observable");
        assert_eq!(stream.n_tensors(), 3);
        stream.finish();
        assert_eq!(p_serial, p_stream);
    }

    #[test]
    fn empty_streaming_step_is_a_no_op() {
        let stream = StreamingStep::new();
        assert_eq!(stream.n_tensors(), 0);
        assert_eq!(stream.n_pending(), 0);
        stream.finish();
    }
}
