//! Adafactor (Shazeer & Stern 2018) — the paper's main memory-efficiency
//! baseline (Tables 1, 4). Per the paper's setup we use the β1 > 0 variant
//! with the *time-independent* β2 formulation (same decay as Adam), no
//! hyperparameter re-tuning.
//!
//! For 2-D tensors the second moment is factored into row/col sums:
//!   R_i ← β2 R_i + (1−β2) Σ_j (g²+ε)_ij,  C_j ← β2 C_j + (1−β2) Σ_i (g²+ε)_ij
//!   V̂_ij = R_i C_j / Σ_i R_i
//! update u = g/√V̂, RMS-clipped to d=1.0; first moment m = β1 m + (1−β1) u;
//! w −= lr · m. 1-D tensors fall back to an unfactored second moment.
//!
//! All states are 32-bit (that is Adafactor's point); with β1 > 0 the full
//! first moment dominates: ≈4 bytes/param ≈ half of 32-bit Adam — exactly
//! the "competitive but still 2× 8-bit Adam" memory row in Table 1.

use super::state::{step_blocks, BlockView, StateTensor};
use super::{OptimConfig, Optimizer};

const EPS1: f32 = 1e-30; // regularizer added to g² (paper's ε₁)
const CLIP_D: f32 = 1.0; // update RMS clip threshold

pub struct Adafactor {
    cfg: OptimConfig,
    /// First moment, full size (β1 > 0 variant).
    m: StateTensor,
    /// Factored second moment for 2-D tensors...
    row: Vec<f32>,
    col: Vec<f32>,
    /// ...or the full second moment for 1-D tensors.
    v: Vec<f32>,
    shape: Option<(usize, usize)>,
    t: u64,
}

impl Adafactor {
    pub fn new(cfg: OptimConfig, n: usize, shape: Option<(usize, usize)>) -> Adafactor {
        let factored = matches!(shape, Some((r, c)) if r > 1 && c > 1 && r * c == n);
        let shape = if factored { shape } else { None };
        let (rows, cols) = shape.unwrap_or((0, 0));
        Adafactor {
            cfg,
            m: StateTensor::new_f32(n),
            row: vec![0.0; rows],
            col: vec![0.0; cols],
            v: if factored { Vec::new() } else { vec![0.0; n] },
            shape,
            t: 0,
        }
    }

    pub fn is_factored(&self) -> bool {
        self.shape.is_some()
    }
}

impl Optimizer for Adafactor {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        self.t += 1;
        let cfg = self.cfg;
        let b2 = cfg.beta2;
        let bias_c2 = 1.0 - b2.powi(self.t as i32);
        let n = params.len();

        // Update second-moment statistics and compute v̂ lookup.
        let vhat_at: Box<dyn Fn(usize) -> f32> = if let Some((rows, cols)) = self.shape {
            for (i, r) in self.row.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for j in 0..cols {
                    let g = grads[i * cols + j];
                    s += g * g + EPS1;
                }
                *r = b2 * *r + (1.0 - b2) * s;
            }
            for (j, c) in self.col.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for i in 0..rows {
                    let g = grads[i * cols + j];
                    s += g * g + EPS1;
                }
                *c = b2 * *c + (1.0 - b2) * s;
            }
            let row_sum: f32 = self.row.iter().sum::<f32>().max(EPS1);
            let row = self.row.clone();
            let col = self.col.clone();
            Box::new(move |idx: usize| {
                let (i, j) = (idx / cols, idx % cols);
                (row[i] * col[j] / row_sum / bias_c2).max(EPS1)
            })
        } else {
            for (v, &g) in self.v.iter_mut().zip(grads) {
                *v = b2 * *v + (1.0 - b2) * (g * g + EPS1);
            }
            let v = self.v.clone();
            Box::new(move |idx: usize| (v[idx] / bias_c2).max(EPS1))
        };

        // u = g/√v̂, RMS-clipped.
        let mut u: Vec<f32> = (0..n).map(|i| grads[i] / vhat_at(i).sqrt()).collect();
        let rms = (u.iter().map(|&x| x as f64 * x as f64).sum::<f64>() / n as f64).sqrt() as f32;
        if rms > CLIP_D {
            let s = CLIP_D / rms;
            for x in u.iter_mut() {
                *x *= s;
            }
        }

        // First moment + apply: elementwise, so it runs through the shared
        // block engine (u takes the "grads" slot).
        let block = crate::quant::BLOCK.min(n.max(1));
        step_blocks(params, &u, &mut self.m, None, block, move |v: BlockView| {
            let BlockView { params, grads: u_b, s1: m, .. } = v;
            for i in 0..params.len() {
                m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * u_b[i];
                let mut step = cfg.lr * m[i];
                if cfg.weight_decay != 0.0 {
                    step += cfg.lr * cfg.weight_decay * params[i];
                }
                params[i] -= step;
            }
        });
    }

    fn state_bytes(&self) -> usize {
        self.m.bytes() + (self.row.len() + self.col.len() + self.v.len()) * 4
    }

    fn name(&self) -> String {
        "32-bit adafactor".into()
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32) -> OptimConfig {
        OptimConfig {
            kind: OptimKind::Adafactor,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            bits: Bits::B32,
        }
    }

    #[test]
    fn factored_only_for_true_2d() {
        assert!(Adafactor::new(cfg(0.01), 100, Some((10, 10))).is_factored());
        assert!(!Adafactor::new(cfg(0.01), 100, Some((1, 100))).is_factored());
        assert!(!Adafactor::new(cfg(0.01), 100, None).is_factored());
    }

    #[test]
    fn factored_memory_is_much_smaller_than_adam() {
        let n = 512 * 512;
        let af = Adafactor::new(cfg(0.01), n, Some((512, 512)));
        let adam = super::super::adam::Adam::new(
            OptimConfig::adam(0.01, Bits::B32),
            n,
        );
        // m (4n) + row+col (tiny) ≈ half of Adam's 8n.
        let ratio = adam.state_bytes() as f64 / af.state_bytes() as f64;
        assert!(ratio > 1.9 && ratio < 2.1, "ratio {ratio}");
    }

    #[test]
    fn converges_on_quadratic_2d() {
        let (rows, cols) = (32, 32);
        let n = rows * cols;
        let mut rng = Rng::new(14);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Adafactor::new(cfg(0.05), n, Some((rows, cols)));
        for _ in 0..1500 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 5e-2, "mse {mse}");
    }

    #[test]
    fn unfactored_1d_converges() {
        let n = 512;
        let mut rng = Rng::new(15);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Adafactor::new(cfg(0.05), n, None);
        for _ in 0..1500 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 5e-2, "mse {mse}");
    }

    #[test]
    fn update_rms_is_clipped() {
        // Huge gradient on fresh state: u = g/|g| has RMS 1, stays ≤ d.
        let mut opt = Adafactor::new(cfg(1.0), 16, None);
        let mut p = vec![0.0f32; 16];
        let g = vec![1e6f32; 16];
        opt.step(&mut p, &g);
        // step ≤ lr·(1-β1)·d per element after clipping
        for &v in &p {
            assert!(v.abs() <= 1.0 + 1e-5, "{v}");
        }
    }
}
