//! Adafactor (Shazeer & Stern 2018) — the paper's main memory-efficiency
//! baseline (Tables 1, 4). Per the paper's setup we use the β1 > 0 variant
//! with the *time-independent* β2 formulation (same decay as Adam), no
//! hyperparameter re-tuning.
//!
//! For 2-D tensors the second moment is factored into row/col sums:
//!   R_i ← β2 R_i + (1−β2) Σ_j (g²+ε)_ij,  C_j ← β2 C_j + (1−β2) Σ_i (g²+ε)_ij
//!   V̂_ij = R_i C_j / Σ_i R_i
//! update u = g/√V̂, RMS-clipped to d=1.0; first moment m = β1 m + (1−β1) u;
//! w −= lr · m. 1-D tensors fall back to an unfactored second moment.
//!
//! All states are 32-bit (that is Adafactor's point); with β1 > 0 the full
//! first moment dominates: ≈4 bytes/param ≈ half of 32-bit Adam — exactly
//! the "competitive but still 2× 8-bit Adam" memory row in Table 1.

use super::state::{
    block_steps, AccessSet, BlockSteps, BlockView, CombineAccess, Grid, Phase, Region, Span,
    StateTensor, StepPlan,
};
use super::{OptimConfig, Optimizer};
use crate::util::parallel::Shared;
use crate::util::reduce;

const EPS1: f32 = 1e-30; // regularizer added to g² (paper's ε₁)
const CLIP_D: f32 = 1.0; // update RMS clip threshold

pub struct Adafactor {
    cfg: OptimConfig,
    /// First moment, full size (β1 > 0 variant).
    m: StateTensor,
    /// Factored second moment for 2-D tensors...
    row: Vec<f32>,
    col: Vec<f32>,
    /// ...or the full second moment for 1-D tensors.
    v: Vec<f32>,
    /// Per-step update direction u = g/√v̂ (reused buffer, not state).
    u: Vec<f32>,
    /// Per-chunk ‖u‖² partials for the RMS clip.
    partials: Vec<f64>,
    /// Σ_i R_i (factored v̂ normalizer), written by the stats combine.
    row_sum: f32,
    /// RMS clip scale, written by the u combine, read by the apply phase.
    clip: f32,
    shape: Option<(usize, usize)>,
    t: u64,
}

impl Adafactor {
    pub fn new(cfg: OptimConfig, n: usize, shape: Option<(usize, usize)>) -> Adafactor {
        let factored = matches!(shape, Some((r, c)) if r > 1 && c > 1 && r * c == n);
        let shape = if factored { shape } else { None };
        let (rows, cols) = shape.unwrap_or((0, 0));
        Adafactor {
            cfg,
            m: StateTensor::new_f32(n),
            row: vec![0.0; rows],
            col: vec![0.0; cols],
            v: if factored { Vec::new() } else { vec![0.0; n] },
            u: vec![0.0; n],
            partials: vec![0.0; reduce::n_chunks(n)],
            row_sum: 0.0,
            clip: 1.0,
            shape,
            t: 0,
        }
    }

    pub fn is_factored(&self) -> bool {
        self.shape.is_some()
    }
}

impl Optimizer for Adafactor {
    /// Factored tensors: three phases — (A) row/col statistics, each slot
    /// written by exactly one item, with the Σ R_i fold as combine; (B)
    /// u = g/√v̂ plus per-chunk ‖u‖² partials, with the RMS-clip fold as
    /// combine; (C) block-local first-moment update + apply. 1-D tensors
    /// skip phase A (v is elementwise) and run two phases.
    fn plan<'a>(&'a mut self, params: &'a mut [f32], grads: &'a [f32]) -> StepPlan<'a> {
        self.t += 1;
        let cfg = self.cfg;
        let b2 = cfg.beta2;
        let bias_c2 = 1.0 - b2.powi(self.t as i32);
        let n = params.len();
        assert_eq!(self.u.len(), n);
        let nc = reduce::n_chunks(n);
        self.partials.resize(nc, 0.0);
        // SAFETY (all `Shared` uses below): within each phase items write
        // disjoint slots (row/col chunks, u chunks, partial slots, param
        // blocks); combines run alone between phase barriers; reads of a
        // phase's output happen only after its barrier. `plan`'s `&'a mut
        // self` borrow keeps every target alive for the plan's lifetime.
        let partials = Shared::new(&mut self.partials);
        let row_sum = Shared::new(std::slice::from_mut(&mut self.row_sum));
        let clip = Shared::new(std::slice::from_mut(&mut self.clip));
        let u_sh = Shared::new(&mut self.u);

        let mut plan = StepPlan::new();
        let chunk = Span::Blocked { base: 0, block: reduce::CHUNK, n };

        // RMS-clip combine, shared by both layouts (captures are Copy, so
        // the closure is too; only the taken branch consumes one).
        let u_combine = move || {
            let p = unsafe { partials.range(0, nc) };
            let rms = (reduce::fold(p) / n as f64).sqrt() as f32;
            unsafe { clip.write(0, if rms > CLIP_D { CLIP_D / rms } else { 1.0 }) };
        };

        if let Some((rows, cols)) = self.shape {
            let row_sh = Shared::new(&mut self.row);
            let col_sh = Shared::new(&mut self.col);
            // ---- phase A: factored statistics, tiled into single-writer
            // row/col items (see `state::Grid`).
            let grid = Grid::new(rows, cols);
            let stats_items = BlockSteps::from_fn(grid.n_items(), move |it| {
                if let Some((r0, r1)) = grid.row_range(it) {
                    let r = unsafe { row_sh.range_mut(r0, r1) };
                    for (i, slot) in (r0..r1).zip(r.iter_mut()) {
                        let mut s = 0.0f32;
                        for &g in &grads[i * cols..(i + 1) * cols] {
                            s += g * g + EPS1;
                        }
                        *slot = b2 * *slot + (1.0 - b2) * s;
                    }
                } else {
                    let (c0, c1) = grid.col_range(it);
                    let c = unsafe { col_sh.range_mut(c0, c1) };
                    for (j, slot) in (c0..c1).zip(c.iter_mut()) {
                        let mut s = 0.0f32;
                        for i in 0..rows {
                            let g = grads[i * cols + j];
                            s += g * g + EPS1;
                        }
                        *slot = b2 * *slot + (1.0 - b2) * s;
                    }
                }
            });
            // Combine: Σ R_i in fixed row order (the v̂ normalizer).
            let stats_combine = move || {
                let r = unsafe { row_sh.range(0, rows) };
                unsafe { row_sum.write(0, r.iter().sum::<f32>().max(EPS1)) };
            };
            plan.push(
                Phase::with_combine(stats_items, stats_combine).with_access(
                    AccessSet::new()
                        .read(Region::Grads, Span::All { lo: 0, hi: n })
                        .rmw(Region::Slot("af.row"), Span::GridRows { grid, stride: 1, base: 0 })
                        .rmw(Region::Slot("af.col"), Span::GridCols { grid, stride: 1, base: 0 })
                        .preset(Region::Slot("af.row"))
                        .preset(Region::Slot("af.col"))
                        .combine(
                            CombineAccess::deterministic()
                                .read(Region::Slot("af.row"), Span::All { lo: 0, hi: rows })
                                .write(Region::Slot("af.row_sum"), Span::All { lo: 0, hi: 1 }),
                        ),
                ),
            );

            // ---- phase B: u = g/√v̂ + per-chunk RMS partials (reads the
            // phase-A statistics after the barrier).
            let u_items = BlockSteps::from_fn(nc, move |c| {
                let (lo, hi) = reduce::chunk_bounds(n, c);
                let u = unsafe { u_sh.range_mut(lo, hi) };
                let row = unsafe { row_sh.range(0, rows) };
                let col = unsafe { col_sh.range(0, cols) };
                let rs = unsafe { row_sum.read(0) };
                for (idx, slot) in (lo..hi).zip(u.iter_mut()) {
                    let (i, j) = (idx / cols, idx % cols);
                    let vhat = (row[i] * col[j] / rs / bias_c2).max(EPS1);
                    *slot = grads[idx] / vhat.sqrt();
                }
                unsafe { partials.write(c, reduce::sum_sq(u)) };
            });
            plan.push(
                Phase::with_combine(u_items, u_combine).with_access(
                    AccessSet::new()
                        .read(Region::Grads, chunk)
                        .read(Region::Slot("af.row"), Span::All { lo: 0, hi: rows })
                        .read(Region::Slot("af.col"), Span::All { lo: 0, hi: cols })
                        .read(Region::Slot("af.row_sum"), Span::All { lo: 0, hi: 1 })
                        .write(Region::Slot("af.u"), chunk)
                        .write(
                            Region::Slot("af.partials"),
                            Span::Blocked { base: 0, block: 1, n: nc },
                        )
                        .combine(
                            CombineAccess::deterministic()
                                .read(Region::Slot("af.partials"), Span::All { lo: 0, hi: nc })
                                .write(Region::Slot("af.clip"), Span::All { lo: 0, hi: 1 }),
                        ),
                ),
            );
        } else {
            // ---- 1-D: v is elementwise, so the stats update fuses into
            // the u phase (two phases total).
            let v_sh = Shared::new(&mut self.v);
            let u_items = BlockSteps::from_fn(nc, move |c| {
                let (lo, hi) = reduce::chunk_bounds(n, c);
                let u = unsafe { u_sh.range_mut(lo, hi) };
                let v = unsafe { v_sh.range_mut(lo, hi) };
                for k in 0..u.len() {
                    let g = grads[lo + k];
                    v[k] = b2 * v[k] + (1.0 - b2) * (g * g + EPS1);
                    let vhat = (v[k] / bias_c2).max(EPS1);
                    u[k] = g / vhat.sqrt();
                }
                unsafe { partials.write(c, reduce::sum_sq(u)) };
            });
            plan.push(
                Phase::with_combine(u_items, u_combine).with_access(
                    AccessSet::new()
                        .read(Region::Grads, chunk)
                        .rmw(Region::Slot("af.v"), chunk)
                        .preset(Region::Slot("af.v"))
                        .write(Region::Slot("af.u"), chunk)
                        .write(
                            Region::Slot("af.partials"),
                            Span::Blocked { base: 0, block: 1, n: nc },
                        )
                        .combine(
                            CombineAccess::deterministic()
                                .read(Region::Slot("af.partials"), Span::All { lo: 0, hi: nc })
                                .write(Region::Slot("af.clip"), Span::All { lo: 0, hi: 1 }),
                        ),
                ),
            );
        }

        // ---- final phase: first moment + apply (block engine, u in the
        // "grads" slot) ---------------------------------------------------
        let block = crate::quant::BLOCK.min(n.max(1));
        let u_ro: &'a [f32] = unsafe { u_sh.range(0, n) };
        let apply = block_steps(params, u_ro, &mut self.m, None, block, move |v: BlockView| {
            let BlockView { params, grads: u_b, s1: m, .. } = v;
            let s = unsafe { clip.read(0) };
            for i in 0..params.len() {
                m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * (s * u_b[i]);
                let mut step = cfg.lr * m[i];
                if cfg.weight_decay != 0.0 {
                    step += cfg.lr * cfg.weight_decay * params[i];
                }
                params[i] -= step;
            }
        });
        plan.push(Phase::new(apply).map_access(|a| {
            a.relabel(Region::Grads, Region::Slot("af.u"))
                .read(Region::Slot("af.clip"), Span::All { lo: 0, hi: 1 })
        }));
        plan
    }

    fn state_bytes(&self) -> usize {
        // Deliberately excludes the persistent `u`/`partials` scratch:
        // Table 1 accounts optimizer *state*, and the module-header claim
        // ("≈ half of 32-bit Adam") plus the memory test pin that
        // semantics. (LAMB opts the other way for its scratch; both
        // choices are documented at their definition.)
        self.m.bytes() + (self.row.len() + self.col.len() + self.v.len()) * 4
    }

    fn name(&self) -> String {
        "32-bit adafactor".into()
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn states(&self) -> Vec<(&'static str, &StateTensor)> {
        vec![("m", &self.m)]
    }

    fn states_mut(&mut self) -> Vec<(&'static str, &mut StateTensor)> {
        vec![("m", &mut self.m)]
    }

    fn set_t(&mut self, t: u64) {
        self.t = t;
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn lr(&self) -> f32 {
        self.cfg.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::{Bits, OptimKind};
    use crate::util::rng::Rng;

    fn cfg(lr: f32) -> OptimConfig {
        let mut cfg = OptimConfig::adam(lr, Bits::B32);
        cfg.kind = OptimKind::Adafactor;
        cfg.beta2 = 0.999;
        cfg.eps = 1e-8;
        cfg
    }

    #[test]
    fn factored_only_for_true_2d() {
        assert!(Adafactor::new(cfg(0.01), 100, Some((10, 10))).is_factored());
        assert!(!Adafactor::new(cfg(0.01), 100, Some((1, 100))).is_factored());
        assert!(!Adafactor::new(cfg(0.01), 100, None).is_factored());
    }

    #[test]
    fn factored_memory_is_much_smaller_than_adam() {
        let n = 512 * 512;
        let af = Adafactor::new(cfg(0.01), n, Some((512, 512)));
        let adam = super::super::adam::Adam::new(
            OptimConfig::adam(0.01, Bits::B32),
            n,
        );
        // m (4n) + row+col (tiny) ≈ half of Adam's 8n.
        let ratio = adam.state_bytes() as f64 / af.state_bytes() as f64;
        assert!(ratio > 1.9 && ratio < 2.1, "ratio {ratio}");
    }

    #[test]
    fn converges_on_quadratic_2d() {
        let (rows, cols) = (32, 32);
        let n = rows * cols;
        let mut rng = Rng::new(14);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Adafactor::new(cfg(0.05), n, Some((rows, cols)));
        for _ in 0..1500 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 5e-2, "mse {mse}");
    }

    #[test]
    fn unfactored_1d_converges() {
        let n = 512;
        let mut rng = Rng::new(15);
        let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut opt = Adafactor::new(cfg(0.05), n, None);
        for _ in 0..1500 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        assert!(mse < 5e-2, "mse {mse}");
    }

    #[test]
    fn update_rms_is_clipped() {
        // Huge gradient on fresh state: u = g/|g| has RMS 1, stays ≤ d.
        let mut opt = Adafactor::new(cfg(1.0), 16, None);
        let mut p = vec![0.0f32; 16];
        let g = vec![1e6f32; 16];
        opt.step(&mut p, &g);
        // step ≤ lr·(1-β1)·d per element after clipping
        for &v in &p {
            assert!(v.abs() <= 1.0 + 1e-5, "{v}");
        }
    }
}
