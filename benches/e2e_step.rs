//! End-to-end training-step bench over the AOT artifacts: fwd/bwd through
//! PJRT + optimizer update (native vs HLO engine, 8-bit vs 32-bit) — the
//! whole-stack complement to `optimizer_speed` (Table 1's "Time" column at
//! this testbed's scale).
//!
//! Run: `cargo bench --bench e2e_step [-- --model small_stable]`

use std::time::Duration;

use bitopt8::config::{parse_optim, Engine, RunConfig, Schedule};
use bitopt8::coordinator::Trainer;
use bitopt8::runtime::Runtime;
use bitopt8::util::args::Args;
use bitopt8::util::bench::bench;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let model = args.get_or("model", "small_stable").to_string();
    let budget = Duration::from_millis(args.get_u64("budget-ms", 8000));
    let rt = Runtime::new(args.get_or("artifacts", "artifacts")).expect("runtime");

    println!("e2e_step: model {model}");
    println!("{:<30} {:>14} {:>16}", "config", "ms/step", "opt state MB");
    for (label, bits, engine) in [
        ("adam32 native", 32usize, Engine::Native),
        ("adam8 native", 8, Engine::Native),
        ("adam8 hlo (Pallas kernels)", 8, Engine::Hlo),
    ] {
        let mut cfg = RunConfig::default();
        cfg.model = model.clone();
        cfg.steps = 10_000; // not used; we drive steps manually
        cfg.seed = 5;
        cfg.optim = parse_optim("adam", bits, "dynamic", true).unwrap();
        cfg.optim.lr = 3e-4;
        cfg.engine = engine;
        cfg.schedule = Schedule::Constant;
        let mut tr = Trainer::new(&rt, cfg).expect("trainer");
        let state_mb = tr.state_bytes() as f64 / 1e6;
        let r = bench(label, budget, 200, || {
            tr.train_step().expect("step");
        });
        println!("{label:<30} {:>14.1} {:>16.2}", r.median_ns / 1e6, state_mb);
    }
}
