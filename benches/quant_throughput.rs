//! Block-wise quantizer throughput (§2.1's efficiency claim): block-wise
//! vs tensor-wide normalization, quantize and dequantize, single vs multi
//! core — plus the packed fast paths (`quantize_block_codes` /
//! `dequantize_block_codes`) at both code widths, lane-chunked vs
//! forced-scalar. The paper's argument: per-block normalization removes
//! cross-core synchronization, so block-wise should scale ~linearly with
//! cores while tensor-wide pays a global reduction; the lane columns show
//! what the fixed-width SIMD chunking buys on top.
//!
//! Run: `cargo bench --bench quant_throughput`

use std::sync::Arc;
use std::time::Duration;

use bitopt8::quant::{dynamic_tree, BlockQuantizer, CodeWidth, BLOCK};
use bitopt8::util::args::Args;
use bitopt8::util::bench::{bench, black_box};
use bitopt8::util::lanes;
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

fn gbps(n: usize, median_ns: f64) -> f64 {
    (n as f64 * 4.0) / median_ns
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n = args.get_usize("n", 16 << 20);
    let budget = Duration::from_millis(args.get_u64("budget-ms", 1500));
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();
    let cb8 = Arc::new(dynamic_tree::dynamic_signed());
    let cb4 = Arc::new(dynamic_tree::dynamic_signed4());

    println!("quant_throughput: n = {n} ({} MB)", n * 4 >> 20);
    println!("{:<34} {:>14} {:>12}", "config", "GB/s (f32 in)", "ns/elem");

    // §2.1 scaling: blockwise vs tensor-wide normalization (packed U8).
    for (label, block, threads) in [
        ("blockwise B=2048, 1 core", BLOCK, Some(1)),
        ("blockwise B=2048, all cores", BLOCK, None),
        ("tensor-wide, 1 core", usize::MAX, Some(1)),
        ("tensor-wide, all cores", usize::MAX, None),
    ] {
        let bq = BlockQuantizer::new(cb8.clone(), block);
        let mut q = bq.quantize(&x);
        let run = || {
            bench(label, budget, 100, || {
                bq.quantize_into(black_box(&x), &mut q);
            })
        };
        let r = match threads {
            Some(t) => parallel::with_threads(t, run),
            None => run(),
        };
        println!("{label:<34} {:>14.2} {:>12.2}", gbps(n, r.median_ns), r.median_ns / n as f64);
    }

    // Packed fast paths at both code widths, lane-chunked vs forced-scalar
    // (single core so the comparison isolates the kernels, not the pool).
    println!();
    println!(
        "{:<26} {:>12} {:>12} {:>9}",
        "packed path (1 core)", "lane GB/s", "scalar GB/s", "speedup"
    );
    for (name, cb, width) in [
        ("U8", cb8.clone(), CodeWidth::U8),
        ("U4", cb4.clone(), CodeWidth::U4),
    ] {
        let bq = BlockQuantizer::with_width(cb, BLOCK, width);
        let mut q = bq.quantize(&x);
        let mut out = vec![0.0f32; n];
        parallel::with_threads(1, || {
            let quant_lane = bench("quantize lane", budget, 100, || {
                bq.quantize_into(black_box(&x), &mut q);
            });
            let quant_scalar = lanes::with_forced_scalar(|| {
                bench("quantize scalar", budget, 100, || {
                    bq.quantize_into(black_box(&x), &mut q);
                })
            });
            let label = format!("quantize_block_codes {name}");
            println!(
                "{label:<26} {:>12.2} {:>12.2} {:>8.2}x",
                gbps(n, quant_lane.median_ns),
                gbps(n, quant_scalar.median_ns),
                quant_scalar.median_ns / quant_lane.median_ns
            );
            let deq_lane = bench("dequantize lane", budget, 100, || {
                bq.dequantize_into(black_box(&q), &mut out);
            });
            let deq_scalar = lanes::with_forced_scalar(|| {
                bench("dequantize scalar", budget, 100, || {
                    bq.dequantize_into(black_box(&q), &mut out);
                })
            });
            let label = format!("dequantize_block_codes {name}");
            println!(
                "{label:<26} {:>12.2} {:>12.2} {:>8.2}x",
                gbps(n, deq_lane.median_ns),
                gbps(n, deq_scalar.median_ns),
                deq_scalar.median_ns / deq_lane.median_ns
            );
        });
    }

    // dequantize at full parallelism (the trainer's hot read path)
    let bq = BlockQuantizer::new(cb8, BLOCK);
    let q = bq.quantize(&x);
    let mut out = vec![0.0f32; n];
    let r = bench("dequantize blockwise, all cores", budget, 100, || {
        bq.dequantize_into(black_box(&q), &mut out);
    });
    println!();
    println!(
        "{:<34} {:>14.2} {:>12.2}",
        "dequantize blockwise, all cores",
        gbps(n, r.median_ns),
        r.median_ns / n as f64
    );
}
