//! Block-wise quantizer throughput (§2.1's efficiency claim): block-wise
//! vs tensor-wide normalization, quantize and dequantize, single vs multi
//! core. The paper's argument: per-block normalization removes cross-core
//! synchronization, so block-wise should scale ~linearly with cores while
//! tensor-wide pays a global reduction.
//!
//! Run: `cargo bench --bench quant_throughput`

use std::sync::Arc;
use std::time::Duration;

use bitopt8::quant::{dynamic_tree, BlockQuantizer, BLOCK};
use bitopt8::util::args::Args;
use bitopt8::util::bench::{bench, black_box};
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n = args.get_usize("n", 16 << 20);
    let budget = Duration::from_millis(args.get_u64("budget-ms", 1500));
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();
    let cb = Arc::new(dynamic_tree::dynamic_signed());

    println!("quant_throughput: n = {n} ({} MB)", n * 4 >> 20);
    println!("{:<34} {:>14} {:>12}", "config", "GB/s (f32 in)", "ns/elem");
    for (label, block, threads) in [
        ("blockwise B=2048, 1 core", BLOCK, Some(1)),
        ("blockwise B=2048, all cores", BLOCK, None),
        ("tensor-wide, 1 core", usize::MAX, Some(1)),
        ("tensor-wide, all cores", usize::MAX, None),
    ] {
        let bq = BlockQuantizer { codebook: cb.clone(), block };
        let mut q = bq.quantize(&x);
        let run = || {
            bench(label, budget, 100, || {
                bq.quantize_into(black_box(&x), &mut q);
            })
        };
        let r = match threads {
            Some(t) => parallel::with_threads(t, run),
            None => run(),
        };
        println!(
            "{label:<34} {:>14.2} {:>12.2}",
            (n as f64 * 4.0) / r.median_ns,
            r.median_ns / n as f64
        );
    }

    // dequantize
    let bq = BlockQuantizer::new(cb, BLOCK);
    let q = bq.quantize(&x);
    let mut out = vec![0.0f32; n];
    let r = bench("dequantize blockwise, all cores", budget, 100, || {
        bq.dequantize_into(black_box(&q), &mut out);
    });
    println!(
        "{:<34} {:>14.2} {:>12.2}",
        "dequantize blockwise, all cores",
        (n as f64 * 4.0) / r.median_ns,
        r.median_ns / n as f64
    );
}
