//! Table 5 bench: isolated optimizer update speed, 32-bit vs 8-bit, for
//! Adam / Momentum / LAMB / LARS (+ AdamW, AdaGrad as extras), reported as
//! ms per update per 1B params (the paper's unit; we measure a smaller
//! tensor and scale — the update is streaming/elementwise).
//!
//! Run: `cargo bench --bench optimizer_speed [-- --n 8388608]`

use std::time::Duration;

use bitopt8::optim::{build, Bits, OptimConfig, OptimKind};
use bitopt8::util::args::Args;
use bitopt8::util::bench::{bench, black_box};
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n = args.get_usize("n", 4 << 20);
    let budget = Duration::from_millis(args.get_u64("budget-ms", 1200));
    let mut rng = Rng::new(7);
    let grads: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.01) as f32).collect();

    println!("optimizer_speed: n = {n} ({} MB grads), scaling to ms/update/1B params", n * 4 >> 20);
    println!(
        "{:<12} {:>16} {:>16} {:>14} {:>10}",
        "optimizer", "32-bit 1-core", "32-bit n-core", "8-bit n-core", "8b vs 32b"
    );
    for kind in [
        OptimKind::Adam,
        OptimKind::AdamW,
        OptimKind::Momentum,
        OptimKind::Lamb,
        OptimKind::Lars,
        OptimKind::Adagrad,
    ] {
        let mut cols = Vec::new();
        let variants = [(Bits::B32, Some(1)), (Bits::B32, None), (Bits::b8_dynamic(), None)];
        for (bits, threads) in variants {
            let mut cfg = OptimConfig::adam(1e-3, bits);
            cfg.kind = kind;
            let mut opt = build(&cfg, n, None);
            let mut params = vec![0.0f32; n];
            let label = format!("{}-{}", kind.name(), bits.describe());
            let run = || {
                bench(&label, budget, 500, || {
                    opt.step(black_box(&mut params), black_box(&grads));
                })
            };
            let r = match threads {
                Some(t) => parallel::with_threads(t, run),
                None => run(),
            };
            cols.push(r.median_ns * 1e-6 * (1e9 / n as f64));
        }
        println!(
            "{:<12} {:>13.0} ms {:>13.0} ms {:>11.0} ms {:>9.2}x",
            kind.name(),
            cols[0],
            cols[1],
            cols[2],
            cols[1] / cols[2]
        );
    }
    println!(
        "\npaper (V100, Table 5): Adam 63->47ms, Momentum 46->34ms — 8-bit beats fused 32-bit"
    );
}
