//! Appendix G bench: SRAM-Quantiles vs exact (full-sort) quantile
//! estimation — the paper reports 0.064 ns/element vs 5–300 ns/element for
//! general-purpose estimators; the *shape* to reproduce is a large
//! constant-factor win that grows with input size.
//!
//! Run: `cargo bench --bench quantiles`

use std::time::Duration;

use bitopt8::quant::sram_quantiles::{estimate_quantiles, exact_quantiles};
use bitopt8::util::args::Args;
use bitopt8::util::bench::{bench, black_box};
use bitopt8::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let budget = Duration::from_millis(args.get_u64("budget-ms", 1200));
    println!(
        "{:>12} {:>16} {:>16} {:>9} {:>14}",
        "n", "SRAM ns/elem", "full-sort ns/elem", "speedup", "max q err"
    );
    for pow in [16usize, 20, 23] {
        let n = 1usize << pow;
        let mut rng = Rng::new(11);
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

        let fast = bench("sram", budget, 200, || {
            black_box(estimate_quantiles(black_box(&data), 257));
        });
        let slow = bench("sort", budget, 50, || {
            black_box(exact_quantiles(black_box(&data), 257));
        });
        // quality check: interior quantile error
        let est = estimate_quantiles(&data, 257);
        let exact = exact_quantiles(&data, 257);
        let max_err = est[8..249]
            .iter()
            .zip(&exact[8..249])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "{:>12} {:>16.3} {:>17.3} {:>8.1}x {:>14.4}",
            n,
            fast.median_ns / n as f64,
            slow.median_ns / n as f64,
            slow.median_ns / fast.median_ns,
            max_err
        );
    }
}
