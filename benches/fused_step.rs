//! Fused multi-tensor stepping vs per-tensor stepping on a many-small-
//! tensors workload — the regime real models live in (dozens of LayerNorm /
//! bias / projection tensors per block) and the one the persistent pool +
//! fused engine target: per-tensor dispatch amortizes to one pool batch
//! per training step, and inter-tensor parallelism covers tensors smaller
//! than one quantization block.
//!
//! Run: `cargo bench --bench fused_step [-- --tensors 48 --n 4096]`

use std::time::Duration;

use bitopt8::optim::{build, engine::fused_update, Bits, OptimConfig, Optimizer};
use bitopt8::util::args::Args;
use bitopt8::util::bench::bench;
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

type Fleet = (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

fn fleet(n_tensors: usize, n: usize, bits: Bits) -> Fleet {
    let mut rng = Rng::new(42);
    let mut opts = Vec::new();
    let mut params: Vec<Vec<f32>> = Vec::new();
    let mut grads: Vec<Vec<f32>> = Vec::new();
    for _ in 0..n_tensors {
        opts.push(build(&OptimConfig::adam(1e-3, bits), n, None));
        params.push((0..n).map(|_| rng.normal() as f32).collect());
        grads.push((0..n).map(|_| rng.normal() as f32 * 0.01).collect());
    }
    (opts, params, grads)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n_tensors = args.get_usize("tensors", 48);
    let n = args.get_usize("n", 4096);
    let budget = Duration::from_millis(args.get_u64("budget-ms", 1200));

    println!(
        "fused_step: {n_tensors} tensors x {n} params, {} threads",
        parallel::num_threads()
    );
    println!("{:<28} {:>14} {:>16}", "config", "µs/step", "vs per-tensor");
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        let mut base_us = 0.0f64;
        for (label, fused) in [("per-tensor step()", false), ("fused multi-tensor", true)] {
            let (mut opts, mut params, grads) = fleet(n_tensors, n, bits);
            let r = bench(label, budget, 2000, || {
                if fused {
                    fused_update(&mut opts, &mut params, &grads);
                } else {
                    for i in 0..opts.len() {
                        opts[i].step(&mut params[i], &grads[i]);
                    }
                }
            });
            let us = r.median_ns / 1e3;
            if !fused {
                base_us = us;
            }
            println!(
                "{:<28} {:>14.1} {:>15.2}x",
                format!("{} {label}", bits.describe()),
                us,
                base_us / us
            );
        }
    }
    println!("\n(speedup from one pool batch per step instead of one dispatch per tensor;");
    println!(" grows with tensor count and core count — small tensors alone cannot fill cores)");
}
