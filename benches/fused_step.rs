//! Fused multi-tensor stepping vs per-tensor stepping — the regime real
//! models live in (dozens of LayerNorm / bias / projection tensors per
//! block) and the one the phased fused engine targets: per-tensor dispatch
//! amortizes to one pool batch per phase per training step, and
//! inter-tensor parallelism covers tensors smaller than one quantization
//! block.
//!
//! Two workloads:
//! * `adam_many_small` — many equal small Adam tensors (block-local,
//!   single-phase plans);
//! * `reduction_mix` — a realistic embedding/projection/bias tensor-count
//!   mix stepped by the reduction-bearing optimizers (LAMB, Adafactor,
//!   factored SM3), whose two-/three-phase plans used to fall back to
//!   caller-side whole-tensor execution.
//!
//! Emits machine-readable results to `BENCH_fused_step.json` (repo root)
//! so the perf trajectory is tracked across PRs.
//!
//! Run: `cargo bench --bench fused_step [-- --tensors 48 --n 4096
//!       --budget-ms 1200 --out BENCH_fused_step.json]`

use std::time::Duration;

use bitopt8::optim::{build, engine::fused_update, Bits, OptimConfig, OptimKind, Optimizer};
use bitopt8::util::args::Args;
use bitopt8::util::bench::bench;
use bitopt8::util::json::{num, obj, s, Json};
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

type Fleet = (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

/// `(kind, elements, 2-D shape)` per tensor.
type Spec = (OptimKind, usize, Option<(usize, usize)>);

fn fleet(spec: &[Spec], bits: Bits) -> Fleet {
    let mut rng = Rng::new(42);
    let mut opts = Vec::new();
    let mut params: Vec<Vec<f32>> = Vec::new();
    let mut grads: Vec<Vec<f32>> = Vec::new();
    for &(kind, n, shape) in spec {
        let mut cfg = OptimConfig::adam(1e-3, bits);
        cfg.kind = kind;
        opts.push(build(&cfg, n, shape));
        params.push((0..n).map(|_| rng.normal() as f32).collect());
        grads.push((0..n).map(|_| rng.normal() as f32 * 0.01).collect());
    }
    (opts, params, grads)
}

/// Many equal small tensors (the PR-1 workload).
fn adam_many_small(n_tensors: usize, n: usize) -> Vec<Spec> {
    (0..n_tensors).map(|_| (OptimKind::Adam, n, None)).collect()
}

/// Realistic per-layer mix for one reduction-bearing optimizer: a couple
/// of large projections, several medium matrices, many bias/norm vectors.
fn reduction_mix(kind: OptimKind, layers: usize) -> Vec<Spec> {
    let mut spec: Vec<Spec> = Vec::new();
    for _ in 0..layers {
        spec.push((kind, 256 * 1024, Some((256, 1024)))); // attention proj
        spec.push((kind, 128 * 512, Some((128, 512)))); // mlp in
        spec.push((kind, 512 * 128, Some((512, 128)))); // mlp out
        for _ in 0..6 {
            spec.push((kind, 1024, None)); // biases / norms
        }
    }
    spec
}

struct Entry {
    workload: &'static str,
    optimizer: &'static str,
    bits: String,
    variant: &'static str,
    us_per_step: f64,
    iters: usize,
    speedup_vs_per_tensor: f64,
}

fn run_workload(
    workload: &'static str,
    optimizer: &'static str,
    spec: &[Spec],
    bits: Bits,
    budget: Duration,
    out: &mut Vec<Entry>,
) {
    let mut base_us = 0.0f64;
    for (variant, fused) in [("per-tensor", false), ("fused", true)] {
        let (mut opts, mut params, grads) = fleet(spec, bits);
        let r = bench(variant, budget, 2000, || {
            if fused {
                fused_update(&mut opts, &mut params, &grads);
            } else {
                for i in 0..opts.len() {
                    opts[i].step(&mut params[i], &grads[i]);
                }
            }
        });
        let us = r.median_ns / 1e3;
        if !fused {
            base_us = us;
        }
        println!(
            "{:<16} {:<10} {:<22} {:<12} {:>12.1} µs/step {:>8.2}x",
            workload,
            optimizer,
            bits.describe(),
            variant,
            us,
            base_us / us
        );
        out.push(Entry {
            workload,
            optimizer,
            bits: bits.describe(),
            variant,
            us_per_step: us,
            iters: r.iters,
            speedup_vs_per_tensor: base_us / us,
        });
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n_tensors = args.get_usize("tensors", 48);
    let n = args.get_usize("n", 4096);
    let layers = args.get_usize("layers", 2);
    let budget = Duration::from_millis(args.get_u64("budget-ms", 1200));
    let out_path = args.get_or("out", "BENCH_fused_step.json").to_string();

    println!(
        "fused_step: adam {n_tensors}x{n}, reduction mix {layers} layers, {} threads",
        parallel::num_threads()
    );
    let mut entries: Vec<Entry> = Vec::new();
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        run_workload(
            "adam_many_small",
            "adam",
            &adam_many_small(n_tensors, n),
            bits,
            budget,
            &mut entries,
        );
    }
    // LAMB exercises the quantized two-phase plan; Adafactor and SM3 are
    // 32-bit by construction, so bench them once.
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        let spec = reduction_mix(OptimKind::Lamb, layers);
        run_workload("reduction_mix", "lamb", &spec, bits, budget, &mut entries);
    }
    run_workload(
        "reduction_mix",
        "adafactor",
        &reduction_mix(OptimKind::Adafactor, layers),
        Bits::B32,
        budget,
        &mut entries,
    );
    run_workload(
        "reduction_mix",
        "sm3",
        &reduction_mix(OptimKind::Sm3, layers),
        Bits::B32,
        budget,
        &mut entries,
    );

    let results: Vec<Json> = entries
        .iter()
        .map(|e| {
            obj(vec![
                ("workload", s(e.workload)),
                ("optimizer", s(e.optimizer)),
                ("bits", s(&e.bits)),
                ("variant", s(e.variant)),
                ("us_per_step", num(e.us_per_step)),
                ("iters", num(e.iters as f64)),
                ("speedup_vs_per_tensor", num(e.speedup_vs_per_tensor)),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("bench", s("fused_step")),
        ("threads", num(parallel::num_threads() as f64)),
        ("tensors", num(n_tensors as f64)),
        ("n", num(n as f64)),
        ("layers", num(layers as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(&out_path, doc.to_string() + "\n").expect("write bench json");
    println!("\nwrote {out_path} ({} results)", entries.len());
    println!("(speedup from one pool batch per phase per step instead of one dispatch per");
    println!(" tensor; grows with tensor count and core count)");
}
